"""Sparsity subsystem: block-sparse format round-trips, zero-skipping kernel
exactness vs the dense path, density-driven dispatch, and profiling stats."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import bitlinear, dataflow, ternary
from repro.kernels import ops, ref
from repro.sparse import format as sparse_format
from repro.sparse import stats as sparse_stats

P_ZERO_SWEEP = (0.1, 1.0 / 3.0, 0.6, 0.9)


def _rand(seed, k, m, p_zero=1.0 / 3.0):
    return ternary.random_ternary(jax.random.PRNGKey(seed), (k, m), p_zero)


class TestBlockSparseFormat:
    @pytest.mark.parametrize("k,m,bk,bm", [
        (256, 256, 128, 128), (512, 384, 256, 128),
        (300, 200, 128, 128),            # ragged K and M
        (128, 128, 128, 128),            # single block
    ])
    def test_roundtrip_to_ternary(self, k, m, bk, bm):
        t = _rand(k + m, k, m)
        bst = sparse_format.from_ternary(t, bk=bk, bm=bm)
        np.testing.assert_array_equal(np.asarray(sparse_format.to_ternary(bst)),
                                      np.asarray(t))

    @pytest.mark.parametrize("p_zero", [0.0, 1.0])
    def test_roundtrip_extreme_densities(self, p_zero):
        """Density 1.0 (no zeros: every block live) and 0.0 (all zeros:
        empty pool) both round-trip exactly."""
        t = _rand(7, 384, 256, p_zero=p_zero)
        bst = sparse_format.from_ternary(t, bk=128, bm=128)
        kb, mb = bst.grid
        if p_zero == 1.0:
            assert bst.n_live == 0 and bst.block_density == 0.0
        else:
            assert bst.n_live == kb * mb and bst.block_density == 1.0
        np.testing.assert_array_equal(np.asarray(sparse_format.to_ternary(bst)),
                                      np.asarray(t))

    def test_roundtrip_to_packed(self):
        t = _rand(11, 512, 256)
        scale = jax.random.uniform(jax.random.PRNGKey(1), (256,), minval=0.5, maxval=2.0)
        tw = ternary.pack(t.astype(jnp.float32), scale)
        bst = sparse_format.from_packed(tw, bk=128, bm=128)
        tw2 = sparse_format.to_packed(bst)
        np.testing.assert_array_equal(np.asarray(tw2.sign_plane), np.asarray(tw.sign_plane))
        np.testing.assert_array_equal(np.asarray(tw2.zero_plane), np.asarray(tw.zero_plane))
        np.testing.assert_allclose(np.asarray(tw2.scale), np.asarray(tw.scale))

    def test_dead_blocks_cost_no_pool_bytes(self):
        key = jax.random.PRNGKey(3)
        t_dense = sparse_format.random_block_sparse_ternary(
            key, (512, 512), bk=128, bm=128, p_zero_block=0.0)
        t_half = t_dense * sparse_format.random_block_sparse_ternary(
            jax.random.PRNGKey(4), (512, 512), bk=128, bm=128,
            p_zero_block=0.75, p_zero=0.0)
        b_dense = sparse_format.from_ternary(t_dense, bk=128, bm=128)
        b_half = sparse_format.from_ternary(t_half, bk=128, bm=128)
        assert b_half.n_live < b_dense.n_live
        assert b_half.nbytes() < b_dense.nbytes()

    def test_occupancy_matches_blocks(self):
        t = sparse_format.random_block_sparse_ternary(
            jax.random.PRNGKey(5), (384, 256), bk=128, bm=128, p_zero_block=0.5)
        bst = sparse_format.from_ternary(t, bk=128, bm=128)
        occ = sparse_stats.block_occupancy(t, 128, 128)
        np.testing.assert_allclose(np.asarray(bst.occupancy), occ, rtol=1e-6)
        assert ((occ > 0) == (np.asarray(bst.block_map) >= 0)).all()

    def test_strip_schedule_covers_live_blocks(self):
        t = sparse_format.random_block_sparse_ternary(
            jax.random.PRNGKey(6), (512, 384), bk=128, bm=128, p_zero_block=0.5)
        bst = sparse_format.from_ternary(t, bk=128, bm=128)
        kids, slots, counts, s_max = sparse_format.strip_schedule(bst)
        bmap = np.asarray(bst.block_map)
        assert int(np.asarray(counts).sum()) == bst.n_live
        assert s_max == int((bmap >= 0).sum(axis=0).max())
        for j in range(bmap.shape[1]):
            c = int(np.asarray(counts)[j])
            live_k = np.nonzero(bmap[:, j] >= 0)[0]
            np.testing.assert_array_equal(np.asarray(kids)[j, :c], live_k)
            np.testing.assert_array_equal(np.asarray(slots)[j, :c], bmap[live_k, j])


class TestSparseKernel:
    @pytest.mark.parametrize("p_zero", P_ZERO_SWEEP)
    def test_exact_vs_dense_kernel_unstructured(self, p_zero):
        """Acceptance: bit-identical (int32 accumulation) to tsar_matmul on
        random ternary weights across the p_zero sweep."""
        n, k, m = 4, 512, 384
        t = _rand(int(p_zero * 100), k, m, p_zero=p_zero)
        scale = jax.random.uniform(jax.random.PRNGKey(8), (m,), minval=0.25, maxval=2.0)
        bst = sparse_format.from_ternary(t, scale, bk=128, bm=128)
        x = jax.random.normal(jax.random.PRNGKey(9), (n, k))
        got = ops.tsar_sparse_matmul(x, bst, interpret=True)
        dense = ops.tsar_matmul(x, ternary.pack(t.astype(jnp.float32), scale),
                                interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(dense))

    @pytest.mark.parametrize("p_zero_block", [0.0, 0.5, 1.0])
    def test_exact_vs_ref_block_structured(self, p_zero_block):
        n, k, m = 3, 640, 256
        t = sparse_format.random_block_sparse_ternary(
            jax.random.PRNGKey(10), (k, m), bk=128, bm=128,
            p_zero_block=p_zero_block)
        bst = sparse_format.from_ternary(t, bk=128, bm=128)
        x = jax.random.normal(jax.random.PRNGKey(11), (n, k))
        got = ops.tsar_sparse_matmul(x, bst, interpret=True)
        want = ref.block_sparse_matmul_ref(x, bst)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_ragged_shapes_and_leading_dims(self):
        t = _rand(12, 300, 200)
        bst = sparse_format.from_ternary(t, bk=128, bm=128)
        x = jax.random.normal(jax.random.PRNGKey(13), (2, 3, 300))
        got = ops.tsar_sparse_matmul(x, bst, interpret=True)
        assert got.shape == (2, 3, 200)
        want = ref.block_sparse_matmul_ref(x.reshape(6, 300), bst).reshape(2, 3, 200)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 10**6), n=st.integers(1, 6),
           pz=st.sampled_from(P_ZERO_SWEEP))
    def test_property_exactness(self, seed, n, pz):
        k, m = 256, 256
        t = sparse_format.random_block_sparse_ternary(
            jax.random.PRNGKey(seed), (k, m), bk=128, bm=128, p_zero_block=pz)
        scale = jax.random.uniform(jax.random.PRNGKey(seed + 1), (m,),
                                   minval=0.25, maxval=2.0)
        bst = sparse_format.from_ternary(t, scale, bk=128, bm=128)
        x = jax.random.normal(jax.random.PRNGKey(seed + 2), (n, k))
        got = ops.tsar_sparse_matmul(x, bst, interpret=True)
        dense = ops.tsar_matmul(x, ternary.pack(t.astype(jnp.float32), scale),
                                interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(dense))


class TestDensityDispatch:
    @pytest.mark.parametrize("n,k,m", [(1, 2560, 6912), (128, 2560, 6912),
                                       (8, 4096, 4096)])
    def test_break_even_is_respected(self, n, k, m):
        """Acceptance: sparse below the analytic break-even, never above."""
        be = dataflow.sparse_break_even(n, k, m)
        assert 0.0 < be < 1.0
        below = dataflow.select_kernel(n, k, m, block_density=be * 0.9)
        above = dataflow.select_kernel(n, k, m,
                                       block_density=min(1.0, be * 1.1))
        at_full = dataflow.select_kernel(n, k, m, block_density=1.0)
        assert below.kernel == "tsar_sparse"
        assert above.kernel != "tsar_sparse"
        assert at_full.kernel != "tsar_sparse"

    def test_default_density_never_speculates_sparse(self):
        """Unstructured zeros leave every block live, so with no measured
        block density the selector must never pick the sparse path."""
        for (n, k, m) in [(1, 2560, 6912), (64, 1024, 1024), (128, 8192, 8192)]:
            assert dataflow.select_kernel(n, k, m).kernel != "tsar_sparse"

    def test_sparse_cost_monotone_in_density(self):
        costs = [max(*dataflow._tsar_sparse_cost(8, 4096, 4096, bd))
                 for bd in (0.1, 0.4, 0.7, 1.0)]
        assert costs == sorted(costs)

    def test_frozen_auto_dispatch_picks_sparse_when_blocks_die(self):
        """End-to-end threading: a checkpoint with structurally dead blocks is
        served by tsar_sparse under kernel='auto' with no caller change."""
        key = jax.random.PRNGKey(20)
        k, m = 512, 512
        w = jax.random.normal(key, (k, m)) * 0.1
        mask = sparse_format.random_block_sparse_ternary(
            jax.random.PRNGKey(21), (k, m), bk=256, bm=256,
            p_zero_block=0.75, p_zero=0.0).astype(jnp.float32)
        fz = bitlinear.freeze({"w": w * jnp.abs(mask)})
        assert fz.block_density is not None and fz.block_density < 0.5
        x = jax.random.normal(jax.random.PRNGKey(22), (4, k))
        choice = dataflow.select_kernel(
            n=4, k=k, m=m, c=fz.c, density=fz.density,
            block_density=fz.block_density, block_shape=fz.sparse.block_shape)
        assert choice.kernel == "tsar_sparse"
        y_auto = bitlinear.apply_frozen(fz, x)   # plan=None -> auto-select
        y_dense = bitlinear.apply_frozen(fz, x, plan="tsar_mxu")
        np.testing.assert_allclose(np.asarray(y_auto), np.asarray(y_dense),
                                   rtol=1e-5, atol=1e-4)

    def test_frozen_without_sidecar_falls_back(self):
        fz = bitlinear.freeze(bitlinear.init(jax.random.PRNGKey(0), 128, 64))
        fz = fz._replace(sparse=None, block_density=0.01)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 128))
        y = bitlinear.apply_frozen(fz, x)                  # must not raise
        assert y.shape == (2, 64)


class TestStats:
    def test_profile_packed_tree(self):
        from repro.models import layers
        w1 = jax.random.normal(jax.random.PRNGKey(0), (256, 128)) * 0.1
        w_stack = jax.random.normal(jax.random.PRNGKey(1), (3, 256, 128)) * 0.1
        tree = {"attn": layers.pack_linear({"w": w1}),
                "mlp": jax.vmap(layers.pack_linear)({"w": w_stack}),
                "embed": {"wd": jnp.zeros((10, 4))}}
        prof = sparse_stats.profile_params(tree)
        assert {p["path"] for p in prof} == {"attn", "mlp"}
        kb_one = -(-256 // sparse_format.DEFAULT_BK)   # blocks along K per layer
        mb_one = -(-128 // sparse_format.DEFAULT_BM)
        expect_blocks = {"attn": kb_one * mb_one, "mlp": 3 * kb_one * mb_one}
        for p in prof:
            assert 0.0 < p["density"] < 1.0
            assert int(p["hist"].sum()) == expect_blocks[p["path"]]
        summ = sparse_stats.summarize(prof)
        assert summ["layers"] == 2
        assert 0.0 < summ["density_mean"] < 1.0
        assert len(sparse_stats.format_report(prof).splitlines()) == 4

    def test_density_leaf_measures_zeros(self):
        from repro.models import layers
        packed = layers.pack_linear({"w": jax.random.normal(jax.random.PRNGKey(2), (256, 128))})
        assert "density" in packed
        d = float(packed["density"])
        assert 0.4 < d < 0.95   # absmean keeps roughly 2/3 nonzero

    def test_block_occupancy_ragged(self):
        t = np.zeros((200, 100), np.int8)
        t[:128, :64] = 1
        occ = sparse_stats.block_occupancy(t, 128, 128)
        assert occ.shape == (2, 1)
        assert occ[0, 0] == pytest.approx(64 * 128 / (128 * 128))
        assert occ[1, 0] == 0.0
