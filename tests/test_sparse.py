"""Sparsity subsystem: block-sparse format round-trips, zero-skipping kernel
exactness vs the dense path, density-driven dispatch, and profiling stats."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import bitlinear, dataflow, ternary
from repro.kernels import ops, ref
from repro.sparse import format as sparse_format
from repro.sparse import stats as sparse_stats

P_ZERO_SWEEP = (0.1, 1.0 / 3.0, 0.6, 0.9)


def _rand(seed, k, m, p_zero=1.0 / 3.0):
    return ternary.random_ternary(jax.random.PRNGKey(seed), (k, m), p_zero)


class TestBlockSparseFormat:
    @pytest.mark.parametrize("k,m,bk,bm", [
        (256, 256, 128, 128), (512, 384, 256, 128),
        (300, 200, 128, 128),            # ragged K and M
        (128, 128, 128, 128),            # single block
    ])
    def test_roundtrip_to_ternary(self, k, m, bk, bm):
        t = _rand(k + m, k, m)
        bst = sparse_format.from_ternary(t, bk=bk, bm=bm)
        np.testing.assert_array_equal(np.asarray(sparse_format.to_ternary(bst)),
                                      np.asarray(t))

    @pytest.mark.parametrize("p_zero", [0.0, 1.0])
    def test_roundtrip_extreme_densities(self, p_zero):
        """Density 1.0 (no zeros: every block live) and 0.0 (all zeros:
        empty pool) both round-trip exactly."""
        t = _rand(7, 384, 256, p_zero=p_zero)
        bst = sparse_format.from_ternary(t, bk=128, bm=128)
        kb, mb = bst.grid
        if p_zero == 1.0:
            assert bst.n_live == 0 and bst.block_density == 0.0
        else:
            assert bst.n_live == kb * mb and bst.block_density == 1.0
        np.testing.assert_array_equal(np.asarray(sparse_format.to_ternary(bst)),
                                      np.asarray(t))

    def test_roundtrip_to_packed(self):
        t = _rand(11, 512, 256)
        scale = jax.random.uniform(jax.random.PRNGKey(1), (256,), minval=0.5, maxval=2.0)
        tw = ternary.pack(t.astype(jnp.float32), scale)
        bst = sparse_format.from_packed(tw, bk=128, bm=128)
        tw2 = sparse_format.to_packed(bst)
        np.testing.assert_array_equal(np.asarray(tw2.sign_plane), np.asarray(tw.sign_plane))
        np.testing.assert_array_equal(np.asarray(tw2.zero_plane), np.asarray(tw.zero_plane))
        np.testing.assert_allclose(np.asarray(tw2.scale), np.asarray(tw.scale))

    def test_dead_blocks_cost_no_pool_bytes(self):
        key = jax.random.PRNGKey(3)
        t_dense = sparse_format.random_block_sparse_ternary(
            key, (512, 512), bk=128, bm=128, p_zero_block=0.0)
        t_half = t_dense * sparse_format.random_block_sparse_ternary(
            jax.random.PRNGKey(4), (512, 512), bk=128, bm=128,
            p_zero_block=0.75, p_zero=0.0)
        b_dense = sparse_format.from_ternary(t_dense, bk=128, bm=128)
        b_half = sparse_format.from_ternary(t_half, bk=128, bm=128)
        assert b_half.n_live < b_dense.n_live
        assert b_half.nbytes() < b_dense.nbytes()

    def test_occupancy_matches_blocks(self):
        t = sparse_format.random_block_sparse_ternary(
            jax.random.PRNGKey(5), (384, 256), bk=128, bm=128, p_zero_block=0.5)
        bst = sparse_format.from_ternary(t, bk=128, bm=128)
        occ = sparse_stats.block_occupancy(t, 128, 128)
        np.testing.assert_allclose(np.asarray(bst.occupancy), occ, rtol=1e-6)
        assert ((occ > 0) == (np.asarray(bst.block_map) >= 0)).all()

    def test_strip_schedule_covers_live_blocks(self):
        t = sparse_format.random_block_sparse_ternary(
            jax.random.PRNGKey(6), (512, 384), bk=128, bm=128, p_zero_block=0.5)
        bst = sparse_format.from_ternary(t, bk=128, bm=128)
        kids, slots, counts, s_max = sparse_format.strip_schedule(bst)
        bmap = np.asarray(bst.block_map)
        assert int(np.asarray(counts).sum()) == bst.n_live
        assert s_max == int((bmap >= 0).sum(axis=0).max())
        for j in range(bmap.shape[1]):
            c = int(np.asarray(counts)[j])
            live_k = np.nonzero(bmap[:, j] >= 0)[0]
            np.testing.assert_array_equal(np.asarray(kids)[j, :c], live_k)
            np.testing.assert_array_equal(np.asarray(slots)[j, :c], bmap[live_k, j])


class TestSparseKernel:
    # Note: the bit-identity sweeps vs the dense kernel (unstructured p_zero
    # grid, hypothesis shape exactness) moved to the cross-kernel
    # conformance suite (tests/test_conformance.py), which covers every
    # registry kernel on a shared shapes x densities x dtypes grid.

    @pytest.mark.parametrize("p_zero_block", [0.0, 0.5, 1.0])
    def test_exact_vs_ref_block_structured(self, p_zero_block):
        n, k, m = 3, 640, 256
        t = sparse_format.random_block_sparse_ternary(
            jax.random.PRNGKey(10), (k, m), bk=128, bm=128,
            p_zero_block=p_zero_block)
        bst = sparse_format.from_ternary(t, bk=128, bm=128)
        x = jax.random.normal(jax.random.PRNGKey(11), (n, k))
        got = ops.tsar_sparse_matmul(x, bst, interpret=True)
        want = ref.block_sparse_matmul_ref(x, bst)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_ragged_shapes_and_leading_dims(self):
        t = _rand(12, 300, 200)
        bst = sparse_format.from_ternary(t, bk=128, bm=128)
        x = jax.random.normal(jax.random.PRNGKey(13), (2, 3, 300))
        got = ops.tsar_sparse_matmul(x, bst, interpret=True)
        assert got.shape == (2, 3, 200)
        want = ref.block_sparse_matmul_ref(x.reshape(6, 300), bst).reshape(2, 3, 200)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

class TestPaddedPool:
    """PaddedBlockSparseTernary: static-shape (vmappable) pool properties."""

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 10**6), kb=st.integers(1, 4),
           mb=st.integers(1, 3), pzb=st.sampled_from((0.0, 0.5, 1.0)))
    def test_roundtrip_to_ternary_and_packed(self, seed, kb, mb, pzb):
        """pad -> decode is exact, pad -> TernaryWeights matches the dense
        packing bit-for-bit, and compact() recovers the compacted format."""
        k, m = kb * 64 - 3, mb * 64          # ragged K on purpose
        t = sparse_format.random_block_sparse_ternary(
            jax.random.PRNGKey(seed), (k, m), bk=64, bm=64, p_zero_block=pzb)
        scale = jax.random.uniform(jax.random.PRNGKey(seed + 1), (m,),
                                   minval=0.25, maxval=2.0)
        pbst = sparse_format.pad_from_ternary(t, scale, bk=64, bm=64)
        np.testing.assert_array_equal(
            np.asarray(sparse_format.padded_to_ternary(pbst)), np.asarray(t))
        tw = ternary.pack(t.astype(jnp.float32), scale)
        tw2 = sparse_format.padded_to_packed(pbst)
        np.testing.assert_array_equal(np.asarray(tw2.sign_plane),
                                      np.asarray(tw.sign_plane))
        np.testing.assert_array_equal(np.asarray(tw2.zero_plane),
                                      np.asarray(tw.zero_plane))
        compacted = sparse_format.compact(pbst)
        np.testing.assert_array_equal(
            np.asarray(sparse_format.to_ternary(compacted)), np.asarray(t))

    def test_pad_pool_from_compacted_is_exact_and_tight(self):
        t = sparse_format.random_block_sparse_ternary(
            jax.random.PRNGKey(2), (320, 192), bk=64, bm=64, p_zero_block=0.6)
        bst = sparse_format.from_ternary(t, bk=64, bm=64)
        pbst = sparse_format.pad_pool(bst)
        assert pbst.max_live == max(bst.n_live, 1)
        assert pbst.s_steps == max(bst.s_max, 1)
        np.testing.assert_array_equal(
            np.asarray(sparse_format.padded_to_ternary(pbst)), np.asarray(t))

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 10**6), extra=st.integers(0, 7))
    def test_nbytes_monotonic_in_max_live(self, seed, extra):
        """More pad slots never cost fewer bytes — max_live trades memory
        for the static shape."""
        t = sparse_format.random_block_sparse_ternary(
            jax.random.PRNGKey(seed), (256, 192), bk=64, bm=64,
            p_zero_block=0.5)
        bst = sparse_format.from_ternary(t, bk=64, bm=64)
        base = max(bst.n_live, 1)
        sizes = [sparse_format.pad_from_ternary(t, bk=64, bm=64,
                                                max_live=base + d).nbytes()
                 for d in (0, extra, extra + 1)]
        assert sizes == sorted(sizes)
        assert sizes[0] < sizes[-1] or extra == 0

    def test_undersized_pool_raises_on_concrete(self):
        t = sparse_format.random_block_sparse_ternary(
            jax.random.PRNGKey(5), (256, 192), bk=64, bm=64, p_zero_block=0.2)
        bst = sparse_format.from_ternary(t, bk=64, bm=64)
        with pytest.raises(ValueError, match="max_live"):
            sparse_format.pad_from_ternary(t, bk=64, bm=64,
                                           max_live=bst.n_live - 1)
        with pytest.raises(ValueError, match="s_steps"):
            sparse_format.pad_from_ternary(t, bk=64, bm=64,
                                           s_steps=bst.s_max - 1)

    def test_traced_undersized_bounds_truncate_consistently(self):
        """Under tracing the undersized-bound raise is unavailable, so an
        overflowing strip is deterministically TRUNCATED — and the kernel
        walk, the block map, and the jnp decode must all see the SAME
        truncated matrix (a schedule-only truncation would make the Pallas
        and jnp realizations of tsar_sparse_padded disagree)."""
        t = _rand(3, 256, 128, p_zero=0.2)      # all 4 k-blocks live per strip
        pbst = jax.jit(lambda w: sparse_format.pad_from_ternary(
            w, bk=64, bm=64, s_steps=2))(t)
        bmap = np.asarray(pbst.block_map)
        assert int((bmap >= 0).sum(axis=0).max()) <= 2   # map truncated too
        dec = sparse_format.padded_to_ternary(pbst)
        x = jax.random.normal(jax.random.PRNGKey(4), (2, 256))
        kernel_y = ops.tsar_sparse_padded_matmul(x, pbst, interpret=True)
        a_q, a_scale = ternary.quantize_activations(x)
        acc = jax.lax.dot_general(
            a_q, dec, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        jnp_y = acc.astype(jnp.float32) * a_scale * pbst.scale
        np.testing.assert_array_equal(np.asarray(kernel_y), np.asarray(jnp_y))

    def test_freeze_padded_true_shapes_match_traced(self):
        """freeze(padded=True) must produce the SAME sidecar shapes eagerly
        and under eval_shape/jit — eval_shape-driven buffer allocation and
        jit(freeze) outputs would otherwise disagree with eager freezes."""
        w = {"w": jax.random.normal(jax.random.PRNGKey(30), (128, 128)) * 0.1}
        fn = lambda p: bitlinear.freeze(p, block_shape=(64, 64), padded=True)
        eager = fn(w)
        traced = jax.eval_shape(fn, w)
        assert eager.padded.sign_pool.shape == traced.padded.sign_pool.shape
        assert eager.padded.kids.shape == traced.padded.kids.shape
        assert eager.padded.max_live == 4          # full grid, not n_live

    def test_construction_is_traceable(self):
        """The whole point: pad_from_ternary runs under tracing (vmap/jit),
        unlike the data-dependent compacted builder."""
        t = sparse_format.random_block_sparse_ternary(
            jax.random.PRNGKey(6), (128, 128), bk=64, bm=64, p_zero_block=0.5)
        fn = jax.jit(lambda w: sparse_format.pad_from_ternary(w, bk=64, bm=64))
        pbst = fn(t)
        np.testing.assert_array_equal(
            np.asarray(sparse_format.padded_to_ternary(pbst)), np.asarray(t))
        # and abstractly (shape-only), the freeze-under-tracing contract
        abs_p = jax.eval_shape(fn, t)
        assert abs_p.sign_pool.shape == pbst.sign_pool.shape

    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(0, 10**6), n=st.integers(1, 4))
    def test_vmap_over_stacked_layers_equals_loop(self, seed, n):
        """Acceptance: stacked scan-layer pools built and consumed under
        vmap match a Python loop of per-layer sparse matmuls bit-for-bit."""
        L = 3
        ts = jnp.stack([
            sparse_format.random_block_sparse_ternary(
                jax.random.PRNGKey(seed + i), (192, 128), bk=64, bm=64,
                p_zero_block=0.5)
            for i in range(L)])
        pools = jax.vmap(
            lambda w: sparse_format.pad_from_ternary(w, bk=64, bm=64))(ts)
        xs = jax.random.normal(jax.random.PRNGKey(seed + 9), (L, n, 192))
        ys = jax.vmap(lambda p, x: ops.tsar_sparse_padded_matmul(
            x, p, interpret=True))(pools, xs)
        for i in range(L):
            per_layer = sparse_format.pad_from_ternary(ts[i], bk=64, bm=64)
            want = ops.tsar_sparse_padded_matmul(xs[i], per_layer,
                                                 interpret=True)
            np.testing.assert_array_equal(np.asarray(ys[i]), np.asarray(want))

    def test_pad_slots_and_schedule_pads_are_inert(self):
        """Oversized pools: pad slots decode to zero blocks and padded
        schedule entries are masked — output identical to the tight pool."""
        t = sparse_format.random_block_sparse_ternary(
            jax.random.PRNGKey(7), (256, 128), bk=64, bm=64, p_zero_block=0.5)
        tight = sparse_format.pad_from_ternary(t, bk=64, bm=64)
        loose = sparse_format.pad_from_ternary(
            t, bk=64, bm=64, max_live=int(np.asarray(tight.n_live)) + 5)
        x = jax.random.normal(jax.random.PRNGKey(8), (2, 256))
        np.testing.assert_array_equal(
            np.asarray(ops.tsar_sparse_padded_matmul(x, tight, interpret=True)),
            np.asarray(ops.tsar_sparse_padded_matmul(x, loose, interpret=True)))


class TestDensityDispatch:
    @pytest.mark.parametrize("n,k,m", [(1, 2560, 6912), (128, 2560, 6912),
                                       (8, 4096, 4096)])
    def test_break_even_is_respected(self, n, k, m):
        """Acceptance: sparse below the analytic break-even, never above."""
        be = dataflow.sparse_break_even(n, k, m)
        assert 0.0 < be < 1.0
        below = dataflow.select_kernel(n, k, m, block_density=be * 0.9)
        above = dataflow.select_kernel(n, k, m,
                                       block_density=min(1.0, be * 1.1))
        at_full = dataflow.select_kernel(n, k, m, block_density=1.0)
        assert below.kernel == "tsar_sparse"
        assert above.kernel != "tsar_sparse"
        assert at_full.kernel != "tsar_sparse"

    def test_default_density_never_speculates_sparse(self):
        """Unstructured zeros leave every block live, so with no measured
        block density the selector must never pick the sparse path."""
        for (n, k, m) in [(1, 2560, 6912), (64, 1024, 1024), (128, 8192, 8192)]:
            assert dataflow.select_kernel(n, k, m).kernel != "tsar_sparse"

    def test_sparse_cost_monotone_in_density(self):
        costs = [max(*dataflow._tsar_sparse_cost(8, 4096, 4096, bd))
                 for bd in (0.1, 0.4, 0.7, 1.0)]
        assert costs == sorted(costs)

    def test_frozen_auto_dispatch_picks_sparse_when_blocks_die(self):
        """End-to-end threading: a checkpoint with structurally dead blocks is
        served by tsar_sparse under kernel='auto' with no caller change."""
        key = jax.random.PRNGKey(20)
        k, m = 512, 512
        w = jax.random.normal(key, (k, m)) * 0.1
        mask = sparse_format.random_block_sparse_ternary(
            jax.random.PRNGKey(21), (k, m), bk=256, bm=256,
            p_zero_block=0.75, p_zero=0.0).astype(jnp.float32)
        fz = bitlinear.freeze({"w": w * jnp.abs(mask)})
        assert fz.block_density is not None and fz.block_density < 0.5
        x = jax.random.normal(jax.random.PRNGKey(22), (4, k))
        choice = dataflow.select_kernel(
            n=4, k=k, m=m, c=fz.c, density=fz.density,
            block_density=fz.block_density, block_shape=fz.sparse.block_shape)
        assert choice.kernel == "tsar_sparse"
        y_auto = bitlinear.apply_frozen(fz, x)   # plan=None -> auto-select
        y_dense = bitlinear.apply_frozen(fz, x, plan="tsar_mxu")
        np.testing.assert_allclose(np.asarray(y_auto), np.asarray(y_dense),
                                   rtol=1e-5, atol=1e-4)

    def test_frozen_without_sidecar_falls_back(self):
        fz = bitlinear.freeze(bitlinear.init(jax.random.PRNGKey(0), 128, 64))
        fz = fz._replace(sparse=None, block_density=0.01)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 128))
        y = bitlinear.apply_frozen(fz, x)                  # must not raise
        assert y.shape == (2, 64)


class TestCalibration:
    """The issue-tax calibration plumbing: fit -> install (core/hw) ->
    every registry cost model reads the fitted value -> save/load."""

    @pytest.fixture(autouse=True)
    def _clean(self):
        from repro.core import hw

        hw.clear_calibration()
        yield
        hw.clear_calibration()

    def test_fit_issue_tax_recovers_planted_constant(self):
        from benchmarks.bench_kernels import fit_issue_tax

        td = 2.0
        rows = [(bd, 1.3 * bd * td, td) for bd in (0.1, 0.4, 0.7, 1.0)]
        assert fit_issue_tax(rows) == pytest.approx(1.3)
        # outlier-robust: one corrupt row does not move the median
        rows.append((0.5, 50.0, td))
        assert fit_issue_tax(rows) == pytest.approx(1.3)
        with pytest.raises(ValueError, match="no usable"):
            fit_issue_tax([(0.0, 1.0, 1.0)])

    def test_calibrated_tax_reaches_cost_models_and_break_even(self):
        from repro.core import hw
        from repro.plan import registry

        n, k, m = 8, 4096, 4096
        base_cost = registry.get("tsar_sparse").cost(n, k, m,
                                                     block_density=0.5)
        base_be = dataflow.sparse_break_even(n, k, m)
        hw.set_calibration(sparse_issue_tax=hw.SPARSE_ISSUE_TAX * 2)
        assert hw.sparse_issue_tax() == pytest.approx(2.2)
        up_cost = registry.get("tsar_sparse").cost(n, k, m, block_density=0.5)
        assert up_cost[0] > base_cost[0]        # compute scaled by the tax
        assert dataflow.sparse_break_even(n, k, m) < base_be
        # the padded kernel reads the same knob
        up_pad = registry.get("tsar_sparse_padded").cost(n, k, m,
                                                         block_density=0.5)
        assert up_pad[0] > up_cost[0]           # pad-walk overhead on top
        hw.clear_calibration("sparse_issue_tax")
        assert registry.get("tsar_sparse").cost(
            n, k, m, block_density=0.5) == base_cost

    def test_save_load_roundtrip_and_validation(self, tmp_path):
        from repro.core import hw

        hw.set_calibration(sparse_issue_tax=1.37)
        path = tmp_path / "calibration.json"
        hw.save_calibration(path)
        hw.clear_calibration()
        assert hw.sparse_issue_tax() == hw.SPARSE_ISSUE_TAX
        loaded = hw.load_calibration(path)
        assert loaded == {"sparse_issue_tax": 1.37}
        assert hw.sparse_issue_tax() == 1.37
        with pytest.raises(ValueError, match="unknown calibration key"):
            hw.set_calibration(bogus=1.0)
        with pytest.raises(ValueError, match="must be > 0"):
            hw.set_calibration(sparse_issue_tax=0.0)

    def test_calibrate_installs_fitted_tax(self, monkeypatch, tmp_path):
        """The bench entry point wires measure -> fit -> install; timings
        are stubbed so the test pins plumbing, not this container's clock."""
        import benchmarks.bench_kernels as bench
        from repro.core import hw

        monkeypatch.setattr(
            bench, "measure_issue_tax_samples",
            lambda quick=True, reps=3: [(0.5, 1.25 * 0.5 * 2.0, 2.0)])
        tax = bench.calibrate(quick=True)
        assert tax == pytest.approx(1.25)
        assert hw.sparse_issue_tax() == pytest.approx(1.25)
        # save is honored even on a dry run (apply=False): fit-and-persist
        # must not require mutating the process-global calibration.
        hw.clear_calibration()
        path = tmp_path / "cal.json"
        bench.calibrate(quick=True, save=path, apply=False)
        assert hw.sparse_issue_tax() == hw.SPARSE_ISSUE_TAX   # untouched
        assert hw.load_calibration(path) == {
            "sparse_issue_tax": pytest.approx(1.25)}


class TestStats:
    def test_profile_packed_tree(self):
        from repro.models import layers
        w1 = jax.random.normal(jax.random.PRNGKey(0), (256, 128)) * 0.1
        w_stack = jax.random.normal(jax.random.PRNGKey(1), (3, 256, 128)) * 0.1
        tree = {"attn": layers.pack_linear({"w": w1}),
                "mlp": jax.vmap(layers.pack_linear)({"w": w_stack}),
                "embed": {"wd": jnp.zeros((10, 4))}}
        prof = sparse_stats.profile_params(tree)
        assert {p["path"] for p in prof} == {"attn", "mlp"}
        kb_one = -(-256 // sparse_format.DEFAULT_BK)   # blocks along K per layer
        mb_one = -(-128 // sparse_format.DEFAULT_BM)
        expect_blocks = {"attn": kb_one * mb_one, "mlp": 3 * kb_one * mb_one}
        for p in prof:
            assert 0.0 < p["density"] < 1.0
            assert int(p["hist"].sum()) == expect_blocks[p["path"]]
        summ = sparse_stats.summarize(prof)
        assert summ["layers"] == 2
        assert 0.0 < summ["density_mean"] < 1.0
        assert len(sparse_stats.format_report(prof).splitlines()) == 4

    def test_density_leaf_measures_zeros(self):
        from repro.models import layers
        packed = layers.pack_linear({"w": jax.random.normal(jax.random.PRNGKey(2), (256, 128))})
        assert "density" in packed
        d = float(packed["density"])
        assert 0.4 < d < 0.95   # absmean keeps roughly 2/3 nonzero

    def test_block_occupancy_ragged(self):
        t = np.zeros((200, 100), np.int8)
        t[:128, :64] = 1
        occ = sparse_stats.block_occupancy(t, 128, 128)
        assert occ.shape == (2, 1)
        assert occ[0, 0] == pytest.approx(64 * 128 / (128 * 128))
        assert occ[1, 0] == 0.0
