"""Per-arch smoke tests (reduced configs, one forward + one train step on CPU)
and decode-consistency checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import model_zoo as zoo
from repro.optim import OptConfig
from repro.train import init_state, make_train_step

ARCHS = list(configs.ASSIGNED) + ["bitnet-2b-4t"]


def _batch(cfg, b=2, s=16, seed=0):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["patches"] = jnp.full((b, cfg.frontend_seq, cfg.frontend_dim), 0.1)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (b, cfg.enc_seq, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_shapes_no_nans(self, arch):
        cfg = configs.get(arch).reduced()
        params = zoo.init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg)
        logits, aux = zoo.forward(cfg, params, batch)
        s_total = 16 + (cfg.frontend_seq if cfg.family == "vlm" else 0)
        assert logits.shape == (2, s_total, cfg.padded_vocab)
        assert not bool(jnp.any(jnp.isnan(logits)))

    def test_one_train_step(self, arch):
        cfg = configs.get(arch).reduced()
        opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)
        state = init_state(cfg, jax.random.PRNGKey(0), opt)
        step = make_train_step(cfg, opt)
        new_state, metrics = step(state, _batch(cfg))
        assert int(new_state.step) == 1
        assert np.isfinite(float(metrics["loss"]))
        # params actually moved
        moved = any(
            float(jnp.max(jnp.abs(a - b))) > 0
            for a, b in zip(jax.tree.leaves(state.params),
                            jax.tree.leaves(new_state.params)))
        assert moved


@pytest.mark.parametrize("arch", [
    "gemma3-4b", "gemma2-2b", "qwen3-32b", "mamba2-780m", "hymba-1.5b",
    "whisper-tiny", "llava-next-mistral-7b",
])
def test_decode_matches_teacher_forcing(arch):
    cfg = configs.get(arch).reduced()
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = _batch(cfg, b, s, seed=3)
    full, _ = zoo.forward(cfg, params, batch, train=False)
    sp = s - 2
    extra = cfg.frontend_seq if cfg.family == "vlm" else 0
    cache = zoo.init_cache(cfg, b, s + extra)
    pre = dict(batch, tokens=batch["tokens"][:, :sp])
    pre.pop("labels")
    lg, cache = zoo.prefill(cfg, params, pre, cache)
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, sp - 1 + extra]),
                               rtol=5e-3, atol=5e-3)
    t = jnp.int32(sp + extra)
    lg1, cache = zoo.decode_step(cfg, params, batch["tokens"][:, sp:sp + 1], cache, t)
    np.testing.assert_allclose(np.asarray(lg1[:, 0]), np.asarray(full[:, sp + extra]),
                               rtol=5e-3, atol=5e-3)


def test_ssm_forward_initial_state_chunks_exactly():
    """ROADMAP satellite: ``ssm_forward`` accepts an initial SSD state and
    conv-window tail, so running a sequence in segments is exact — the
    building block for chunked prefill on SSM/hybrid families."""
    from repro.models import layers, ssm as ssm_lib

    cfg = configs.get("mamba2-780m").reduced()
    p = ssm_lib.init_ssm(jax.random.PRNGKey(1), cfg)
    b, s, split = 2, 64, 32          # both halves multiples of ssm_chunk (16)
    u = jax.random.normal(jax.random.PRNGKey(2), (b, s, cfg.d_model))

    y_full, final_full = ssm_lib.ssm_forward(cfg, p, u, train=False)
    y1, state1 = ssm_lib.ssm_forward(cfg, p, u[:, :split], train=False)
    # Conv tail: pre-activation xBC of the first segment's last W-1 inputs
    # (same recomputation _ssm_prefill_cache uses to seed decode).
    w = cfg.ssm_conv_width
    tail = u[:, split - (w - 1):split, :]
    _, xs, bs, cs, _ = ssm_lib._split_in(
        cfg, layers.linear(p["in_proj"], tail, train=False))
    conv_tail = jnp.concatenate([xs, bs, cs], axis=-1)
    y2, final_seg = ssm_lib.ssm_forward(
        cfg, p, u[:, split:], train=False,
        initial_state=state1, initial_conv=conv_tail)

    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_full[:, :split]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, split:]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final_seg), np.asarray(final_full),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["deepseek-moe-16b", "llama4-maverick-400b-a17b"])
def test_moe_decode_matches_teacher_forcing_dropless(arch):
    # Dropless capacity makes the comparison exact (capacity windows differ
    # between a 14- and 16-token call otherwise; see DESIGN.md).
    cfg = dataclasses.replace(configs.get(arch).reduced(), capacity_factor=8.0)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = _batch(cfg, b, s, seed=4)
    full, _ = zoo.forward(cfg, params, batch, train=False)
    sp = s - 1
    cache = zoo.init_cache(cfg, b, s)
    lg, cache = zoo.prefill(cfg, params, {"tokens": batch["tokens"][:, :sp]}, cache)
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, sp - 1]),
                               rtol=5e-3, atol=5e-3)


def test_sliding_window_blocks_far_attention():
    """A local layer must not attend beyond its window."""
    cfg = dataclasses.replace(
        configs.get("gemma2-2b").reduced(),
        window_pattern=("L",), window_size=4, n_layers=1, ternary=False)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0, cfg.vocab_size)
    base, _ = zoo.forward(cfg, params, {"tokens": toks}, train=False)
    # Perturb token 0: outputs at positions >= window must be unchanged.
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    pert, _ = zoo.forward(cfg, params, {"tokens": toks2}, train=False)
    np.testing.assert_allclose(np.asarray(base[0, 8:]), np.asarray(pert[0, 8:]),
                               rtol=1e-4, atol=1e-4)
    assert float(jnp.max(jnp.abs(base[0, 1] - pert[0, 1]))) > 1e-6  # near pos: affected


def test_remat_matches_no_remat():
    cfg = configs.get("gemma2-2b").reduced()
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    l1, _ = zoo.loss_fn(cfg, params, batch, remat=False)
    l2, _ = zoo.loss_fn(cfg, params, batch, remat=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    g1 = jax.grad(lambda p: zoo.loss_fn(cfg, p, batch, remat=False)[0])(params)
    g2 = jax.grad(lambda p: zoo.loss_fn(cfg, p, batch, remat=True)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)


def test_qchunk_scan_matches_direct_for_batched_chunk_mask(monkeypatch):
    """The bounded-memory query-block scan must handle the per-slot chunked
    decode mask (leading batch dim) identically to the direct path."""
    from repro.models import layers

    cfg = configs.get("bitnet-2b-4t").reduced()
    key = jax.random.PRNGKey(0)
    p = layers.init_attention(key, cfg)
    b, s, t = 2, 8, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model))
    cache = {
        "k": jnp.zeros((b, t, cfg.n_kv_heads, cfg.head_dim)),
        "v": jnp.zeros((b, t, cfg.n_kv_heads, cfg.head_dim)),
    }
    pos = jnp.stack([jnp.arange(s), jnp.arange(s) + 2])   # per-slot offsets
    lengths = jnp.asarray([0, 2], jnp.int32)
    direct, _ = layers.attention(cfg, p, x, pos=pos, is_global=True,
                                 cache=cache, cache_len=lengths, train=False)
    monkeypatch.setattr(layers, "Q_CHUNK", 4)             # force the scan path
    scanned, _ = layers.attention(cfg, p, x, pos=pos, is_global=True,
                                  cache=cache, cache_len=lengths, train=False)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(scanned),
                               rtol=1e-5, atol=1e-5)
