"""Training-substrate integration: loss decreases, grad accumulation
equivalence, data-pipeline determinism, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.data import DataConfig, PrefetchIterator, SyntheticLMStream
from repro.optim import OptConfig, compression
from repro.train import init_state, make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = configs.get("bitnet-2b-4t").reduced()
    opt = OptConfig(lr=2e-3, warmup_steps=5, total_steps=100)
    return cfg, opt


def test_loss_decreases(tiny):
    cfg, opt = tiny
    state = init_state(cfg, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(cfg, opt))
    stream = SyntheticLMStream(DataConfig(cfg.vocab_size, 64, 8, seed=1))
    losses = []
    for i in range(30):
        state, m = step(state, stream.batch(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05


def test_grad_accum_matches_full_batch(tiny):
    cfg, opt = tiny
    state = init_state(cfg, jax.random.PRNGKey(0), opt)
    stream = SyntheticLMStream(DataConfig(cfg.vocab_size, 32, 8, seed=2))
    batch = stream.batch(0)
    s1, m1 = jax.jit(make_train_step(cfg, opt, accum_steps=1))(state, batch)
    s2, m2 = jax.jit(make_train_step(cfg, opt, accum_steps=4))(state, batch)
    # same gradient mean => same update (tolerances: accumulation reorders sums)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-5)


def test_data_pipeline_deterministic_and_resumable():
    dc = DataConfig(vocab_size=512, seq_len=32, global_batch=8, seed=7)
    s1, s2 = SyntheticLMStream(dc), SyntheticLMStream(dc)
    for step in (0, 5, 1000):
        np.testing.assert_array_equal(np.asarray(s1.batch(step)["tokens"]),
                                      np.asarray(s2.batch(step)["tokens"]))
    # host sharding: different hosts see different data
    d2 = DataConfig(vocab_size=512, seq_len=32, global_batch=8, n_hosts=2, host_id=1, seed=7)
    assert not np.array_equal(np.asarray(SyntheticLMStream(d2).batch(0)["tokens"]),
                              np.asarray(s1.batch(0)["tokens"]))


def test_prefetch_iterator_order():
    dc = DataConfig(vocab_size=128, seq_len=8, global_batch=4, seed=3)
    stream = SyntheticLMStream(dc)
    it = PrefetchIterator(stream, start_step=10)
    try:
        for expect in (10, 11, 12):
            step, batch = next(it)
            assert step == expect
            np.testing.assert_array_equal(np.asarray(batch["tokens"]),
                                          np.asarray(stream.batch(expect)["tokens"]))
    finally:
        it.close()


class TestGradCompression:
    def test_compress_leaf_error_feedback(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        err = jnp.zeros_like(g)
        q, scale, new_err = compression.compress_leaf(g, err)
        assert q.dtype == jnp.int8
        # dequantized + error == original exactly (EF invariant)
        np.testing.assert_allclose(
            np.asarray(q, np.float32) * float(scale) + np.asarray(new_err),
            np.asarray(g), rtol=1e-5, atol=1e-6)

    def test_error_feedback_reduces_bias(self):
        """Accumulated EF error stays bounded; naive quantization drifts."""
        g = jax.random.normal(jax.random.PRNGKey(1), (512,)) * 0.01
        err = jnp.zeros_like(g)
        total_sent = jnp.zeros_like(g)
        for _ in range(50):
            q, scale, err = compression.compress_leaf(g, err)
            total_sent = total_sent + q.astype(jnp.float32) * scale
        # mean transmitted ~= g (error feedback recovers the small signal)
        np.testing.assert_allclose(np.asarray(total_sent / 50), np.asarray(g),
                                   rtol=0.02, atol=5e-5)

    def test_compressed_psum_single_device(self):
        """shard_map over a 1-device mesh: compression must be ~lossless-mean."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        mesh = jax.make_mesh((1,), ("data",))
        grads = {"w": jax.random.normal(jax.random.PRNGKey(2), (64, 64))}
        err = compression.init_error_buffer(grads)

        def f(g, e):
            return compression.psum_compressed(g, e, "data")

        out, new_err = jax.jit(shard_map(
            f, mesh=mesh,
            in_specs=(P(), P()), out_specs=(P(), P()), check_rep=False,
        ))(grads, err)
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(grads["w"]), rtol=2e-2, atol=2e-2)
