"""Cross-kernel conformance suite: the kernel-equivalence contract.

One parametrized harness asserting every kernel registered in
``repro.plan.registry`` agrees on a shared grid of shapes x block densities
x dtypes, through the same entry point serving uses
(``bitlinear.apply_frozen(plan=<kernel>)``), in both realizations (Pallas
interpret mode and the traceable jnp spelling).  This replaces the ad-hoc
per-kernel equality checks that used to be scattered across
``test_kernels.py`` / ``test_sparse.py``.

The contract, per kernel, lives in ``KERNEL_CASES``:

* ``exact=True`` — the int8-pipeline family (``tsar_mxu`` and the sparse
  kernels): output BIT-IDENTICAL to the quantized int32-accumulation oracle
  (``ref.quantized_matmul_ref``), and the Pallas kernel bit-identical to the
  jnp spelling.  Zero-skipping (dead weight blocks, dead activation tiles)
  must not change a single bit.
* ``exact=False`` — the fp-math family (``tsar_lut``'s LUT identity,
  ``memory_lut``'s DRAM gather, ``dense``'s dequantized matmul): tight
  allclose against the fp oracle (``ref.ternary_matmul_ref``).

``test_registry_has_conformance_row`` (unmarked — runs in the fast lane)
pins the table to the registry: a kernel added without a conformance row
fails it.  The grid itself is marked ``conformance`` and runs in its own CI
lane (see ``.github/workflows/ci.yml``).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitlinear, ternary
from repro.kernels import ref
from repro.plan import registry
from repro.sparse import format as sparse_format

# kernel -> contract.  exact: bit-identical to the quantized int8 oracle
# (and Pallas == jnp); pallas: the lowering binds a Pallas kernel off-TPU
# under interpret=True.  EVERY registry kernel needs a row (enforced below).
KERNEL_CASES = {
    "tsar_mxu": dict(exact=True, pallas=True),
    "tsar_lut": dict(exact=False, pallas=True),
    "tsar_sparse": dict(exact=True, pallas=True),
    "tsar_sparse_padded": dict(exact=True, pallas=True),
    "memory_lut": dict(exact=False, pallas=False),
    "dense": dict(exact=False, pallas=False),
}

# (n, k, m): one block-aligned shape, one ragged K/M (exercises zero-padded
# plane tails, partial edge blocks, and LUT pad blocks).
SHAPES = [(4, 256, 256), (3, 300, 200)]

# Target LIVE-BLOCK fractions: empty pool, BitNet-ish, nearly dense, fully
# dense (every block live, only unstructured zeros).
DENSITIES = (0.0, 1.0 / 3.0, 0.95, 1.0)

BK = BM = 128   # sparse tiling for the grid (small shapes)


@functools.lru_cache(maxsize=None)
def _case(shape, density):
    """One frozen layer carrying EVERY kernel's encoding + an activation."""
    n, k, m = shape
    seed = int(n * 1009 + k * 13 + m * 7 + density * 997)
    t = sparse_format.random_block_sparse_ternary(
        jax.random.PRNGKey(seed), (k, m), bk=BK, bm=BM,
        p_zero_block=1.0 - density)
    scale = jax.random.uniform(jax.random.PRNGKey(seed + 1), (m,),
                               minval=0.25, maxval=2.0)
    idx_pos, idx_zero = ternary.pack_indices(t, 4)
    fz = bitlinear.FrozenBitLinear(
        packed=ternary.pack(t.astype(jnp.float32), scale),
        idx_pos=idx_pos, idx_zero=idx_zero, c=4,
        sparse=sparse_format.from_ternary(t, scale, bk=BK, bm=BM),
        padded=sparse_format.pad_from_ternary(t, scale, bk=BK, bm=BM),
        density=float(ternary.ternary_density(t)),
        block_density=None,
    )
    x = jax.random.normal(jax.random.PRNGKey(seed + 2), (n, k))
    return fz, t, x


def test_registry_has_conformance_row():
    """A kernel registered without a conformance row fails here — the suite
    IS the kernel-equivalence contract, so coverage is not optional."""
    assert set(KERNEL_CASES) == set(registry.names()), (
        "conformance table out of sync with plan/registry: "
        f"missing rows {set(registry.names()) - set(KERNEL_CASES)}, "
        f"stale rows {set(KERNEL_CASES) - set(registry.names())}")


def test_static_inventory_matches_imported_registry():
    """The static-analysis inventory (what `python -m repro.analysis`
    cross-checks in CI) must see the same kernel list the imported registry
    exposes — a registration idiom the AST scan can't follow would
    otherwise let the lint lane and this suite silently disagree."""
    from pathlib import Path

    from repro.analysis import inventory

    repo_root = Path(__file__).resolve().parents[1]
    assert set(inventory.registry_kernel_names(repo_root)) \
        == set(registry.names()), (
        "repro.analysis.inventory parsed a different kernel set than the "
        "imported registry registers — update inventory's idiom handling")
    rows = inventory.conformance_kernel_rows(repo_root)
    assert set(rows) == set(KERNEL_CASES)


def test_every_kernel_supported_by_conformance_fixture():
    """The fixture layer carries every encoding, so no kernel can silently
    skip the grid via its supports() gate."""
    fz, _, _ = _case(SHAPES[0], DENSITIES[1])
    assert set(registry.available(fz)) == set(registry.names())


@pytest.mark.conformance
@pytest.mark.parametrize("density", DENSITIES)
@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: "x".join(map(str, s)))
@pytest.mark.parametrize("kernel", sorted(KERNEL_CASES))
def test_kernel_conformance(kernel, shape, density):
    spec = KERNEL_CASES[kernel]
    fz, t, x = _case(shape, density)

    exact_oracle = ref.quantized_matmul_ref(x, fz.packed)
    fp_oracle = ref.ternary_matmul_ref(x, t, fz.packed.scale)

    y_jnp = bitlinear.apply_frozen(fz, x, plan=kernel)
    if spec["exact"]:
        np.testing.assert_array_equal(np.asarray(y_jnp),
                                      np.asarray(exact_oracle))
    else:
        np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(fp_oracle),
                                   rtol=1e-4, atol=2e-3)

    if spec["pallas"]:
        y_pal = bitlinear.apply_frozen(fz, x, plan=kernel, interpret=True)
        if spec["exact"]:
            np.testing.assert_array_equal(np.asarray(y_pal),
                                          np.asarray(y_jnp))
        else:
            np.testing.assert_allclose(np.asarray(y_pal),
                                       np.asarray(fp_oracle),
                                       rtol=1e-4, atol=2e-3)


@pytest.mark.conformance
@pytest.mark.parametrize("kernel", sorted(KERNEL_CASES))
def test_kernel_conformance_bf16(kernel):
    """bf16 activations through every kernel — BOTH realizations (the jnp
    spelling and, where bound, the Pallas interpret path): the int8 family
    stays bit-identical to the oracle run through the same cast chain; the
    fp family stays within bf16 tolerance."""
    spec = KERNEL_CASES[kernel]
    fz, t, x = _case(SHAPES[0], DENSITIES[1])
    xb = x.astype(jnp.bfloat16)

    realizations = [bitlinear.apply_frozen(fz, xb, plan=kernel)]
    if spec["pallas"]:
        realizations.append(
            bitlinear.apply_frozen(fz, xb, plan=kernel, interpret=True))
    for y in realizations:
        assert y.dtype == jnp.bfloat16
        if spec["exact"]:
            want = ref.quantized_matmul_ref(xb, fz.packed).astype(jnp.bfloat16)
            np.testing.assert_array_equal(np.asarray(y), np.asarray(want))
        else:
            want = ref.ternary_matmul_ref(xb, t, fz.packed.scale)
            np.testing.assert_allclose(
                np.asarray(y, np.float32), np.asarray(want, np.float32),
                rtol=2e-2, atol=2e-1)
