"""Chunked-prefill continuous batching: stall-freedom, equivalence with the
whole-prompt prefill path, packed/qat agreement, per-slot sampling."""
import jax
import numpy as np
import pytest

import repro.configs as configs
from repro.models import model_zoo as zoo
from repro.serving import Request, ServingEngine

CHUNK = 8


@pytest.fixture(scope="module")
def model():
    cfg = configs.get("bitnet-2b-4t").reduced()
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mixed_reqs(maxnew=6):
    """Mixed prompt lengths: shorter than, equal to, and spanning many chunks."""
    rng = np.random.default_rng(7)
    lens = [3, CHUNK, 21, 40]
    return [Request(uid=i, prompt=rng.integers(0, 100, size=s).astype(np.int32),
                    max_new_tokens=maxnew)
            for i, s in enumerate(lens)]


def test_chunked_matches_whole_prompt_prefill(model):
    """(a) Chunked prefill must be token-identical to the whole-prompt
    reference path — same per-slot positions, same cache contents."""
    cfg, params = model
    o_chunk = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                            prefill_chunk=CHUNK).run(_mixed_reqs())
    o_whole = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                            policy="whole").run(_mixed_reqs())
    for a, b in zip(o_chunk, o_whole):
        assert a.out_tokens == b.out_tokens, (a.uid, a.out_tokens, b.out_tokens)


def test_long_prompt_does_not_stall_decode(model):
    """(b) A long prompt admitted mid-stream advances one bounded chunk per
    step; running requests keep emitting one token per step throughout."""
    cfg, params = model
    eng = ServingEngine(cfg, params, max_len=128, batch_slots=2,
                        prefill_chunk=CHUNK)
    a = Request(uid=0, prompt=np.arange(4, dtype=np.int32), max_new_tokens=40)
    eng.submit(a)
    while len(a.out_tokens) < 4:          # A reaches steady-state decode
        eng.step()

    long_prompt = np.arange(5 * CHUNK, dtype=np.int32) % 97
    b = Request(uid=1, prompt=long_prompt, max_new_tokens=4)
    eng.submit(b)
    stalls = 0
    while not b.out_tokens:               # B still prefilling
        before = len(a.out_tokens)
        assert eng.step()
        if not a.done and len(a.out_tokens) == before:
            stalls += 1
    assert stalls == 0, "decode stalled during chunked prefill"
    # Whole-prompt prefills never ran, and every step's real work stayed
    # within the chunk + one-decode-token-per-slot budget.
    assert eng.stats["whole_prefills"] == 0
    assert eng.max_step_tokens() <= CHUNK + eng.slots
    eng.run([])  # drain


def test_step_budget_under_mixed_load(model):
    """No engine step ever exceeds prefill_chunk + slots real tokens — the
    whole-prompt prefill spike (40-token calls in the seed engine) is gone."""
    cfg, params = model
    eng = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                        prefill_chunk=CHUNK)
    eng.run(_mixed_reqs())
    assert eng.stats["whole_prefills"] == 0
    assert eng.max_step_tokens() <= CHUNK + eng.slots


def test_more_requests_than_slots_all_complete(model):
    """(c) Oversubscription: every request finishes with full output and
    latency stamps."""
    cfg, params = model
    eng = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                        prefill_chunk=CHUNK)
    reqs = _mixed_reqs() + _mixed_reqs()
    for i, r in enumerate(reqs):
        r.uid = i
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == r.max_new_tokens for r in reqs)
    assert all(r.ttft is not None and r.ttft >= 0 for r in reqs)
    assert all(r.tpot is not None and r.tpot >= 0 for r in reqs)


def test_packed_equals_qat_chunked(model):
    """(d) The 2-bit packed storage format must not change chunked-prefill
    outputs (identical quantized math)."""
    cfg, params = model
    o_qat = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                          prefill_chunk=CHUNK).run(_mixed_reqs())
    o_pak = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                          prefill_chunk=CHUNK, packed=True).run(_mixed_reqs())
    for a, b in zip(o_qat, o_pak):
        assert a.out_tokens == b.out_tokens


def test_per_slot_temperature_sampling(model):
    """Decode sampling honors each request's temperature (seed engine bug:
    step() sampled every slot greedily).  A greedy request batched next to a
    stochastic one must still produce its solo greedy tokens."""
    cfg, params = model
    greedy_solo = ServingEngine(cfg, params, max_len=64, batch_slots=2).run(
        [Request(uid=0, prompt=np.arange(5, dtype=np.int32), max_new_tokens=6)])

    eng = ServingEngine(cfg, params, max_len=64, batch_slots=2, seed=3)
    mixed = [
        Request(uid=0, prompt=np.arange(5, dtype=np.int32), max_new_tokens=6),
        Request(uid=1, prompt=np.arange(7, dtype=np.int32), max_new_tokens=6,
                temperature=5.0),
    ]
    eng.run(mixed)
    assert mixed[0].out_tokens == greedy_solo[0].out_tokens
    assert all(0 <= t < cfg.vocab_size for t in mixed[1].out_tokens)

    # High temperature must actually reach the sampler: across seeds the
    # stochastic request's tokens should not all collapse to the greedy run.
    greedy_ref = ServingEngine(cfg, params, max_len=64, batch_slots=2).run(
        [Request(uid=1, prompt=np.arange(7, dtype=np.int32), max_new_tokens=6)]
    )[0].out_tokens
    draws = []
    for seed in range(4):
        e = ServingEngine(cfg, params, max_len=64, batch_slots=2, seed=seed)
        r = e.run([Request(uid=1, prompt=np.arange(7, dtype=np.int32),
                           max_new_tokens=6, temperature=5.0)])[0]
        draws.append(r.out_tokens)
    assert any(d != greedy_ref for d in draws)


def test_oversized_prompts_finished_ignored_not_fatal(model):
    """Prompts that can never fit are marked done with no output (vLLM's
    finished-ignored) and must not block later valid requests — even when
    there are more oversized requests than slots."""
    cfg, params = model
    eng = ServingEngine(cfg, params, max_len=32, batch_slots=2, prefill_chunk=8)
    reqs = [Request(uid=i, prompt=np.arange(100, dtype=np.int32) % 50,
                    max_new_tokens=4) for i in range(3)]
    reqs.append(Request(uid=9, prompt=np.arange(5, dtype=np.int32),
                        max_new_tokens=4))
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert [len(r.out_tokens) for r in reqs] == [0, 0, 0, 4]


def test_unservable_request_raises_not_hangs(model):
    """A pool smaller than the admission gate is a config error: run() must
    raise, not busy-loop."""
    cfg, params = model
    eng = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                        prefill_chunk=16, block_size=16, kv_blocks=2)
    with pytest.raises(RuntimeError, match="admitted"):
        eng.run([Request(uid=0, prompt=np.arange(17, dtype=np.int32),
                         max_new_tokens=4)])


def test_chunked_policy_refused_for_recurrent_families():
    """Explicitly requesting chunked prefill where the SSM recurrence cannot
    chunk must fail loudly, not silently downgrade to whole."""
    cfg = configs.get("mamba2-780m").reduced()
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="chunked"):
        ServingEngine(cfg, params, policy="chunked")


def test_preemption_recovers(model):
    """A deliberately tiny block pool forces recompute-preemption; everything
    still completes and greedy outputs match an unconstrained engine."""
    cfg, params = model
    reqs = lambda: [
        Request(uid=i, prompt=np.arange(10 + 3 * i, dtype=np.int32) % 89,
                max_new_tokens=8)
        for i in range(3)
    ]
    roomy = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                          prefill_chunk=CHUNK).run(reqs())
    # 9 real blocks of 4 tokens: two growing requests must collide.
    tight_eng = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                              prefill_chunk=CHUNK, block_size=4, kv_blocks=10)
    tight = tight_eng.run(reqs())
    assert all(r.done for r in tight)
    for a, b in zip(roomy, tight):
        assert a.out_tokens == b.out_tokens
