"""Golden tests for the repro.analysis invariant linter.

Each rule gets a positive fixture (the violation fires, with an exact
count so new false positives are loud) and a negative fixture encoding
the repo idioms the rule must NOT flag (constant-folded numpy tables,
static shape queries, the tracer alias + early-exit guard spellings).
Fixture snippets live in ``tests/fixtures/analysis/`` — excluded from
pytest collection (pytest.ini norecursedirs) because they contain
deliberate violations and fake project trees.
"""
import json
import shutil
from pathlib import Path

import pytest

from repro.analysis import baseline as baseline_mod
from repro.analysis.cli import main as cli_main
from repro.analysis.engine import all_rules, analyze

FIXTURES = Path(__file__).parent / "fixtures" / "analysis"
REPO_ROOT = Path(__file__).resolve().parents[1]


def _tree(root, **files):
    """Build a mini project: {dest relpath: fixture filename}."""
    for dest, fixture in files.items():
        out = root / dest
        out.parent.mkdir(parents=True, exist_ok=True)
        shutil.copyfile(FIXTURES / fixture, out)
    return root


def _tree_from(root, fixture_dir):
    shutil.copytree(FIXTURES / fixture_dir, root, dirs_exist_ok=True)
    return root


# (rule, positive fixture, expected findings, negative fixture)
GOLDEN = [
    ("jit-purity", "jit_purity_bad.py", 5, "jit_purity_ok.py"),
    ("retrace-hazard", "retrace_hazard_bad.py", 3, "retrace_hazard_ok.py"),
    ("traced-branch", "traced_branch_bad.py", 2, "traced_branch_ok.py"),
    ("tracer-guard", "tracer_guard_bad.py", 2, "tracer_guard_ok.py"),
]


@pytest.mark.parametrize("rule,bad,count,_ok", GOLDEN,
                         ids=[g[0] for g in GOLDEN])
def test_rule_fires_on_positive_fixture(tmp_path, rule, bad, count, _ok):
    _tree(tmp_path, **{f"src/{bad}": bad})
    found = [f for f in analyze(tmp_path) if f.rule == rule]
    assert len(found) == count, "\n".join(f.format() for f in found)
    assert all(f.path == f"src/{bad}" and f.line > 0 for f in found)


@pytest.mark.parametrize("rule,_bad,_count,ok", GOLDEN,
                         ids=[g[0] for g in GOLDEN])
def test_rule_quiet_on_negative_fixture(tmp_path, rule, _bad, _count, ok):
    _tree(tmp_path, **{f"src/{ok}": ok})
    found = analyze(tmp_path)
    assert found == [], "\n".join(f.format() for f in found)


def test_flat_step_is_name_seeded_root(tmp_path):
    """``flat_step`` joins ``chunk_step`` as a name-seeded jit root: the
    flat serving entry point is jitted through an engine lambda (an
    attribute-on-call-result the resolver can't follow), so jit-purity
    reachability must come from ROOT_FUNCTION_NAMES — this pins that the
    flat refactor did not shrink what the lint lane covers."""
    _tree(tmp_path, **{"src/flat_step_root_bad.py": "flat_step_root_bad.py"})
    found = [f for f in analyze(tmp_path) if f.rule == "jit-purity"]
    assert len(found) == 1, "\n".join(f.format() for f in found)
    assert found[0].path == "src/flat_step_root_bad.py"
    assert "print" in found[0].message


def test_registry_completeness_positive(tmp_path):
    _tree_from(tmp_path, "registry_bad")
    found = [f for f in analyze(tmp_path)
             if f.rule == "registry-completeness"]
    msgs = "\n".join(f.format() for f in found)
    assert len(found) == 4, msgs
    assert "never register()-ed" in msgs          # Ghost defined, unused
    assert "no KERNEL_CASES row" in msgs          # dense registered, unrowed
    assert "stale conformance row" in msgs        # 'stale' rows a ghost
    assert "does not define it" in msgs           # ref.missing_ref


def test_registry_completeness_negative(tmp_path):
    _tree_from(tmp_path, "registry_ok")
    found = analyze(tmp_path)
    assert found == [], "\n".join(f.format() for f in found)


def test_schema_drift_positive(tmp_path):
    _tree_from(tmp_path, "schema_bad")
    found = [f for f in analyze(tmp_path) if f.rule == "schema-drift"]
    msgs = "\n".join(f.format() for f in found)
    assert len(found) == 2, msgs
    assert "bare int literal" in msgs
    assert "doc cites OBS_TRACE schema v2" in msgs


def test_schema_drift_negative(tmp_path):
    _tree_from(tmp_path, "schema_ok")
    found = analyze(tmp_path)
    assert found == [], "\n".join(f.format() for f in found)


def test_line_suppression(tmp_path):
    _tree(tmp_path, **{"src/suppressed.py": "suppressed.py"})
    found = analyze(tmp_path)
    assert [f.rule for f in found] == ["jit-purity"]
    assert "print" in found[0].message    # the H.count store was ignored


def test_file_suppression(tmp_path):
    _tree(tmp_path, **{"src/suppressed_file.py": "suppressed_file.py"})
    assert analyze(tmp_path) == []


def test_parse_error_is_a_finding(tmp_path):
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "broken.py").write_text("def f(:\n")
    found = analyze(tmp_path)
    assert [f.rule for f in found] == ["parse-error"]


def test_baseline_round_trip(tmp_path):
    root = _tree(tmp_path / "proj",
                 **{"src/jit_purity_bad.py": "jit_purity_bad.py"})
    findings = analyze(root)
    assert findings
    bl = tmp_path / "baseline.json"
    baseline_mod.save(bl, findings)
    keys = baseline_mod.load(bl)
    new, old, expired = baseline_mod.split(findings, keys)
    assert not new and not expired and len(old) == len(findings)
    # everything fixed: every baseline entry expires (the file can only
    # shrink honestly)
    new, old, expired = baseline_mod.split([], keys)
    assert not new and not old and len(expired) == len(set(keys))


def test_cli_baseline_gate_and_update(tmp_path):
    root = _tree(tmp_path,
                 **{"src/jit_purity_bad.py": "jit_purity_bad.py"})
    bl = str(tmp_path / "analysis-baseline.json")
    args = ["--root", str(root), "--baseline", bl]
    assert cli_main(args) == 1                       # new findings
    assert cli_main(args + ["--update-baseline"]) == 0
    assert cli_main(args) == 0                       # all baselined
    (root / "src" / "jit_purity_bad.py").write_text("X = 1\n")
    assert cli_main(args) == 1                       # expired entries
    assert cli_main(args + ["--update-baseline"]) == 0
    assert cli_main(args) == 0
    assert baseline_mod.load(bl) == []


def test_cli_json_report_schema(tmp_path, capsys):
    root = _tree(tmp_path,
                 **{"src/jit_purity_bad.py": "jit_purity_bad.py"})
    rc = cli_main(["--root", str(root), "--json"])
    doc = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert doc["report_version"] == 1
    assert doc["ok"] is False
    assert doc["counts"]["total"] == doc["counts"]["new"] \
        == len(doc["findings"])
    assert doc["counts"]["baselined"] == doc["counts"]["expired"] == 0
    assert {f["rule"] for f in doc["findings"]} == {"jit-purity"}
    assert all(set(f) == {"rule", "path", "line", "message", "baselined"}
               for f in doc["findings"])
    assert {r["name"] for r in doc["rules"]} \
        == {r.name for r in all_rules()}


def test_cli_rejects_unknown_rule(tmp_path, capsys):
    assert cli_main(["--root", str(tmp_path), "--rule", "nope"]) == 2
    assert "unknown rule" in capsys.readouterr().err


def test_repo_tree_is_clean():
    """The shipped baseline is empty: the live tree must stay finding-free
    (fix or suppress in source, never park — docs/static-analysis.md)."""
    findings = analyze(REPO_ROOT)
    assert findings == [], "\n".join(f.format() for f in findings)
    assert baseline_mod.load(REPO_ROOT / "analysis-baseline.json") == []
