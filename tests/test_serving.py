"""Serving engine: batched prefill/decode, continuous batching, packed-weight
equivalence, frontend stubs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import model_zoo as zoo
from repro.serving import Request, ServingEngine, freeze_params
from repro.serving.engine import packed_fraction


@pytest.fixture(scope="module")
def model():
    cfg = configs.get("bitnet-2b-4t").reduced()
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _reqs(n, maxnew=5):
    return [Request(uid=i, prompt=np.arange(4 + i) % 100, max_new_tokens=maxnew)
            for i in range(n)]


def test_greedy_decode_deterministic(model):
    cfg, params = model
    out1 = ServingEngine(cfg, params, max_len=48, batch_slots=2).run(_reqs(2))
    out2 = ServingEngine(cfg, params, max_len=48, batch_slots=2).run(_reqs(2))
    for a, b in zip(out1, out2):
        assert a.out_tokens == b.out_tokens


def test_packed_equals_qat_outputs(model):
    """The 2-bit packed path must produce the same tokens as latent weights
    (identical quantized math, only the storage format differs)."""
    cfg, params = model
    o_qat = ServingEngine(cfg, params, max_len=48, batch_slots=2).run(_reqs(3))
    o_pak = ServingEngine(cfg, params, max_len=48, batch_slots=2, packed=True).run(_reqs(3))
    for a, b in zip(o_qat, o_pak):
        assert a.out_tokens == b.out_tokens


def test_continuous_batching_more_requests_than_slots(model):
    cfg, params = model
    eng = ServingEngine(cfg, params, max_len=48, batch_slots=2)
    reqs = eng.run(_reqs(5))
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 5 for r in reqs)


def test_freeze_params_structure(model):
    cfg, params = model
    frozen = freeze_params(params)
    flat = jax.tree_util.tree_flatten_with_path(frozen)[0]
    names = {getattr(k, "key", "") for path, _ in flat for k in path}
    assert "sign" in names and "zero" in names
    assert packed_fraction(frozen) > 0.5  # most weight bytes now 2-bit

    # matmul results preserved through packing (same ternary values)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, cfg.d_model))
    batch = {"tokens": jnp.zeros((2, 4), jnp.int32)}
    l1, _ = zoo.forward(cfg, params, batch, train=False)
    l2, _ = zoo.forward(cfg, frozen, batch, train=False)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ["whisper-tiny", "llava-next-mistral-7b", "mamba2-780m"])
def test_frontend_and_ssm_serving(arch):
    cfg = configs.get(arch).reduced()
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_len=64, batch_slots=2)
    reqs = eng.run(_reqs(2, maxnew=4))
    assert all(r.done and len(r.out_tokens) == 4 for r in reqs)
