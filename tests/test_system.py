"""End-to-end system behaviour: the paper's claims reproduced at test scale.

These tests assert the three headline claims of T-SAR (Sec. IV):
  1. end-to-end speedup of the T-SAR dataflow over the memory-LUT baseline,
  2. the memory-traffic reduction mechanism (2-bit weights, no stored TLUT),
  3. adaptive AP/OP kernel selection per layer shape.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.core import dataflow, lut, ternary
from repro.models import model_zoo as zoo
from repro.serving import Request, ServingEngine


def _time(fn, *args, reps=3):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps


class TestClaim1Speedup:
    def test_kernel_variants_agree_and_serving_speedup(self):
        """Claim 1, in the form this substrate can honestly assert.

        The paper's kernel-level GEMV win REQUIRES its ISA extension (in-
        register LUT generation) — on stock CPU kernels, LUT methods beat
        decode-and-matmul, which is the paper's own motivation (T-MAC/TL-2
        exist precisely because of it).  Our hardware answer is the Pallas
        TPU kernel (validated in test_kernels.py) + the roofline analysis.
        What IS measurable here end-to-end: the deployment-level decode win
        of the packed 2-bit format in the serving engine, with identical
        outputs (weights are session constants there, so XLA pre-decodes —
        the legitimate CPU-fallback serving mode).
        """
        # (a) all kernel spellings agree numerically on the paper's shape
        k, m, c = 2560, 6912, 4
        t = ternary.random_ternary(jax.random.PRNGKey(0), (k, m))
        a = jax.random.normal(jax.random.PRNGKey(1), (1, k))
        li = lut.ternary_lut_indices(t, c)
        sc = jnp.ones((m,))
        assert dataflow.select_kernel(1, k, m).kernel == "tsar_mxu"
        y_int = lut.bitlinear_matmul_exact_int(a, t, sc)
        y_fast = lut.bitlinear_matmul_fast(a, t, sc)
        y_base = lut.memory_lut_matmul(a, li, c)
        np.testing.assert_array_equal(np.asarray(y_int), np.asarray(y_fast))
        # y_base is fp-exact, y_int carries int8 activation-quant error
        # (absmax step ~2*absmax/255 accumulated over K=2560 -> few units)
        np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_base),
                                   rtol=0.1, atol=4.0)

        # (b) serving engine: packed 2-bit weights at least match latent-fp
        # decode throughput with identical tokens (measured ~1.7x faster).
        cfg = configs.get("bitnet-2b-4t").reduced()
        params = zoo.init_params(cfg, jax.random.PRNGKey(0))
        reqs = lambda: [Request(uid=i, prompt=np.arange(6), max_new_tokens=6)
                        for i in range(3)]
        e_lat = ServingEngine(cfg, params, max_len=48, batch_slots=2)
        e_pak = ServingEngine(cfg, params, max_len=48, batch_slots=2, packed=True)
        # Warm both engines' prefill/decode executables first: the initial
        # pure-decode step pays its XLA compile inside decode_s, and compile
        # latency scales with how loaded the test process already is — which
        # is noise, not the steady-state decode cadence this asserts.
        e_lat.run(reqs())
        e_pak.run(reqs())
        for e in (e_lat, e_pak):
            e.stats.update(decode_s=0.0, decode_tokens=0)
        r_lat = e_lat.run(reqs())
        r_pak = e_pak.run(reqs())
        assert [r.out_tokens for r in r_lat] == [r.out_tokens for r in r_pak]
        assert e_pak.throughput() > 0.8 * e_lat.throughput(), (
            e_pak.throughput(), e_lat.throughput())


class TestClaim2MemoryTraffic:
    def test_weight_bytes_8x_smaller_than_bf16(self):
        cfg = configs.get("bitnet-2b-4t").reduced()
        params = zoo.init_params(cfg, jax.random.PRNGKey(0))
        from repro.serving.engine import freeze_params
        frozen = freeze_params(params)

        def linear_bytes(tree, keys):
            tot = 0
            for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
                names = [getattr(kk, "key", "") for kk in path]
                if any(n in keys for n in names):
                    tot += leaf.size * leaf.dtype.itemsize
            return tot

        dense_bytes = linear_bytes(params, {"w"}) / 2       # as bf16
        packed_bytes = linear_bytes(frozen, {"sign", "zero"})
        assert packed_bytes * 7 < dense_bytes  # ~8x (scales excluded)

    def test_no_lut_tensor_survives_in_tsar_graph(self):
        """In the T-SAR jitted graph the LUT is an internal value, never an
        input — the in-register residency property."""
        k, m, c = 256, 128, 4
        t = ternary.random_ternary(jax.random.PRNGKey(0), (k, m))
        ip, iz = ternary.pack_indices(t, c)
        a = jax.random.normal(jax.random.PRNGKey(1), (1, k))
        lowered = jax.jit(lambda a: lut.tsar_lut_matmul(a, ip, iz, c)).lower(a)
        # inputs: activations only (weights are closure constants) — no 3^c
        # or 2^c-entry table is an argument.
        txt = lowered.as_text()
        assert f"[{3**c}" not in txt.split("ENTRY")[0]


class TestClaim3Adaptivity:
    def test_plan_switches_with_shape(self):
        gemv = dataflow.select_kernel(1, 4096, 14336)
        gemm = dataflow.select_kernel(512, 4096, 14336)
        assert gemv.dataflow != gemm.dataflow

    def test_serving_end_to_end(self):
        cfg = configs.get("bitnet-2b-4t").reduced()
        params = zoo.init_params(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, params, max_len=48, batch_slots=2, packed=True)
        reqs = eng.run([Request(uid=i, prompt=np.arange(6), max_new_tokens=4)
                        for i in range(3)])
        assert all(r.done for r in reqs)
        assert eng.throughput() > 0
