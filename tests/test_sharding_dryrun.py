"""Sharding rules + mini dry-run on a small in-process mesh.

The full 512-device production dry-run lives in src/repro/launch/dryrun.py
(it must own the XLA device-count flag); here we verify the same machinery —
spec construction, lowering, compile, roofline extraction — on a small mesh
that fits the test process's single real device count via subprocess-free
checks of pure spec logic, plus HLO parsing unit tests.
"""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

import repro.configs as configs
from repro.launch import roofline as rl
from repro.models import model_zoo as zoo
from repro.train import sharding


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _mesh16():
    # Abstract 16x16 mesh for spec logic (never used to place data).
    import numpy as np
    devs = np.array(jax.devices() * 256)[:256].reshape(16, 16)
    return Mesh(devs, ("data", "model"))


class TestParamSpecs:
    def test_rules(self):
        cfg = configs.get("deepseek-moe-16b")
        params = zoo.param_specs(cfg)
        specs = sharding.param_specs(params)
        # attention col-parallel
        assert specs["blocks"]["attn"]["wq"]["w"][-1] == "model"
        assert specs["blocks"]["attn"]["wo"]["w"][-2] == "model"
        # experts sharded on E
        assert specs["blocks"]["moe"]["w_gate"]["w"][-3] == "model"
        # router replicated
        assert all(s is None for s in specs["blocks"]["moe"]["router"]["wd"])
        # embed vocab-sharded
        assert specs["embed"][0] == "model"

    def test_sanitize_drops_nondivisible(self):
        m = _mesh16()
        spec = sharding.sanitize_spec(m, (51865, 384), P("model", None))
        assert spec == P(None, None)
        spec = sharding.sanitize_spec(m, (53248, 384), P("model", None))
        assert spec == P("model", None)

    def test_fsdp_placeholder_resolution(self):
        m = _mesh16()
        spec = sharding.sanitize_spec(m, (64, 128, 256), P(None, "__data__", "model"))
        assert spec == P(None, ("data",), "model")

    def test_cache_specs(self):
        m = _mesh16()
        # attention cache (L,B,S,Hk,Dh): batch on data, heads on model
        # (PartitionSpec normalizes 1-tuples to scalars)
        s = sharding.cache_spec(m, (32, 128, 4096, 16, 128), 16)
        assert s[1] in ("data", ("data",)) and s[3] == "model"
        # Hkv=4 < 16: falls back to SEQUENCE sharding (split-KV decode;
        # Dh-sharding would force full-cache all-gathers, see §Perf iter 3)
        s = sharding.cache_spec(m, (32, 128, 4096, 4, 256), 4)
        assert s[3] is None and s[2] == "model"
        # batch=1 long-context: sequence-parallel
        s = sharding.cache_spec(m, (32, 1, 524288, 4, 256), 4)
        assert s[2] in ("data", ("data",))


class TestHLOParsing:
    def test_collective_bytes(self):
        hlo = """
  %all-reduce = f32[1024,1024]{1,0} all-reduce(%dot), channel_id=1
  %ag = bf16[64,512]{1,0} all-gather(%p0), dimensions={0}
  %ar-start = f32[16]{0} all-reduce-start(%x), channel_id=3
  %ar-done = f32[16]{0} all-reduce-done(%ar-start)
"""
        out = rl.collective_bytes_from_hlo(hlo)
        assert out["all-reduce"] == 1024 * 1024 * 4 + 16 * 4
        assert out["all-gather"] == 64 * 512 * 2
        assert out["count"] == 3

    def test_tuple_shapes(self):
        hlo = "%x = (f32[8,8]{1,0}, f32[4]{0}) all-reduce(%a, %b), channel_id=9"
        out = rl.collective_bytes_from_hlo(hlo)
        assert out["all-reduce"] == 8 * 8 * 4 + 4 * 4

    def test_roofline_bound_selection(self):
        r = rl.analyze("a", "s", "single", 256,
                       {"flops": 1e12, "bytes accessed": 1e9}, "", 6e14)
        assert r.bound == "compute"
        assert r.compute_s == pytest.approx(1e12 / rl.PEAK_FLOPS_BF16)


class TestMiniLower:
    """Lower + compile a reduced model on a 1x1 mesh — same code path as the
    production dry-run, exercisable inside pytest."""

    def test_train_cell_lowers(self, mesh):
        from repro.launch.dryrun import cost_dict, lower_cell
        cfg = configs.get("gemma2-2b").reduced()
        shape = configs.ShapeConfig("t", 64, 4, "train")
        lowered, meta = lower_cell(cfg, shape, mesh, fsdp=False)
        compiled = lowered.compile()
        assert meta["mode"] == "train_step"
        assert cost_dict(compiled)["flops"] > 0

    def test_decode_cell_lowers(self, mesh):
        from repro.launch.dryrun import lower_cell
        cfg = configs.get("gemma2-2b").reduced()
        shape = configs.ShapeConfig("d", 64, 4, "decode")
        lowered, meta = lower_cell(cfg, shape, mesh, fsdp=False)
        compiled = lowered.compile()
        assert meta["mode"] == "serve_step"
        hlo = compiled.as_text()
        assert len(hlo) > 0

    def test_packed_weights_shrink_arguments(self, mesh):
        """The T-SAR serve path must move ~8x fewer weight bytes than dense
        bf16 — checked on compiled argument sizes."""
        from repro.launch.dryrun import lower_cell
        cfg = configs.get("bitnet-2b-4t").reduced()
        shape = configs.ShapeConfig("d", 64, 4, "decode")
        sizes = {}
        for w in ("packed", "dense"):
            lowered, _ = lower_cell(cfg, shape, mesh, fsdp=False, weights=w)
            mem = lowered.compile().memory_analysis()
            sizes[w] = mem.argument_size_in_bytes
        assert sizes["packed"] < sizes["dense"]
