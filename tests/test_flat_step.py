"""Flat token-packed engine step (``policy="flat"``): token identity to the
rectangular chunked and whole-prompt paths across dense/MoE and prefix-cache
on/off, behavior under a preemption storm, planner budget/ordering
properties, and the rejection accounting satellite."""
import dataclasses

import jax
import numpy as np
import pytest

import repro.configs as configs
from repro.models import model_zoo as zoo
from repro.serving import Request, ServingEngine
from repro.serving.scheduler import ChunkedScheduler, FlatStepPlan, SlotState

CHUNK = 8


@pytest.fixture(scope="module")
def dense_model():
    cfg = configs.get("bitnet-2b-4t").reduced()
    return cfg, zoo.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def moe_model():
    # Dropless capacity: with ample capacity nothing overflows and every
    # layout routes identically (the overflow regime is a true cross-policy
    # divergence, documented in tests/test_moe_serving.py).
    cfg = dataclasses.replace(configs.get("deepseek-moe-16b").reduced(),
                              capacity_factor=8.0)
    return cfg, zoo.init_params(cfg, jax.random.PRNGKey(0))


def _mixed_reqs(maxnew=6, seed=7):
    rng = np.random.default_rng(seed)
    lens = [3, CHUNK, 21, 40]
    return [Request(uid=i, prompt=rng.integers(0, 100, size=s).astype(np.int32),
                    max_new_tokens=maxnew)
            for i, s in enumerate(lens)]


# ---------------------------------------------------------------------------
# Token-identity matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["dense", "moe"])
def test_flat_matches_chunked_and_whole(family, dense_model, moe_model):
    """Greedy outputs are identical across flat / chunked / whole for both
    chunkable families — the flat repack changes the layout, not the math."""
    cfg, params = dense_model if family == "dense" else moe_model
    outs = {}
    for policy in ("flat", "chunked", "whole"):
        reqs = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                             prefill_chunk=CHUNK, policy=policy
                             ).run(_mixed_reqs())
        outs[policy] = [r.out_tokens for r in reqs]
    assert outs["flat"] == outs["chunked"]
    assert outs["flat"] == outs["whole"]


@pytest.mark.parametrize("family", ["dense", "moe"])
def test_flat_prefix_cache_token_identical_and_cheaper(family, dense_model,
                                                       moe_model):
    """Flat + prefix cache: warm outputs identical to cache-off, with a
    nonzero hit rate and strictly fewer prefill tokens scheduled."""
    cfg, params = dense_model if family == "dense" else moe_model
    sys_prompt = (np.arange(32, dtype=np.int32) * 5 + 1) % 90
    rng = np.random.default_rng(3)
    tails = [rng.integers(0, 90, size=12).astype(np.int32) for _ in range(4)]
    mk = lambda: [Request(uid=i, prompt=np.concatenate([sys_prompt, tails[i]]),
                          max_new_tokens=5) for i in range(4)]
    off = ServingEngine(cfg, params, max_len=128, batch_slots=2,
                        prefill_chunk=CHUNK, policy="flat")
    r_off = off.run(mk())
    on = ServingEngine(cfg, params, max_len=128, batch_slots=2,
                       prefill_chunk=CHUNK, policy="flat", prefix_cache=True)
    r_on = on.run(mk())
    for a, b in zip(r_off, r_on):
        assert a.out_tokens == b.out_tokens, (a.uid, a.out_tokens, b.out_tokens)
    assert on.stats["prefix_hit_rate"] > 0
    assert on.sched.cached_tokens_skipped > 0
    assert on.sched.prefill_tokens_planned < off.sched.prefill_tokens_planned
    on.prefix.check()


def test_flat_preemption_storm_token_identical(dense_model):
    """A pool tight enough to preempt under the flat policy still finishes
    every request with outputs identical to a roomy flat engine."""
    cfg, params = dense_model
    rng = np.random.default_rng(11)
    mk = lambda: [Request(uid=i, prompt=rng.integers(0, 90, size=30 + i),
                          max_new_tokens=6) for i in range(3)]
    rng2 = np.random.default_rng(11)
    mk2 = lambda: [Request(uid=i, prompt=rng2.integers(0, 90, size=30 + i),
                           max_new_tokens=6) for i in range(3)]
    roomy = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                          prefill_chunk=CHUNK, policy="flat").run(mk())
    tight_eng = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                              prefill_chunk=CHUNK, policy="flat",
                              block_size=4, kv_blocks=16)
    tight = tight_eng.run(mk2())
    assert tight_eng.stats["preemptions"] > 0, "pool not tight enough"
    assert all(r.done for r in tight)
    for a, b in zip(roomy, tight):
        assert a.out_tokens == b.out_tokens, (a.uid, a.out_tokens, b.out_tokens)


def test_flat_is_default_policy_and_budget_bound(dense_model):
    """Flat is the auto policy for chunkable families; real work per step is
    bounded by the token budget (default prefill_chunk + slots)."""
    cfg, params = dense_model
    eng = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                        prefill_chunk=CHUNK)
    assert eng.policy == "flat"
    assert eng.token_budget == CHUNK + eng.slots
    eng.run(_mixed_reqs())
    assert eng.stats["whole_prefills"] == 0
    assert eng.max_step_tokens() <= eng.token_budget


def test_flat_policy_refused_for_recurrent_families():
    cfg = configs.get("mamba2-780m").reduced()
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="flat"):
        ServingEngine(cfg, params, max_len=32, batch_slots=1, policy="flat")


def test_token_budget_validated(dense_model):
    cfg, params = dense_model
    with pytest.raises(ValueError, match="token_budget"):
        ServingEngine(cfg, params, max_len=64, batch_slots=4, token_budget=4)


def test_multi_prefill_concurrency(dense_model):
    """Two prompts admitted together both advance in the SAME step — the
    one-prefill-per-step restriction is gone (TTFT under concurrency)."""
    cfg, params = dense_model
    eng = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                        prefill_chunk=CHUNK, policy="flat")
    reqs = [Request(uid=i, prompt=np.arange(20, dtype=np.int32) + i,
                    max_new_tokens=2) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng._admit()
    plan = eng.sched.plan_flat(eng._slots, eng.kv, eng.token_budget)
    assert plan.prefill_mask.all(), "both prefills must advance concurrently"
    assert plan.n_real[0] == plan.n_real[1] == eng.token_budget // 2
    eng.run([])  # drain


# ---------------------------------------------------------------------------
# Planner properties (no model, stub allocator)
# ---------------------------------------------------------------------------

class _KVStub:
    """Minimal allocator facade for pure planner tests."""

    def __init__(self, slots):
        self.lengths = np.zeros(slots, np.int64)

    def ensure(self, i, n):
        return True

    def view_blocks(self, n_tokens):
        vb = 1
        while vb * 16 < max(1, n_tokens):
            vb *= 2
        return vb


def _random_slots(rng, b):
    """Random mix of empty / prefilling / decoding slots + the stub kv."""
    kv = _KVStub(b)
    slots = []
    for i in range(b):
        r = rng.random()
        if r < 0.25:
            slots.append(None)
            continue
        plen = int(rng.integers(1, 30))
        st = SlotState(req=None, prompt=np.arange(plen, dtype=np.int32),
                       admitted_at=int(rng.integers(0, 100)), last_tok=1)
        if r < 0.6:                      # prefilling, possibly mid-prompt
            st.cursor = int(rng.integers(0, plen))
            kv.lengths[i] = st.cursor
        else:                            # decoding
            st.cursor = plen
            kv.lengths[i] = plen + int(rng.integers(0, 4))
        slots.append(st)
    return slots, kv


def test_plan_flat_budget_and_ordering_properties():
    """For random slot mixes: ``sum(n_real) == min(budget, available)``, each
    slot's rows carry contiguous ascending positions starting at its live
    length (never interleaved out of position order), padding rows carry the
    slot sentinel, and emit rows point at each slot's last real token."""
    rng = np.random.default_rng(0)
    for _ in range(200):
        b = int(rng.integers(1, 6))
        slots, kv = _random_slots(rng, b)
        budget = int(rng.integers(b + 1, 40))
        sched = ChunkedScheduler(prefill_chunk=CHUNK)
        plan = sched.plan_flat(slots, kv, budget)
        active = [i for i in range(b) if slots[i] is not None]
        if not active:
            assert plan is None
            continue
        assert isinstance(plan, FlatStepPlan)
        available = sum(
            (len(slots[i].prompt) - slots[i].cursor)
            if slots[i].prefilling else 1
            for i in active)
        assert plan.real_tokens == min(budget, available)
        assert plan.width == (budget if plan.prefill_tokens else b)
        for i in range(b):
            rows = np.flatnonzero(plan.slot == i)
            assert len(rows) == plan.n_real[i]
            if not len(rows):
                continue
            # Contiguous ascending positions from the slot's live length —
            # in row order, so no slot's tokens interleave out of order.
            want = kv.lengths[i] + np.arange(len(rows))
            np.testing.assert_array_equal(plan.pos[rows], want)
            if plan.emit[i]:
                assert plan.emit_row[i] == rows[-1]
            if slots[i].prefilling:
                np.testing.assert_array_equal(
                    plan.tokens[rows],
                    slots[i].prompt[slots[i].cursor:
                                    slots[i].cursor + len(rows)])
        # Padding rows: sentinel slot index b, exactly the unused width.
        assert (plan.slot == b).sum() == plan.width - plan.real_tokens
        assert plan.real_tokens == plan.prefill_tokens + plan.decode_tokens


def test_plan_flat_decode_never_starved():
    """Every decoding slot gets its token even when prefill demand alone
    exceeds the budget."""
    b = 4
    kv = _KVStub(b)
    slots = []
    for i in range(b):
        plen = 100
        st = SlotState(req=None, prompt=np.arange(plen, dtype=np.int32),
                       admitted_at=i, last_tok=1)
        if i < 2:                        # two huge prefills
            st.cursor = 0
        else:                            # two decoders
            st.cursor = plen
            kv.lengths[i] = plen
        slots.append(st)
    plan = ChunkedScheduler(prefill_chunk=CHUNK).plan_flat(slots, kv, 12)
    assert plan.n_real[2] == plan.n_real[3] == 1
    assert plan.emit[2] and plan.emit[3]
    # Remaining 10 tokens fair-shared across the two concurrent prefills.
    assert plan.n_real[0] == plan.n_real[1] == 5
    assert plan.decode_tokens == 2 and plan.prefill_tokens == 10


# ---------------------------------------------------------------------------
# Rejection accounting (satellite)
# ---------------------------------------------------------------------------

def test_prompt_too_long_rejection_is_metric_visible(dense_model):
    """A prompt that can never fit is finished-ignored AND accounted: the
    ``rejections`` counter increments, ``t_done`` is stamped, and the
    workload counter block surfaces the count."""
    from benchmarks.workloads.metrics import engine_counters

    cfg, params = dense_model
    eng = ServingEngine(cfg, params, max_len=32, batch_slots=2,
                        prefill_chunk=CHUNK)
    good = Request(uid=0, prompt=np.arange(6, dtype=np.int32),
                   max_new_tokens=3)
    bad = Request(uid=1, prompt=np.arange(64, dtype=np.int32),
                  max_new_tokens=3)
    eng.run([bad, good])
    assert bad.done and not bad.out_tokens
    assert bad.t_done is not None, "rejection must stamp t_done"
    assert eng.stats["rejections"] == 1
    assert eng.metrics.get("rejections").value == 1
    assert engine_counters(eng)["rejections"] == 1
    assert good.out_tokens and len(good.out_tokens) == 3
    # reset_run_stats clears it like every other run counter.
    eng.reset_run_stats()
    assert eng.stats["rejections"] == 0 and eng.sched.rejections == 0
