"""Fault tolerance: checkpoint/restart, fault injection, straggler detection,
elastic re-meshing, heartbeats."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro import checkpoint as ckpt
from repro.data import DataConfig, SyntheticLMStream
from repro.optim import OptConfig
from repro.runtime import (Heartbeat, StepMonitor, elastic_remesh_plan,
                           run_with_restarts)
from repro.train import init_state, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get("bitnet-2b-4t").reduced()
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    stream = SyntheticLMStream(DataConfig(cfg.vocab_size, 32, 8, seed=5))
    return cfg, opt, stream


def test_checkpoint_roundtrip_and_gc(setup, tmp_path):
    cfg, opt, stream = setup
    state = init_state(cfg, jax.random.PRNGKey(0), opt)
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, state, keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [4, 5]
    st2 = ckpt.restore(str(tmp_path), 5, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpoint(setup, tmp_path):
    cfg, opt, stream = setup
    state = init_state(cfg, jax.random.PRNGKey(1), opt)
    h = ckpt.save(str(tmp_path), 7, state, async_save=True)
    h.wait()
    assert ckpt.latest_step(str(tmp_path)) == 7


def test_crash_recovery_bit_exact(setup, tmp_path):
    """Train 10 steps straight == train 6, 'crash', restore, train 4.

    The data stream is step-indexed so the replay consumes identical batches
    — the recovered run must be bit-identical to the uninterrupted one.
    """
    cfg, opt, stream = setup
    step = jax.jit(make_train_step(cfg, opt))

    state = init_state(cfg, jax.random.PRNGKey(2), opt)
    for i in range(10):
        state, _ = step(state, stream.batch(i))
    straight = state

    state = init_state(cfg, jax.random.PRNGKey(2), opt)
    for i in range(6):
        state, _ = step(state, stream.batch(i))
    ckpt.save(str(tmp_path), 6, state)
    del state  # "crash"
    target = init_state(cfg, jax.random.PRNGKey(99), opt)  # fresh process
    state = ckpt.restore(str(tmp_path), 6, target)
    for i in range(6, 10):
        state, _ = step(state, stream.batch(i))

    for a, b in zip(jax.tree.leaves(straight.params), jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_run_with_restarts_fault_injection(setup, tmp_path):
    cfg, opt, stream = setup
    step = jax.jit(make_train_step(cfg, opt))
    crashes = {"armed": 2}  # fail twice, then succeed

    def restore_fn():
        latest = ckpt.latest_step(str(tmp_path))
        target = init_state(cfg, jax.random.PRNGKey(0), opt)
        if latest is None:
            return target, 0
        return ckpt.restore(str(tmp_path), latest, target), latest

    def body(state, start):
        for i in range(start, 12):
            if i == 5 and crashes["armed"] > 0:
                crashes["armed"] -= 1
                raise RuntimeError("simulated node failure")
            state, _ = step(state, stream.batch(i))
            if (i + 1) % 2 == 0:
                ckpt.save(str(tmp_path), i + 1, state)
        return 12

    report = run_with_restarts(body, restore_fn=restore_fn, max_restarts=3)
    assert report.completed and report.restarts == 2
    assert len(report.failures) == 2
    assert ckpt.latest_step(str(tmp_path)) == 12


def test_elastic_restore_to_different_mesh(setup, tmp_path):
    """Save replicated, restore sharded onto a 1x1 'mesh' with explicit
    shardings — exercises the device_put-with-new-sharding path the
    multi-pod elastic restart uses."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg, opt, stream = setup
    state = init_state(cfg, jax.random.PRNGKey(3), opt)
    ckpt.save(str(tmp_path), 1, state.params)

    mesh = jax.make_mesh((1,), ("data",))
    shardings = jax.tree.map(
        lambda x: NamedSharding(mesh, P(*([None] * x.ndim))), state.params)
    restored = ckpt.restore(str(tmp_path), 1, state.params, shardings=shardings)
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_detection():
    mon = StepMonitor(window=16, straggler_factor=2.0)
    for i in range(10):
        mon.start(i)
        mon.times.append(0.1)  # synthetic fast steps
    mon.start(99)
    assert mon.is_straggler(0.5)
    assert not mon.is_straggler(0.15)


def test_heartbeat(tmp_path):
    path = os.path.join(str(tmp_path), "hb.json")
    hb = Heartbeat(path, interval_s=0.0)
    hb.beat(step=3)
    assert Heartbeat.is_alive(path, deadline_s=60)
    assert not Heartbeat.is_alive(path + ".missing")


def test_elastic_remesh_plan():
    assert elastic_remesh_plan(512, 16) == (32, 16)
    assert elastic_remesh_plan(496, 16) == (31, 16)  # lost a host: fewer DP
    assert elastic_remesh_plan(16, 16) == (1, 16)
    with pytest.raises(ValueError):
        elastic_remesh_plan(8, 16)
