"""Pallas kernel validation: interpret-mode shape/dtype sweeps vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import ternary
from repro.kernels import ops, ref


def _mk(seed, n, k, m):
    t = ternary.random_ternary(jax.random.PRNGKey(seed), (k, m))
    scale = jax.random.uniform(jax.random.PRNGKey(seed + 1), (m,), minval=0.25, maxval=2.0)
    x = jax.random.normal(jax.random.PRNGKey(seed + 2), (n, k))
    return t, scale, x


class TestTSARMatmulKernel:
    @pytest.mark.parametrize("n,k,m", [
        (1, 128, 128), (1, 256, 256), (8, 512, 384),
        (16, 1024, 256), (3, 136, 72), (128, 256, 128),
    ])
    @pytest.mark.parametrize("dataflow", ["AP", "OP"])
    def test_sweep_vs_oracle(self, n, k, m, dataflow):
        t, scale, x = _mk(n * 7 + k, n, k, m)
        tw = ternary.pack(t.astype(jnp.float32), scale)
        got = ops.tsar_matmul(x, tw, dataflow=dataflow, interpret=True)
        want = ref.quantized_matmul_ref(x, tw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)

    # Note: the dtype sweep (f32/bf16) moved to the cross-kernel conformance
    # suite (tests/test_conformance.py::test_kernel_conformance_bf16), which
    # covers every registry kernel, not just this one.

    def test_leading_batch_dims(self):
        t, scale, x = _mk(9, 6, 128, 64)
        tw = ternary.pack(t.astype(jnp.float32), scale)
        x3 = x.reshape(2, 3, 128)
        got = ops.tsar_matmul(x3, tw, interpret=True)
        assert got.shape == (2, 3, 64)
        want = ref.quantized_matmul_ref(x, tw).reshape(2, 3, 64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10**6), n=st.integers(1, 9),
           kb=st.integers(1, 6), mb=st.integers(1, 4))
    def test_property_shapes(self, seed, n, kb, mb):
        k, m = kb * 128, mb * 128
        t, scale, x = _mk(seed, n, k, m)
        tw = ternary.pack(t.astype(jnp.float32), scale)
        got = ops.tsar_matmul(x, tw, interpret=True)
        want = ref.quantized_matmul_ref(x, tw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)


class TestTSARLutKernel:
    @pytest.mark.parametrize("n,k,m", [
        (1, 128, 128), (4, 512, 384), (8, 256, 256), (2, 132, 70),
    ])
    def test_sweep_vs_oracle(self, n, k, m):
        t, scale, x = _mk(n * 13 + m, n, k, m)
        ip, iz = ternary.pack_indices(t, 4)
        got = ops.tsar_lut_gemv(x, ip, iz, scale, c=4, interpret=True)
        want = ref.ternary_matmul_ref(x, t, scale)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)

    @pytest.mark.parametrize("c", [2, 4])
    def test_block_sizes(self, c):
        t, scale, x = _mk(77, 2, 256, 128)
        ip, iz = ternary.pack_indices(t, c)
        got = ops.tsar_lut_gemv(x, ip, iz, scale, c=c, interpret=True)
        want = ref.ternary_matmul_ref(x, t, scale)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)

    def test_paper_gemv_shape(self):
        """The paper's Fig. 10 GEMV shape (scaled): 1 x 2560 -> 6912/4."""
        t, scale, x = _mk(99, 1, 2560, 1728)
        ip, iz = ternary.pack_indices(t, 4)
        got = ops.tsar_lut_gemv(x, ip, iz, scale, c=4, interpret=True)
        want = ref.ternary_matmul_ref(x, t, scale)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=2e-3)
