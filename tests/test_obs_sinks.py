"""Streaming trace sinks, the flight recorder + incident snapshots, and
the metrics export surface (PR 10):

* ``StreamingSink`` fingerprints **byte-for-byte identically** to a
  ``MemorySink`` export of the same run, survives segment rotation, keeps
  a bounded number of events resident, and truncates on ``reset()`` so
  warm-up never leaks into a saved stream;
* ``timeline`` analyzes the JSONL stream to exactly the document analysis
  (property-tested via the hypothesis shim), and its CLI fails a
  ``--min-step-utilization`` gate on a zero-step trace with a clear
  message instead of silently passing;
* ``repro.obs.export`` renders the registry so a scrape matches
  ``registry.snapshot()`` sample-for-sample, over HTTP and textfile;
* ``IncidentMonitor`` dumps schema-valid snapshots with debouncing, and
  attaching it to an engine perturbs no exact-gated counter.
"""
import json
import math
import os
import tempfile
import urllib.error
import urllib.request

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.obs import timeline
from repro.obs import trace as obs_trace
from repro.obs.export import (MetricsServer, TextfileWriter, parse_samples,
                              render, start_server)
from repro.obs.incident import (INCIDENT_KIND, INCIDENT_SCHEMA_VERSION,
                                TRIGGERS, IncidentMonitor, load_incident,
                                validate_incident)
from repro.obs.metrics import DEFAULT_BUCKETS, MetricsRegistry
from repro.obs.trace import (EventTracer, MemorySink, RingSink, StreamReader,
                             StreamingSink, TeeSink, meta_events, read_stream,
                             stream_segments, stream_to_perfetto)


def _tick():
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    return clock


def _emit_lifecycle(tr, uid=1):
    """One request lifecycle + two steps — enough to touch every phase."""
    tr.begin(uid, "req", prompt_len=8)
    tr.mark(uid, "admitted", slot=0, cached_len=4, readmission=False)
    tr.mark(uid, "prefix_hit", cached_len=4)
    tr.begin(uid, "prefill", slot=0)
    tr.step(0.2, planned=8, realized=6, prefill_tokens=4, decode_tokens=2,
            kv_blocks=3, active_slots=1, kernel="tsar_mxu")
    tr.instant("kv_pressure", slot=0, need=2, free=0)
    tr.end(uid, "prefill")
    tr.begin(uid, "decode")
    tr.mark(uid, "first_token")
    tr.step(0.1, planned=2, realized=2, prefill_tokens=0, decode_tokens=2,
            kv_blocks=4, active_slots=1, kernel="tsar_mxu")
    tr.end(uid, "decode")
    tr.mark(uid, "finished", n_out=3, preemptions=0)
    tr.end(uid, "req")


# ---------------------------------------------------------------------------
# sinks (pure, no jax)
# ---------------------------------------------------------------------------

class TestSinks:
    def test_memory_sink_recent_and_reset(self):
        s = MemorySink()
        for i in range(5):
            s.append({"i": i})
        assert s.n_appended == 5 and len(s.events) == 5
        assert s.recent(2) == [{"i": 3}, {"i": 4}]
        s.reset()
        assert s.events == []

    def test_ring_sink_drops_oldest(self):
        s = RingSink(capacity=3)
        for i in range(10):
            s.append({"i": i})
        assert s.events == [{"i": 7}, {"i": 8}, {"i": 9}]
        assert s.n_appended == 10 and s.n_dropped == 7
        assert s.recent(2) == [{"i": 8}, {"i": 9}]
        s.reset()
        assert s.events == [] and s.n_appended == 0 and s.n_dropped == 0

    def test_tee_fans_out_reads_primary(self, tmp_path):
        mem, ring = MemorySink(), RingSink(capacity=2)
        tee = TeeSink(mem, ring)
        for i in range(4):
            tee.append({"i": i})
        assert tee.events is mem.events and len(mem.events) == 4
        assert ring.events == [{"i": 2}, {"i": 3}]
        tee.reset()
        assert mem.events == [] and ring.events == []
        with pytest.raises(ValueError, match="at least one sink"):
            TeeSink()

    def test_streaming_sink_does_not_retain_events(self, tmp_path):
        sink = StreamingSink(str(tmp_path / "s.jsonl"))
        with pytest.raises(RuntimeError, match="read_stream"):
            sink.events
        sink.finalize()


# ---------------------------------------------------------------------------
# streaming sink <-> memory sink identity (the tentpole contract)
# ---------------------------------------------------------------------------

class TestStreamingSink:
    def _twin_run(self, tmp_path, **sink_kw):
        """The same emission sequence through a memory tracer and a
        streaming tracer (deterministic clocks)."""
        mem = EventTracer(clock=_tick())
        sink = StreamingSink(str(tmp_path / "t.jsonl"), rev="testrev",
                             **sink_kw)
        strm = EventTracer(clock=_tick(), sink=sink)
        for tr in (mem, strm):
            _emit_lifecycle(tr, uid=1)
            _emit_lifecycle(tr, uid=2)
        return mem, sink

    def test_fingerprint_identical_to_memory(self, tmp_path):
        mem, sink = self._twin_run(tmp_path)
        doc = mem.to_perfetto(rev="testrev")
        info = sink.finalize()
        assert info["fingerprint"] == doc["otherData"]["fingerprint"]
        # finalize is idempotent and append-after-finalize refuses
        assert sink.finalize() == info
        with pytest.raises(RuntimeError, match="finalized"):
            sink.append({"ph": "i", "name": "late", "ts": 0, "args": {}})
        with pytest.raises(RuntimeError, match="finalized"):
            sink.reset()

    def test_jsonl_roundtrips_events_exactly(self, tmp_path):
        mem, sink = self._twin_run(tmp_path)
        doc = mem.to_perfetto(rev="testrev")
        info = sink.finalize()
        evs, reader = read_stream(info["path"])
        # meta events are part of the stream, so the full traceEvents list
        # round-trips (ts included: deterministic twin clocks)
        assert evs == doc["traceEvents"]
        assert reader.complete and reader.n_events == info["n_events"]
        assert reader.fingerprint == info["fingerprint"]
        assert reader.header["git_rev"] == "testrev"

    def test_rotation_chains_segments(self, tmp_path):
        mem, sink = self._twin_run(tmp_path, max_segment_bytes=512)
        info = sink.finalize()
        assert info["segments"] > 1
        segs = stream_segments(info["path"])
        assert len(segs) == info["segments"]
        assert segs[-1] == info["path"]
        assert [f"{info['path']}.{i}" for i in range(1, len(segs))] \
            == segs[:-1]
        # the chained read still fingerprints identically
        _, reader = read_stream(info["path"])
        assert reader.complete
        assert reader.fingerprint \
            == mem.to_perfetto(rev="x")["otherData"]["fingerprint"]

    def test_peak_resident_events_bounded(self, tmp_path):
        _, sink = self._twin_run(tmp_path, flush_every=4)
        n = sink.n_events
        sink.finalize()
        assert n > 4                       # the bound actually binds
        assert sink.peak_resident_events <= 4

    def test_reset_truncates_stream(self, tmp_path):
        # 600B segments: small enough that the warm-up lifecycle rotates,
        # large enough that a fresh header + meta events alone do not.
        sink = StreamingSink(str(tmp_path / "t.jsonl"), rev="x",
                             max_segment_bytes=600)
        warm = EventTracer(clock=_tick(), sink=sink)
        _emit_lifecycle(warm, uid=99)      # warm-up, rotates a few segments
        rotated = stream_segments(sink.path)[:-1]
        assert rotated                     # rotation actually happened
        warm.reset()                       # the engine's reset_run_stats path
        assert all(not os.path.exists(p) for p in rotated)
        assert sink.n_events == len(meta_events())
        _emit_lifecycle(warm, uid=1)       # may legitimately rotate again
        info = sink.finalize()
        fresh = EventTracer(clock=_tick())
        _emit_lifecycle(fresh, uid=1)
        # no trace of uid 99 survives: the stream equals a fresh run's
        assert info["fingerprint"] \
            == fresh.to_perfetto(rev="x")["otherData"]["fingerprint"]
        evs, _ = read_stream(info["path"])
        assert not any(e.get("id") == 99 for e in evs)

    def test_footerless_stream_reads_incomplete(self, tmp_path):
        _, sink = self._twin_run(tmp_path)
        sink.flush()                       # no finalize: writer "died"
        evs, reader = read_stream(sink.path)
        assert evs and reader.complete is False
        s = timeline.analyze_stream(sink.path)
        assert s["stream"]["complete"] is False
        assert "INCOMPLETE" in timeline.format_summary(s)
        sink.finalize()

    def test_truncated_tail_tolerated_in_active_segment(self, tmp_path):
        _, sink = self._twin_run(tmp_path)
        sink.flush()
        with open(sink.path, "a") as f:
            f.write('{"ph": "i", "name": "half')   # died mid-line
        evs, reader = read_stream(sink.path)
        assert len(evs) == sink.n_events and not reader.complete

    def test_tampered_stream_raises(self, tmp_path):
        _, sink = self._twin_run(tmp_path)
        info = sink.finalize()
        lines = open(info["path"]).read().splitlines()
        for i, ln in enumerate(lines):
            obj = json.loads(ln)
            if obj.get("ph") == "X":
                obj["args"]["planned"] += 1
                lines[i] = json.dumps(obj, sort_keys=True,
                                      separators=(",", ":"))
                break
        with open(info["path"], "w") as f:
            f.write("\n".join(lines) + "\n")
        with pytest.raises(ValueError, match="fingerprint"):
            read_stream(info["path"])

    def test_corrupt_rotated_segment_raises(self, tmp_path):
        _, sink = self._twin_run(tmp_path, max_segment_bytes=512)
        info = sink.finalize()
        with open(f"{info['path']}.1", "a") as f:
            f.write("not json\n")          # corruption NOT in the active tail
        with pytest.raises(ValueError, match="not valid JSON"):
            read_stream(info["path"])

    def test_stream_to_perfetto_validates(self, tmp_path):
        mem, sink = self._twin_run(tmp_path)
        sink.finalize()
        doc = stream_to_perfetto(sink.path)
        assert doc["otherData"]["kind"] == obs_trace.TRACE_KIND
        assert doc["otherData"]["fingerprint"] \
            == mem.to_perfetto(rev="x")["otherData"]["fingerprint"]

    def test_load_any_sniffs_stream_vs_doc(self, tmp_path):
        mem, sink = self._twin_run(tmp_path)
        sink.finalize()
        p = tmp_path / "doc.json"
        mem.save(str(p), rev="x")
        kind, obj = obs_trace.load_any(sink.path)
        assert kind == "stream" and isinstance(obj, StreamReader)
        kind, obj = obs_trace.load_any(str(p))
        assert kind == "doc" and isinstance(obj, dict)


# ---------------------------------------------------------------------------
# timeline over streams + the zero-step satellite
# ---------------------------------------------------------------------------

class TestTimelineStream:
    def test_stream_analysis_matches_document(self, tmp_path):
        sink = StreamingSink(str(tmp_path / "t.jsonl"), rev="x")
        tr = EventTracer(clock=_tick(), sink=TeeSink(MemorySink(), sink))
        _emit_lifecycle(tr)
        doc = tr.to_perfetto(rev="x")
        sink.finalize()
        mem_s = timeline.analyze(doc)
        st_s = timeline.analyze_stream(sink.path)
        assert st_s.pop("stream") == {"complete": True, "segments": 1}
        assert mem_s == st_s

    def test_cli_over_jsonl(self, tmp_path, capsys):
        sink = StreamingSink(str(tmp_path / "t.jsonl"), rev="x")
        tr = EventTracer(clock=_tick(), sink=sink)
        _emit_lifecycle(tr)
        sink.finalize()
        assert timeline.main([sink.path, "--require", "prefill-span",
                              "decode-span", "prefix-hit", "step",
                              "--min-step-utilization", "0.5"]) == 0
        capsys.readouterr()
        assert timeline.main([sink.path, "--json"]) == 0
        out = json.loads(capsys.readouterr().out)
        assert out["steps"]["n"] == 2 and out["stream"]["complete"]

    @pytest.mark.parametrize("suffix", ["json", "jsonl"])
    def test_zero_step_trace_fails_utilization_gate(self, tmp_path, capsys,
                                                    suffix):
        """Satellite: ``nan < x`` is always False — a zero-step trace must
        fail the gate with a clear message, not silently pass."""
        p = tmp_path / f"empty.{suffix}"
        if suffix == "json":
            tr = EventTracer(clock=_tick())
            tr.begin(1, "req")
            tr.end(1, "req")
            tr.save(str(p), rev="x")
        else:
            sink = StreamingSink(str(p), rev="x")
            tr = EventTracer(clock=_tick(), sink=sink)
            tr.begin(1, "req")
            tr.end(1, "req")
            sink.finalize()
        assert timeline.main([str(p)]) == 0          # analysis itself is fine
        capsys.readouterr()
        assert timeline.main([str(p), "--min-step-utilization", "0.5"]) == 1
        err = capsys.readouterr().err
        assert "no step records" in err
        s = timeline.analyze_events([])
        assert s["steps"]["budget_utilization"] is None
        assert s["steps"]["mean_active_slots"] is None
        # the text renderer survives the all-None summary too
        s.update(n_events=0, schema_version=1, fingerprint="sha256:" + "0" * 64)
        assert "n/a" in timeline.format_summary(s)


# -- hypothesis-shim property: stream == memory for arbitrary sequences ------

class TestStreamProperty:
    @settings(max_examples=15, deadline=None)
    @given(ops=st.lists(st.tuples(st.integers(min_value=0, max_value=4),
                                  st.integers(min_value=1, max_value=3)),
                        min_size=0, max_size=40),
           flush=st.integers(min_value=1, max_value=7),
           seg=st.integers(min_value=128, max_value=4096))
    def test_roundtrip_matches_memory(self, ops, flush, seg):
        """Any emission sequence streamed to JSONL (any flush cadence, any
        rotation threshold) analyzes and fingerprints identically to the
        in-memory path."""
        d = tempfile.mkdtemp(prefix="obs-stream-prop-")
        path = os.path.join(d, "t.jsonl")
        mem = EventTracer(clock=_tick())
        sink = StreamingSink(path, rev="x", flush_every=flush,
                             max_segment_bytes=seg)
        strm = EventTracer(clock=_tick(), sink=sink)

        def emit(tr):
            for op, uid in ops:
                if op == 0:
                    tr.begin(uid, "req", prompt_len=uid)
                elif op == 1:
                    tr.end(uid, "req")
                elif op == 2:
                    tr.mark(uid, "admitted", slot=0, cached_len=0,
                            readmission=False)
                elif op == 3:
                    tr.step(0.1, planned=2 * uid, realized=uid,
                            prefill_tokens=uid % 2, kv_blocks=uid,
                            active_slots=1)
                else:
                    tr.instant("kv_pressure", need=uid, free=0)

        emit(mem)
        emit(strm)
        doc = mem.to_perfetto(rev="x")
        info = sink.finalize()
        assert info["fingerprint"] == doc["otherData"]["fingerprint"]
        assert sink.peak_resident_events <= flush
        mem_s = timeline.analyze(doc)
        st_s = timeline.analyze_stream(path)
        st_s.pop("stream")
        assert mem_s == st_s


# ---------------------------------------------------------------------------
# metrics export surface (pure, no jax)
# ---------------------------------------------------------------------------

def _populated_registry():
    reg = MetricsRegistry()
    reg.counter("steps", "engine steps").inc(7)
    g = reg.gauge("kv_blocks", "blocks in use")
    g.set(9)
    g.set(4)
    fam = reg.counter("step_time_s", "step wall", labels=("phase",))
    fam.labels(phase="prefill").inc(1.5)
    fam.labels(phase="decode").inc(2.5)
    h = reg.histogram("ttft_s", "time to first token")
    for v in (0.004, 0.02, 0.02, 0.3, 2.0):
        h.observe(v)
    reg.histogram("tpot_s", "per-token latency")   # stays empty
    return reg


class TestExportRender:
    def test_scrape_matches_snapshot_exactly(self):
        """The acceptance contract: every counter/gauge value in the
        exposition equals the ``snapshot()`` value under the corresponding
        name, histograms match summary-for-summary."""
        reg = _populated_registry()
        snap = reg.snapshot()
        samples = parse_samples(render(reg))
        assert samples["tsar_steps"] == snap["steps"]
        assert samples["tsar_kv_blocks"] == snap["kv_blocks"] == 4
        assert samples["tsar_kv_blocks_peak"] == snap["kv_blocks_peak"] == 9
        assert samples['tsar_step_time_s{phase="prefill"}'] \
            == snap["step_time_s{phase=prefill}"]
        assert samples['tsar_step_time_s{phase="decode"}'] == 2.5
        s = snap["ttft_s"]
        assert samples["tsar_ttft_s_count"] == s["n"] == 5
        assert samples["tsar_ttft_s_sum"] == pytest.approx(2.344)
        for q, p in (("0.5", "p50"), ("0.9", "p90"), ("0.99", "p99")):
            assert samples[f'tsar_ttft_s_quantile{{quantile="{q}"}}'] \
                == pytest.approx(s[p])
        assert samples["tsar_ttft_s_mean"] == pytest.approx(s["mean"])
        assert samples["tsar_ttft_s_max"] == s["max"] == 2.0

    def test_histogram_buckets_cumulative(self):
        reg = _populated_registry()
        samples = parse_samples(render(reg))
        counts = [samples[f'tsar_ttft_s_bucket{{le="{_le}"}}']
                  for _le in [repr(float(b)) for b in DEFAULT_BUCKETS]
                  + ["+Inf"]]
        assert counts == sorted(counts)            # cumulative
        assert counts[-1] == 5                     # +Inf == count
        assert samples['tsar_ttft_s_bucket{le="0.005"}'] == 1
        assert samples['tsar_ttft_s_bucket{le="0.025"}'] == 3
        # the empty histogram renders NaN-free zeros (sentinel satellite)
        assert samples["tsar_tpot_s_count"] == 0
        assert samples['tsar_tpot_s_quantile{quantile="0.5"}'] == 0.0
        assert "NaN" not in render(reg)

    def test_type_and_help_lines(self):
        text = render(_populated_registry())
        assert "# TYPE tsar_steps counter" in text
        assert "# TYPE tsar_kv_blocks gauge" in text
        assert "# TYPE tsar_ttft_s histogram" in text
        assert "# HELP tsar_ttft_s time to first token" in text
        assert "_total" not in text     # names stay the snapshot names

    def test_namespace_off(self):
        samples = parse_samples(render(_populated_registry(), namespace=""))
        assert "steps" in samples


class TestExportEndpoints:
    def test_http_scrape_matches_registry(self):
        reg = _populated_registry()
        srv = start_server(reg, port=0)
        try:
            assert srv.url.endswith(f":{srv.port}/metrics")
            body = urllib.request.urlopen(srv.url, timeout=5).read().decode()
            assert parse_samples(body) == parse_samples(render(reg))
            js = urllib.request.urlopen(
                srv.url + ".json", timeout=5).read().decode()
            assert json.loads(js) == json.loads(json.dumps(reg.snapshot()))
            # live registry: a scrape after mutation sees the new value
            reg.get("steps").inc(3)
            body = urllib.request.urlopen(srv.url, timeout=5).read().decode()
            assert parse_samples(body)["tsar_steps"] == 10
            with pytest.raises(urllib.error.HTTPError):
                urllib.request.urlopen(
                    f"http://{srv.host}:{srv.port}/nope", timeout=5)
        finally:
            srv.stop()

    def test_server_context_manager(self):
        with MetricsServer(_populated_registry(), port=0) as srv:
            body = urllib.request.urlopen(srv.url, timeout=5).read().decode()
            assert "tsar_steps" in body

    def test_textfile_writer(self, tmp_path):
        reg = _populated_registry()
        p = tmp_path / "metrics.prom"
        w = TextfileWriter(reg, str(p), interval_s=3600.0)
        w.write_once()
        assert parse_samples(p.read_text()) == parse_samples(render(reg))
        w.start()
        reg.get("steps").inc(5)
        w.stop()                 # final write flushes the last state
        assert parse_samples(p.read_text())["tsar_steps"] == 12
        assert w.n_writes >= 2
        assert not os.path.exists(str(p) + ".tmp")


# ---------------------------------------------------------------------------
# incident monitor (pure, no jax)
# ---------------------------------------------------------------------------

def _monitor(tmp_path, **kw):
    kw.setdefault("clock", lambda: 1700000000.0)
    kw.setdefault("rev", "testrev")
    return IncidentMonitor(str(tmp_path / "inc"), **kw)


class _FakeReq:
    def __init__(self, uid=7, ttft=None, tpot=None):
        self.uid, self.ttft, self.tpot = uid, ttft, tpot


class TestIncidentMonitor:
    def test_dump_is_schema_valid_with_ring_and_metrics(self, tmp_path):
        reg = _populated_registry()
        tr = EventTracer(clock=_tick(), sink=RingSink(capacity=4))
        _emit_lifecycle(tr)
        mon = _monitor(tmp_path).bind(registry=reg, tracer=tr)
        path = mon.observe("kv_pressure", slot=0, need=2, free=0)
        assert path and os.path.exists(path)
        doc = load_incident(path)
        assert doc["kind"] == INCIDENT_KIND
        assert doc["schema_version"] == INCIDENT_SCHEMA_VERSION
        assert doc["trigger"] == "kv_pressure"
        assert doc["context"] == {"slot": 0, "need": 2, "free": 0}
        assert doc["git_rev"] == "testrev"
        assert doc["metrics"]["steps"] == 7
        assert doc["ring"]["n_events"] == 4            # ring capacity
        assert doc["ring"]["n_dropped"] == tr.sink.n_dropped > 0
        assert doc["ring"]["events"] == tr.sink.events
        assert mon.summary() == {"n": 1, "by_trigger": {"kv_pressure": 1},
                                 "suppressed": 0, "paths": [path]}

    def test_validate_rejects_malformed(self, tmp_path):
        mon = _monitor(tmp_path)
        doc = load_incident(mon.observe("rejection", n=1))
        bad = dict(doc)
        del bad["ring"]
        with pytest.raises(ValueError, match="ring"):
            validate_incident(bad)
        bad = dict(doc, trigger="meteor_strike")
        with pytest.raises(ValueError, match="unknown trigger"):
            validate_incident(bad)
        bad = dict(doc, schema_version=99)
        with pytest.raises(ValueError, match="schema_version"):
            validate_incident(bad)

    def test_unknown_trigger_config_raises(self, tmp_path):
        with pytest.raises(ValueError, match="unknown incident triggers"):
            _monitor(tmp_path, triggers=("slo_breach", "meteor_strike"))

    def test_unconfigured_trigger_is_ignored(self, tmp_path):
        mon = _monitor(tmp_path, triggers=("preemption",))
        assert mon.observe("rejection", n=1) is None
        assert mon.summary()["n"] == 0 and mon.suppressed == 0

    def test_cooldown_debounces_per_trigger(self, tmp_path):
        mon = _monitor(tmp_path, cooldown_steps=10)
        assert mon.observe("preemption", uid=1)
        assert mon.observe("preemption", uid=2) is None    # in cooldown
        assert mon.observe("rejection", n=1)               # other trigger ok
        for _ in range(10):
            mon.step_tick()
        assert mon.observe("preemption", uid=3)            # cooldown expired
        assert mon.suppressed == 1

    def test_max_incidents_caps_total(self, tmp_path):
        mon = _monitor(tmp_path, max_incidents=2, cooldown_steps=0)
        assert mon.observe("preemption", uid=1)
        assert mon.observe("preemption", uid=2)
        assert mon.observe("preemption", uid=3) is None
        assert mon.summary()["n"] == 2 and mon.suppressed == 1

    def test_eviction_storm_sliding_window(self, tmp_path):
        mon = _monitor(tmp_path, eviction_storm_n=6, eviction_window_steps=4)
        # a slow trickle never accumulates 6 within 4 steps
        for _ in range(12):
            mon.step_tick(evictions=1)
            mon.step_tick()
            mon.step_tick()
            mon.step_tick()
        assert mon.summary()["by_trigger"].get("eviction_storm") is None
        # a burst does
        for _ in range(3):
            mon.step_tick(evictions=2)
        assert mon.summary()["by_trigger"]["eviction_storm"] == 1
        doc = load_incident(mon.paths[-1])
        assert doc["context"]["evictions"] >= 6

    def test_slo_breach_hooks(self, tmp_path):
        mon = _monitor(tmp_path, slo_ttft_s=0.5, slo_tpot_s=0.05,
                       cooldown_steps=0)
        mon.request_first_token(_FakeReq(ttft=0.4))        # under threshold
        mon.request_first_token(_FakeReq(ttft=None))       # unfinished
        assert mon.summary()["n"] == 0
        mon.request_first_token(_FakeReq(uid=3, ttft=0.9))
        mon.request_finished(_FakeReq(uid=4, tpot=0.2))
        assert mon.summary()["by_trigger"]["slo_breach"] == 2
        kinds = {load_incident(p)["context"]["kind"] for p in mon.paths}
        assert kinds == {"ttft", "tpot"}
        # thresholds unset -> hooks are inert
        off = _monitor(tmp_path, prefix="off")
        off.request_first_token(_FakeReq(ttft=100.0))
        assert off.summary()["n"] == 0

    def test_reset_run_discards_warmup_files(self, tmp_path):
        mon = _monitor(tmp_path, cooldown_steps=0)
        paths = [mon.observe("preemption", uid=i) for i in range(2)]
        assert all(os.path.exists(p) for p in paths)
        mon.reset_run()
        assert all(not os.path.exists(p) for p in paths)
        assert mon.summary() == {"n": 0, "by_trigger": {}, "suppressed": 0,
                                 "paths": []}
        # re-armed: fires again from seq 0
        p = mon.observe("preemption", uid=9)
        assert p and "-000-" in os.path.basename(p)


# ---------------------------------------------------------------------------
# engine integration (reduced model)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model():
    import jax

    import repro.configs as configs
    from repro.models import model_zoo as zoo

    cfg = configs.get("bitnet-2b-4t").reduced()
    return cfg, zoo.init_params(cfg, jax.random.PRNGKey(0))


def _small_engine(model, **kw):
    from repro.serving import ServingEngine

    cfg, params = model
    return ServingEngine(cfg, params, max_len=48, batch_slots=2,
                         prefill_chunk=8, block_size=8, **kw)


class TestEngineIncidents:
    def test_flight_recorder_kwarg(self, model):
        from repro.serving import Request

        eng = _small_engine(model, flight_recorder=64)
        assert isinstance(eng.tracer.sink, RingSink)
        assert eng.tracer.sink.capacity == 64
        eng.run([Request(uid=0, prompt=np.arange(8) + 1, max_new_tokens=3)])
        assert eng.tracer.sink.events                  # recorder recorded
        assert isinstance(_small_engine(model, flight_recorder=True)
                          .tracer.sink, RingSink)

    def test_rejection_incident_fires(self, model, tmp_path):
        from repro.serving import Request

        mon = IncidentMonitor(str(tmp_path / "inc"), rev="t")
        eng = _small_engine(model, incidents=mon, flight_recorder=32)
        eng.submit(Request(uid=0, prompt=np.arange(100) + 1,
                           max_new_tokens=4))          # can never fit
        eng.step()
        assert mon.summary()["by_trigger"]["rejection"] == 1
        doc = load_incident(mon.paths[0])
        assert doc["context"]["n"] == 1
        assert doc["metrics"]["rejections"] == 1       # registry was bound
        assert doc["ring"]["events"]                   # flight recorder dump

    def test_slo_breach_incident_fires_end_to_end(self, model, tmp_path):
        from repro.serving import Request

        mon = IncidentMonitor(str(tmp_path / "inc"), slo_ttft_s=1e-9,
                              rev="t")                 # everything breaches
        eng = _small_engine(model, incidents=mon)
        eng.run([Request(uid=0, prompt=np.arange(8) + 1, max_new_tokens=3)])
        assert mon.summary()["by_trigger"]["slo_breach"] >= 1

    def test_warmup_incidents_discarded_on_reset(self, model, tmp_path):
        from repro.serving import Request

        mon = IncidentMonitor(str(tmp_path / "inc"), slo_ttft_s=1e-9,
                              rev="t")
        eng = _small_engine(model, incidents=mon)
        eng.run([Request(uid=0, prompt=np.arange(8) + 1, max_new_tokens=3)])
        warm_paths = list(mon.paths)
        assert warm_paths
        eng.reset_run_stats()
        assert mon.summary()["n"] == 0
        assert all(not os.path.exists(p) for p in warm_paths)
        eng.run([Request(uid=1, prompt=np.arange(8) + 1, max_new_tokens=3)])
        assert mon.summary()["by_trigger"]["slo_breach"] >= 1


@pytest.fixture(scope="module")
def storm_twin(model):
    """The preemption-storm quick trace replayed with and without an
    armed monitor — the counters must be bit-identical (attaching the
    incident path cannot perturb the exact-gated baseline)."""
    from benchmarks.workloads import runner
    from benchmarks.workloads.generator import generate, preset

    cfg, params = model
    spec = preset("preemption-storm", quick=True)
    trace = generate(spec)
    d = tempfile.mkdtemp(prefix="obs-incidents-")
    mon = IncidentMonitor(d, prefix="storm", rev="t")
    tr = EventTracer(sink=RingSink(capacity=256))
    b1, e1, r1 = runner.run_workload(spec, cfg, params, trace=trace,
                                     tracer=tr, incidents=mon)
    b0, e0, r0 = runner.run_workload(spec, cfg, params, trace=trace)
    return {"mon": mon, "blocks": (b1, b0), "reqs": (r1, r0),
            "engines": (e1, e0)}


class TestStormIncidents:
    def test_monitor_does_not_perturb_counters(self, storm_twin):
        b1, b0 = storm_twin["blocks"]
        r1, r0 = storm_twin["reqs"]
        assert b1["counters"] == b0["counters"]
        assert b1["trace_fingerprint"] == b0["trace_fingerprint"]
        assert [r.out_tokens for r in r1] == [r.out_tokens for r in r0]

    def test_preemption_incidents_fired_with_flight_recording(self,
                                                              storm_twin):
        mon = storm_twin["mon"]
        assert storm_twin["blocks"][0]["counters"]["preemptions"] > 0
        assert mon.summary()["by_trigger"].get("preemption", 0) >= 1
        doc = load_incident(
            next(p for p in mon.paths if "-preemption-" in p))
        assert doc["ring"]["events"]          # ring dump captured the lead-up
        assert {"uid", "slot", "cursor", "n_preempted"} <= set(doc["context"])
        assert doc["metrics"]["preemptions"] >= 1

    def test_metrics_scrape_of_live_engine(self, storm_twin):
        """Acceptance: a curl-equivalent fetch of the scrape endpoint
        exposes counters/histograms matching ``snapshot()`` exactly."""
        eng = storm_twin["engines"][0]
        snap = eng.metrics.snapshot()
        with MetricsServer(eng.metrics, port=0) as srv:
            js = urllib.request.urlopen(
                srv.url + ".json", timeout=5).read().decode()
            assert json.loads(js) == json.loads(json.dumps(snap))
            samples = parse_samples(
                urllib.request.urlopen(srv.url, timeout=5).read().decode())
        assert samples["tsar_steps"] == snap["steps"]
        assert samples["tsar_preemptions"] == snap["preemptions"]
        assert samples["tsar_ttft_s_count"] == snap["ttft_s"]["n"]
        assert samples["tsar_ttft_s_max"] == pytest.approx(
            snap["ttft_s"]["max"])
        assert math.isfinite(samples["tsar_ttft_s_sum"])

    def test_fresh_engine_percentiles_nan_free(self, model):
        """Satellite: ``latency_percentiles()`` on an engine that has
        served nothing returns the sentinel, never NaN."""
        eng = _small_engine(model)
        pct = eng.latency_percentiles()
        for s in pct.values():
            assert s["n"] == 0 and s["empty"] is True
            assert not any(isinstance(v, float) and math.isnan(v)
                           for v in s.values())
        json.dumps(pct, allow_nan=False)      # strict-JSON safe


class TestSharedPrefixStreamIdentity:
    def test_tee_stream_identity_on_engine_trace(self, model, tmp_path):
        """The tentpole acceptance on a real engine run: TeeSink(memory,
        streaming) over the shared-prefix quick replay — identical
        fingerprints, identical timeline analysis, bounded residency."""
        from benchmarks.workloads import runner
        from benchmarks.workloads.generator import generate, preset

        cfg, params = model
        spec = preset("shared-prefix", quick=True)
        trace = generate(spec)
        sink = StreamingSink(str(tmp_path / "sp.jsonl"), flush_every=64)
        tr = EventTracer(sink=TeeSink(MemorySink(), sink))
        block, eng, reqs = runner.run_workload(spec, cfg, params, trace=trace,
                                               tracer=tr)
        doc = tr.to_perfetto(rev="x")
        info = sink.finalize()
        assert info["fingerprint"] == doc["otherData"]["fingerprint"]
        assert info["n_events"] == len(doc["traceEvents"])
        assert sink.peak_resident_events <= 64
        mem_s = timeline.analyze(doc)
        st_s = timeline.analyze_stream(info["path"])
        st_s.pop("stream")
        assert mem_s == st_s
        assert mem_s["steps"]["n"] == block["counters"]["steps"] > 0
        assert mem_s["prefix"]["hits"] > 0
