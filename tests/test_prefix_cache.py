"""Prefix-caching KV reuse subsystem: radix-cache properties (insert/lookup/
evict round-trips, refcount safety, LRU order), hit-path token identity with
a cold engine, scheduler token-budget accounting, preemption under sharing,
and the chunk_step nonzero-start-offset contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

import repro.configs as configs
from repro.models import model_zoo as zoo
from repro.serving import PagedKVCache, PrefixCache, Request, ServingEngine

BS = 4  # block size for the data-structure tests


@pytest.fixture(scope="module")
def cfg():
    return configs.get("bitnet-2b-4t").reduced()


@pytest.fixture(scope="module")
def model(cfg):
    return cfg, zoo.init_params(cfg, jax.random.PRNGKey(0))


def _fill_and_register(kv, cache, slot, tokens):
    """Simulate a finished prefill: allocate blocks for ``tokens`` in
    ``slot`` and register the full blocks with the cache."""
    assert kv.ensure(slot, len(tokens))
    kv.lengths[slot] = len(tokens)
    cache.insert(tokens, kv.table[slot])


class TestRadixCache:
    """Pure data-structure properties over the real allocator."""

    def _mk(self, cfg, num_blocks=64, capacity=None):
        kv = PagedKVCache(cfg, slots=4, max_len=16 * BS, block_size=BS,
                          num_blocks=num_blocks)
        return kv, PrefixCache(kv, capacity_blocks=capacity)

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(min_value=1, max_value=40),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_insert_lookup_roundtrip(self, cfg, n, seed):
        """A registered sequence matches back exactly its full blocks capped
        below the sequence length, with the registering slot's block ids."""
        kv, pc = self._mk(cfg)
        rng = np.random.default_rng(seed)
        toks = rng.integers(0, 50, size=n).astype(np.int32)
        _fill_and_register(kv, pc, 0, toks)
        want_blocks = min(len(toks) // BS, max(0, (len(toks) - 1) // BS))
        cached, blocks = pc.match(toks)
        assert cached == want_blocks * BS
        assert blocks == [int(kv.table[0, j]) for j in range(want_blocks)]
        # A diverging suffix only matches the shared full blocks.
        div = toks.copy()
        if len(div) > BS:
            div[-1] = (div[-1] + 1) % 50
            c2, _ = pc.match(div)
            assert c2 <= cached
        pc.check()
        # Freeing the slot keeps cached blocks alive (cache holds a ref).
        kv.free_slot(0)
        c3, _ = pc.match(toks)
        assert c3 == cached
        pc.check()

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_refcount_never_negative_random_ops(self, cfg, seed):
        """Random interleavings of fill/register/fork/free/evict keep every
        allocator + tree invariant (refcount >= 0, free list consistent,
        no cached block freed while referenced)."""
        kv, pc = self._mk(cfg, num_blocks=40)
        rng = np.random.default_rng(seed)
        seqs = [rng.integers(0, 20, size=rng.integers(1, 3 * BS + 2))
                .astype(np.int32) for _ in range(4)]
        busy = set()
        for _ in range(30):
            op = rng.integers(0, 4)
            slot = int(rng.integers(0, 4))
            toks = seqs[int(rng.integers(0, len(seqs)))]
            if op == 0 and slot not in busy:
                cached = pc.fork(slot, toks)
                if kv.ensure(slot, len(toks)):
                    kv.lengths[slot] = len(toks)
                    pc.insert(toks, kv.table[slot])
                    busy.add(slot)
                else:
                    kv.free_slot(slot)
                assert cached % BS == 0 and cached < max(len(toks), 1)
            elif op == 1 and slot in busy:
                kv.free_slot(slot)
                busy.discard(slot)
            elif op == 2:
                pc.evict(int(rng.integers(1, 4)))
            else:
                pc.match(toks)
            pc.check()
        for slot in list(busy):
            kv.free_slot(slot)
        pc.check()
        # Draining the cache returns every block to the free list.
        pc.evict(pc.cached_blocks)
        pc.check()
        assert kv.blocks_in_use == 0

    def test_eviction_order_is_lru(self, cfg):
        kv, pc = self._mk(cfg)
        a = np.arange(BS, dtype=np.int32) + 1          # distinct single blocks
        b = np.arange(BS, dtype=np.int32) + 100
        c = np.arange(BS, dtype=np.int32) + 200
        for slot, toks in enumerate((a, b, c)):
            # +1 so the full block is insertable AND matchable (the matcher
            # always leaves >= 1 token to recompute).
            _fill_and_register(kv, pc, slot, np.append(toks, 7))
            kv.free_slot(slot)
        assert pc.cached_blocks == 3
        assert pc.fork(0, np.append(a, 7)) == BS       # touch A
        kv.free_slot(0)
        pc.evict(1)
        assert pc.match(np.append(b, 7))[0] == 0       # B was LRU -> gone
        assert pc.match(np.append(a, 7))[0] == BS
        assert pc.match(np.append(c, 7))[0] == BS
        pc.evict(2)
        assert pc.match(np.append(c, 7))[0] == 0       # C before touched A
        assert pc.cached_blocks == 0
        assert kv.blocks_in_use == 0
        pc.check()

    def test_eviction_never_touches_live_slots(self, cfg):
        kv, pc = self._mk(cfg)
        toks = np.arange(3 * BS + 1, dtype=np.int32)
        _fill_and_register(kv, pc, 0, toks)
        kv.free_slot(0)
        # Slot 1 forks the prefix — its blocks are now live.
        cached = pc.fork(1, toks)
        assert cached == 3 * BS
        freed = pc.evict(10)
        assert freed == 0                              # all cached blocks live
        assert pc.evictable() == 0
        kv.free_slot(1)
        assert pc.evictable() == 3
        assert pc.evict(10) == 3
        pc.check()

    def test_capacity_bound_evicts_lru(self, cfg):
        kv, pc = self._mk(cfg, capacity=2)
        for base in (0, 100, 200):
            toks = np.arange(BS, dtype=np.int32) + base
            slot = 0
            _fill_and_register(kv, pc, slot, np.append(toks, 7))
            kv.free_slot(slot)
        assert pc.cached_blocks <= 2
        assert pc.match(np.append(np.arange(BS, dtype=np.int32), 7))[0] == 0
        pc.check()

    def test_partial_last_block_never_cached(self, cfg):
        """Block-aligned cap: a sequence shorter than one block caches
        nothing; an exact-multiple sequence keeps its last block out of the
        MATCH (>= 1 token always recomputed) though it may be registered."""
        kv, pc = self._mk(cfg)
        short = np.arange(BS - 1, dtype=np.int32)
        _fill_and_register(kv, pc, 0, short)
        assert pc.cached_blocks == 0
        exact = np.arange(2 * BS, dtype=np.int32) + 50
        _fill_and_register(kv, pc, 1, exact)
        cached, _ = pc.match(exact)
        assert cached == BS                            # not 2*BS: last stays hot
        pc.check()


class TestEnginePrefixReuse:
    def _shared_reqs(self, sys_prompt, n=4, tail=16, maxnew=5):
        rng = np.random.default_rng(3)
        tails = [rng.integers(0, 90, size=tail).astype(np.int32)
                 for _ in range(n)]
        return [Request(uid=i,
                        prompt=np.concatenate([sys_prompt, tails[i]]),
                        max_new_tokens=maxnew)
                for i in range(n)]

    def test_shared_prefix_token_identical_and_cheaper(self, model):
        """Acceptance: 75%-shared prompts under the prefix cache produce
        token-identical outputs to the cache-off engine, schedule strictly
        fewer prefill chunk-tokens, and report a nonzero hit rate; the
        cache-off engine's stats carry no prefix keys (PR 4 unchanged)."""
        cfg, params = model
        sys_prompt = (np.arange(48, dtype=np.int32) * 5 + 1) % 90
        mk = lambda: self._shared_reqs(sys_prompt)     # 48 shared / 64 total
        off = ServingEngine(cfg, params, max_len=128, batch_slots=2,
                            prefill_chunk=8)
        r_off = off.run(mk())
        on = ServingEngine(cfg, params, max_len=128, batch_slots=2,
                           prefill_chunk=8, prefix_cache=True)
        r_on = on.run(mk())
        for a, b in zip(r_off, r_on):
            assert a.out_tokens == b.out_tokens, (a.uid, a.out_tokens, b.out_tokens)
        assert on.sched.prefill_tokens_planned < off.sched.prefill_tokens_planned
        assert on.stats["prefill_tokens"] < off.stats["prefill_tokens"]
        assert on.sched.cached_tokens_skipped > 0
        assert on.stats["prefix_hit_rate"] > 0
        assert on.stats["prefix_hit_tokens"] >= 48     # later reqs hit 48 each
        assert "prefix_hit_rate" not in off.stats
        assert "cached_blocks" not in off.stats
        on.prefix.check()

    def test_prefix_cache_off_is_default(self, model):
        cfg, params = model
        eng = ServingEngine(cfg, params, max_len=64, batch_slots=2)
        assert eng.prefix is None
        eng.run([Request(uid=0, prompt=np.arange(6, dtype=np.int32),
                         max_new_tokens=3)])
        assert "prefix_hit_rate" not in eng.stats

    def test_multi_turn_reuse_via_generated_tokens(self, model):
        """A follow-up prompt quoting prompt+answer of a finished request
        hits the registered generated blocks too."""
        cfg, params = model
        eng = ServingEngine(cfg, params, max_len=128, batch_slots=2,
                            prefill_chunk=8, block_size=4, prefix_cache=True)
        first = Request(uid=0, prompt=np.arange(24, dtype=np.int32) % 70,
                        max_new_tokens=8)
        eng.run([first])
        turn2_prompt = np.concatenate(
            [first.prompt, np.asarray(first.out_tokens, np.int32),
             np.arange(5, dtype=np.int32) + 7])
        hit0 = eng.stats["prefix_hit_tokens"]
        follow = Request(uid=1, prompt=turn2_prompt, max_new_tokens=4)
        eng.run([follow])
        # prompt (24) + all but the last generated token (7) are cached;
        # the fork reuses at least the prompt's six 4-token blocks.
        assert eng.stats["prefix_hit_tokens"] - hit0 >= 24
        eng.prefix.check()

    def test_preemption_with_shared_prefix_recovers(self, model):
        """Satellite regression: recompute-preemption of a request whose
        blocks are shared (prefix cache + a sibling fork) must release
        references, not free-list them — outputs stay identical to a roomy
        engine and to cache-off, and the pool drains clean."""
        cfg, params = model
        sys_prompt = (np.arange(16, dtype=np.int32) * 3 + 2) % 80
        mk = lambda: self._shared_reqs(sys_prompt, n=3, tail=8, maxnew=8)
        roomy = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                              prefill_chunk=8, prefix_cache=True).run(mk())
        off = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                            prefill_chunk=8).run(mk())
        # Tight pool: two growing requests + cached blocks must collide.
        tight_eng = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                                  prefill_chunk=8, block_size=4, kv_blocks=16,
                                  prefix_cache=True)
        tight = tight_eng.run(mk())
        assert all(r.done for r in tight)
        for a, b, c in zip(roomy, tight, off):
            assert a.out_tokens == b.out_tokens
            assert a.out_tokens == c.out_tokens
        tight_eng.prefix.check()
        # Every non-cached block is back on the free list.
        assert tight_eng.kv.blocks_in_use == tight_eng.prefix.cached_blocks

    def test_preempted_partial_prefill_reused_at_readmission(self, model):
        """Satellite regression (PR 6): at preemption time the victim's
        partial prefill is registered into the prefix cache, so its
        recompute re-admission forks the already-computed blocks instead of
        re-prefilling from token zero.

        Prompts are pairwise-distinct here, so ``cached_tokens_skipped`` can
        ONLY come from a preempted request re-matching its own registered
        blocks — with registration absent it is provably zero.  The cache-on
        engine must also schedule strictly fewer prefill chunk-tokens than
        the cache-off engine preempting over the same pool."""
        cfg, params = model
        rng = np.random.default_rng(11)
        mk = lambda: [Request(uid=i, prompt=rng.integers(0, 90, size=30 + i),
                              max_new_tokens=6) for i in range(3)]
        rng2 = np.random.default_rng(11)
        mk2 = lambda: [Request(uid=i, prompt=rng2.integers(0, 90, size=30 + i),
                               max_new_tokens=6) for i in range(3)]
        tight = dict(max_len=64, batch_slots=2, prefill_chunk=8,
                     block_size=4, kv_blocks=16)
        roomy = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                              prefill_chunk=8).run(mk())
        on = ServingEngine(cfg, params, prefix_cache=True, **tight)
        r_on = on.run(mk2())
        assert on.stats["preemptions"] > 0, "pool not tight enough to preempt"
        assert on.sched.readmissions > 0
        # The tentpole assertion: re-admissions reused registered partials.
        assert on.sched.cached_tokens_skipped > 0
        assert on.stats["prefix_hit_tokens"] > 0
        for a, b in zip(roomy, r_on):
            assert a.out_tokens == b.out_tokens, (a.uid, a.out_tokens,
                                                  b.out_tokens)
        rng2 = np.random.default_rng(11)
        off = ServingEngine(cfg, params, **tight)
        r_off = off.run(mk2())
        assert off.stats["preemptions"] > 0
        assert off.sched.cached_tokens_skipped == 0
        assert (on.sched.prefill_tokens_planned
                < off.sched.prefill_tokens_planned), \
            "preemption-time registration did not reduce re-prefill work"
        for a, b in zip(r_on, r_off):
            assert a.out_tokens == b.out_tokens
        on.prefix.check()

    def test_pool_pressure_evicts_cache_before_preempting(self, model):
        """A pool mostly consumed by stale cached prefixes must be reclaimed
        by the allocator's evictor hook, not strand admissions."""
        cfg, params = model
        eng = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                            prefill_chunk=8, block_size=4, kv_blocks=20,
                            prefix_cache=True)
        rng = np.random.default_rng(0)
        # Distinct prompts fill the cache with ~unreusable prefixes.
        warm = [Request(uid=i, prompt=rng.integers(0, 90, size=20),
                        max_new_tokens=4) for i in range(3)]
        eng.run(warm)
        assert eng.stats["cached_blocks"] > 0
        more = [Request(uid=9 + i, prompt=rng.integers(0, 90, size=24),
                        max_new_tokens=4) for i in range(2)]
        eng.run(more)
        assert all(r.done and len(r.out_tokens) == 4 for r in more)
        assert eng.stats["prefix_evictions"] > 0
        eng.prefix.check()

    @pytest.mark.parametrize("policy", ["flat", "chunked"])
    def test_finish_at_prefill_end_registers_once(self, model, policy):
        """Satellite regression: a request whose final prefill chunk also
        emits its last token (max_new_tokens=1) used to be registered with
        the prefix cache TWICE in one step — once at prefill end, once at
        finish.  ``PrefixCache.inserts`` counts insert() calls, pinning
        single registration per lifecycle event."""
        cfg, params = model
        eng = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                            prefill_chunk=8, policy=policy,
                            prefix_cache=True)
        eng.run([Request(uid=0, prompt=np.arange(24, dtype=np.int32),
                         max_new_tokens=1)])
        assert eng.prefix.inserts == 1
        # A request that keeps decoding registers once at prefill end and
        # once at finish — two lifecycle events, two inserts.
        eng2 = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                             prefill_chunk=8, policy=policy,
                             prefix_cache=True)
        eng2.run([Request(uid=0, prompt=np.arange(24, dtype=np.int32),
                          max_new_tokens=4)])
        assert eng2.prefix.inserts == 2

    def test_ssm_family_degrades_to_cold(self):
        """Satellite: state-carrying families accept prefix_cache=True but
        degrade gracefully — whole-prefill policy, zero hit rate, identical
        outputs to a cache-off engine."""
        cfg = configs.get("mamba2-780m").reduced()
        params = zoo.init_params(cfg, jax.random.PRNGKey(0))
        mk = lambda: [Request(uid=i, prompt=np.arange(6 + i) % 50,
                              max_new_tokens=4) for i in range(2)]
        eng = ServingEngine(cfg, params, max_len=48, batch_slots=2,
                            prefix_cache=True)
        assert eng.policy == "whole" and eng.prefix is None
        out = eng.run(mk())
        assert eng.stats["prefix_hit_rate"] == 0.0
        assert eng.stats["cached_blocks"] == 0
        ref = ServingEngine(cfg, params, max_len=48, batch_slots=2).run(mk())
        for a, b in zip(out, ref):
            assert a.out_tokens == b.out_tokens


def test_chunk_step_accepts_nonzero_start(model):
    """Model-zoo contract: a chunk starting at lengths[i] > 0 over a
    pre-populated cache matches the same positions computed in one shot."""
    cfg, params = model
    S, split = 24, 16
    toks = (np.arange(S, dtype=np.int32) * 11 + 3) % 80
    cache = zoo.init_cache(cfg, 1, 32)
    logits_a, cache_a = zoo.chunk_step(
        cfg, params, jnp.asarray(toks[None]),
        jnp.arange(S, dtype=jnp.int32)[None], cache,
        jnp.zeros((1,), jnp.int32), train=False)
    cache = zoo.init_cache(cfg, 1, 32)
    _, cache_b = zoo.chunk_step(
        cfg, params, jnp.asarray(toks[None, :split]),
        jnp.arange(split, dtype=jnp.int32)[None], cache,
        jnp.zeros((1,), jnp.int32), train=False)
    logits_b, cache_b = zoo.chunk_step(
        cfg, params, jnp.asarray(toks[None, split:]),
        jnp.arange(split, S, dtype=jnp.int32)[None], cache_b,
        jnp.full((1,), split, jnp.int32), train=False)
    np.testing.assert_allclose(np.asarray(logits_a[:, -1]),
                               np.asarray(logits_b[:, -1]),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(cache_a["k"][:, :, :S]),
                               np.asarray(cache_b["k"][:, :, :S]),
                               rtol=2e-5, atol=2e-5)
