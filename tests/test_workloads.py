"""Property tests for the trace-driven workload generator
(``benchmarks.workloads``): same-seed byte-identity, sampler statistics,
declared shared-prefix structure, and trace serialization round-trips.

These are generator-only tests (no engine, no jax) — the replay integration
lives in ``tests/test_bench_report.py``.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from benchmarks.workloads import generator
from benchmarks.workloads.generator import WorkloadSpec, generate, preset
from benchmarks.workloads.trace import TRACE_VERSION, Trace

PRESETS = sorted(generator.WORKLOADS)


# ---------------------------------------------------------------------------
# determinism / identity
# ---------------------------------------------------------------------------

class TestDeterminism:
    @settings(max_examples=10, deadline=None)
    @given(name=st.sampled_from(PRESETS),
           seed=st.integers(min_value=0, max_value=2**20),
           quick=st.booleans())
    def test_same_seed_byte_identical(self, name, seed, quick):
        """Trace identity is (name, quick, seed): two generator runs must
        produce byte-identical canonical JSON (and thus fingerprints)."""
        a = generate(preset(name, quick=quick, seed=seed))
        b = generate(preset(name, quick=quick, seed=seed))
        assert a.to_json() == b.to_json()
        assert a.fingerprint() == b.fingerprint()

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**20))
    def test_seed_shifts_trace(self, seed):
        a = generate(preset("steady", seed=seed))
        b = generate(preset("steady", seed=seed + 1))
        assert a.fingerprint() != b.fingerprint()

    def test_quick_halves_but_keeps_at_least_two(self):
        for name in PRESETS:
            full = preset(name).n_requests
            quick = preset(name, quick=True).n_requests
            assert 2 <= quick <= full


# ---------------------------------------------------------------------------
# sampler statistics
# ---------------------------------------------------------------------------

class TestSamplers:
    N = 4000  # large-sample checks: tolerances are ~10 standard errors

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**16),
           rate=st.floats(min_value=0.25, max_value=2.0))
    def test_poisson_mean_gap(self, seed, rate):
        rng = np.random.default_rng(seed)
        t = generator._arrivals({"kind": "poisson", "rate": rate}, self.N, rng)
        gaps = np.diff(t)
        assert (gaps >= 0).all()
        assert abs(gaps.mean() - 1.0 / rate) < 0.15 / rate

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**16),
           cv=st.floats(min_value=0.1, max_value=2.0))
    def test_gamma_mean_gap_independent_of_cv(self, seed, cv):
        """The cv knob reshapes burstiness but must preserve the rate."""
        rng = np.random.default_rng(seed)
        t = generator._arrivals({"kind": "gamma", "rate": 0.5, "cv": cv},
                                self.N, rng)
        assert abs(np.diff(t).mean() - 2.0) < 2.0 * 0.15 / min(1.0, cv)**0.5

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**16),
           lo=st.integers(min_value=1, max_value=8),
           width=st.integers(min_value=1, max_value=60))
    def test_uniform_length_mean_and_bounds(self, seed, lo, width):
        hi = lo + width
        rng = np.random.default_rng(seed)
        out = generator._lengths({"kind": "uniform", "lo": lo, "hi": hi},
                                 self.N, rng)
        assert out.min() >= lo and out.max() <= hi
        assert abs(out.mean() - (lo + hi) / 2) < 0.05 * width + 0.25

    def test_lognormal_clipped_to_bounds(self):
        rng = np.random.default_rng(0)
        out = generator._lengths(
            {"kind": "lognormal", "mean": 3.0, "sigma": 0.6,
             "lo": 4, "hi": 96}, self.N, rng)
        assert out.min() >= 4 and out.max() <= 96

    def test_choice_draws_only_declared_values(self):
        rng = np.random.default_rng(0)
        vals = [5, 9, 48, 12]
        out = generator._lengths({"kind": "choice", "values": vals}, 200, rng)
        assert set(out.tolist()) <= set(vals)

    def test_burst_all_arrive_at_zero(self):
        rng = np.random.default_rng(0)
        assert (generator._arrivals({"kind": "burst"}, 16, rng) == 0).all()

    def test_arrivals_start_at_zero_and_are_monotone(self):
        for kind in ("uniform", "poisson", "gamma"):
            rng = np.random.default_rng(1)
            t = generator._arrivals({"kind": kind, "rate": 0.7, "cv": 0.3},
                                    100, rng)
            assert t[0] == 0.0
            assert (np.diff(t) >= 0).all()

    def test_bad_specs_rejected(self):
        rng = np.random.default_rng(0)
        with pytest.raises(ValueError):
            generator._arrivals({"kind": "weird"}, 4, rng)
        with pytest.raises(ValueError):
            generator._arrivals({"kind": "poisson", "rate": 0}, 4, rng)
        with pytest.raises(ValueError):
            generator._arrivals({"kind": "gamma", "rate": 1, "cv": 0}, 4, rng)
        with pytest.raises(ValueError):
            generator._lengths({"kind": "weird"}, 4, rng)
        with pytest.raises(ValueError):
            preset("no-such-workload")


# ---------------------------------------------------------------------------
# shared-prefix structure
# ---------------------------------------------------------------------------

class TestSharedPrefix:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**20),
           groups=st.integers(min_value=1, max_value=4),
           prefix_len=st.integers(min_value=4, max_value=24),
           fraction=st.floats(min_value=0.3, max_value=1.0))
    def test_declared_structure_holds(self, seed, groups, prefix_len,
                                      fraction):
        """Every request's recorded (prefix_group, prefix_len) must match
        the actual token structure: group members share exactly the leading
        prefix and always carry a fresh tail token."""
        spec = WorkloadSpec(
            name="sp-prop", n_requests=24,
            arrival={"kind": "uniform", "rate": 1.0},
            prompt_len={"kind": "fixed", "value": prefix_len + 8},
            output_len={"kind": "fixed", "value": 2},
            shared_prefix={"groups": groups, "prefix_len": prefix_len,
                           "fraction": fraction},
            seed=seed)
        tr = generate(spec)
        by_group = {}
        for r in tr.requests:
            if r.prefix_group < 0:
                assert r.prefix_len == 0
                continue
            assert 0 <= r.prefix_group < groups
            assert r.prefix_len == prefix_len
            assert len(r.prompt) > prefix_len
            by_group.setdefault(r.prefix_group, []).append(r)
        assert by_group, "fraction >= 0.3 over 24 requests never shared"
        heads = {}
        for g, members in by_group.items():
            hs = {tuple(r.prompt[:prefix_len]) for r in members}
            assert len(hs) == 1, f"group {g} does not share its prefix"
            heads[g] = hs.pop()
        # Distinct groups draw distinct prefixes (collision odds ~ vocab^-4).
        assert len(set(heads.values())) == len(heads)

    def test_full_fraction_covers_every_request(self):
        tr = generate(preset("shared-prefix", seed=7))
        assert all(r.prefix_group >= 0 for r in tr.requests)


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------

class TestTraceIO:
    @settings(max_examples=6, deadline=None)
    @given(name=st.sampled_from(PRESETS),
           seed=st.integers(min_value=0, max_value=2**20))
    def test_save_load_roundtrip(self, tmp_path, name, seed):
        tr = generate(preset(name, quick=True, seed=seed))
        p = tmp_path / "trace.json"
        tr.save(str(p))
        tr2 = Trace.load(str(p))
        assert tr2.to_json() == tr.to_json()
        assert tr2.fingerprint() == tr.fingerprint()

    def test_version_gate(self):
        d = generate(preset("steady", quick=True)).to_dict()
        d["version"] = TRACE_VERSION + 1
        with pytest.raises(ValueError, match="version"):
            Trace.from_dict(d)

    def test_spec_roundtrip(self):
        spec = preset("eviction-pressure", quick=True, seed=5)
        assert WorkloadSpec.from_dict(spec.to_dict()) == spec

    def test_registry_covers_presets(self):
        for name in generator.WORKLOADS:
            assert preset(name).name == name
