"""MoE serving: chunked vs whole-prompt prefill under expert-capacity
overflow.

The router's capacity window is computed **per forward call** —
``cap = ceil(s * top_k * capacity_factor / E)`` over that call's sequence
length ``s`` — and the position-in-expert cumsum restarts every call (see
``repro/models/moe.py``).  Consequences for the two serving prefill
policies:

* with ample capacity (dropless, ``capacity_factor=8.0``) nothing
  overflows, every token is routed identically, and chunked prefill is
  exactly equivalent to whole-prompt prefill;
* under heavy overflow (``capacity_factor=0.25``) an 8-token chunk gets its
  own small capacity window while the whole prompt gets one large one, so
  the two policies drop DIFFERENT tokens — a true, documented divergence of
  the serving policies (xfail below), not a bug in either kernel.
"""
import dataclasses

import jax
import numpy as np
import pytest

import repro.configs as configs
from repro.models import model_zoo as zoo
from repro.serving import Request, ServingEngine

ARCH = "deepseek-moe-16b"


@pytest.fixture(scope="module")
def moe_model():
    # capacity_factor only reshapes the dispatch tensor, not the params, so
    # one init serves every capacity variant below.
    cfg = configs.get(ARCH).reduced()
    return cfg, zoo.init_params(cfg, jax.random.PRNGKey(0))


def _serve(cfg, params, policy: str, seed: int):
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=24 + 3 * i),
                    max_new_tokens=6)
            for i in range(2)]
    eng = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                        prefill_chunk=8, policy=policy)
    eng.run(reqs)
    return [r.out_tokens for r in reqs]


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_dropless_chunked_matches_whole(moe_model, seed):
    """Ample capacity: chunked and whole-prompt prefill are equivalent."""
    cfg, params = moe_model
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    assert _serve(cfg, params, "chunked", seed) == \
        _serve(cfg, params, "whole", seed)


@pytest.mark.xfail(
    strict=True,
    reason="TRUE divergence, documented: under heavy overflow "
           "(capacity_factor=0.25, prompt 24 @ chunk 8, request seed 1) the "
           "per-call capacity window differs between an 8-token chunk and "
           "the whole prompt, and the per-chunk position-in-expert cumsum "
           "restarts, so the policies drop different tokens.  Chunked "
           "serving of overflowing MoE configs is approximate by design; "
           "fixing it would need capacity windows carried across chunks.")
def test_overflow_chunked_matches_whole(moe_model):
    cfg, params = moe_model
    cfg = dataclasses.replace(cfg, capacity_factor=0.25)
    assert _serve(cfg, params, "chunked", 1) == \
        _serve(cfg, params, "whole", 1)


def test_overflow_policies_each_deterministic(moe_model):
    """Both policies remain individually deterministic under overflow —
    the divergence above is cross-policy, not run-to-run noise."""
    cfg, params = moe_model
    cfg = dataclasses.replace(cfg, capacity_factor=0.25)
    for policy in ("chunked", "whole"):
        assert _serve(cfg, params, policy, 1) == _serve(cfg, params, policy, 1)
