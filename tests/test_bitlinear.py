"""BitLinear layer: QAT forward/backward, freezing, kernel dispatch,
and the AP/OP dataflow selector."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitlinear, dataflow


@pytest.fixture
def setup():
    key = jax.random.PRNGKey(0)
    p = bitlinear.init(key, 128, 64)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 128))
    return p, x


class TestQAT:
    def test_train_close_to_eval(self, setup):
        p, x = setup
        y_train = bitlinear.apply_train(p, x)
        y_eval = bitlinear.apply_eval(p, x)
        np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_eval),
                                   rtol=0.1, atol=0.1)

    def test_ste_gradients_flow(self, setup):
        p, x = setup

        def loss(p):
            return jnp.sum(bitlinear.apply_train(p, x) ** 2)

        g = jax.grad(loss)(p)
        assert g["w"].shape == p["w"].shape
        assert float(jnp.max(jnp.abs(g["w"]))) > 0.0
        assert not bool(jnp.any(jnp.isnan(g["w"])))

    def test_ste_is_identity_through_quant(self):
        """d/dw of ste_ternarize == identity (the STE contract)."""
        w = jax.random.normal(jax.random.PRNGKey(3), (16, 8))
        g = jax.grad(lambda w: jnp.sum(bitlinear.ste_ternarize(w) * 2.0))(w)
        np.testing.assert_allclose(np.asarray(g), 2.0 * np.ones_like(g), rtol=1e-6)


class TestFrozen:
    def test_all_kernels_agree(self, setup):
        p, x = setup
        fz = bitlinear.freeze(p)
        outs = {k: bitlinear.apply_frozen(fz, x, plan=k)
                for k in ("tsar_lut", "tsar_mxu", "memory_lut", "dense")}
        base = np.asarray(outs["dense"])
        for k, v in outs.items():
            np.testing.assert_allclose(np.asarray(v), base, rtol=0.05, atol=0.1,
                                       err_msg=f"kernel {k} diverges")

    def test_auto_dispatch_runs(self, setup):
        p, x = setup
        fz = bitlinear.freeze(p)
        y = bitlinear.apply_frozen(fz, x)   # plan=None -> auto-select
        assert y.shape == (8, 64)

    def test_packed_storage_is_2bit(self, setup):
        p, _ = setup
        fz = bitlinear.freeze(p)
        weight_bits = 8 * (fz.packed.sign_plane.size + fz.packed.zero_plane.size)
        assert weight_bits == 2 * 128 * 64


class TestDataflowSelector:
    def test_gemv_prefers_op(self):
        """Decode (n=1, high M) -> output-persistent (paper Fig. 7(b))."""
        choice = dataflow.select_kernel(n=1, k=2560, m=6912)
        assert choice.dataflow == "OP"

    def test_gemm_prefers_ap(self):
        """Prefill (high N) -> activation-persistent (paper Fig. 7(a))."""
        choice = dataflow.select_kernel(n=128, k=2560, m=6912)
        assert choice.dataflow == "AP"

    def test_gemv_is_memory_bound_gemm_compute_bound(self):
        """The paper's central bottleneck claim, reproduced by the model."""
        gemv = dataflow.select_kernel(n=1, k=8192, m=45568)
        gemm = dataflow.select_kernel(n=128, k=2560, m=6912)
        assert gemv.bound == "memory"
        assert gemm.bound == "compute"

    def test_layer_plan(self):
        plan = dataflow.layer_plan({
            "qkv": (1, 2560, 7680), "mlp_up": (1, 2560, 6912)})
        assert set(plan) == {"qkv", "mlp_up"}
        assert all(c.kernel in ("tsar_mxu", "tsar_lut") for c in plan.values())
