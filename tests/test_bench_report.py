"""The persisted BENCH_e2e report and its regression gate:

* schema round-trip — load / validate / dump reproduces the exact document
  (canonical JSON is byte-stable);
* validator — malformed documents fail with the offending path named;
* comparator — an identical run passes, an injected 20% TTFT regression is
  flagged at the default tolerance, deterministic-counter drift and trace-
  fingerprint drift are flagged, and the CLI exit codes match;
* replay integration — one real (reduced-model) workload replay produces a
  schema-valid report block whose deterministic counters reproduce exactly
  across a second replay of the same trace.
"""
import copy

import pytest

from benchmarks import compare
from benchmarks.workloads import schema
from benchmarks.workloads.generator import preset

FP = "sha256:" + "0" * 64


def _pct(v, n=4):
    return {"p50": v, "p90": v * 1.2, "p99": v * 1.5, "mean": v * 1.05,
            "max": v * 2, "n": n}


def _block(ttft=0.1):
    return {
        "spec": {"name": "synthetic"},
        "trace_fingerprint": FP,
        "metrics": {
            "ttft_s": _pct(ttft),
            "tpot_s": _pct(0.01),
            "queue_s": _pct(0.05),
            "goodput": {"slo_attained": 1.0, "good": 4, "total": 4,
                        "good_per_s": 2.0},
            "output_tok_s": 100.0,
            "wall_s": 2.0,
        },
        "counters": {
            "steps": 10, "preemptions": 1, "preempt_readmissions": 1,
            "prefill_tokens": 64, "prefill_tokens_planned": 64,
            "cached_tokens_skipped": 0, "decode_tokens": 16,
            "total_tokens": 80, "max_step_tokens": 20, "peak_kv_blocks": 8,
            "whole_prefills": 0, "planned_tokens": 200,
            "realized_tokens": 80, "prefill_steps": 6, "decode_steps": 4,
            "admissions": 5, "plan_kernel": "tsar_mxu",
        },
    }


def _report():
    return schema.make_report(arch="bitnet-2b-4t-reduced", seed=0, quick=True,
                              workloads={"steady": _block()},
                              created_unix=123.0, rev="deadbeef")


# ---------------------------------------------------------------------------
# schema
# ---------------------------------------------------------------------------

class TestSchema:
    def test_roundtrip_byte_exact(self, tmp_path):
        doc = _report()
        p = tmp_path / "BENCH_e2e.json"
        schema.save(doc, str(p))
        loaded = schema.load(str(p))
        assert loaded == doc
        # load -> validate -> dump reproduces the on-disk bytes exactly.
        assert schema.dumps(loaded) == p.read_text()

    def test_validator_names_offending_path(self):
        doc = _report()
        del doc["workloads"]["steady"]["counters"]["preemptions"]
        with pytest.raises(ValueError, match=r"counters.*preemptions"):
            schema.validate(doc)

    def test_validator_rejects_bad_fingerprint(self):
        doc = _report()
        doc["workloads"]["steady"]["trace_fingerprint"] = "md5:nope"
        with pytest.raises(ValueError, match="fingerprint"):
            schema.validate(doc)

    def test_validator_rejects_wrong_version_and_kind(self):
        doc = _report()
        doc["kind"] = "BENCH_other"
        with pytest.raises(ValueError, match="kind"):
            schema.validate(doc)
        doc = _report()
        doc["schema_version"] = schema.SCHEMA_VERSION + 1
        with pytest.raises(ValueError, match="schema_version"):
            schema.validate(doc)

    def test_validator_rejects_missing_percentile(self):
        doc = _report()
        del doc["workloads"]["steady"]["metrics"]["ttft_s"]["p99"]
        with pytest.raises(ValueError, match="p99"):
            schema.validate(doc)

    def test_validator_requires_registry_counters(self):
        """v2: registry step accounting is part of the required counter set."""
        for k in ("planned_tokens", "realized_tokens", "prefill_steps",
                  "decode_steps", "admissions"):
            doc = _report()
            del doc["workloads"]["steady"]["counters"][k]
            with pytest.raises(ValueError, match=k):
                schema.validate(doc)

    def test_validator_requires_slo_calibration_provenance(self):
        doc = _report()
        assert doc["slo_scale"] == 1.0 and doc["ref_decode_step_s"] == 0.0
        del doc["slo_scale"]
        with pytest.raises(ValueError, match="slo_scale"):
            schema.validate(doc)
        doc = _report()
        doc["ref_decode_step_s"] = "fast"
        with pytest.raises(ValueError, match="ref_decode_step_s"):
            schema.validate(doc)

    def test_validator_checks_optional_obs_trace_block(self):
        doc = _report()
        doc["workloads"]["steady"]["obs_trace"] = {
            "path": "trace.json", "fingerprint": FP,
            "schema_version": 1, "n_events": 42}
        schema.validate(doc)   # well-formed attachment passes
        doc["workloads"]["steady"]["obs_trace"]["fingerprint"] = "md5:nope"
        with pytest.raises(ValueError, match="obs_trace.fingerprint"):
            schema.validate(doc)
        del doc["workloads"]["steady"]["obs_trace"]["fingerprint"]
        with pytest.raises(ValueError, match="fingerprint"):
            schema.validate(doc)


# ---------------------------------------------------------------------------
# comparator
# ---------------------------------------------------------------------------

class TestCompare:
    def test_identical_run_passes(self):
        assert compare.compare(_report(), _report()) == []

    def test_injected_20pct_ttft_regression_flagged(self):
        """The acceptance scenario: +20% on TTFT percentiles must trip the
        default 15% timing tolerance."""
        run = _report()
        m = run["workloads"]["steady"]["metrics"]["ttft_s"]
        for k in ("p50", "p90", "p99", "mean", "max"):
            m[k] *= 1.20
        regs = compare.compare(run, _report())
        assert regs and all("ttft_s" in r for r in regs)
        # ...and a looser CI tolerance lets the same run through.
        assert compare.compare(run, _report(), timing_tol=1.0) == []

    def test_timing_floor_absorbs_micro_jitter(self):
        """Sub-floor absolute deltas never flag, however large relatively."""
        run = _report()
        m = run["workloads"]["steady"]["metrics"]["tpot_s"]
        m["p50"] *= 1.19   # +19% of 10ms = 1.9ms < the 2ms floor
        assert compare.compare(run, _report()) == []

    def test_counter_drift_gated_exactly(self):
        run = _report()
        run["workloads"]["steady"]["counters"]["preemptions"] += 1
        regs = compare.compare(run, _report())
        assert any("preemptions" in r for r in regs)
        assert compare.compare(run, _report(), counter_tol=2.0) == []

    def test_plan_kernel_change_flagged(self):
        run = _report()
        run["workloads"]["steady"]["counters"]["plan_kernel"] = "mem"
        assert any("plan_kernel" in r
                   for r in compare.compare(run, _report()))

    def test_goodput_drop_flagged(self):
        run = _report()
        g = run["workloads"]["steady"]["metrics"]["goodput"]
        g["slo_attained"] = 0.75
        assert any("goodput" in r for r in compare.compare(run, _report()))

    def test_trace_drift_blocks_unless_allowed(self):
        run = _report()
        run["workloads"]["steady"]["trace_fingerprint"] = \
            "sha256:" + "f" * 64
        assert any("fingerprint" in r for r in compare.compare(run, _report()))
        assert compare.compare(run, _report(), allow_trace_drift=True) == []

    def test_missing_workload_flagged(self):
        run = _report()
        run["workloads"]["extra"] = copy.deepcopy(
            run["workloads"]["steady"])
        # run superset of baseline: fine.
        assert compare.compare(run, _report()) == []
        # baseline superset of run: regression.
        assert any("missing" in r for r in compare.compare(_report(), run))

    def test_quick_mismatch_incomparable(self):
        run = _report()
        run["quick"] = False
        regs = compare.compare(run, _report())
        assert regs and "not comparable" in regs[0]

    def test_cli_exit_codes(self, tmp_path, capsys):
        base_p, run_p = tmp_path / "base.json", tmp_path / "run.json"
        schema.save(_report(), str(base_p))
        run = _report()
        run["workloads"]["steady"]["metrics"]["ttft_s"]["p99"] *= 1.5
        schema.save(run, str(run_p))
        assert compare.main([str(run_p), str(base_p)]) == 1
        assert "REGRESSIONS" in capsys.readouterr().out
        assert compare.main([str(base_p), str(base_p)]) == 0
        assert compare.main(["/nonexistent.json", str(base_p)]) == 2


# ---------------------------------------------------------------------------
# replay integration (real engine, reduced model)
# ---------------------------------------------------------------------------

class TestReplayIntegration:
    @pytest.fixture(scope="class")
    def replayed(self):
        import jax

        import repro.configs as configs
        from benchmarks.workloads import runner
        from repro.models import model_zoo as zoo

        cfg = configs.get("bitnet-2b-4t").reduced()
        params = zoo.init_params(cfg, jax.random.PRNGKey(0))
        spec = preset("decode-heavy", quick=True)
        block, engine, reqs = runner.run_workload(spec, cfg, params)
        block2, _, reqs2 = runner.run_workload(spec, cfg, params)
        return cfg, spec, block, block2, reqs, reqs2

    def test_report_block_is_schema_valid_and_roundtrips(self, replayed,
                                                         tmp_path):
        cfg, spec, block, _, reqs, _ = replayed
        assert all(r.out_tokens for r in reqs), "replay left requests undone"
        doc = schema.make_report(arch=cfg.name, seed=spec.seed, quick=True,
                                 workloads={spec.name: block},
                                 created_unix=1.0, rev="test")
        p = tmp_path / "BENCH_e2e.json"
        schema.save(doc, str(p))
        assert schema.dumps(schema.load(str(p))) == p.read_text()
        m = block["metrics"]
        assert m["ttft_s"]["n"] == spec.n_requests
        assert m["goodput"]["total"] == spec.n_requests

    def test_deterministic_side_reproduces_exactly(self, replayed):
        """Same trace, same code: counters, fingerprints and emitted tokens
        must match exactly across replays (greedy decoding) — the property
        the comparator's exact counter gate stands on."""
        _, _, block, block2, reqs, reqs2 = replayed
        assert block["trace_fingerprint"] == block2["trace_fingerprint"]
        assert block["counters"] == block2["counters"]
        assert [r.out_tokens for r in reqs] == [r.out_tokens for r in reqs2]

    def test_comparator_passes_self(self, replayed):
        cfg, spec, block, block2, _, _ = replayed
        mk = lambda b: schema.make_report(
            arch=cfg.name, seed=spec.seed, quick=True,
            workloads={spec.name: b}, created_unix=1.0, rev="test")
        # Two real replays of the same trace differ only in wall clock —
        # the loose-timing CI configuration must pass them.
        assert compare.compare(mk(block), mk(block2), timing_tol=10.0,
                               timing_floor=1.0) == []
