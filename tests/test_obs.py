"""The observability subsystem (``repro.obs``): typed metrics registry,
``engine.stats`` back-compat view, event tracer + Perfetto export, timeline
analysis, and the engine wiring contracts:

* tracing OFF is the default and near-free — an untraced engine runs the
  no-op recorder and its deterministic counters are bit-identical to a
  traced twin on the same workload trace;
* tracing ON yields a deterministic event *structure* — same-seed replays
  produce identical structure fingerprints (wall clock lives only in
  ts/dur), and every request's span sequence is well-formed
  (property-tested via the hypothesis shim);
* ``reset_run_stats`` REBASES peak gauges to current state instead of
  zeroing them (the satellite fix pinned here);
* per-machine SLO calibration scales ``is_good`` thresholds and is recorded
  in the report provenance.
"""
import json
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.obs import NULL_TRACER, MetricsRegistry, StatsView
from repro.obs import timeline
from repro.obs import trace as obs_trace
from repro.obs.metrics import Counter, Gauge, Histogram
from repro.obs.trace import EventTracer


# ---------------------------------------------------------------------------
# metrics registry (pure, no jax)
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_counter(self):
        c = Counter("x")
        c.inc()
        c.inc(4)
        assert c.value == 5
        c.set(2)          # legacy write-through hook
        assert c.value == 2
        c.reset()
        assert c.value == 0

    def test_gauge_tracks_peak_and_rebases(self):
        g = Gauge("kv")
        g.set(7)
        g.set(3)
        assert (g.value, g.peak) == (3, 7)
        g.reset_peak()    # REBASE to current, not zero
        assert (g.value, g.peak) == (3, 3)
        g.set(5)
        assert g.peak == 5

    def test_histogram_summary(self):
        h = Histogram("lat")
        empty = h.summary()
        # empty histograms return an explicit NaN-free sentinel, not NaN
        assert empty["n"] == 0 and empty["empty"] is True
        assert empty["p50"] == 0.0 and empty["mean"] == 0.0
        assert not any(isinstance(v, float) and math.isnan(v)
                       for v in empty.values())
        for v in range(1, 101):
            h.observe(v / 100.0)
        h.observe(None)   # ignored, like an unfinished request's ttft
        s = h.summary()
        assert s["n"] == 100
        assert s["p50"] == pytest.approx(0.505, abs=0.01)
        assert s["p99"] <= s["max"] == 1.0
        assert h.percentile(50) == pytest.approx(s["p50"])

    def test_histogram_bounds_memory(self):
        h = Histogram("x", max_obs=8)
        for v in range(10):
            h.observe(v)
        assert h.count <= 8
        assert h.summary()["max"] == 9.0   # recent half survives

    def test_registry_typed_redeclare(self):
        reg = MetricsRegistry()
        c = reg.counter("steps")
        assert reg.counter("steps") is c          # declare-or-get
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("steps")
        f = reg.counter("t", labels=("phase",))
        with pytest.raises(ValueError, match="already registered"):
            reg.counter("t")                      # labeled vs not

    def test_labels(self):
        reg = MetricsRegistry()
        f = reg.counter("step_time_s", labels=("phase",))
        f.labels(phase="prefill").inc(2.0)
        f.labels(phase="decode").inc(1.0)
        assert f.labels(phase="prefill").value == 2.0
        with pytest.raises(ValueError, match="declared labels"):
            f.labels(stage="prefill")
        snap = reg.snapshot()
        assert snap["step_time_s{phase=prefill}"] == 2.0

    def test_reset_run_semantics(self):
        reg = MetricsRegistry()
        c, g, h = reg.counter("c"), reg.gauge("g"), reg.histogram("h")
        c.inc(3)
        g.set(9)
        g.set(4)
        h.observe(1.0)
        reg.reset_run()
        assert c.value == 0
        assert (g.value, g.peak) == (4, 4)   # rebased, not zeroed
        assert h.count == 0
        snap = reg.snapshot()
        assert snap["g_peak"] == 4 and snap["c"] == 0


class TestStatsView:
    def _view(self):
        c = Counter("decode_tokens")
        g = Gauge("kv")
        v = StatsView({"decode_tokens": (lambda: c.value, c.set),
                       "peak_kv": (lambda: g.peak, None)})
        return v, c, g

    def test_read_write_through(self):
        v, c, g = self._view()
        c.inc(5)
        assert v["decode_tokens"] == 5
        v["decode_tokens"] = 0        # legacy reset idiom writes through
        assert c.value == 0
        v.update(decode_tokens=7)
        assert c.value == 7

    def test_read_only_key_raises(self):
        v, _, g = self._view()
        g.set(3)
        assert v["peak_kv"] == 3
        with pytest.raises(KeyError, match="read-only"):
            v["peak_kv"] = 0

    def test_extra_keys_and_order(self):
        v, _, _ = self._view()
        v["plan_layers"] = 4          # unknown key -> side dict
        assert list(v) == ["decode_tokens", "peak_kv", "plan_layers"]
        assert dict(v)["plan_layers"] == 4
        assert "plan_layers" in v and len(v) == 3
        del v["plan_layers"]
        assert "plan_layers" not in v


# ---------------------------------------------------------------------------
# tracer + document schema (pure, no jax)
# ---------------------------------------------------------------------------

def _tick():
    """Deterministic fake clock: one unit per call."""
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    return clock


class TestTracer:
    def test_null_tracer_is_inert(self):
        assert NULL_TRACER.enabled is False
        assert NULL_TRACER.begin(1, "req") is None
        assert NULL_TRACER.step(0.1, planned=4) is None
        NULL_TRACER.reset()
        assert not hasattr(NULL_TRACER, "events")

    def test_event_shapes(self):
        tr = EventTracer(clock=_tick())
        tr.begin(3, "req", prompt_len=5)
        tr.mark(3, "admitted", slot=0, cached_len=0, readmission=False)
        tr.instant("kv_pressure", need=2, free=1)
        tr.step(0.5, planned=8, realized=5, kv_blocks=3, active_slots=2)
        tr.end(3, "req")
        phs = [e["ph"] for e in tr.events]
        # step emits X + one counter sample per known series
        assert phs == ["b", "n", "i", "X", "C", "C", "C", "e"]
        x = tr.events[3]
        assert x["dur"] == pytest.approx(0.5e6)
        assert x["ts"] + x["dur"] == pytest.approx(tr.events[2]["ts"] + 1e6)
        names = {e["name"] for e in tr.events if e["ph"] == "C"}
        assert names == {"step_tokens", "kv_blocks", "active_slots"}
        for e in tr.events:
            if e["ph"] in ("b", "e", "n"):
                assert e["cat"] == "req" and e["id"] == 3

    def test_reset_drops_events_and_rebases_epoch(self):
        tr = EventTracer(clock=_tick())
        tr.begin(1, "req")
        first_ts = tr.events[0]["ts"]
        tr.reset()
        assert tr.events == []
        tr.begin(2, "req")
        # epoch rebased: second trace starts near zero again
        assert tr.events[0]["ts"] == pytest.approx(first_ts)

    def test_fingerprint_ignores_wall_clock_only(self):
        def record(clock):
            tr = EventTracer(clock=clock)
            tr.begin(1, "req")
            tr.step(0.1, planned=4, realized=4)
            tr.end(1, "req")
            return tr

        a, b = record(_tick()), record(lambda t=[0.0]: (t.__setitem__(
            0, t[0] + 17.3) or t[0]))
        fa = obs_trace.structure_fingerprint(a.events)
        assert fa == obs_trace.structure_fingerprint(b.events)
        # ...but any structural change shifts it
        c = record(_tick())
        c.events[1]["args"]["planned"] = 5
        assert obs_trace.structure_fingerprint(c.events) != fa

    def test_save_load_validate_roundtrip(self, tmp_path):
        tr = EventTracer(clock=_tick())
        tr.begin(1, "req")
        tr.step(0.2, planned=4, realized=3)
        tr.end(1, "req")
        p = tmp_path / "trace.json"
        doc = tr.save(str(p), rev="testrev")
        od = doc["otherData"]
        assert od["kind"] == obs_trace.TRACE_KIND
        assert od["schema_version"] == obs_trace.TRACE_SCHEMA_VERSION
        assert od["git_rev"] == "testrev"
        loaded = obs_trace.load(str(p))
        assert loaded == doc
        # canonical serialization round-trips byte-exact
        assert obs_trace.dumps(loaded) == p.read_text()
        # metadata events name the process/threads for the Perfetto UI
        metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
        assert {m["name"] for m in metas} == {"process_name", "thread_name"}

    def test_validate_rejects_tampering(self, tmp_path):
        tr = EventTracer(clock=_tick())
        tr.begin(1, "req")
        tr.end(1, "req")
        doc = tr.to_perfetto(rev="x")
        obs_trace.validate(doc)
        bad = json.loads(json.dumps(doc))
        bad["traceEvents"][-1]["args"]["injected"] = True
        with pytest.raises(ValueError, match="fingerprint"):
            obs_trace.validate(bad)
        bad = json.loads(json.dumps(doc))
        bad["traceEvents"][-1]["ph"] = "Z"
        with pytest.raises(ValueError, match="unknown phase"):
            obs_trace.validate(bad)
        bad = json.loads(json.dumps(doc))
        del bad["otherData"]["kind"]
        with pytest.raises(ValueError, match="kind"):
            obs_trace.validate(bad)

    def test_step_annotation_is_context_manager(self):
        # Works with or without a usable jax.profiler — never raises.
        with obs_trace.step_annotation(3):
            pass


# ---------------------------------------------------------------------------
# timeline analysis CLI (synthetic docs, no jax)
# ---------------------------------------------------------------------------

def _synthetic_tracer():
    """A hand-built lifecycle with one preemption and one prefix hit."""
    tr = EventTracer(clock=_tick())
    tr.begin(1, "req", prompt_len=8)
    tr.begin(1, "queued")
    tr.end(1, "queued")
    tr.mark(1, "admitted", slot=0, cached_len=4, readmission=False)
    tr.mark(1, "prefix_hit", cached_len=4)
    tr.begin(1, "prefill", slot=0, cached_len=4)
    tr.step(0.2, step=0, planned=8, realized=6, prefill_tokens=4,
            decode_tokens=2, kv_blocks=3, active_slots=1, kernel="tsar_mxu")
    tr.instant("kv_pressure", slot=0, need=2, free=0)
    tr.end(1, "prefill", preempted=True)
    tr.mark(1, "preempted", slot=0, cursor=4, cached_len=4)
    tr.begin(1, "queued")
    tr.end(1, "queued")
    tr.mark(1, "admitted", slot=0, cached_len=4, readmission=True)
    tr.begin(1, "prefill", slot=0, cached_len=4)
    tr.end(1, "prefill")
    tr.begin(1, "decode")
    tr.mark(1, "first_token")
    tr.step(0.1, step=1, planned=2, realized=2, prefill_tokens=0,
            decode_tokens=2, kv_blocks=4, active_slots=1, kernel="tsar_mxu")
    tr.end(1, "decode")
    tr.mark(1, "finished", n_out=3, preemptions=1)
    tr.end(1, "req")
    return tr


class TestTimeline:
    def test_analyze_synthetic(self):
        doc = _synthetic_tracer().to_perfetto(rev="x")
        s = timeline.analyze(doc)
        st_ = s["steps"]
        assert st_["n"] == 2 and st_["prefill"] == 1 and st_["decode"] == 1
        assert st_["planned_tokens"] == 10 and st_["realized_tokens"] == 8
        assert st_["budget_utilization"] == pytest.approx(0.8)
        assert st_["kernel_steps"] == {"tsar_mxu": 2}
        assert s["n_requests"] == 1
        assert s["spans_us"]["queued"]["n"] == 2
        assert s["spans_us"]["prefill"]["n"] == 2
        pre = s["preemptions"]
        assert pre["n"] == 1 and pre["readmitted"] == 1
        chain = pre["chains"][0]
        assert chain["cause"]["event"] == "kv_pressure"
        assert chain["finished"]
        assert s["prefix"] == {"hits": 1, "hit_tokens": 4, "inserts": 0,
                               "evictions_by_cause": {}}
        assert s["kv_pressure_events"] == 1
        # the text renderer handles the full summary without crashing
        txt = timeline.format_summary(s)
        assert "budget utilization: 80.0%" in txt

    def test_cli_require_gate(self, tmp_path, capsys):
        p = tmp_path / "t.json"
        _synthetic_tracer().save(str(p), rev="x")
        assert timeline.main([str(p)]) == 0
        assert timeline.main([str(p), "--require", "prefill-span",
                              "decode-span", "prefix-hit", "preemption",
                              "step"]) == 0
        capsys.readouterr()
        assert timeline.main([str(p), "--json"]) == 0
        assert json.loads(capsys.readouterr().out)["steps"]["n"] == 2
        # a step-only trace misses the lifecycle features -> exit 1
        tr = EventTracer(clock=_tick())
        tr.step(0.1, planned=2, realized=2)
        q = tmp_path / "steps.json"
        tr.save(str(q), rev="x")
        assert timeline.main([str(q), "--require", "prefill-span"]) == 1
        assert "MISSING" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# engine integration (reduced model)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model():
    import jax

    import repro.configs as configs
    from repro.models import model_zoo as zoo

    cfg = configs.get("bitnet-2b-4t").reduced()
    return cfg, zoo.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def storm(model):
    """The preemption-storm quick trace replayed three ways: traced twice
    (same seed — structure must reproduce) and untraced (counters must be
    bit-identical to the traced runs)."""
    from benchmarks.workloads import runner
    from benchmarks.workloads.generator import generate, preset

    cfg, params = model
    spec = preset("preemption-storm", quick=True)
    trace = generate(spec)
    t1, t2 = EventTracer(), EventTracer()
    b1, e1, r1 = runner.run_workload(spec, cfg, params, trace=trace,
                                     tracer=t1)
    b2, e2, r2 = runner.run_workload(spec, cfg, params, trace=trace,
                                     tracer=t2)
    b0, e0, r0 = runner.run_workload(spec, cfg, params, trace=trace)
    return {"spec": spec, "trace": trace, "tracers": (t1, t2),
            "blocks": (b1, b2, b0), "engines": (e1, e2, e0),
            "reqs": (r1, r2, r0)}


def _spans_by_uid(events):
    seq: dict = {}
    for e in events:
        if e.get("ph") in ("b", "e", "n"):
            seq.setdefault(e["id"], []).append((e["ph"], e["name"], e))
    return seq


class TestEngineTracing:
    def test_untraced_engine_runs_null_tracer(self, storm):
        e0 = storm["engines"][2]
        assert e0.tracer is NULL_TRACER
        assert not hasattr(e0.tracer, "events")

    def test_tracing_off_counters_bit_identical(self, storm):
        """The near-zero-overhead contract, in its strongest observable
        form: attaching a tracer changes NO deterministic counter and no
        emitted token."""
        b1, _, b0 = storm["blocks"]
        r1, _, r0 = storm["reqs"]
        assert b1["counters"] == b0["counters"]
        assert b1["trace_fingerprint"] == b0["trace_fingerprint"]
        assert [r.out_tokens for r in r1] == [r.out_tokens for r in r0]

    def test_same_seed_replay_identical_structure(self, storm):
        t1, t2 = storm["tracers"]
        assert len(t1.events) == len(t2.events)
        assert (obs_trace.structure_fingerprint(t1.events)
                == obs_trace.structure_fingerprint(t2.events))

    def test_storm_trace_contains_lifecycle(self, storm):
        t1 = storm["tracers"][0]
        names = {(e["ph"], e["name"]) for e in t1.events}
        for needed in (("b", "req"), ("b", "queued"), ("b", "prefill"),
                       ("b", "decode"), ("n", "admitted"),
                       ("n", "first_token"), ("n", "finished"),
                       ("n", "preempted"), ("n", "prefix_hit"),
                       ("X", "step")):
            assert needed in names, f"missing {needed}"
        # preempted marks match the engine's preemption counter
        n_pre = sum(1 for e in t1.events
                    if e.get("ph") == "n" and e["name"] == "preempted")
        assert n_pre == storm["blocks"][0]["counters"]["preemptions"] > 0

    def test_timestamps_monotone_per_track(self, storm):
        t1 = storm["tracers"][0]
        by_tid: dict = {}
        for e in t1.events:
            by_tid.setdefault(e["tid"], []).append(e["ts"])
        for tid, ts in by_tid.items():
            assert all(a <= b for a, b in zip(ts, ts[1:])), \
                f"tid {tid} timestamps regressed"

    def test_saved_doc_validates_and_analyzes(self, storm, tmp_path):
        t1 = storm["tracers"][0]
        p = tmp_path / "storm.json"
        doc = t1.save(str(p))
        s = timeline.analyze(obs_trace.load(str(p)))
        c = storm["blocks"][0]["counters"]
        assert s["steps"]["n"] == c["steps"]
        assert s["steps"]["planned_tokens"] == c["planned_tokens"]
        assert s["steps"]["realized_tokens"] == c["realized_tokens"]
        assert 0.0 < s["steps"]["budget_utilization"] <= 1.0
        assert s["preemptions"]["n"] == c["preemptions"]
        assert s["preemptions"]["readmitted"] >= 1
        assert s["n_requests"] == storm["spec"].n_requests
        assert timeline.main([str(p), "--require", "prefill-span",
                              "decode-span", "preemption", "step"]) == 0


# -- hypothesis-style trace invariants (satellite) ---------------------------

class TestTraceInvariants:
    @settings(max_examples=20, deadline=None)
    @given(pick=st.integers(min_value=0, max_value=10**6))
    def test_request_span_sequences_well_formed(self, storm, pick):
        """For a sampled request: queued precedes admitted precedes
        prefill; no decode activity after finished; every preemption is
        followed by a re-admission or the request never finishes."""
        seq = _spans_by_uid(storm["tracers"][0].events)
        uids = sorted(seq)
        uid = uids[pick % len(uids)]
        evs = seq[uid]
        kinds = [(ph, name) for ph, name, _ in evs]
        # envelope: req opens first, closes last (if closed)
        assert kinds[0] == ("b", "req")
        if ("e", "req") in kinds:
            assert kinds[-1] == ("e", "req")
        open_spans: list = []
        admitted = finished = False
        for ph, name, e in evs:
            if ph == "b":
                if name == "prefill":
                    assert admitted, "prefill span before any admission"
                assert name not in open_spans, f"re-opened {name}"
                open_spans.append(name)
            elif ph == "e":
                assert open_spans and open_spans[-1] == name, (
                    f"unbalanced end {name} over {open_spans}")
                open_spans.pop()
            elif name == "admitted":
                assert "queued" not in open_spans, \
                    "admitted while still queued"
                admitted = True
            elif name == "preempted":
                admitted = False
            elif name == "finished":
                finished = True
            assert not (finished and name in ("prefill_chunk", "admitted",
                                              "preempted")), \
                f"{name} after finished"
        if finished:
            assert not open_spans, f"finished with open spans {open_spans}"
        # preempt => later re-admission (storm replays run to completion)
        pre_idx = [i for i, k in enumerate(kinds) if k == ("n", "preempted")]
        for i in pre_idx:
            later = kinds[i + 1:]
            assert ("n", "admitted") in later or ("n", "finished") not in later

    @settings(max_examples=10, deadline=None)
    @given(which=st.booleans())
    def test_monotone_and_deterministic_per_replay(self, storm, which):
        tr = storm["tracers"][int(which)]
        last: dict = {}
        for e in tr.events:
            t = last.get(e["tid"])
            assert t is None or e["ts"] >= t
            last[e["tid"]] = e["ts"]


# -- engine-level metrics surface -------------------------------------------

class TestEngineMetrics:
    def test_stats_view_keys_and_write_through(self, storm):
        eng = storm["engines"][2]
        keys = list(eng.stats)
        assert keys[:10] == ["prefill_s", "decode_s", "decode_tokens",
                             "total_tokens", "prefill_tokens", "steps",
                             "whole_prefills", "preemptions",
                             "peak_kv_blocks", "max_step_tokens"]
        # the legacy warm-reset idiom still works (test_system uses it)
        old = eng.stats["decode_tokens"]
        eng.stats.update(decode_s=0.0, decode_tokens=0)
        assert eng.stats["decode_tokens"] == 0
        eng.stats["decode_tokens"] = old   # restore for other tests

    def test_latency_percentiles_from_registry(self, storm):
        eng = storm["engines"][0]
        pct = eng.latency_percentiles()
        assert set(pct) == {"ttft_s", "tpot_s", "queue_s"}
        n_req = storm["spec"].n_requests
        assert pct["ttft_s"]["n"] == n_req
        for s in pct.values():
            if s["n"]:
                assert s["p50"] <= s["p99"] <= s["max"]

    def test_reset_run_stats_rebases_peaks(self, model):
        """Satellite: warm-up no longer leaks into steady-state peaks, and
        the rebase starts from live state, not zero."""
        from repro.serving import Request, ServingEngine

        cfg, params = model
        eng = ServingEngine(cfg, params, max_len=48, batch_slots=2,
                            prefill_chunk=8, block_size=8)
        mk = lambda o: [Request(uid=o + i, prompt=np.arange(10) + 1,
                                max_new_tokens=4) for i in range(2)]
        eng.run(mk(0))
        assert eng.stats["peak_kv_blocks"] > 0
        assert eng.stats["max_step_tokens"] > 0
        assert eng.stats["steps"] > 0
        eng.reset_run_stats()
        assert eng.stats["steps"] == 0
        assert eng.stats["decode_tokens"] == 0
        # peaks REBASED to current occupancy (idle engine: nothing held)
        assert eng.stats["peak_kv_blocks"] == int(eng.kv.blocks_in_use)
        assert eng.stats["max_step_tokens"] == 0
        assert eng.latency_percentiles()["ttft_s"]["n"] == 0
        # a fresh run re-establishes peaks from the new run only
        eng.run(mk(10))
        assert eng.stats["peak_kv_blocks"] > 0
        assert eng.stats["max_step_tokens"] > 0

    def test_reset_clears_attached_tracer(self, model):
        from repro.serving import Request, ServingEngine

        cfg, params = model
        tr = EventTracer()
        eng = ServingEngine(cfg, params, max_len=48, batch_slots=2,
                            prefill_chunk=8, block_size=8, tracer=tr)
        eng.run([Request(uid=0, prompt=np.arange(8) + 1, max_new_tokens=3)])
        assert tr.events
        eng.reset_run_stats()
        assert tr.events == []   # warm-up events can't pollute a saved trace


# ---------------------------------------------------------------------------
# SLO calibration (satellite)
# ---------------------------------------------------------------------------

class _FakeReq:
    def __init__(self, ttft, tpot):
        self.out_tokens = [1]
        self.ttft, self.tpot = ttft, tpot


class _FakeTraceReq:
    def __init__(self, slo_ttft_s, slo_tpot_s):
        self.slo_ttft_s, self.slo_tpot_s = slo_ttft_s, slo_tpot_s


class TestSloCalibration:
    def test_is_good_scales_thresholds(self):
        from benchmarks.workloads import metrics as wl_metrics

        tr = _FakeTraceReq(slo_ttft_s=1.0, slo_tpot_s=0.1)
        req = _FakeReq(ttft=1.5, tpot=0.15)
        assert not wl_metrics.is_good(req, tr)                 # unscaled: miss
        assert wl_metrics.is_good(req, tr, slo_scale=2.0)      # slow box: ok
        assert not wl_metrics.is_good(req, tr, slo_scale=0.5)  # fast box

    def test_measure_slo_scale(self, model):
        from benchmarks.workloads import runner

        cfg, params = model
        scale, per_step = runner.measure_slo_scale(cfg, params)
        assert 0.2 <= scale <= 50.0
        assert per_step > 0
        # the report records the calibration as provenance
        from benchmarks.workloads import schema
        doc = schema.make_report(
            arch=cfg.name, seed=0, quick=True,
            workloads={"steady": _minimal_block()},
            created_unix=1.0, rev="t", slo_scale=scale,
            ref_decode_step_s=per_step)
        assert doc["slo_scale"] == scale


def _minimal_block():
    pct = {"p50": 0.1, "p90": 0.1, "p99": 0.1, "mean": 0.1, "max": 0.1,
           "n": 1}
    return {
        "spec": {"name": "s"}, "trace_fingerprint": "sha256:" + "0" * 64,
        "metrics": {"ttft_s": dict(pct), "tpot_s": dict(pct),
                    "queue_s": dict(pct),
                    "goodput": {"slo_attained": 1.0, "good": 1, "total": 1,
                                "good_per_s": 1.0},
                    "output_tok_s": 1.0, "wall_s": 1.0},
        "counters": {"steps": 1, "preemptions": 0,
                     "preempt_readmissions": 0, "prefill_tokens": 1,
                     "prefill_tokens_planned": 1,
                     "cached_tokens_skipped": 0, "decode_tokens": 1,
                     "total_tokens": 2, "max_step_tokens": 1,
                     "peak_kv_blocks": 1, "whole_prefills": 0,
                     "planned_tokens": 2, "realized_tokens": 2,
                     "prefill_steps": 1, "decode_steps": 0,
                     "admissions": 1, "plan_kernel": "tsar_mxu"},
    }
