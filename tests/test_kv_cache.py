"""Block-paged KV cache: allocator bookkeeping, gather/scatter through block
tables, live-token accounting, and slot-recycling isolation."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import model_zoo as zoo
from repro.serving import PagedKVCache, Request, ServingEngine


@pytest.fixture(scope="module")
def cfg():
    return configs.get("bitnet-2b-4t").reduced()


class TestAllocator:
    def test_alloc_free_roundtrip(self, cfg):
        kv = PagedKVCache(cfg, slots=2, max_len=32, block_size=4)
        free0 = kv.free_blocks
        assert kv.ensure(0, 10)          # 3 blocks
        assert kv.n_blocks[0] == 3
        assert kv.ensure(0, 12)          # still 3 (12 = 3*4 exactly)
        assert kv.n_blocks[0] == 3
        assert kv.ensure(0, 13)          # grows to 4
        assert kv.n_blocks[0] == 4
        assert kv.free_blocks == free0 - 4
        handed = set(kv.table[0, :4].tolist())
        assert len(handed) == 4 and 0 not in handed  # unique, scratch reserved
        kv.free_slot(0)
        assert kv.free_blocks == free0
        assert kv.n_blocks[0] == 0 and kv.lengths[0] == 0
        assert (kv.table[0] == 0).all()

    def test_oom_reports_without_allocating(self, cfg):
        kv = PagedKVCache(cfg, slots=2, max_len=32, block_size=4, num_blocks=4)
        assert kv.ensure(0, 12)          # takes all 3 real blocks
        before = kv.n_blocks.copy()
        assert not kv.can_allocate(1)
        assert not kv.ensure(1, 4)       # refused, nothing half-allocated
        assert (kv.n_blocks == before).all()
        kv.free_slot(0)
        assert kv.ensure(1, 4)

    def test_fork_release_refcounts(self, cfg):
        """Shared blocks survive any one holder's free: fork takes a
        reference per block, release returns a block to the free list only
        when the LAST holder lets go."""
        kv = PagedKVCache(cfg, slots=3, max_len=32, block_size=4)
        assert kv.ensure(0, 8)                   # two exclusive blocks
        blocks = [int(kv.table[0, j]) for j in range(2)]
        assert all(kv.refcount[b] == 1 for b in blocks)
        kv.fork_blocks(1, blocks)
        kv.fork_blocks(2, blocks)
        assert all(kv.refcount[b] == 3 for b in blocks)
        free0 = kv.free_blocks
        kv.free_slot(0)
        kv.free_slot(2)
        assert kv.free_blocks == free0           # slot 1 still holds them
        assert all(kv.refcount[b] == 1 for b in blocks)
        kv.check()
        kv.free_slot(1)
        assert kv.free_blocks == free0 + 2
        kv.check()

    def test_fork_into_occupied_slot_rejected(self, cfg):
        kv = PagedKVCache(cfg, slots=2, max_len=32, block_size=4)
        kv.ensure(0, 4)
        kv.ensure(1, 4)
        with pytest.raises(ValueError, match="non-empty"):
            kv.fork_blocks(1, [int(kv.table[0, 0])])
        kv.free_slot(1)
        with pytest.raises(ValueError, match="unowned"):
            kv.fork_blocks(1, [kv._free[-1]])    # free block: not forkable
        with pytest.raises(ValueError, match="scratch"):
            kv.release(0)

    def test_view_covers_chunk_past_max_len(self, cfg):
        kv = PagedKVCache(cfg, slots=2, max_len=32, block_size=4)
        vb = kv.view_blocks(32 + 16)     # near-full slot + chunk-wide write
        assert vb * kv.block_size >= 32 + 16
        assert kv.table_view(vb).shape == (2, vb)


class TestGatherScatter:
    def test_roundtrip_through_block_tables(self, cfg):
        kv = PagedKVCache(cfg, slots=2, max_len=16, block_size=4)
        kv.ensure(0, 8)
        kv.ensure(1, 8)
        key = jax.random.PRNGKey(0)
        kv.pools["k"] = jax.random.normal(key, kv.pools["k"].shape)
        table = kv.table_view(2)
        view = zoo.gather_cache_view(kv.pools, table)
        s0, s1 = int(table[0, 0]), int(table[1, 1])
        np.testing.assert_array_equal(
            np.asarray(view["k"])[:, 0, :4], np.asarray(kv.pools["k"])[:, s0])
        np.testing.assert_array_equal(
            np.asarray(view["k"])[:, 1, 4:8], np.asarray(kv.pools["k"])[:, s1])
        # scatter writes modified blocks back to their pool homes
        view["k"] = view["k"] + 1.0
        pools2 = zoo.scatter_cache_view(kv.pools, table, view)
        np.testing.assert_array_equal(
            np.asarray(pools2["k"])[:, s0], np.asarray(view["k"])[:, 0, :4])
        # untouched pool blocks stay untouched
        owned = set(np.asarray(table).ravel().tolist())
        for blk in range(kv.num_blocks):
            if blk not in owned:
                np.testing.assert_array_equal(
                    np.asarray(pools2["k"])[:, blk],
                    np.asarray(kv.pools["k"])[:, blk])


class TestEngineAccounting:
    @pytest.fixture(scope="class")
    def model(self, cfg):
        return cfg, zoo.init_params(cfg, jax.random.PRNGKey(0))

    def test_blocks_in_use_tracks_live_tokens(self, model):
        """Paged memory claim: blocks in use never exceed
        live_tokens / block_size + one partial block per active slot."""
        cfg, params = model
        eng = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                            prefill_chunk=8, block_size=8)
        rng = np.random.default_rng(0)
        for i, s in enumerate([5, 30, 12, 44]):
            eng.submit(Request(uid=i, prompt=rng.integers(0, 90, size=s),
                               max_new_tokens=5))
        while eng.step():
            live = eng.kv.live_tokens()
            bound = math.ceil(live / eng.kv.block_size) + eng.slots
            assert eng.kv.blocks_in_use <= bound, (eng.kv.blocks_in_use, bound)
        assert eng.kv.blocks_in_use == 0  # all freed at completion

    def test_no_cross_slot_leakage_after_recycle(self, model):
        """A slot recycled to a new request must produce exactly the tokens a
        fresh engine produces — stale cache blocks are never attended."""
        cfg, params = model
        mk = lambda uid, s: Request(
            uid=uid, prompt=(np.arange(s, dtype=np.int32) * 7 + uid) % 83,
            max_new_tokens=6)
        # Third request reuses a recycled slot (2 slots, 3 requests).
        shared = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                               prefill_chunk=8)
        r_shared = shared.run([mk(0, 6), mk(1, 9), mk(2, 13)])
        solo = ServingEngine(cfg, params, max_len=64, batch_slots=2,
                             prefill_chunk=8)
        r_solo = solo.run([mk(2, 13)])
        assert r_shared[2].out_tokens == r_solo[0].out_tokens

    def test_dense_state_families_still_serve(self, model):
        """SSM caches have no paged leaves; the paged engine must still serve
        them (whole-prefill policy, dense per-slot state)."""
        cfg = configs.get("mamba2-780m").reduced()
        params = zoo.init_params(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, params, max_len=48, batch_slots=2)
        assert eng.policy == "whole"
        reqs = [Request(uid=i, prompt=np.arange(4 + i) % 50, max_new_tokens=4)
                for i in range(2)]
        eng.run(reqs)
        assert all(r.done and len(r.out_tokens) == 4 for r in reqs)
