"""Suppression fixture: the attribute store is ignored on its line, the
print is not."""
import jax


class Holder:
    count = 0


H = Holder()


@jax.jit
def step(x):
    H.count = 1  # repro: ignore[jit-purity]
    print("once")
    return x
