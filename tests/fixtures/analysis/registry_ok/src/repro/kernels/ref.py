def dense_ref(t, x):
    return None
