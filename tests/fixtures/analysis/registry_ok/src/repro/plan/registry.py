"""registry-completeness negative fixture: the for-loop registration idiom,
every kernel rowed and oracled — no findings."""

_REGISTRY = {}


def register(impl):
    _REGISTRY[impl.name] = impl


def names():
    return sorted(_REGISTRY)


class Dense:
    name = "dense"

    def lower(self, fz):
        return None


for _impl in (Dense(),):
    register(_impl)
