from repro.kernels import ref

KERNEL_CASES = {
    "dense": dict(oracle=ref.dense_ref),
}
