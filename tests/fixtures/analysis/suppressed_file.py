"""File-level suppression fixture: every jit-purity finding here is off."""
# repro: ignore-file[jit-purity]
import jax


@jax.jit
def step(x):
    print("once")
    return x
