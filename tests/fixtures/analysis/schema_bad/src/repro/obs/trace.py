"""schema-drift positive fixture: a validator comparing the version field
against a bare int literal (the docs mismatch lives in docs/format.md)."""

TRACE_SCHEMA_VERSION = 1
STREAM_SCHEMA_VERSION = 1


def validate(doc):
    if doc["schema_version"] != 1:
        raise ValueError("bad trace")
