"""jit-purity negative fixture: numpy constant tables over static values
and host-side prints are the intended idioms — no findings."""
import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.lru_cache(maxsize=None)
def _table(c):
    # numpy over a static int: deliberate trace-time constant folding
    return np.arange(1 << c)


@jax.jit
def step(x):
    t = jnp.asarray(_table(4))
    return x + t


def host_driver():
    print("host side is free to print")
    return step(jnp.zeros((4,)))
