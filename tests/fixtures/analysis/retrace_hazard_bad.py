"""retrace-hazard positive fixture: method jit, self-closure, list statics."""
import jax


def fn(n, x):
    return x + n


class Engine:
    def __init__(self):
        self.scale = 2.0

    @jax.jit
    def step(self, x):
        return x * 2

    def build(self):
        return jax.jit(lambda x: x * self.scale)


g = jax.jit(fn, static_argnums=[0])
