"""tracer-guard negative fixture: guarded emits via the alias idiom, the
early-exit spelling, and exempt non-emit methods — no findings."""


class Engine:
    def __init__(self, tracer):
        self.tracer = tracer

    def run(self, x):
        tr = self.tracer
        if tr.enabled:
            tr.begin("step")
        if not tr.enabled:
            return x
        tr.mark("ok")
        tr.end("step")
        return x

    def flush(self, path):
        self.tracer.save(path)
