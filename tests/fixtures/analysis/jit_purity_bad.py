"""jit-purity positive fixture: five host side effects in a jitted body."""
import random

import jax
import numpy as np

STATE = {"traces": 0}


class Holder:
    count = 0


H = Holder()


@jax.jit
def step(x):
    global STATE
    print("tracing")
    H.count = 1
    r = random.random()
    y = np.abs(x)
    return y + r
