"""tracer-guard positive fixture: two emits not dominated by an
`.enabled` check."""


class Engine:
    def __init__(self, tracer):
        self.tracer = tracer

    def run(self, x):
        self.tracer.begin("step")
        if x:
            self.tracer.mark("odd")
        return x
