"""retrace-hazard negative fixture: tuple statics, free-function jit,
closures over immutable locals — no findings."""
import functools

import jax


@functools.partial(jax.jit, static_argnums=(0,))
def fn(n, x):
    return x + n


def build(scale):
    return jax.jit(lambda x: x * scale)
