"""traced-branch positive fixture: Python control flow on traced values."""
import jax
import jax.numpy as jnp


@jax.jit
def step(x):
    y = jnp.sum(x)
    if y > 0:
        return y
    while x:
        break
    return -y
