"""flat_step name-seeded-root fixture: the flat serving entry point is
jitted through an engine lambda, so only ROOT_FUNCTION_NAMES seeding makes
its body reachable — the print below must still be flagged."""


def flat_step(cfg, params, tokens, slot, pos, cache, emit_row, train=False):
    print("tracing flat step")
    return tokens
