"""schema-drift negative fixture: validator compares against the named
constant, docs cite the current version — no findings."""

TRACE_SCHEMA_VERSION = 1
STREAM_SCHEMA_VERSION = 1


def validate(doc):
    if doc["schema_version"] != TRACE_SCHEMA_VERSION:
        raise ValueError("bad trace")
