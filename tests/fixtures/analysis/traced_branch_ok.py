"""traced-branch negative fixture: static queries (`is None`, shape, ndim)
stay branchable, and traced selects go through jnp.where — no findings."""
import jax
import jax.numpy as jnp


@jax.jit
def step(x, cache=None):
    if cache is not None and jnp.ndim(x) == 0:
        x = x[None]
    if x.shape[0] > 1:
        x = x[:1]
    return jnp.where(jnp.sum(x) > 0, x, -x)
