from repro.kernels import ref

KERNEL_CASES = {
    "stale": dict(oracle=ref.missing_ref),
}
