"""registry-completeness positive fixture: one unregistered kernel class,
one registered kernel with no conformance row."""

_REGISTRY = {}


def register(impl):
    _REGISTRY[impl.name] = impl


def names():
    return sorted(_REGISTRY)


class Dense:
    name = "dense"

    def lower(self, fz):
        return None


class Ghost:
    name = "ghost"

    def lower(self, fz):
        return None


register(Dense())
