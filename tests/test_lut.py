"""Tests for the LUT GEMM/GEMV algorithms: T-SAR on-the-fly vs memory-LUT
baseline vs dense reference, including the single-shared-LUT compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import lut, ternary


def _setup(seed, n, k, m):
    t = ternary.random_ternary(jax.random.PRNGKey(seed), (k, m))
    a = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, k))
    ref = np.asarray(a @ t.astype(jnp.float32))
    return t, a, ref


class TestTSARLut:
    @pytest.mark.parametrize("c", [2, 4, 8])
    @pytest.mark.parametrize("n,k,m", [(1, 64, 32), (8, 256, 48), (128, 512, 64)])
    def test_matches_dense(self, c, n, k, m):
        t, a, ref = _setup(c * 100 + n, n, k, m)
        ip, iz = ternary.pack_indices(t, c)
        y = lut.tsar_lut_matmul(a, ip, iz, c)
        np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-3)

    def test_single_lut_equals_two_lut(self):
        """Our compressed shared-LUT identity == the paper's two-LUT form."""
        t, a, _ = _setup(7, 4, 128, 32)
        ip, iz = ternary.pack_indices(t, 4)
        y1 = lut.tsar_lut_matmul(a, ip, iz, 4)
        y2 = lut.tsar_lut_matmul_twolut(a, ip, iz, 4)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-4)

    def test_with_scale(self):
        t, a, ref = _setup(11, 2, 64, 16)
        scale = jnp.linspace(0.5, 2.0, 16)
        ip, iz = ternary.pack_indices(t, 4)
        y = lut.tsar_lut_matmul(a, ip, iz, 4, w_scale=scale)
        np.testing.assert_allclose(np.asarray(y), ref * np.asarray(scale), rtol=1e-4, atol=1e-3)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10**6), n=st.integers(1, 4),
           blocks=st.integers(1, 32), m=st.integers(1, 40),
           c=st.sampled_from([2, 4]))
    def test_property_random_shapes(self, seed, n, blocks, m, c):
        k = blocks * c
        t, a, ref = _setup(seed, n, k, m)
        ip, iz = ternary.pack_indices(t, c)
        y = lut.tsar_lut_matmul(a, ip, iz, c)
        np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-3, atol=1e-2)


class TestMemoryLUTBaseline:
    @pytest.mark.parametrize("c", [2, 4])
    def test_matches_dense(self, c):
        t, a, ref = _setup(21, 4, 128, 32)
        li = lut.ternary_lut_indices(t, c)
        y = lut.memory_lut_matmul(a, li, c)
        np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-3)

    def test_precomputed_lut_reuse(self):
        """Steady-state decode: baseline reuses the stored TLUT."""
        t, a, ref = _setup(22, 1, 64, 16)
        li = lut.ternary_lut_indices(t, 4)
        stored = lut.memory_lut_precompute(a, 4)
        y = lut.memory_lut_matmul(a, li, 4, precomputed_lut=stored)
        np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-3)

    def test_lut_sizes_match_paper(self):
        """Baseline stores 3^c entries/block; T-SAR needs 2^c (shared)."""
        a = jax.random.normal(jax.random.PRNGKey(0), (1, 64))
        assert lut.memory_lut_precompute(a, 4).shape == (1, 16, 81)   # 3^4
        assert lut.build_lut(a, 4).shape == (1, 16, 16)               # 2^4


class TestIntPipeline:
    def test_exact_int8_pipeline_close_to_fp(self):
        # int8 absmax quantization: per-element error ~ scale/2, accumulated
        # over K=256 -> relative error stays within a few percent.
        t, a, ref = _setup(31, 8, 256, 64)
        y = lut.bitlinear_matmul_exact_int(a, t, jnp.ones(64))
        denom = np.maximum(np.abs(ref), 1.0)
        assert float(np.max(np.abs(np.asarray(y) - ref) / denom)) < 0.3
        assert float(np.mean(np.abs(np.asarray(y) - ref) / denom)) < 0.02
