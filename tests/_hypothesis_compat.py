"""Drop-in fallback for the tiny subset of `hypothesis` this suite uses.

When the real package is importable it is re-exported unchanged (install it
via ``requirements-dev.txt`` to get shrinking and adversarial search).  When
it is missing — as on the minimal CI/container image — ``@given`` degrades to
drawing a fixed number of seeded pseudo-random examples per test, so the
property tests still collect and run everywhere instead of killing the whole
session at import time.

Supported subset: ``@settings(max_examples=..., deadline=...)``, ``@given``
with keyword strategies, and ``st.integers`` / ``st.sampled_from`` /
``st.booleans`` / ``st.floats`` / ``st.tuples`` / ``st.lists``.
"""
try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class strategies:  # noqa: D401 - namespace mirroring hypothesis.strategies
        @staticmethod
        def integers(min_value=0, max_value=2**31 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_ignored):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def tuples(*strats):
            return _Strategy(
                lambda rng: tuple(s.example(rng) for s in strats))

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_ignored):
            return _Strategy(
                lambda rng: [elements.example(rng)
                             for _ in range(rng.randint(min_size, max_size))])

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                # Deterministic per-test stream: reruns hit the same examples.
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for _ in range(n):
                    drawn = {name: s.example(rng) for name, s in strats.items()}
                    fn(*args, **drawn, **kwargs)

            # Hide the strategy-filled parameters from pytest, which would
            # otherwise try to resolve them as fixtures.
            params = [p for p in inspect.signature(fn).parameters.values()
                      if p.name not in strats]
            wrapper.__signature__ = inspect.Signature(params)
            del wrapper.__wrapped__
            return wrapper

        return deco

    def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco
