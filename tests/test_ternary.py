"""Unit + property tests for the T-SAR algorithmic core (paper Sec. III-A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import lut, ternary


def _rand_ternary(seed, k, m):
    return ternary.random_ternary(jax.random.PRNGKey(seed), (k, m))


class TestDecomposition:
    def test_dense_sparse_identity(self):
        t = _rand_ternary(0, 64, 32).astype(jnp.float32)
        wd, ws = ternary.decompose(t)
        assert set(np.unique(np.asarray(wd))) <= {-1.0, 1.0}
        assert set(np.unique(np.asarray(ws))) <= {0.0, 1.0}
        np.testing.assert_array_equal(np.asarray(ternary.recompose(wd, ws)), np.asarray(t))

    def test_dot_product_decomposition(self):
        """The paper's core identity: <w,a> = <w_D,a> - <w_S,a>."""
        t = _rand_ternary(1, 128, 16).astype(jnp.float32)
        a = jax.random.normal(jax.random.PRNGKey(2), (128,))
        wd, ws = ternary.decompose(t)
        np.testing.assert_allclose(
            np.asarray(a @ t), np.asarray(a @ wd - a @ ws), rtol=1e-5, atol=1e-4)


class TestPacking:
    @pytest.mark.parametrize("k,m", [(8, 4), (64, 32), (256, 100), (1024, 7)])
    def test_roundtrip(self, k, m):
        t = _rand_ternary(k + m, k, m)
        tw = ternary.pack(t.astype(jnp.float32))
        np.testing.assert_array_equal(np.asarray(ternary.unpack(tw)), np.asarray(t))

    def test_matches_numpy_packbits(self):
        t = np.asarray(_rand_ternary(3, 128, 24))
        tw = ternary.pack(jnp.asarray(t, jnp.float32))
        sp, zp = ternary.np_pack_reference(t)
        np.testing.assert_array_equal(np.asarray(tw.sign_plane), sp)
        np.testing.assert_array_equal(np.asarray(tw.zero_plane), zp)

    def test_two_bits_per_weight(self):
        t = _rand_ternary(4, 1024, 512)
        tw = ternary.pack(t.astype(jnp.float32))
        plane_bytes = tw.sign_plane.size + tw.zero_plane.size
        assert plane_bytes * 8 == 2 * 1024 * 512  # 2 bits/weight exactly

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           kb=st.integers(1, 16), m=st.integers(1, 64))
    def test_roundtrip_property(self, seed, kb, m):
        t = _rand_ternary(seed, kb * 8, m)
        tw = ternary.pack(t.astype(jnp.float32))
        np.testing.assert_array_equal(np.asarray(ternary.unpack(tw)), np.asarray(t))


class TestAbsmean:
    def test_values_are_ternary(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        t, scale = ternary.absmean_ternarize(w)
        assert set(np.unique(np.asarray(t))) <= {-1.0, 0.0, 1.0}
        assert scale.shape == (32,)

    def test_batched_leading_dims(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (3, 5, 64, 32))
        t, scale = ternary.absmean_ternarize(w)
        assert t.shape == w.shape and scale.shape == (3, 5, 32)
        # per-matrix gamma: each (64, 32) block independently thresholded
        t0, s0 = ternary.absmean_ternarize(w[1, 2])
        np.testing.assert_array_equal(np.asarray(t[1, 2]), np.asarray(t0))

    def test_reconstruction_error_reasonable(self):
        w = jax.random.normal(jax.random.PRNGKey(1), (512, 256))
        t, scale = ternary.absmean_ternarize(w)
        rel = float(jnp.linalg.norm(w - t * scale[None, :]) / jnp.linalg.norm(w))
        assert rel < 0.65  # ternary keeps the bulk of the signal


class TestActivationQuant:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 8), k=st.integers(1, 300))
    def test_bounded_error(self, seed, n, k):
        a = jax.random.normal(jax.random.PRNGKey(seed), (n, k)) * 3.0
        q, scale = ternary.quantize_activations(a)
        assert q.dtype == jnp.int8
        err = np.abs(np.asarray(q, np.float32) * np.asarray(scale) - np.asarray(a))
        # absmax quant: error bounded by scale/2 per element
        assert (err <= np.asarray(scale) * 0.51 + 1e-6).all()


class TestRaggedPacking:
    """Edge cases: K not a multiple of the bit-pack width (8) or LUT block (c).

    Ragged tails are zero-padded at pack time; the unpackers slice them off,
    so round-trips are exact at any K.
    """

    @pytest.mark.parametrize("k", [1, 3, 7, 9, 13, 127, 133])
    def test_pack_unpack_ragged_k(self, k):
        t = _rand_ternary(k, k, 12)
        tw = ternary.pack(t.astype(jnp.float32))
        assert tw.sign_plane.shape[0] == -(-k // ternary.PACK)
        assert ternary.unpack(tw).shape == (k, 12)
        np.testing.assert_array_equal(np.asarray(ternary.unpack(tw)), np.asarray(t))

    @pytest.mark.parametrize("k,c", [(10, 4), (7, 2), (65, 8), (130, 4), (5, 3)])
    def test_pack_indices_roundtrip_ragged_k(self, k, c):
        t = _rand_ternary(k * 7 + c, k, 9)
        ip, iz = ternary.pack_indices(t, c)
        assert ip.shape == (-(-k // c), 9)
        back = ternary.unpack_indices(ip, iz, c, k)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(t))

    @pytest.mark.parametrize("k,c", [(64, 4), (128, 2), (48, 8)])
    def test_pack_indices_roundtrip_aligned(self, k, c):
        t = _rand_ternary(k + c, k, 16)
        ip, iz = ternary.pack_indices(t, c)
        back = ternary.unpack_indices(ip, iz, c)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(t))

    def test_ragged_pad_bits_are_marked_zero_in_indices(self):
        """pack_indices pads with idx_s bits so the LUT identity contributes
        exactly 0 per pad position."""
        t = jnp.ones((5, 3), jnp.int8)
        ip, iz = ternary.pack_indices(t, 4)
        # last block: rows 4..7 -> row 4 live (+1), rows 5..7 padded zeros
        assert int(ip[1, 0]) == 0b0001
        assert int(iz[1, 0]) == 0b1110

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 200),
           m=st.integers(1, 16))
    def test_roundtrip_property_any_k(self, seed, k, m):
        t = _rand_ternary(seed, k, m)
        tw = ternary.pack(t.astype(jnp.float32))
        np.testing.assert_array_equal(np.asarray(ternary.unpack(tw)), np.asarray(t))

    def test_zero_plane_density(self):
        t = _rand_ternary(42, 133, 10)
        tw = ternary.pack(t.astype(jnp.float32))
        want = float(np.count_nonzero(np.asarray(t))) / t.size
        got = float(ternary.zero_plane_density(tw.zero_plane, 133))
        assert got == pytest.approx(want)


class TestLUTIndices:
    @pytest.mark.parametrize("c", [2, 4, 8])
    def test_index_encoding_bounds(self, c):
        t = _rand_ternary(0, 64, 16)
        ip, iz = ternary.pack_indices(t, c)
        assert ip.shape == (64 // c, 16)
        assert int(jnp.max(ip)) < 2 ** c and int(jnp.max(iz)) < 2 ** c
        # positive and zero encodings are disjoint bitmasks
        assert int(jnp.max(jnp.bitwise_and(ip, iz))) == 0
