"""Unit + property tests for the T-SAR algorithmic core (paper Sec. III-A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import lut, ternary


def _rand_ternary(seed, k, m):
    return ternary.random_ternary(jax.random.PRNGKey(seed), (k, m))


class TestDecomposition:
    def test_dense_sparse_identity(self):
        t = _rand_ternary(0, 64, 32).astype(jnp.float32)
        wd, ws = ternary.decompose(t)
        assert set(np.unique(np.asarray(wd))) <= {-1.0, 1.0}
        assert set(np.unique(np.asarray(ws))) <= {0.0, 1.0}
        np.testing.assert_array_equal(np.asarray(ternary.recompose(wd, ws)), np.asarray(t))

    def test_dot_product_decomposition(self):
        """The paper's core identity: <w,a> = <w_D,a> - <w_S,a>."""
        t = _rand_ternary(1, 128, 16).astype(jnp.float32)
        a = jax.random.normal(jax.random.PRNGKey(2), (128,))
        wd, ws = ternary.decompose(t)
        np.testing.assert_allclose(
            np.asarray(a @ t), np.asarray(a @ wd - a @ ws), rtol=1e-5, atol=1e-4)


class TestPacking:
    @pytest.mark.parametrize("k,m", [(8, 4), (64, 32), (256, 100), (1024, 7)])
    def test_roundtrip(self, k, m):
        t = _rand_ternary(k + m, k, m)
        tw = ternary.pack(t.astype(jnp.float32))
        np.testing.assert_array_equal(np.asarray(ternary.unpack(tw)), np.asarray(t))

    def test_matches_numpy_packbits(self):
        t = np.asarray(_rand_ternary(3, 128, 24))
        tw = ternary.pack(jnp.asarray(t, jnp.float32))
        sp, zp = ternary.np_pack_reference(t)
        np.testing.assert_array_equal(np.asarray(tw.sign_plane), sp)
        np.testing.assert_array_equal(np.asarray(tw.zero_plane), zp)

    def test_two_bits_per_weight(self):
        t = _rand_ternary(4, 1024, 512)
        tw = ternary.pack(t.astype(jnp.float32))
        plane_bytes = tw.sign_plane.size + tw.zero_plane.size
        assert plane_bytes * 8 == 2 * 1024 * 512  # 2 bits/weight exactly

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           kb=st.integers(1, 16), m=st.integers(1, 64))
    def test_roundtrip_property(self, seed, kb, m):
        t = _rand_ternary(seed, kb * 8, m)
        tw = ternary.pack(t.astype(jnp.float32))
        np.testing.assert_array_equal(np.asarray(ternary.unpack(tw)), np.asarray(t))


class TestAbsmean:
    def test_values_are_ternary(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        t, scale = ternary.absmean_ternarize(w)
        assert set(np.unique(np.asarray(t))) <= {-1.0, 0.0, 1.0}
        assert scale.shape == (32,)

    def test_batched_leading_dims(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (3, 5, 64, 32))
        t, scale = ternary.absmean_ternarize(w)
        assert t.shape == w.shape and scale.shape == (3, 5, 32)
        # per-matrix gamma: each (64, 32) block independently thresholded
        t0, s0 = ternary.absmean_ternarize(w[1, 2])
        np.testing.assert_array_equal(np.asarray(t[1, 2]), np.asarray(t0))

    def test_reconstruction_error_reasonable(self):
        w = jax.random.normal(jax.random.PRNGKey(1), (512, 256))
        t, scale = ternary.absmean_ternarize(w)
        rel = float(jnp.linalg.norm(w - t * scale[None, :]) / jnp.linalg.norm(w))
        assert rel < 0.65  # ternary keeps the bulk of the signal


class TestActivationQuant:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 8), k=st.integers(1, 300))
    def test_bounded_error(self, seed, n, k):
        a = jax.random.normal(jax.random.PRNGKey(seed), (n, k)) * 3.0
        q, scale = ternary.quantize_activations(a)
        assert q.dtype == jnp.int8
        err = np.abs(np.asarray(q, np.float32) * np.asarray(scale) - np.asarray(a))
        # absmax quant: error bounded by scale/2 per element
        assert (err <= np.asarray(scale) * 0.51 + 1e-6).all()


class TestLUTIndices:
    @pytest.mark.parametrize("c", [2, 4, 8])
    def test_index_encoding_bounds(self, c):
        t = _rand_ternary(0, 64, 16)
        ip, iz = ternary.pack_indices(t, c)
        assert ip.shape == (64 // c, 16)
        assert int(jnp.max(ip)) < 2 ** c and int(jnp.max(iz)) < 2 ** c
        # positive and zero encodings are disjoint bitmasks
        assert int(jnp.max(jnp.bitwise_and(ip, iz))) == 0
