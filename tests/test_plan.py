"""Execution plans: kernel registry completeness, ModelPlan JSON round-trip,
deprecation-shim equivalence, n-bucket selection, and the serve-path
acceptance — zero ``select_kernel`` calls after engine init, and JSON-loaded
plans serving identically to in-memory ones."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.core import bitlinear, dataflow
from repro.models import layers, model_zoo as zoo
from repro.plan import (
    BatchProfile,
    LayerPlan,
    ModelPlan,
    compile_plan,
    registry,
    runtime,
)
from repro.serving import Request, ServingEngine
from repro.sparse import format as sparse_format

SERVABLE = {"tsar_mxu", "tsar_lut", "tsar_sparse", "tsar_sparse_padded",
            "memory_lut", "dense"}


@pytest.fixture(scope="module")
def frozen_layer():
    p = bitlinear.init(jax.random.PRNGKey(0), 128, 64)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 128))
    return bitlinear.freeze(p), x


@pytest.fixture(scope="module")
def frozen_sparse_layer():
    """A layer frozen with structurally dead blocks (sparse sidecar present)."""
    k = m = 512
    w = jax.random.normal(jax.random.PRNGKey(2), (k, m)) * 0.1
    mask = sparse_format.random_block_sparse_ternary(
        jax.random.PRNGKey(3), (k, m), bk=256, bm=256,
        p_zero_block=0.75, p_zero=0.0).astype(jnp.float32)
    fz = bitlinear.freeze({"w": w * jnp.abs(mask)})
    assert fz.sparse is not None
    x = jax.random.normal(jax.random.PRNGKey(4), (4, k))
    return fz, x


class TestRegistry:
    def test_registry_is_complete(self):
        """Every servable kernel name is registered and vice versa."""
        assert set(registry.names()) == SERVABLE
        assert set(registry.selectable_names()) == {
            "tsar_mxu", "tsar_lut", "tsar_sparse", "tsar_sparse_padded"}
        assert set(registry.SPARSE_KERNELS) == {
            "tsar_sparse", "tsar_sparse_padded"}

    def test_every_registered_kernel_serves(self, frozen_layer,
                                            frozen_sparse_layer):
        """supports() gates lower(): every supported kernel produces the
        right shape through apply_frozen(plan=name)."""
        for frozen, x in (frozen_layer, frozen_sparse_layer):
            names = registry.available(frozen)
            assert set(names) >= SERVABLE - set(registry.SPARSE_KERNELS)
            for name in names:
                y = bitlinear.apply_frozen(frozen, x, plan=name)
                assert y.shape == x.shape[:-1] + (frozen.shape[1],), name

    def test_sparse_gated_by_sidecar(self, frozen_layer, frozen_sparse_layer):
        assert "tsar_sparse" not in registry.available(frozen_layer[0])
        assert "tsar_sparse_padded" not in registry.available(frozen_layer[0])
        assert "tsar_sparse" in registry.available(frozen_sparse_layer[0])
        # freeze emits the padded twin alongside the compacted pool
        assert "tsar_sparse_padded" in registry.available(
            frozen_sparse_layer[0])

    def test_unknown_kernel_raises(self, frozen_layer):
        fz, x = frozen_layer
        with pytest.raises(ValueError, match="unknown kernel"):
            bitlinear.apply_frozen(fz, x, plan="tsar_gpu")

    def test_select_kernel_only_returns_registered(self):
        for n, k, m in [(1, 2560, 6912), (128, 2560, 6912), (8, 4096, 4096)]:
            choice = dataflow.select_kernel(n, k, m)
            assert choice.kernel in registry.selectable_names()
            assert set(choice.detail["candidates"]) == set(
                registry.selectable_names())

    def test_interpret_forces_pallas_off_tpu(self, frozen_layer, monkeypatch):
        """An explicit interpret= request must run the Pallas kernel (that is
        the off-TPU validation path), not the jnp fallback."""
        from repro.kernels import ops

        fz, x = frozen_layer
        called = {"n": 0}
        orig = ops.tsar_matmul

        def spy(*a, **kw):
            called["n"] += 1
            return orig(*a, **kw)

        monkeypatch.setattr(ops, "tsar_matmul", spy)
        y_pal = bitlinear.apply_frozen(fz, x, plan="tsar_mxu", interpret=True)
        assert called["n"] == 1
        # interpret=False means "not interpret mode", NOT "force compiled
        # Pallas" — off-TPU it must keep the jnp fallback, not crash.
        y_no = bitlinear.apply_frozen(fz, x, plan="tsar_mxu", interpret=False)
        assert called["n"] == 1
        # the Pallas kernel is bit-identical to the jnp spelling
        y_jnp = bitlinear.apply_frozen(fz, x, plan="tsar_mxu")
        np.testing.assert_array_equal(np.asarray(y_pal), np.asarray(y_jnp))
        np.testing.assert_array_equal(np.asarray(y_no), np.asarray(y_jnp))

    def test_available_kernels_lower_on_packed_dicts(self):
        """supports() and lower() agree for pack_linear-style plane dicts,
        including ragged K (planes store the padded ceil(K/8)*8)."""
        for k, m in ((128, 64), (133, 64)):
            w = jax.random.normal(jax.random.PRNGKey(11), (k, m)) * 0.1
            p = layers.pack_linear({"w": w})
            x = jax.random.normal(jax.random.PRNGKey(12), (4, k))
            names = registry.available(p)
            assert "tsar_mxu" in names and "dense" in names
            for name in names:
                y = registry.get(name).lower(p, x)
                assert y.shape == (4, m), (name, k)
        # stacked (vmapped) plane dicts are not lowerable directly
        stacked = jax.vmap(layers.pack_linear)(
            {"w": jax.random.normal(jax.random.PRNGKey(13), (2, 64, 32))})
        assert registry.available(stacked) == ()

    def test_cost_methods_match_dataflow_aliases(self):
        n, k, m = 16, 1024, 2048
        assert dataflow._tsar_mxu_cost(n, k, m) == \
            registry.get("tsar_mxu").cost(n, k, m)
        assert dataflow._tsar_lut_cost(n, k, m, 4) == \
            registry.get("tsar_lut").cost(n, k, m, 4)


class TestDeprecationShim:
    """The old string-keyed apply_frozen signature warns but bit-matches."""

    @pytest.mark.parametrize("kernel", ["tsar_mxu", "tsar_lut", "memory_lut",
                                        "dense"])
    def test_old_kernel_arg_bit_matches(self, frozen_layer, kernel):
        fz, x = frozen_layer
        with pytest.warns(DeprecationWarning, match="^repro\\."):
            y_old = bitlinear.apply_frozen(fz, x, kernel=kernel)
        y_new = bitlinear.apply_frozen(fz, x, plan=kernel)
        np.testing.assert_array_equal(np.asarray(y_old), np.asarray(y_new))

    def test_old_use_pallas_false_bit_matches(self, frozen_layer):
        fz, x = frozen_layer
        with pytest.warns(DeprecationWarning):
            y_old = bitlinear.apply_frozen(fz, x, kernel="tsar_mxu",
                                           use_pallas=False)
        y_new = bitlinear.apply_frozen(fz, x, plan="tsar_mxu")
        np.testing.assert_array_equal(np.asarray(y_old), np.asarray(y_new))

    def test_old_auto_bit_matches(self, frozen_sparse_layer):
        fz, x = frozen_sparse_layer
        with pytest.warns(DeprecationWarning):
            y_old = bitlinear.apply_frozen(fz, x, kernel="auto")
        y_new = bitlinear.apply_frozen(fz, x)
        np.testing.assert_array_equal(np.asarray(y_old), np.asarray(y_new))

    def test_new_signature_does_not_warn(self, frozen_layer):
        import warnings

        fz, x = frozen_layer
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            bitlinear.apply_frozen(fz, x, plan="tsar_mxu")
            bitlinear.apply_frozen(fz, x)


class TestModelPlan:
    @pytest.fixture(scope="class")
    def packed_tree(self):
        cfg = configs.get("bitnet-2b-4t").reduced()
        params = zoo.init_params(cfg, jax.random.PRNGKey(0))
        from repro.serving import freeze_params

        return freeze_params(params)

    def test_json_round_trip_equality(self, packed_tree):
        plan = compile_plan(packed_tree,
                            BatchProfile(decode_ns=(1, 4), prefill_ns=(16, 64)))
        assert plan.layers and plan.buckets == (1, 4, 16, 64)
        assert ModelPlan.from_json(plan.to_json()) == plan

    def test_save_load_file(self, packed_tree, tmp_path):
        plan = compile_plan(packed_tree)
        path = tmp_path / "plan.json"
        plan.save(path)
        assert ModelPlan.load(path) == plan

    def test_version_mismatch_raises(self, packed_tree):
        plan = compile_plan(packed_tree)
        bad = plan.to_json().replace('"version": 1', '"version": 99', 1)
        with pytest.raises(ValueError, match="version"):
            ModelPlan.from_json(bad)

    def test_per_layer_density_is_measured(self, packed_tree):
        """compile_plan feeds each layer's stamped density, not one global."""
        plan = compile_plan(packed_tree)
        densities = {lp.density for by_b in plan.layers.values()
                     for lp in by_b.values()}
        assert len(densities) > 1          # layers measured individually
        assert all(0.0 < d <= 1.0 for d in densities)

    def test_bucket_resolution(self, packed_tree):
        plan = compile_plan(packed_tree,
                            BatchProfile(decode_ns=(1, 8), prefill_ns=(64,)))
        assert plan.bucket_for(1) == 1
        assert plan.bucket_for(3) == 8     # smallest bucket >= n
        assert plan.bucket_for(64) == 64
        assert plan.bucket_for(999) == 64  # overflow -> largest

    def test_nbucket_selection_decode_vs_prefill(self):
        """Decode (n=1) and prefill (n=128) buckets commit to different
        dataflows for the same layer (paper Fig. 7)."""
        w = jax.random.normal(jax.random.PRNGKey(0), (512, 2048)) * 0.05
        plan = compile_plan({"proj": {"w": w}},
                            BatchProfile(decode_ns=(1,), prefill_ns=(128,)))
        lp_dec = plan.lookup("proj", 1)
        lp_pre = plan.lookup("proj", 128)
        assert lp_dec.dataflow == "OP"
        assert lp_pre.dataflow == "AP"
        assert lp_dec.kernel in registry.selectable_names()
        assert lp_dec.est_time_s < lp_pre.est_time_s

    def test_layer_plan_wrapper_per_layer_c_and_density(self):
        """The satellite fix: per-layer c / measured densities change the
        per-layer costs instead of one global default."""
        plan = dataflow.layer_plan({
            "dense_mlp": (1, 2560, 6912),
            "expert_c2": {"n": 1, "k": 2560, "m": 6912, "c": 2},
            "pruned": {"n": 1, "k": 2560, "m": 6912, "density": 0.3,
                       "block_density": 0.3},
        })
        assert set(plan) == {"dense_mlp", "expert_c2", "pruned"}
        assert plan["pruned"].kernel == "tsar_sparse"
        assert plan["dense_mlp"].kernel != "tsar_sparse"
        # c rescales the LUT candidate cost
        base = plan["dense_mlp"].detail
        assert plan["expert_c2"].detail["tile_sizes"] is not None
        assert base["bucket"] == 1


class TestPlannedDispatch:
    def test_packed_linear_honors_dense_plan(self):
        """An active plan pinning a layer to 'dense' switches the packed
        forward to the dequantized fp path (observably different math)."""
        w = jax.random.normal(jax.random.PRNGKey(5), (128, 64)) * 0.1
        packed = layers.pack_linear({"w": w})
        x = jax.random.normal(jax.random.PRNGKey(6), (4, 128))
        lp = LayerPlan(kernel="dense", dataflow="OP", tile_sizes=(8, 128, 64),
                       est_time_s=0.0, bound="memory", density=0.66)
        plan = ModelPlan(buckets=(4,), shapes={"l": (128, 64, 4)},
                         layers={"l": {4: lp}})
        y_default = layers.linear(packed, x, train=False)
        with runtime.activate(plan):
            y_planned = layers.linear(packed, x, train=False)
        assert y_planned.shape == y_default.shape
        # fp path: exact dequantized matmul; int8 path: activation-quantized
        np.testing.assert_allclose(np.asarray(y_planned), np.asarray(y_default),
                                   rtol=0.1, atol=0.1)
        assert not np.array_equal(np.asarray(y_planned), np.asarray(y_default))

    def test_conflicting_same_shape_layers_fall_back(self):
        """Two layers sharing (k, m) with DIFFERENT plans: the nameless
        shape lookup must refuse to guess (returns None -> default
        realization), never serve one layer with the other's plan."""
        mk = lambda kern: {1: LayerPlan(kernel=kern, dataflow="OP",
                                        tile_sizes=(), est_time_s=0.0,
                                        bound="memory", density=0.66)}
        plan = ModelPlan(buckets=(1,),
                         shapes={"wk": (128, 64, 4), "wv": (128, 64, 4)},
                         layers={"wk": mk("tsar_mxu"), "wv": mk("dense")})
        assert plan.lookup_shape(128, 64, 1) is None
        assert plan.shape_conflicts() == ((128, 64),)
        # named lookups still resolve per layer
        assert plan.lookup("wv", 1).kernel == "dense"
        # agreeing same-shape layers keep resolving
        ok = ModelPlan(buckets=(1,),
                       shapes={"wk": (128, 64, 4), "wv": (128, 64, 4)},
                       layers={"wk": mk("dense"), "wv": mk("dense")})
        assert ok.lookup_shape(128, 64, 1).kernel == "dense"
        assert ok.shape_conflicts() == ()

    def test_ragged_k_layers_resolve_via_padded_planes(self):
        """Plan shapes store the bitplane-padded K, and lookups accept the
        true K — a ragged-K layer's plan is not silently ignored."""
        w = jax.random.normal(jax.random.PRNGKey(10), (300, 64)) * 0.1
        plan = compile_plan({"proj": {"w": w}},
                            BatchProfile(decode_ns=(1,), prefill_ns=(16,)))
        assert plan.shapes["proj"][0] == 304          # ceil(300/8)*8
        assert plan.lookup_shape(300, 64, 1) is not None
        assert plan.lookup_shape(304, 64, 1) is not None

    def test_planned_sparse_degrades_without_sidecar(self, frozen_layer):
        """A saved plan that picked tsar_sparse, applied to a layer frozen
        without a sidecar (e.g. re-frozen under tracing), degrades to
        tsar_mxu; only the explicit string still raises."""
        fz, x = frozen_layer
        assert fz.sparse is None
        lp = LayerPlan(kernel="tsar_sparse", dataflow="OP", tile_sizes=(),
                       est_time_s=0.0, bound="memory", density=0.5)
        y = bitlinear.apply_frozen(fz, x, plan=lp)     # degrades, same math
        np.testing.assert_array_equal(
            np.asarray(y),
            np.asarray(bitlinear.apply_frozen(fz, x, plan="tsar_mxu")))
        with pytest.raises(ValueError, match="sidecar"):
            bitlinear.apply_frozen(fz, x, plan="tsar_sparse")

    def test_packed_linear_honors_memory_lut_plan(self):
        """A plan pinning 'memory_lut' (the A/B baseline) must actually run
        the DRAM-LUT gather, not the int8-dot path with a wrong label."""
        w = jax.random.normal(jax.random.PRNGKey(15), (128, 64)) * 0.1
        packed = layers.pack_linear({"w": w})
        x = jax.random.normal(jax.random.PRNGKey(16), (4, 128))
        lp = LayerPlan(kernel="memory_lut", dataflow="OP", tile_sizes=(),
                       est_time_s=0.0, bound="memory", density=0.66)
        plan = ModelPlan(buckets=(4,), shapes={"l": (128, 64, 4)},
                         layers={"l": {4: lp}})
        y_default = layers.linear(packed, x, train=False)
        with runtime.activate(plan):
            y_mlut = layers.linear(packed, x, train=False)
        # fp LUT gather vs int8 pipeline: close but not the same bits
        np.testing.assert_allclose(np.asarray(y_mlut), np.asarray(y_default),
                                   rtol=0.1, atol=0.1)
        assert not np.array_equal(np.asarray(y_mlut), np.asarray(y_default))

    def test_layer_plan_dataflow_reaches_pallas_kernel(self, frozen_layer,
                                                       monkeypatch):
        """The LayerPlan's dataflow/tile decisions are executed, not just
        recorded: the Pallas wrapper receives them."""
        from repro.kernels import ops

        fz, x = frozen_layer
        seen = {}
        orig = ops.tsar_matmul

        def spy(*a, **kw):
            seen.update(kw)
            return orig(*a, **kw)

        monkeypatch.setattr(ops, "tsar_matmul", spy)
        lp = LayerPlan(kernel="tsar_mxu", dataflow="OP",
                       tile_sizes=(8, 128, 128), est_time_s=0.0,
                       bound="memory", density=0.66)
        bitlinear.apply_frozen(fz, x, plan=lp, interpret=True)
        assert seen["dataflow"] == "OP"
        assert (seen["bn"], seen["bk"], seen["bm"]) == (8, 128, 128)

    def test_activate_none_is_transparent(self):
        lp = LayerPlan(kernel="tsar_mxu", dataflow="OP", tile_sizes=(),
                       est_time_s=0.0, bound="memory", density=0.66)
        plan = ModelPlan(buckets=(1,), shapes={"l": (8, 8, 4)},
                         layers={"l": {1: lp}})
        with runtime.activate(plan):
            with runtime.activate(None):       # must keep the outer plan
                assert runtime.current() is plan
            assert runtime.current() is plan
        assert runtime.current() is None

    def test_pack_linear_plan_directed_dense(self):
        """A layer the plan pins to 'dense' keeps fp weights at pack time."""
        w = jax.random.normal(jax.random.PRNGKey(7), (64, 32)) * 0.1
        p = layers.pack_linear({"w": w}, lp="dense")
        assert set(p) == {"wd"}
        x = jax.random.normal(jax.random.PRNGKey(8), (2, 64))
        y = layers.linear(p, x, train=False)
        assert y.shape == (2, 32)

    def test_pack_linear_accepts_model_plan(self):
        """pack_linear resolves a whole ModelPlan through the layer name."""
        w = jax.random.normal(jax.random.PRNGKey(9), (64, 32)) * 0.1
        mk = lambda kern: ModelPlan(
            buckets=(1,), shapes={"proj": (64, 32, 4)},
            layers={"proj": {1: LayerPlan(kernel=kern, dataflow="OP",
                                          tile_sizes=(), est_time_s=0.0,
                                          bound="memory", density=0.66)}})
        assert set(layers.pack_linear({"w": w}, mk("dense"),
                                      name="proj")) == {"wd"}
        assert "sign" in layers.pack_linear({"w": w}, mk("tsar_mxu"),
                                            name="proj")
        assert "sign" in layers.pack_linear({"w": w}, mk("dense"))  # no name


class TestServingWithPlan:
    @pytest.fixture(scope="class")
    def model(self):
        cfg = configs.get("bitnet-2b-4t").reduced()
        params = zoo.init_params(cfg, jax.random.PRNGKey(0))
        return cfg, params

    def _reqs(self, n=3):
        return [Request(uid=i, prompt=np.arange(4 + i) % 100, max_new_tokens=5)
                for i in range(n)]

    def test_zero_select_kernel_calls_after_init(self, model, monkeypatch):
        """Acceptance: the plan is compiled once at init; serving performs
        ZERO select_kernel calls afterwards."""
        cfg, params = model
        init_calls = {"n": 0}
        orig = dataflow.select_kernel

        def counting(*a, **kw):
            init_calls["n"] += 1
            return orig(*a, **kw)

        monkeypatch.setattr(dataflow, "select_kernel", counting)
        eng = ServingEngine(cfg, params, max_len=48, batch_slots=2, packed=True)
        assert init_calls["n"] > 0            # plan compilation costed layers
        assert eng.plan is not None
        assert eng.stats["plan_layers"] == len(eng.plan.layers)

        run_calls = {"n": 0}

        def forbidden(*a, **kw):
            run_calls["n"] += 1
            return orig(*a, **kw)

        monkeypatch.setattr(dataflow, "select_kernel", forbidden)
        out = eng.run(self._reqs())
        assert all(r.done for r in out)
        assert run_calls["n"] == 0

    def test_json_loaded_plan_serves_identically(self, model):
        """Acceptance: to_json -> from_json -> serve == in-memory planning."""
        cfg, params = model
        eng_mem = ServingEngine(cfg, params, max_len=48, batch_slots=2,
                                packed=True)
        out_mem = eng_mem.run(self._reqs())
        plan = ModelPlan.from_json(eng_mem.plan.to_json())
        eng_json = ServingEngine(cfg, params, max_len=48, batch_slots=2,
                                 packed=True, plan=plan)
        out_json = eng_json.run(self._reqs())
        for a, b in zip(out_mem, out_json):
            assert a.out_tokens == b.out_tokens

    def test_hand_edited_dense_plan_serves(self, model):
        """The plan is a first-class artifact: an operator can pin layers to
        the dense escape hatch and the engine honors it."""
        cfg, params = model
        base = ServingEngine(cfg, params, max_len=48, batch_slots=2,
                             packed=True)
        dense_layers = {
            name: {n: dataclasses.replace(lp, kernel="dense")
                   for n, lp in by_b.items()}
            for name, by_b in base.plan.layers.items()}
        dense_plan = ModelPlan(buckets=base.plan.buckets,
                               shapes=dict(base.plan.shapes),
                               layers=dense_layers)
        eng = ServingEngine(cfg, params, max_len=48, batch_slots=2,
                            packed=True, plan=dense_plan)
        out = eng.run(self._reqs())
        assert all(r.done for r in out)
        assert eng.plan.dominant_kernel(1) == "dense"

    def test_qat_engine_has_no_plan(self, model):
        cfg, params = model
        eng = ServingEngine(cfg, params, max_len=48, batch_slots=2)
        assert eng.plan is None

    def test_mismatched_plan_warns(self, model):
        """A plan saved for a different config resolves nothing — the engine
        must say so instead of silently serving un-planned."""
        cfg, params = model
        lp = LayerPlan(kernel="tsar_mxu", dataflow="OP", tile_sizes=(),
                       est_time_s=0.0, bound="memory", density=0.66)
        alien = ModelPlan(buckets=(1,), shapes={"other": (4096, 9999, 4)},
                          layers={"other": {1: lp}})
        with pytest.warns(UserWarning, match="resolves only 0/"):
            eng = ServingEngine(cfg, params, max_len=48, batch_slots=2,
                                packed=True, plan=alien)
        assert eng.stats["plan_matched_layers"] == 0
        # a matching plan (round-tripped) raises no warning
        good = ServingEngine(cfg, params, max_len=48, batch_slots=2,
                             packed=True)
        import warnings as _w
        with _w.catch_warnings():
            _w.simplefilter("error")
            eng2 = ServingEngine(cfg, params, max_len=48, batch_slots=2,
                                 packed=True,
                                 plan=ModelPlan.from_json(good.plan.to_json()))
        assert eng2.stats["plan_matched_layers"] == eng2.stats["plan_layers"]

    def test_plan_with_qat_weights_warns(self, model):
        cfg, params = model
        base = ServingEngine(cfg, params, max_len=48, batch_slots=2,
                             packed=True)
        with pytest.warns(UserWarning, match="packed=False"):
            ServingEngine(cfg, params, max_len=48, batch_slots=2,
                          plan=base.plan)


class TestSparseServing:
    """The padded-pool sparse path through the serving loop: freeze emits
    vmappable pools, the plan commits to ``tsar_sparse_padded``, and the
    jitted step dispatches it with output token-identical to a dense plan."""

    BK = 64   # reduced-config dims (128/256) need a finer grid than 256x256

    @pytest.fixture(scope="class")
    def sparse_model(self):
        """Reduced bitnet checkpoint with ~half the (64, 64) weight blocks
        structurally dead in every BitLinear layer.  Seeds derive from a
        deterministic digest of the layer path (``hash()`` is randomized per
        process) and the first block is force-killed so every layer is
        guaranteed below the sparse threshold."""
        import zlib

        cfg = configs.get("bitnet-2b-4t").reduced()
        params = zoo.init_params(cfg, jax.random.PRNGKey(0))

        def blockify(node, path=""):
            if isinstance(node, dict):
                if set(node) == {"w"}:
                    w = node["w"]
                    k, m = w.shape[-2:]
                    seed = zlib.crc32(path.encode()) % 2**31
                    mask = jnp.abs(sparse_format.random_block_sparse_ternary(
                        jax.random.PRNGKey(seed), (k, m),
                        bk=self.BK, bm=self.BK, p_zero_block=0.5,
                        p_zero=0.0).astype(jnp.float32))
                    mask = mask.at[:self.BK, :self.BK].set(0.0)
                    return {"w": w * mask}
                return {k2: blockify(v, f"{path}/{k2}")
                        for k2, v in node.items()}
            return node

        return cfg, blockify(params)

    def _reqs(self, n=3):
        return [Request(uid=i, prompt=np.arange(4 + i) % 100, max_new_tokens=5)
                for i in range(n)]

    def test_freeze_params_emits_stacked_padded_pools(self, sparse_model):
        """Acceptance: freeze_params on a stacked (vmapped) scan model emits
        padded-pool sidecars — per-layer pools with UNIFORM static shapes,
        sized by the host-side measurement pass."""
        from repro.serving import freeze_params

        cfg, params = sparse_model
        packed = freeze_params(params, block_shape=(self.BK, self.BK))
        wq = packed["blocks"]["attn"]["wq"]
        assert {"sp_sign", "sp_zero", "sp_map", "sp_kids", "sp_slots",
                "sp_counts", "block_density"} <= set(wq)
        # stacked: leading dim = n_layers, pool dims shared across the stack
        assert wq["sp_sign"].shape[0] == cfg.n_layers
        assert wq["sp_sign"].shape[1:] == wq["sp_zero"].shape[1:]
        # the measured pool is TIGHT: no larger than the full block grid
        kb = -(-128 // self.BK)
        mb = -(-128 // self.BK)
        assert wq["sp_sign"].shape[1] <= kb * mb
        assert float(np.mean(np.asarray(wq["block_density"]))) < 0.95

    def test_freeze_params_emits_padded_pools_under_tracing(self, sparse_model):
        """sparse=True freezes are fully traceable (static pool shapes), so
        freeze_params can run under jit/eval_shape — no data-dependent
        compaction on the trace path."""
        from repro.serving import freeze_params

        cfg, params = sparse_model
        fn = lambda p: freeze_params(p, sparse=True,
                                     block_shape=(self.BK, self.BK))
        abstract = jax.eval_shape(fn, params)
        wq = abstract["blocks"]["attn"]["wq"]
        assert "sp_sign" in wq
        concrete = jax.jit(fn)(params)
        got = concrete["blocks"]["attn"]["wq"]["sp_sign"]
        assert got.shape == wq["sp_sign"].shape

    def test_sparse_plan_dispatches_padded_kernel(self, sparse_model,
                                                  monkeypatch):
        """Acceptance: the engine's compiled plan commits BitLinear layers to
        ``tsar_sparse_padded``, serves through it in the jitted step with
        ZERO select_kernel calls after init, and the output is
        token-identical to a dense-plan engine on the same checkpoint."""
        cfg, params = sparse_model
        eng = ServingEngine(cfg, params, max_len=48, batch_slots=2,
                            packed=True, sparse_block=(self.BK, self.BK))
        counts = eng.plan.kernel_counts(1)
        assert counts.get("tsar_sparse_padded", 0) > 0, counts

        orig = dataflow.select_kernel
        run_calls = {"n": 0}

        def forbidden(*a, **kw):
            run_calls["n"] += 1
            return orig(*a, **kw)

        monkeypatch.setattr(dataflow, "select_kernel", forbidden)
        out_sparse = eng.run(self._reqs())
        assert all(r.done for r in out_sparse)
        assert run_calls["n"] == 0

        # dense plan on the SAME packed checkpoint: pin every layer/bucket
        # to tsar_mxu and compare tokens (the padded pool decodes to the
        # same ternary matrix, so greedy decode must match bit-for-bit).
        monkeypatch.setattr(dataflow, "select_kernel", orig)
        dense_layers = {
            name: {n: dataclasses.replace(lp, kernel="tsar_mxu")
                   for n, lp in by_b.items()}
            for name, by_b in eng.plan.layers.items()}
        dense_plan = ModelPlan(buckets=eng.plan.buckets,
                               shapes=dict(eng.plan.shapes),
                               layers=dense_layers)
        eng_dense = ServingEngine(cfg, params, max_len=48, batch_slots=2,
                                  packed=True, sparse_block=(self.BK, self.BK),
                                  plan=dense_plan)
        out_dense = eng_dense.run(self._reqs())
        for a, b in zip(out_sparse, out_dense):
            assert a.out_tokens == b.out_tokens

    def test_sparse_plan_json_roundtrip_serves_identically(self, sparse_model):
        """The sparse-kernel plan survives to_json/from_json and serves the
        same tokens (extends TestServingWithPlan's invariant)."""
        cfg, params = sparse_model
        eng = ServingEngine(cfg, params, max_len=48, batch_slots=2,
                            packed=True, sparse_block=(self.BK, self.BK))
        out_mem = eng.run(self._reqs())
        plan = ModelPlan.from_json(eng.plan.to_json())
        assert plan.kernel_counts(1).get("tsar_sparse_padded", 0) > 0
        eng2 = ServingEngine(cfg, params, max_len=48, batch_slots=2,
                             packed=True, sparse_block=(self.BK, self.BK),
                             plan=plan)
        out_json = eng2.run(self._reqs())
        for a, b in zip(out_mem, out_json):
            assert a.out_tokens == b.out_tokens

    def test_sparse_false_keeps_planes_only(self, sparse_model):
        from repro.serving import freeze_params

        cfg, params = sparse_model
        packed = freeze_params(params, sparse=False)
        wq = packed["blocks"]["attn"]["wq"]
        assert set(wq) == {"sign", "zero", "scale", "density"}

    def test_outlier_slice_does_not_emit_pools(self):
        """The auto pre-pass gates on the MEAN live-block fraction (the
        planner's signal): one sparse outlier slice in a dense stack must
        not stamp near-full-grid pools the plan would never dispatch."""
        from repro.serving import freeze_params

        k = m = 128
        dense_w = jax.random.normal(jax.random.PRNGKey(40), (k, m)) * 0.1
        sparse_w = dense_w * jnp.zeros((k, m)).at[:64, :64].set(1.0)
        # 1 slice at bd=0.25 among 19 dense slices: mean ~ 0.96 >= 0.95
        # threshold -> no pools, even though the outlier alone sits far
        # below it.
        stack = {"proj": {"w": jnp.stack([sparse_w] + [dense_w] * 19)}}
        packed = freeze_params(stack, block_shape=(64, 64))
        assert "sp_sign" not in packed["proj"]
        # a uniformly sparse stack still emits
        stack = {"proj": {"w": jnp.stack([sparse_w] * 4)}}
        packed = freeze_params(stack, block_shape=(64, 64))
        assert "sp_sign" in packed["proj"]

    def test_unrecognized_sparse_value_raises(self, sparse_model):
        """A typo'd sparse= must not silently freeze planes-only while the
        operator believes the sparse path is active."""
        from repro.serving import freeze_params

        cfg, params = sparse_model
        with pytest.raises(ValueError, match="sparse="):
            freeze_params(params, sparse="Auto")

    def test_undersized_max_live_raises_on_concrete_stack(self, sparse_model):
        """sparse=True with a too-small bound must raise host-side — the
        vmapped construction traces even concrete stacks, so without this
        check live blocks would be silently dropped."""
        from repro.serving import freeze_params

        cfg, params = sparse_model
        with pytest.raises(ValueError, match="max_live"):
            freeze_params(params, sparse=True,
                          block_shape=(self.BK, self.BK), max_live=1)

    def test_auto_bound_floors_give_uniform_pools(self, sparse_model):
        """Under sparse='auto' caller max_live/s_steps floor the measured
        sizes, so re-freezes can keep EVERY sp_* leaf shape uniform (the
        kids/slots schedules are shaped by s_steps, not just the pools)."""
        from repro.serving import freeze_params

        cfg, params = sparse_model
        live_floor, step_floor = 7, 2
        packed = freeze_params(params, block_shape=(self.BK, self.BK),
                               max_live=live_floor, s_steps=step_floor)
        for proj in ("wq", "wk", "wv", "wo"):
            leaf = packed["blocks"]["attn"][proj]
            if "sp_sign" in leaf:
                assert leaf["sp_sign"].shape[1] >= live_floor
                assert leaf["sp_kids"].shape[-1] >= step_floor
