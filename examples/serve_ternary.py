"""End-to-end serving driver (the paper's inference scenario): batched
requests against a ternary LM with packed 2-bit weights, chunked-prefill
continuous batching over a block-paged KV cache — the paper's Sec. IV
protocol at example scale.

Prints per-request latency percentiles (registry histograms) alongside
throughput:
  * TTFT — time to first token (admission + prefill latency),
  * TPOT — mean time per output token after the first (decode cadence),
plus the engine's step-budget telemetry showing that no step ran more than
``prefill_chunk + slots`` real tokens (no whole-prompt stall).

    PYTHONPATH=src python examples/serve_ternary.py [--arch gemma2-2b] [--requests 8]
"""
import argparse
import time

import numpy as np
import jax

import repro.configs as configs
from repro.plan import format_plan
from repro.models import model_zoo as zoo
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bitnet-2b-4t")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--policy", choices=["chunked", "whole"], default=None,
                    help="default: chunked where the family supports it")
    ap.add_argument("--no-packed", action="store_true")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="enable prefix-caching KV reuse (shared system "
                         "prompts fork cached blocks instead of re-prefilling)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="prepend an N-token shared system prompt to every "
                         "request (demonstrates prefix-cache hits)")
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced()
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))

    # Mixed prompt lengths: short chats next to prompts spanning many chunks.
    rng = np.random.default_rng(0)
    lens = [6 + i % 5 if i % 3 else 3 * args.prefill_chunk + i for i in range(args.requests)]
    # max_len tracks the workload so large --prefill-chunk values don't push
    # the long prompts past the admission limit (finished-ignored).
    max_len = max(128, max(lens, default=0) + args.shared_prefix + args.max_new + 1)
    engine = ServingEngine(cfg, params, max_len=max_len, batch_slots=args.slots,
                           packed=not args.no_packed,
                           prefill_chunk=args.prefill_chunk, policy=args.policy,
                           prefix_cache=args.prefix_cache)
    if engine.plan is not None:
        # Compile-once kernel plan (paper Sec. III-D / Fig. 5): the engine
        # costed every registered kernel per layer per n-bucket at init;
        # the jitted steps below just execute this table.
        print("execution plan (compiled once at engine init):")
        print(format_plan(engine.plan, max_rows=12))
    if engine.density is not None:
        print(f"weight density (measured): mean {engine.density['density_mean']:.3f} "
              f"min {engine.density['density_min']:.3f} | "
              f"live-block fraction {engine.density['block_density_mean']:.3f} "
              f"over {engine.density['layers']} BitLinear layers "
              f"(tsar_sparse break-even ~0.9; see docs/kernels.md)")
    sys_prompt = rng.integers(0, cfg.vocab_size, size=args.shared_prefix)
    reqs = [Request(uid=i,
                    prompt=np.concatenate(
                        [sys_prompt,
                         rng.integers(0, cfg.vocab_size, size=lens[i])]),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    engine.run(reqs)
    wall = time.perf_counter() - t0

    total_new = sum(len(r.out_tokens) for r in reqs)
    span = f"prompts {min(lens)}..{max(lens)} tok, " if lens else ""
    print(f"\n{args.requests} requests ({span}policy={engine.policy}), "
          f"{total_new} tokens in {wall:.2f}s")
    print(f"prefill time {engine.stats['prefill_s']:.2f}s | "
          f"decode time {engine.stats['decode_s']:.2f}s | "
          f"steady-state decode {engine.throughput():.1f} tok/s")
    # Percentiles come straight off the engine's metrics registry (real
    # histograms, repro.obs.metrics) — no external replay needed.
    pct = engine.latency_percentiles()
    ttft, tpot = pct["ttft_s"], pct["tpot_s"]
    print(f"TTFT p50 {ttft['p50'] * 1e3:.0f}ms p99 {ttft['p99'] * 1e3:.0f}ms "
          f"max {ttft['max'] * 1e3:.0f}ms | "
          f"TPOT p50 {tpot['p50'] * 1e3:.0f}ms p99 {tpot['p99'] * 1e3:.0f}ms")
    print(f"max step load {engine.max_step_tokens()} real tokens "
          f"(budget {args.prefill_chunk} + {args.slots} slots) | "
          f"whole prefills {engine.stats['whole_prefills']} | "
          f"peak KV blocks {engine.stats['peak_kv_blocks']}/{engine.kv.num_blocks - 1}")
    if engine.prefix is not None:
        print(f"prefix cache: hit rate {engine.stats['prefix_hit_rate']:.2f} "
              f"({engine.stats['prefix_hit_tokens']} prompt tokens reused) | "
              f"{engine.stats['cached_blocks']} cached blocks | "
              f"{engine.stats['prefix_evictions']} evictions")


if __name__ == "__main__":
    main()
