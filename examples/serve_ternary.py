"""End-to-end serving driver (the paper's inference scenario): batched
requests against a ternary LM with packed 2-bit weights, continuous batching,
prefill/decode phase stats — the paper's Sec. IV protocol at example scale.

    PYTHONPATH=src python examples/serve_ternary.py [--arch gemma2-2b] [--requests 8]
"""
import argparse
import time

import numpy as np
import jax

import repro.configs as configs
from repro.core.dataflow import layer_plan
from repro.models import model_zoo as zoo
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bitnet-2b-4t")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--no-packed", action="store_true")
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced()
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))

    # Compile-time kernel plan (paper Sec. III-D): per-layer AP/OP choice.
    d, f = cfg.d_model, cfg.d_ff or cfg.d_model
    plan = layer_plan({
        "attn_qkv (decode)": (1, d, 3 * d),
        "attn_out (decode)": (1, d, d),
        "mlp_up   (decode)": (1, d, f),
        "mlp_down (decode)": (1, f, d),
        "attn_qkv (prefill)": (128, d, 3 * d),
        "mlp_up   (prefill)": (128, d, f),
    })
    print("kernel plan (per-layer, compile time):")
    for name, choice in plan.items():
        print(f"  {name:22s} -> {choice.kernel:9s} {choice.dataflow}  "
              f"bound={choice.bound}")

    engine = ServingEngine(cfg, params, max_len=128, batch_slots=args.slots,
                           packed=not args.no_packed)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, size=6 + i % 5),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    engine.run(reqs)
    wall = time.perf_counter() - t0

    total_new = sum(len(r.out_tokens) for r in reqs)
    print(f"\n{args.requests} requests, {total_new} tokens in {wall:.2f}s")
    print(f"prefill time {engine.stats['prefill_s']:.2f}s | "
          f"decode time {engine.stats['decode_s']:.2f}s | "
          f"steady-state decode {engine.throughput():.1f} tok/s")


if __name__ == "__main__":
    main()
