"""Fault-tolerant training example: checkpointed QAT training with a
simulated mid-run failure, automatic restore, and bit-exact continuation —
the runtime substrate the multi-pod deployment relies on.

    PYTHONPATH=src python examples/train_resume.py
"""
import os
import tempfile

import jax
import numpy as np

import repro.configs as configs
from repro import checkpoint as ckpt
from repro.data import DataConfig, SyntheticLMStream
from repro.optim import OptConfig
from repro.runtime import StepMonitor, run_with_restarts
from repro.train import init_state, make_train_step

STEPS = 24
CKPT_EVERY = 4


def main():
    cfg = configs.get("gemma2-2b").reduced()
    opt = OptConfig(lr=1e-3, warmup_steps=5, total_steps=STEPS)
    step = jax.jit(make_train_step(cfg, opt, accum_steps=2))
    stream = SyntheticLMStream(DataConfig(cfg.vocab_size, 32, 8, seed=11))
    ckdir = tempfile.mkdtemp(prefix="tsar_ckpt_")
    monitor = StepMonitor()
    crash = {"armed": True}

    def restore_fn():
        target = init_state(cfg, jax.random.PRNGKey(0), opt)
        latest = ckpt.latest_step(ckdir)
        if latest is None:
            print("[restore] cold start")
            return target, 0
        print(f"[restore] resuming from checkpoint step {latest}")
        return ckpt.restore(ckdir, latest, target), latest

    def body(state, start):
        for i in range(start, STEPS):
            if i == 13 and crash["armed"]:
                crash["armed"] = False
                raise RuntimeError("simulated node failure at step 13")
            monitor.start(i)
            state, m = step(state, stream.batch(i))
            dt = monitor.stop()
            if (i + 1) % CKPT_EVERY == 0:
                ckpt.save(ckdir, i + 1, state, async_save=True)
            print(f"step {i:2d} loss {float(m['loss']):.3f} ({dt*1e3:.0f} ms)"
                  + ("  [straggler]" if monitor.is_straggler(dt) else ""))
        return STEPS

    report = run_with_restarts(body, restore_fn=restore_fn, max_restarts=2)
    print(f"\ncompleted={report.completed} after {report.restarts} restart(s); "
          f"failures={report.failures}")
    print(f"median step time {monitor.median()*1e3:.0f} ms; "
          f"straggler steps: {monitor.straggler_steps}")


if __name__ == "__main__":
    main()
