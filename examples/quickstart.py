"""Quickstart: build a small ternary LM, train it briefly, pack to 2-bit
T-SAR format, and generate text — the full framework loop in one file.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax

import repro.configs as configs
from repro.data import DataConfig, SyntheticLMStream
from repro.models import model_zoo as zoo
from repro.optim import OptConfig
from repro.serving import Request, ServingEngine
from repro.train import init_state, make_train_step


def main():
    # 1. A reduced BitNet-style config (same family as the paper's models).
    cfg = configs.get("bitnet-2b-4t").reduced(n_layers=4, d_model=256, d_ff=512)
    print(f"model: {cfg.name}  ~{cfg.n_params()/1e6:.1f}M params, ternary={cfg.ternary}")

    # 2. Train with QAT (absmean ternarization + STE) on the synthetic stream.
    opt = OptConfig(lr=2e-3, warmup_steps=10, total_steps=200)
    state = init_state(cfg, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(cfg, opt))
    stream = SyntheticLMStream(DataConfig(cfg.vocab_size, 64, 8, seed=0))
    for i in range(60):
        state, metrics = step(state, stream.batch(i))
        if i % 20 == 0:
            print(f"step {i:3d}  loss {float(metrics['loss']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}")

    # 3. Freeze to packed 2-bit planes and serve (T-SAR inference path).
    engine = ServingEngine(cfg, state.params, max_len=96, batch_slots=2,
                           packed=True)
    reqs = [Request(uid=i, prompt=np.arange(8) + i, max_new_tokens=12)
            for i in range(3)]
    engine.run(reqs)
    for r in reqs:
        print(f"req {r.uid}: {r.out_tokens}")
    print(f"decode throughput: {engine.throughput():.1f} tok/s "
          f"(packed 2-bit weights)")


if __name__ == "__main__":
    main()
