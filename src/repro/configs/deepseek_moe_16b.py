"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained experts.

28L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400  [arXiv:2401.06066; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,             # MHA (kv=16)
    d_ff=1408,                 # fine-grained expert hidden dim
    vocab_size=102_400,
    n_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    d_expert=1408,
    notes=("first layer is dense-FFN in the release; all-MoE here (noted in "
           "DESIGN.md); long_500k skipped (full attention)"),
)
