"""BitNet-b1.58-2B-4T — the paper's own evaluation model family (Sec. IV).

Shapes from the paper's kernel microbenchmarks (Fig. 10): K=2560, M=6912.
Not part of the assigned 10-arch pool; used by the paper-reproduction
benchmarks and examples.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="bitnet-2b-4t",
    family="dense",
    n_layers=30,
    d_model=2560,
    n_heads=20,
    n_kv_heads=5,
    d_ff=6912,
    vocab_size=128_256,
    notes="paper's BitNet-b1.58-2B-4T; ternary by construction",
)
