"""Model/experiment configuration system.

One frozen dataclass describes every supported architecture family (dense /
MoE / SSM / hybrid / enc-dec / VLM); per-arch modules in this package
instantiate it with published hyperparameters.  ``reduced()`` derives the
small-config variant used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0             # 0 -> d_model // n_heads

    # --- attention features ---
    qk_norm: bool = False
    attn_softcap: float = 0.0       # gemma2 attention-logit softcap
    logit_softcap: float = 0.0      # gemma2 final-logit softcap
    window_pattern: tuple = ()      # per-layer 'L'(ocal)/'G'(lobal), tiled over depth
    window_size: int = 4096
    rope_theta: float = 10_000.0
    mlp_gated: bool = True          # SwiGLU vs plain GELU MLP

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_expert: int = 0               # routed-expert hidden dim
    capacity_factor: float = 1.25

    # --- SSM (Mamba-2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # --- encoder-decoder ---
    n_enc_layers: int = 0
    enc_seq: int = 0                # encoder frame count (audio frontend stub)

    # --- modality frontends (stubs per assignment spec) ---
    frontend: str = ""              # '' | 'audio' | 'vision'
    frontend_seq: int = 0           # patch/frame tokens prepended (vision)
    frontend_dim: int = 0           # raw embedding dim before projection

    # --- ternary / T-SAR ---
    ternary: bool = True
    lut_block_c: int = 4

    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    notes: str = ""

    # ----- derived -----
    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        """Embedding-table size: vocab rounded up so the vocab axis shards
        cleanly on the 16-wide model axis (padded logits masked in the head).
        Standard practice (Megatron pads vocab to a multiple of 128*TP)."""
        mult = 2048 if self.vocab_size > 2048 else 16
        return ((self.vocab_size + mult - 1) // mult) * mult

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Supports 500k-token decode: SSM/hybrid or local-window attention."""
        if self.family in ("ssm", "hybrid"):
            return True
        return bool(self.window_pattern) and "L" in self.window_pattern

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def layer_is_global(self, i: int) -> bool:
        if not self.window_pattern:
            return True
        return self.window_pattern[i % len(self.window_pattern)] == "G"

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for rooflines."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        attn = (d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
                + (self.n_heads * hd) * d) if self.n_heads else 0
        mlp = (3 if self.mlp_gated else 2) * d * f if f else 0
        if self.is_moe:
            de = self.d_expert or f
            routed = self.n_experts * 3 * d * de
            shared = self.n_shared_experts * 3 * d * de
            router = d * self.n_experts
            mlp = routed + shared + router
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            ssm = d * (2 * di + 2 * ns + nh) + di * d + di  # in/out proj + conv-ish
        per_layer = {
            "dense": attn + mlp, "moe": attn + mlp, "vlm": attn + mlp,
            "ssm": ssm, "hybrid": attn + mlp + ssm,
            "encdec": attn + mlp,
        }[self.family]
        total = self.n_layers * per_layer
        if self.family == "encdec":
            total += self.n_enc_layers * (attn + mlp) + self.n_layers * (2 * d * self.n_kv_heads * hd + d * self.n_heads * hd + self.n_heads * hd * d)
        total += v * d * (1 if self.tie_embeddings else 2)
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE top-k counting) for MODEL_FLOPS."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        de = self.d_expert or self.d_ff
        hd = self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        active_mlp = (self.moe_top_k + self.n_shared_experts) * 3 * d * de + d * self.n_experts
        total = self.n_layers * (attn + active_mlp)
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return int(total)

    def reduced(self, **overrides) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        base = dict(
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_head=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            window_size=32,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_seq=min(self.enc_seq, 16) if self.enc_seq else 0,
            frontend_seq=min(self.frontend_seq, 16) if self.frontend_seq else 0,
            frontend_dim=min(self.frontend_dim, 64) if self.frontend_dim else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.family in ("ssm", "hybrid") else self.ssm_head_dim,
            ssm_chunk=16,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            d_expert=64 if self.d_expert else 0,
            name=self.name + "-reduced",
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
