"""whisper-tiny [audio] — enc-dec, conv frontend stubbed per assignment.

4L d_model=384 6H (GQA kv=6) d_ff=1536 vocab=51865  [arXiv:2212.04356; unverified]
The conv frontend is a STUB: ``input_specs()`` supplies precomputed frame
embeddings (B, enc_seq, d_model); the transformer backbone is what we build.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,              # decoder layers
    n_enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    enc_seq=1500,            # 30 s of audio at 50 Hz post-conv
    frontend="audio",
    mlp_gated=False,         # whisper uses plain GELU MLPs
    tie_embeddings=True,
    rope_theta=10_000.0,     # backbone uses RoPE in our repro (orig: learned pos)
    notes="enc-dec; conv frontend stubbed (frame embeddings from input_specs)",
)
