"""Architecture config registry: ``--arch <id>`` resolution."""
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig  # noqa: F401

from repro.configs import (  # noqa: F401
    bitnet_2b,
    deepseek_coder_33b,
    deepseek_moe_16b,
    gemma2_2b,
    gemma3_4b,
    hymba_1p5b,
    llama4_maverick_400b,
    llava_next_mistral_7b,
    mamba2_780m,
    qwen3_32b,
    whisper_tiny,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        whisper_tiny, gemma3_4b, deepseek_coder_33b, qwen3_32b, gemma2_2b,
        llama4_maverick_400b, deepseek_moe_16b, mamba2_780m, hymba_1p5b,
        llava_next_mistral_7b, bitnet_2b,
    )
}

# The ten assigned pool archs (bitnet-2b-4t is the paper's own, extra).
ASSIGNED = [n for n in ARCHS if n != "bitnet-2b-4t"]


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def cells(include_skipped: bool = False):
    """Yield every (arch, shape) cell, honoring the skip rules:
    long_500k only for sub-quadratic archs (decode is the lowered fn)."""
    for arch in ASSIGNED:
        cfg = ARCHS[arch]
        for shape in SHAPES.values():
            skip = shape.name == "long_500k" and not cfg.sub_quadratic
            if include_skipped or not skip:
                yield cfg, shape, skip
