"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1, early fusion.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts top-1
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,                 # also shared-expert hidden dim
    vocab_size=202_048,
    n_experts=128,
    n_shared_experts=1,
    moe_top_k=1,
    d_expert=8192,
    qk_norm=True,
    rope_theta=500_000.0,
    notes=("all layers MoE in this repro (HF interleaves dense/MoE); "
           "router kept fp; long_500k skipped (full attention)"),
)
