"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.

48L d_model=1536 d_ff=0 vocab=50280, ssm_state=128  [arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    tie_embeddings=True,
    notes=("attention-free; T-SAR applies to in/out projections, SSD "
           "recurrence stays fp (DESIGN.md §Arch-applicability); runs long_500k"),
)
