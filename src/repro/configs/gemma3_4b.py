"""gemma3-4b [dense] — 5:1 local:global attention, 128k context.

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144  [hf:google/gemma-3-1b-pt; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=10_240,
    vocab_size=262_144,
    window_pattern=("L", "L", "L", "L", "L", "G"),  # 5:1 local:global
    window_size=1024,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    notes="5:1 local:global; runs long_500k (local layers sub-quadratic)",
)
