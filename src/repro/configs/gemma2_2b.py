"""gemma2-2b [dense] — local/global alternating, logit softcap.

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000  [arXiv:2408.00118; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=9216,
    vocab_size=256_000,
    window_pattern=("L", "G"),      # alternating local/global
    window_size=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    tie_embeddings=True,
    notes="alternating local/global; softcaps; runs long_500k",
)
