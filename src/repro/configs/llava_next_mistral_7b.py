"""llava-next-mistral-7b [vlm] — anyres tiling, vision tower stubbed.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
The vision tower is a STUB per assignment: ``input_specs()`` supplies
precomputed patch embeddings; the backbone projects + prepends them.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    vocab_size=32_000,
    frontend="vision",
    frontend_seq=2880,       # anyres: base 576 + 4 tiles x 576
    frontend_dim=1024,       # CLIP-L patch embedding dim before projection
    rope_theta=1_000_000.0,
    notes="vision tower stubbed; long_500k skipped (full attention)",
)
