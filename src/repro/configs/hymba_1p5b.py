"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16
[arXiv:2411.13676; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab_size=32_001,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    window_pattern=("L", "L", "L", "L", "L", "L", "L", "G"),  # mostly SWA + few global
    window_size=1024,
    notes=("parallel attn+SSM heads fused per layer; meta-tokens omitted "
           "(noted in DESIGN.md); runs long_500k"),
)
