from repro.data.pipeline import DataConfig, PrefetchIterator, SyntheticLMStream  # noqa: F401
