"""Deterministic synthetic data pipeline (sharded, resumable, prefetching).

Real ternary-LLM training data (BitNet corpora) is not available offline; the
pipeline generates a deterministic synthetic LM stream with enough structure
for loss to fall (n-gram-ish transition table), which is what the examples
train on.  The substrate matters for the framework: per-host sharding,
explicit step-indexed randomness (resume = same stream), background prefetch.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    host_id: int = 0
    seed: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticLMStream:
    """Step-indexed deterministic batches: ``batch(step)`` is a pure function,
    so restart-at-step-N replays the identical stream (checkpoint/resume
    correctness is tested on this property)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # Sparse-ish markov transition structure => learnable signal.
        self._shift = rng.integers(1, max(2, v - 1))
        self._mix = rng.integers(0, v, size=(256,))

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4099 + cfg.host_id
        )
        b, s, v = cfg.host_batch, cfg.seq_len, cfg.vocab_size
        start = rng.integers(0, v, size=(b, 1))
        noise = rng.integers(0, v, size=(b, s + 1))
        drift = np.cumsum(np.ones((b, s + 1), np.int64), axis=1) * self._shift
        seq = (start + drift + (noise // 16) * self._mix[noise % 256]) % v
        # 7/8 of tokens follow the deterministic pattern; 1/8 noise.
        use_noise = rng.random((b, s + 1)) < 0.125
        seq = np.where(use_noise, noise, seq)
        tokens = seq[:, :-1].astype(np.int32)
        labels = seq[:, 1:].astype(np.int32)
        return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}


class PrefetchIterator:
    """Background-thread prefetch of the step-indexed stream."""

    def __init__(self, stream: SyntheticLMStream, start_step: int = 0, depth: int = 2):
        self._stream = stream
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._stream.batch(step)), timeout=0.25)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
