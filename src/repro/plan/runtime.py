"""Serve-time plan activation: the thin table lookup the runtime does.

``activate(plan)`` installs a :class:`~repro.plan.plan.ModelPlan` for the
duration of a ``with`` block (thread-local, re-entrant).  Model code that has
no layer names — ``models.layers._packed_linear`` deep inside a jitted step —
asks ``planned(k, m, n)`` and gets the LayerPlan the offline phase committed
to, or None when no plan is active.  Shapes are static at trace time, so the
lookup is a trace-time constant: zero cost inside the compiled step, and no
``select_kernel`` call ever happens at serve time.

``activate(None)`` is a no-op (keeps whatever plan is already active), so
plan-threading entry points can default to ``plan=None`` without clobbering
an enclosing engine context.
"""
from __future__ import annotations

import contextlib
import threading

_STATE = threading.local()


def current():
    """The active ModelPlan, or None."""
    return getattr(_STATE, "plan", None)


@contextlib.contextmanager
def activate(plan):
    """Install ``plan`` for the dynamic extent of the block (None = no-op)."""
    if plan is None:
        yield current()
        return
    prev = current()
    # Deliberate trace-time mutation: plan dispatch IS a trace-time
    # constant (shapes are static), so the thread-local install/restore
    # is the mechanism, not a leak.
    _STATE.plan = plan  # repro: ignore[jit-purity]
    try:
        yield plan
    finally:
        _STATE.plan = prev  # repro: ignore[jit-purity]


def planned(k: int, m: int, n: int):
    """LayerPlan for a (k, m) BitLinear at step width n, or None."""
    plan = current()
    if plan is None:
        return None
    return plan.lookup_shape(k, m, n)
