"""Compile-once / serve-many execution plans (paper Fig. 5 "offline" phase).

``compile_plan(frozen_params, batch_profile)`` walks a frozen/packed params
tree once, costs every registered kernel per BitLinear layer over a small set
of n-buckets (decode widths and chunked-prefill chunk widths), and freezes
the argmin into a :class:`ModelPlan` — a durable, inspectable artifact that:

* maps ``layer name -> {n_bucket -> LayerPlan(kernel, dataflow, tile_sizes,
  est_time_s, bound, density)}``;
* round-trips through JSON (``to_json``/``from_json``) so it can be saved
  next to a checkpoint and loaded at serve time without re-costing;
* is registered as a leafless pytree node, so it can ride a params tree or a
  closure into ``jax.jit`` without being traced;
* resolves runtime shapes to buckets (``lookup`` by name, ``lookup_shape``
  by (k, m) for the in-model dispatch that has no layer names).

The serving engine compiles (or loads) one plan at init and activates it
around every jitted step (``repro.plan.runtime``); after init, no
``select_kernel`` call ever runs again.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Mapping

import jax

from repro.plan import registry

PLAN_VERSION = 1

# Marks a (k, m) shape shared by layers whose plans DISAGREE: the nameless
# shape-keyed serve-path lookup cannot tell such layers apart, so it returns
# None (default realization) rather than silently serving one layer with
# another's plan.
_AMBIGUOUS = "<ambiguous>"


def _pad8(k: int) -> int:
    """Bitplane-padded K (planes store ceil(K/8) bytes; ragged tails decode
    to 0).  Plan shapes are keyed on this so packed-dict walks (which only
    see the padded planes) and serve-time lookups (which see the true K)
    agree."""
    return -(-k // 8) * 8


@dataclasses.dataclass(frozen=True)
class BatchProfile:
    """The n-buckets a deployment will actually run.

    ``decode_ns`` are flattened token counts of pure-decode steps (slots
    decoding in lockstep), ``prefill_ns`` the chunked-prefill step widths.
    """

    decode_ns: tuple[int, ...] = (1, 2, 4, 8)
    prefill_ns: tuple[int, ...] = (16, 128)

    @property
    def buckets(self) -> tuple[int, ...]:
        return tuple(sorted(set(self.decode_ns) | set(self.prefill_ns)))


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One (layer, n-bucket) decision: what to run and why."""

    kernel: str
    dataflow: str                 # 'AP' | 'OP'
    tile_sizes: tuple[int, ...]
    est_time_s: float
    bound: str                    # 'compute' | 'memory'
    density: float

    @staticmethod
    def from_dict(d: dict) -> "LayerPlan":
        return LayerPlan(kernel=d["kernel"], dataflow=d["dataflow"],
                         tile_sizes=tuple(d["tile_sizes"]),
                         est_time_s=float(d["est_time_s"]), bound=d["bound"],
                         density=float(d["density"]))


@dataclasses.dataclass(frozen=True, eq=True)
class ModelPlan:
    """Whole-model execution plan: layer name -> n-bucket -> LayerPlan."""

    buckets: tuple[int, ...]
    # name -> (k, m, c)
    shapes: Mapping[str, tuple[int, int, int]]
    # name -> {n_bucket -> LayerPlan}
    layers: Mapping[str, Mapping[int, LayerPlan]]
    version: int = PLAN_VERSION
    # (k, m) -> layer name, for the in-model dispatch (derived, not compared)
    _shape_index: dict = dataclasses.field(
        init=False, repr=False, compare=False, default_factory=dict)

    def __post_init__(self):
        # Layers agree for lookup purposes when their per-bucket DECISIONS
        # (kernel/dataflow/tiles) match; telemetry floats (density,
        # est_time_s) legitimately differ per layer and must not poison the
        # shared-shape key.
        def decisions(name):
            return tuple(sorted(
                (n, lp.kernel, lp.dataflow, lp.tile_sizes)
                for n, lp in self.layers.get(name, {}).items()))

        idx = {}
        for name, (k, m, _c) in self.shapes.items():
            key = (_pad8(k), m)
            other = idx.get(key)
            if other is None:
                idx[key] = name
            elif other != _AMBIGUOUS and decisions(other) != decisions(name):
                # Same shape, different decisions: a nameless lookup could
                # misapply one layer's plan to the other — poison the key.
                idx[key] = _AMBIGUOUS
        object.__setattr__(self, "_shape_index", idx)

    # -- resolution ----------------------------------------------------------

    def bucket_for(self, n: int) -> int:
        """Smallest bucket >= n, else the largest (prefill overflow)."""
        ge = [b for b in self.buckets if b >= n]
        return min(ge) if ge else max(self.buckets)

    def lookup(self, name: str, n: int) -> LayerPlan | None:
        by_bucket = self.layers.get(name)
        if not by_bucket:
            return None
        b = self.bucket_for(n)
        if b in by_bucket:
            return by_bucket[b]
        # shape-plans (layer_plan wrapper) carry per-layer buckets
        ks = sorted(by_bucket)
        ge = [x for x in ks if x >= n]
        return by_bucket[min(ge) if ge else max(ks)]

    def lookup_shape(self, k: int, m: int, n: int) -> LayerPlan | None:
        """Nameless (serve-path) lookup by weight shape; ``k`` may be the
        true or the bitplane-padded K.  Returns None when no layer has this
        shape OR when same-shape layers carry conflicting plans (the default
        realization is always correct; misapplying another layer's plan is
        not)."""
        name = self._shape_index.get((_pad8(k), m))
        if name is None or name == _AMBIGUOUS:
            return None
        return self.lookup(name, n)

    def shape_conflicts(self) -> tuple[tuple[int, int], ...]:
        """(k, m) shapes whose layers disagree — served by the default
        realization; surfaced in engine telemetry."""
        return tuple(sorted(
            key for key, name in self._shape_index.items()
            if name == _AMBIGUOUS))

    def coverage(self, params, n: int | None = None) -> tuple[int, int]:
        """(matched, total) BitLinear layers of ``params`` whose shapes this
        plan resolves — the sanity check for a plan loaded from disk: a plan
        saved for a different model silently resolves nothing, so callers
        (e.g. the serving engine) compare matched against total and warn."""
        if n is None:
            n = self.buckets[0] if self.buckets else 1
        matched = total = 0
        for _name, k, m, *_ in _iter_bitlinear_layers(params, 4):
            total += 1
            if self.lookup_shape(k, m, n) is not None:
                matched += 1
        return matched, total

    # -- telemetry -----------------------------------------------------------

    def kernel_counts(self, n: int) -> dict[str, int]:
        """How many layers run each kernel at step width n."""
        counts: dict[str, int] = {}
        for name in self.layers:
            lp = self.lookup(name, n)
            if lp is not None:
                counts[lp.kernel] = counts.get(lp.kernel, 0) + 1
        return counts

    def dominant_kernel(self, n: int) -> str:
        """The kernel serving the most layers at step width n."""
        counts = self.kernel_counts(n)
        return max(counts, key=counts.get) if counts else "none"

    def summary(self) -> dict:
        return {
            "layers": len(self.layers),
            "buckets": list(self.buckets),
            "decode_kernel": self.dominant_kernel(1),
            "prefill_kernel": self.dominant_kernel(max(self.buckets)),
        }

    # -- persistence ---------------------------------------------------------

    def to_json(self, indent: int | None = 2) -> str:
        payload = {
            "version": self.version,
            "buckets": list(self.buckets),
            "layers": {
                name: {
                    "shape": list(self.shapes[name]),
                    "buckets": {
                        str(n): dataclasses.asdict(lp)
                        for n, lp in sorted(self.layers[name].items())
                    },
                }
                for name in sorted(self.layers)
            },
        }
        return json.dumps(payload, indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "ModelPlan":
        payload = json.loads(text)
        if payload.get("version") != PLAN_VERSION:
            raise ValueError(
                f"plan version {payload.get('version')!r} != {PLAN_VERSION}")
        shapes, layers = {}, {}
        for name, entry in payload["layers"].items():
            shapes[name] = tuple(entry["shape"])
            layers[name] = {int(n): LayerPlan.from_dict(d)
                            for n, d in entry["buckets"].items()}
        return cls(buckets=tuple(payload["buckets"]), shapes=shapes,
                   layers=layers, version=payload["version"])

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path) -> "ModelPlan":
        with open(path) as f:
            return cls.from_json(f.read())


# A plan is compile-time metadata, never traced: register it as a leafless
# pytree so it can sit inside pytrees / jit closures untouched.
jax.tree_util.register_pytree_node(
    ModelPlan,
    lambda p: ((), p),
    lambda aux, _children: aux,
)


# ---------------------------------------------------------------------------
# Compilation
# ---------------------------------------------------------------------------

def _iter_bitlinear_layers(params, default_c: int):
    """Yield (name, k, m, c, density, block_density, sparse_ok, block_shape)
    per BitLinear layer.

    Understands packed dicts (``layers.pack_linear`` / ``freeze_params``
    output), latent ``{'w'}`` dicts, and ``FrozenBitLinear`` tuples.  Stacked
    (scan-layer / expert) weights are one entry — every slice shares a shape
    and therefore a plan; the stamped density leaf is averaged.

    ``sparse_ok`` is the subset of ``registry.SPARSE_KERNELS`` the layer's
    stored formats can actually serve (a packed dict with ``sp_*`` padded
    pool leaves supports ``tsar_sparse_padded`` only; a FrozenBitLinear
    whatever sidecars it carries) and ``block_shape`` the format's tiling —
    both feed ``select_kernel`` so a plan never commits to a sparse kernel
    the layer cannot run, and costs it at the real block size.
    """
    import numpy as np

    def walk(node, path):
        if isinstance(node, dict):
            keys = set(node)
            if {"sign", "zero"} <= keys:
                ps = node["sign"].shape
                k, m = ps[-2] * 8, ps[-1]
                density = (float(np.mean(np.asarray(node["density"])))
                           if "density" in node else registry.DEFAULT_DENSITY)
                block_density = None
                sparse_ok: tuple = ()
                block_shape = None
                if "sp_sign" in keys:
                    sparse_ok = ("tsar_sparse_padded",)
                    sp = node["sp_sign"].shape
                    block_shape = (sp[-2] * 8, sp[-1])
                    if "block_density" in keys:
                        block_density = float(
                            np.mean(np.asarray(node["block_density"])))
                yield (path, k, m, default_c, density, block_density,
                       sparse_ok, block_shape)
                return
            if keys == {"w"}:
                from repro.core import ternary
                k, m = node["w"].shape[-2:]
                t, _ = ternary.absmean_ternarize(node["w"])
                density = float(np.mean(np.asarray(ternary.ternary_density(t))))
                yield (path, _pad8(k), m, default_c, density, None, (), None)
                return
            for key in sorted(node):
                yield from walk(node[key], f"{path}/{key}" if path else str(key))
        elif hasattr(node, "packed") and hasattr(node, "c"):  # FrozenBitLinear
            k, m = node.shape
            sparse_ok = tuple(kn for kn in registry.SPARSE_KERNELS
                              if registry.get(kn).supports(node))
            sidecar = node.sparse if node.sparse is not None \
                else getattr(node, "padded", None)
            yield (path or "layer", _pad8(k), m, int(node.c),
                   float(node.density) if node.density is not None
                   else registry.DEFAULT_DENSITY,
                   float(node.block_density)
                   if node.block_density is not None else None,
                   sparse_ok,
                   sidecar.block_shape if sidecar is not None else None)

    yield from walk(params, "")


def compile_plan(frozen_params, batch_profile: BatchProfile | None = None,
                 *, default_c: int = 4) -> ModelPlan:
    """One-time, whole-model kernel/dataflow planning.

    Walks the frozen params tree, and for every BitLinear layer and every
    n-bucket in ``batch_profile`` runs the registry-backed selector
    (``core.dataflow.select_kernel``) with that layer's measured density —
    per-layer ``c`` and densities, not one global default.  The result is the
    whole offline phase as one artifact.
    """
    from repro.core import dataflow  # lazy: core imports repro.plan

    profile = batch_profile or BatchProfile()
    shapes: dict[str, tuple[int, int, int]] = {}
    layers: dict[str, dict[int, LayerPlan]] = {}
    for (name, k, m, c, density, block_density, sparse_ok,
         block_shape) in _iter_bitlinear_layers(frozen_params, default_c):
        shapes[name] = (k, m, c)
        kw: dict = {"sparse_ok": sparse_ok}
        if block_density is not None:
            kw["block_density"] = block_density
        if block_shape is not None:
            kw["block_shape"] = block_shape
        per_bucket: dict[int, LayerPlan] = {}
        for n in profile.buckets:
            choice = dataflow.select_kernel(
                n=n, k=k, m=m, c=c, density=density, **kw)
            per_bucket[n] = LayerPlan(
                kernel=choice.kernel,
                dataflow=choice.dataflow,
                tile_sizes=tuple(registry.get(choice.kernel).tiles(n, k, m, c)),
                est_time_s=choice.est_time_s,
                bound=choice.bound,
                density=density,
            )
        layers[name] = per_bucket
    return ModelPlan(buckets=profile.buckets, shapes=shapes, layers=layers)


def compile_plan_from_shapes(shapes: Mapping[str, tuple | dict],
                             c: int = 4) -> ModelPlan:
    """Plan from explicit per-layer shapes (the ``dataflow.layer_plan`` path).

    Each spec is ``(n, k, m)``, ``(n, k, m, c)``, or a dict with keys
    ``n, k, m`` and optional ``c, density, block_density`` — per-layer ``c``
    and measured densities, so e.g. MoE expert layers with a different LUT
    block size cost correctly.
    """
    from repro.core import dataflow

    plan_shapes: dict[str, tuple[int, int, int]] = {}
    layers: dict[str, dict[int, LayerPlan]] = {}
    buckets: set[int] = set()
    for name, spec in shapes.items():
        if isinstance(spec, dict):
            n, k, m = spec["n"], spec["k"], spec["m"]
            lc = spec.get("c", c)
            kw = {key: spec[key] for key in ("density", "block_density")
                  if key in spec}
        else:
            n, k, m = spec[:3]
            lc = spec[3] if len(spec) > 3 else c
            kw = {}
        choice = dataflow.select_kernel(n=n, k=k, m=m, c=lc, **kw)
        plan_shapes[name] = (k, m, lc)
        layers[name] = {n: LayerPlan(
            kernel=choice.kernel, dataflow=choice.dataflow,
            tile_sizes=tuple(registry.get(choice.kernel).tiles(n, k, m, lc)),
            est_time_s=choice.est_time_s, bound=choice.bound,
            density=choice.detail.get("density", registry.DEFAULT_DENSITY),
        )}
        buckets.add(n)
    return ModelPlan(buckets=tuple(sorted(buckets)), shapes=plan_shapes,
                     layers=layers)


def format_plan(plan: ModelPlan, max_rows: int = 40) -> str:
    """Human-readable per-layer, per-bucket table."""
    lines = [f"| {'layer':32s} | {'(k, m, c)':>18s} | {'n':>5s} "
             f"| {'kernel':11s} | df | bound   | est(us) |"]
    lines.append("|" + "-" * 96 + "|")
    rows = 0
    for name in sorted(plan.layers):
        k, m, c = plan.shapes[name]
        for n, lp in sorted(plan.layers[name].items()):
            if rows >= max_rows:
                lines.append(f"... ({len(plan.layers)} layers x "
                             f"{len(plan.buckets)} buckets total)")
                return "\n".join(lines)
            lines.append(
                f"| {name[-32:]:32s} | {str((k, m, c)):>18s} | {n:5d} "
                f"| {lp.kernel:11s} | {lp.dataflow} | {lp.bound:7s} "
                f"| {lp.est_time_s * 1e6:7.2f} |")
            rows += 1
    return "\n".join(lines)
