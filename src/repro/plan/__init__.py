"""First-class execution plans: the kernel registry + compile-once/serve-many
planning API (paper Sec. III-D / Fig. 5 "offline" phase).

* ``registry`` — the :class:`KernelImpl` protocol and the five registered
  kernels (``tsar_mxu``, ``tsar_lut``, ``tsar_sparse``, ``memory_lut``,
  ``dense``); cost models, capability gates, and lowerings in one table.
* ``plan`` — ``compile_plan(frozen_params, batch_profile) -> ModelPlan``,
  JSON save/load, per-bucket lookup.
* ``runtime`` — ``activate(plan)`` context + the ``planned(k, m, n)`` lookup
  the serving forward path uses instead of re-running ``select_kernel``.

See ``docs/plan.md`` for the lifecycle: freeze -> compile_plan -> save/load
-> serve.
"""
from repro.plan import registry, runtime  # noqa: F401
from repro.plan.plan import (  # noqa: F401
    BatchProfile,
    LayerPlan,
    ModelPlan,
    compile_plan,
    compile_plan_from_shapes,
    format_plan,
)
