"""The kernel registry: every servable BitLinear kernel, declared once.

The paper's offline phase "empirically selects the fastest kernel for each
layer" (Sec. III-D / Fig. 5) and the runtime then just executes the choice.
This module is the repo's single source of truth for what "a kernel" is:

* :class:`KernelImpl` — the protocol every implementation satisfies:
  ``name``, an analytic ``cost(n, k, m, c, density, block_density)`` against
  the shared roofline constants, a ``supports(frozen)`` capability gate, a
  ``tiles(n, k, m, c)`` default tile pick, and ``lower(frozen, x)`` — the
  actual computation on a frozen layer.
* the five implementations (``tsar_mxu``, ``tsar_lut``, ``tsar_sparse``,
  ``memory_lut``, ``dense``) registered declaratively at import time.

``core/dataflow.select_kernel`` reduces to an argmin over the registry's
``selectable`` costs; ``core/bitlinear.apply_frozen`` reduces to
``registry.get(name).lower(...)``; ``repro.plan.plan.compile_plan`` freezes
the per-layer argmin into a durable :class:`~repro.plan.plan.ModelPlan`.

Import-graph note: this module sits BELOW ``repro.core`` (core imports it),
so everything from ``repro.core``/``repro.kernels`` is imported lazily
inside methods.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

# The BitNet-b1.58 prior: absmean ternarization zeroes ~1/3 of the weights.
# Used when no measured density is supplied.
DEFAULT_DENSITY = 2.0 / 3.0

# Canonical block-sparse tiling default; sparse/format re-exports it (via
# core/dataflow) as DEFAULT_BLOCK_SHAPE.
SPARSE_BLOCK = (256, 256)

# Issue-efficiency tax on the sparse kernel's live-block work: the
# scalar-prefetched gather walks the pool non-sequentially (no streaming
# prefetch), and strips with fewer live blocks than the grid's s_max still
# burn masked steps.  Charged on compute and the weight stream, it puts the
# analytic break-even near 1/1.1 ~ 0.9 live blocks instead of degenerately
# at 1.0.
SPARSE_ISSUE_TAX = 1.1


def _hw():
    from repro.core import hw

    return hw


def _leaf(frozen, key: str):
    """Uniform access to FrozenBitLinear fields / packed-param dict leaves."""
    if isinstance(frozen, dict):
        return frozen.get(key)
    return getattr(frozen, key, None)


def has_planes(frozen) -> bool:
    if isinstance(frozen, dict):
        # Stacked (scan/expert) plane dicts need a vmap wrapper, not lower().
        return ("sign" in frozen and "zero" in frozen
                and getattr(frozen["sign"], "ndim", 0) == 2)
    return _leaf(frozen, "packed") is not None


def _packed_of(frozen, x):
    """The layer's TernaryWeights: FrozenBitLinear carries it; packed-param
    dicts (``layers.pack_linear`` output) rebuild it from the planes, taking
    the true K from the activations (planes store the padded ceil(K/8)*8)."""
    packed = _leaf(frozen, "packed")
    if packed is not None:
        return packed
    from repro.core import ternary

    return ternary.TernaryWeights(
        frozen["sign"], frozen["zero"], frozen["scale"],
        (x.shape[-1], frozen["sign"].shape[-1]))


def _c_of(frozen) -> int:
    c = _leaf(frozen, "c")
    return 4 if c is None else c


def resolve_use_pallas(use_pallas: bool | None,
                       interpret: bool | None = None) -> bool:
    """``None`` auto-resolves from the backend: Pallas on TPU, the traceable
    jnp spelling elsewhere.  Explicit True/False still forces — and so does
    ``interpret=True``: requesting interpret mode means running the Pallas
    kernel (that is how the kernels are validated off-TPU).  ``interpret=
    False`` does NOT force Pallas — off-TPU the compiled Pallas path cannot
    run, so it keeps the backend auto-resolution (jnp fallback on CPU)."""
    if use_pallas is None:
        if interpret:
            return True
        from repro.kernels import ops

        return not ops._auto_interpret()
    return use_pallas


@runtime_checkable
class KernelImpl(Protocol):
    """What the planner and the runtime need from one kernel."""

    name: str
    selectable: bool  # costed by select_kernel (baselines are not)

    def cost(self, n: int, k: int, m: int, c: int = 4,
             density: float = DEFAULT_DENSITY,
             block_density: float | None = None,
             block_shape: tuple = SPARSE_BLOCK) -> tuple[float, float]:
        """(compute_s, memory_s) roofline estimate."""
        ...

    def supports(self, frozen) -> bool:
        """Can this kernel serve this frozen layer (encodings present)?"""
        ...

    def tiles(self, n: int, k: int, m: int, c: int = 4) -> tuple[int, ...]:
        """Default tile sizes the Pallas wrapper would pick for this shape."""
        ...

    def lower(self, frozen, x: jax.Array, *, use_pallas: bool | None = None,
              interpret: bool | None = None, lp=None) -> jax.Array:
        """Run the kernel on a frozen layer: x (..., K) -> (..., M) f32.

        ``lp`` (a ``repro.plan.LayerPlan``) carries the planned dataflow and
        tile sizes; Pallas-bound lowerings execute them (grid order + tiling),
        the jnp spellings ignore them (no grid to order)."""
        ...


def _int8_dot(frozen, x32):
    """Shared exact decode->int8-dot spelling (traceable realization of the
    decode-near-datapath kernels off-TPU; bit-equal to the Pallas output)."""
    from repro.core import lut, ternary

    packed = _packed_of(frozen, x32)
    a_q, a_scale = ternary.quantize_activations(x32)
    t = ternary.unpack(packed)
    return lut.dense_int8_matmul(a_q, a_scale, t, packed.scale)


def _ops_tiles(n: int, k: int, m: int) -> tuple[int, int, int]:
    from repro.kernels import ops

    return (ops._tile(n, 128, 8), ops._tile(k, 512, 128), ops._tile(m, 256, 128))


class TsarMXU:
    """Decode 2-bit planes to {-1,0,+1} int8 in VMEM, feed the MXU."""

    name = "tsar_mxu"
    selectable = True

    def cost(self, n, k, m, c=4, density=DEFAULT_DENSITY, block_density=None,
             block_shape=SPARSE_BLOCK):
        hw = _hw()
        flops = 2.0 * n * k * m                      # int8 MACs on the MXU
        decode_ops = k * m * 4.0                     # bitplane unpack ALU ops
        compute = flops / hw.PEAK_FLOPS_INT8 + decode_ops / (hw.PEAK_FLOPS_INT8 / 2)
        bytes_moved = (
            k * m * 0.25                             # 2-bit packed weights
            + n * k * 1.0                            # int8 activations
            + n * m * 2.0                            # bf16 outputs
            + m * 4.0                                # scales
        )
        return compute, bytes_moved / hw.HBM_BW

    def supports(self, frozen):
        return has_planes(frozen)

    def tiles(self, n, k, m, c=4):
        return _ops_tiles(n, k, m)

    def lower(self, frozen, x, *, use_pallas=None, interpret=None, lp=None):
        x32 = x.astype(jnp.float32)
        if resolve_use_pallas(use_pallas, interpret):
            from repro.kernels import ops

            kw = {}
            if lp is not None:      # execute the planned grid order + tiling
                kw["dataflow"] = lp.dataflow
                if len(lp.tile_sizes) == 3:
                    kw["bn"], kw["bk"], kw["bm"] = lp.tile_sizes
            return ops.tsar_matmul(x32, _packed_of(frozen, x),
                                   interpret=interpret, **kw)
        return _int8_dot(frozen, x32)


class TsarLUT:
    """Paper-faithful in-VMEM shared-LUT kernel (TLUT build + TGEMV gather)."""

    name = "tsar_lut"
    selectable = True

    def cost(self, n, k, m, c=4, density=DEFAULT_DENSITY, block_density=None,
             block_shape=SPARSE_BLOCK):
        hw = _hw()
        blocks = k / c
        lut_build = n * blocks * (2 ** c) * 1.0      # TLUT expansion ops
        # Each gather lowered as one-hot x LUT: 2^c MACs per (block, m) pair,
        # two gathers per block (pos/zero) fused into one 2^c-wide matmul.
        gather = 2.0 * n * blocks * m * (2 ** c) / 8.0
        compute = (lut_build + gather) / hw.PEAK_FLOPS_INT8
        bytes_moved = (
            2.0 * (k / c) * m * 1.0                  # idx_pos + idx_zero, 1B each
            + n * k * 1.0
            + n * m * 2.0
            + m * 4.0
        )
        return compute, bytes_moved / hw.HBM_BW

    def supports(self, frozen):
        return _leaf(frozen, "idx_pos") is not None

    def tiles(self, n, k, m, c=4):
        from repro.kernels import ops

        return (ops._tile(-(-k // c), 128, 8), ops._tile(m, 256, 128))

    def lower(self, frozen, x, *, use_pallas=None, interpret=None, lp=None):
        from repro.core import lut

        x32 = x.astype(jnp.float32)
        c = _c_of(frozen)
        scale = _packed_of(frozen, x).scale
        if resolve_use_pallas(use_pallas, interpret):
            from repro.kernels import ops

            kw = {}
            if lp is not None and len(lp.tile_sizes) == 2:
                kw["bb"], kw["bm"] = lp.tile_sizes
            return ops.tsar_lut_gemv(x32, _leaf(frozen, "idx_pos"),
                                     _leaf(frozen, "idx_zero"), scale,
                                     c=c, interpret=interpret, **kw)
        return lut.tsar_lut_matmul(x32, _leaf(frozen, "idx_pos"),
                                   _leaf(frozen, "idx_zero"), c, scale)


class TsarSparse:
    """Zero-block-skipping matmul over a compacted BlockSparseTernary pool."""

    name = "tsar_sparse"
    selectable = True

    def cost(self, n, k, m, c=4, density=DEFAULT_DENSITY, block_density=None,
             block_shape=SPARSE_BLOCK):
        """MXU work and weight bytes scale with the LIVE-block fraction; the
        index map (int32 per block) and per-strip gather lists are the
        sparsity tax, which is why the dense kernel wins at density ~ 1."""
        hw = _hw()
        if block_density is None:
            block_density = estimate_block_density(density, block_shape)
        bk, bm = block_shape
        kb, mb = max(k / bk, 1.0), max(m / bm, 1.0)
        live = block_density * kb * mb
        flops = 2.0 * n * bk * bm * live             # int8 MACs, live blocks only
        decode_ops = bk * bm * live * 4.0            # bitplane unpack, live only
        compute = SPARSE_ISSUE_TAX * (
            flops / hw.PEAK_FLOPS_INT8 + decode_ops / (hw.PEAK_FLOPS_INT8 / 2))
        bytes_moved = (
            SPARSE_ISSUE_TAX * live * bk * bm * 0.25  # 2-bit planes, live blocks
            + kb * mb * 4.0                          # block-index map (int32)
            + 2.0 * live * 4.0                       # kids+slots gather lists
            + n * k * 1.0                            # int8 activations
            + n * m * 2.0                            # bf16 outputs
            + m * 4.0                                # scales
        )
        return compute, bytes_moved / hw.HBM_BW

    def supports(self, frozen):
        return _leaf(frozen, "sparse") is not None

    def tiles(self, n, k, m, c=4):
        from repro.kernels import ops

        bk, bm = SPARSE_BLOCK
        return (ops._tile(n, 128, 8), bk, bm)

    def lower(self, frozen, x, *, use_pallas=None, interpret=None, lp=None):
        sparse = _leaf(frozen, "sparse")
        if sparse is None:
            raise ValueError("layer was frozen without a block-sparse sidecar")
        x32 = x.astype(jnp.float32)
        if resolve_use_pallas(use_pallas, interpret):
            from repro.kernels import ops

            kw = {}
            if lp is not None and lp.tile_sizes:
                kw["bn"] = lp.tile_sizes[0]   # bk/bm are fixed by the format
            return ops.tsar_sparse_matmul(x32, sparse, interpret=interpret,
                                          **kw)
        # Traceable jnp fallback: identical math to the sparse kernel (the
        # planes decode to the same ternary matrix, and skipped blocks
        # contribute exact int32 zeros either way).  The zero-skip advantage
        # itself only materializes in the Pallas kernel.
        return _int8_dot(frozen, x32)


class MemoryLUT:
    """DRAM-resident 3^c-entry LUT gather — the bitnet.cpp-style baseline the
    paper beats; kept servable for A/B runs, never chosen by the planner."""

    name = "memory_lut"
    selectable = False

    def cost(self, n, k, m, c=4, density=DEFAULT_DENSITY, block_density=None,
             block_shape=SPARSE_BLOCK):
        hw = _hw()
        blocks = k / c
        compute = 2.0 * n * blocks * m / hw.PEAK_FLOPS_INT8
        bytes_moved = (
            n * blocks * (3 ** c) * 4.0              # DRAM-resident LUT tables
            + blocks * m * 1.0                       # index stream
            + n * k * 1.0 + n * m * 2.0 + m * 4.0
        )
        return compute, bytes_moved / hw.HBM_BW

    def supports(self, frozen):
        return has_planes(frozen)

    def tiles(self, n, k, m, c=4):
        return _ops_tiles(n, k, m)

    def lower(self, frozen, x, *, use_pallas=None, interpret=None, lp=None):
        from repro.core import lut, ternary

        packed = _packed_of(frozen, x)
        c = _c_of(frozen)
        x32 = x.astype(jnp.float32)
        t = ternary.unpack(packed)
        pad = (-t.shape[0]) % c   # ragged K: zero channels x zero weights = 0
        if pad:
            t = jnp.pad(t, ((0, pad), (0, 0)))
            x32 = jnp.pad(x32, [(0, 0)] * (x32.ndim - 1) + [(0, pad)])
        li = lut.ternary_lut_indices(t, c)
        return lut.memory_lut_matmul(x32, li, c, packed.scale)


class Dense:
    """Dequantize to fp and run a plain matmul — the correctness oracle and
    the escape hatch a hand-edited plan can force per layer."""

    name = "dense"
    selectable = False

    def cost(self, n, k, m, c=4, density=DEFAULT_DENSITY, block_density=None,
             block_shape=SPARSE_BLOCK):
        hw = _hw()
        compute = 2.0 * n * k * m / hw.PEAK_FLOPS_BF16
        bytes_moved = k * m * 2.0 + n * k * 2.0 + n * m * 2.0
        return compute, bytes_moved / hw.HBM_BW

    def supports(self, frozen):
        return has_planes(frozen)

    def tiles(self, n, k, m, c=4):
        return _ops_tiles(n, k, m)

    def lower(self, frozen, x, *, use_pallas=None, interpret=None, lp=None):
        from repro.core import lut, ternary

        w = ternary.unpack_dequant(_packed_of(frozen, x))
        return lut.dense_matmul(x.astype(jnp.float32), w)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, KernelImpl] = {}


def register(impl: KernelImpl) -> KernelImpl:
    """Register a kernel implementation (later registrations override)."""
    _REGISTRY[impl.name] = impl
    return impl


def get(name: str) -> KernelImpl:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown kernel {name!r}; registered: {names()}") from None


def names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def selectable_names() -> tuple[str, ...]:
    return tuple(n for n in names() if _REGISTRY[n].selectable)


def available(frozen) -> tuple[str, ...]:
    """Kernel names whose encodings are present on this frozen layer."""
    return tuple(n for n in names() if _REGISTRY[n].supports(frozen))


def estimate_block_density(density: float, block_shape: tuple = SPARSE_BLOCK) -> float:
    """Live-block fraction under UNSTRUCTURED zeros at this density — which
    makes essentially every block live (``1 - (1-d)^(bk*bm) ~ 1``), so the
    sparse path is only chosen on *measured* structured sparsity."""
    bk, bm = block_shape
    return 1.0 - (1.0 - min(density, 1.0 - 1e-12)) ** (bk * bm)


def candidate_costs(n: int, k: int, m: int, c: int = 4,
                    density: float = DEFAULT_DENSITY,
                    block_density: float | None = None,
                    block_shape: tuple = SPARSE_BLOCK,
                    ) -> dict[str, tuple[float, float]]:
    """(compute_s, memory_s) per selectable kernel — the planner's input."""
    return {
        name: _REGISTRY[name].cost(n, k, m, c, density=density,
                                   block_density=block_density,
                                   block_shape=block_shape)
        for name in selectable_names()
    }


for _impl in (TsarMXU(), TsarLUT(), TsarSparse(), MemoryLUT(), Dense()):
    register(_impl)
del _impl
