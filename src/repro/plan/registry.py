"""The kernel registry: every servable BitLinear kernel, declared once.

The paper's offline phase "empirically selects the fastest kernel for each
layer" (Sec. III-D / Fig. 5) and the runtime then just executes the choice.
This module is the repo's single source of truth for what "a kernel" is:

* :class:`KernelImpl` — the protocol every implementation satisfies:
  ``name``, an analytic ``cost(n, k, m, c, density, block_density)`` against
  the shared roofline constants, a ``supports(frozen)`` capability gate, a
  ``tiles(n, k, m, c)`` default tile pick, and ``lower(frozen, x)`` — the
  actual computation on a frozen layer.
* the six implementations (``tsar_mxu``, ``tsar_lut``, ``tsar_sparse``,
  ``tsar_sparse_padded``, ``memory_lut``, ``dense``) registered
  declaratively at import time.

``core/dataflow.select_kernel`` reduces to an argmin over the registry's
``selectable`` costs; ``core/bitlinear.apply_frozen`` reduces to
``registry.get(name).lower(...)``; ``repro.plan.plan.compile_plan`` freezes
the per-layer argmin into a durable :class:`~repro.plan.plan.ModelPlan`.

Import-graph note: this module sits BELOW ``repro.core`` (core imports it),
so everything from ``repro.core``/``repro.kernels`` is imported lazily
inside methods.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

# The BitNet-b1.58 prior: absmean ternarization zeroes ~1/3 of the weights.
# Used when no measured density is supplied.
DEFAULT_DENSITY = 2.0 / 3.0

# Canonical block-sparse tiling default; sparse/format re-exports it (via
# core/dataflow) as DEFAULT_BLOCK_SHAPE.
SPARSE_BLOCK = (256, 256)

# The issue-efficiency tax on the sparse kernels' live-block work lives in
# ``repro.core.hw`` (SPARSE_ISSUE_TAX analytic default, overridable by the
# bench_kernels --calibrate fit); cost models read it via
# ``hw.sparse_issue_tax()``.  No alias here — this module sits below
# repro.core in the import graph and a second literal would desynchronize;
# ``core/dataflow`` re-exports the hw constant for back-compat.

# The sparse kernel family.  select_kernel treats these specially (strict
# improvement over the best dense kernel required) and planners restrict
# them to the formats a layer actually carries.
SPARSE_KERNELS = ("tsar_sparse", "tsar_sparse_padded")


def _hw():
    from repro.core import hw

    return hw


def _leaf(frozen, key: str):
    """Uniform access to FrozenBitLinear fields / packed-param dict leaves."""
    if isinstance(frozen, dict):
        return frozen.get(key)
    return getattr(frozen, key, None)


def has_planes(frozen) -> bool:
    if isinstance(frozen, dict):
        # Stacked (scan/expert) plane dicts need a vmap wrapper, not lower().
        return ("sign" in frozen and "zero" in frozen
                and getattr(frozen["sign"], "ndim", 0) == 2)
    return _leaf(frozen, "packed") is not None


def _packed_of(frozen, x):
    """The layer's TernaryWeights: FrozenBitLinear carries it; packed-param
    dicts (``layers.pack_linear`` output) rebuild it from the planes, taking
    the true K from the activations (planes store the padded ceil(K/8)*8)."""
    packed = _leaf(frozen, "packed")
    if packed is not None:
        return packed
    from repro.core import ternary

    return ternary.TernaryWeights(
        frozen["sign"], frozen["zero"], frozen["scale"],
        (x.shape[-1], frozen["sign"].shape[-1]))


def _c_of(frozen) -> int:
    c = _leaf(frozen, "c")
    return 4 if c is None else c


def resolve_use_pallas(use_pallas: bool | None,
                       interpret: bool | None = None) -> bool:
    """``None`` auto-resolves from the backend: Pallas on TPU, the traceable
    jnp spelling elsewhere.  Explicit True/False still forces — and so does
    ``interpret=True``: requesting interpret mode means running the Pallas
    kernel (that is how the kernels are validated off-TPU).  ``interpret=
    False`` does NOT force Pallas — off-TPU the compiled Pallas path cannot
    run, so it keeps the backend auto-resolution (jnp fallback on CPU)."""
    if use_pallas is None:
        if interpret:
            return True
        from repro.kernels import ops

        return not ops._auto_interpret()
    return use_pallas


@runtime_checkable
class KernelImpl(Protocol):
    """What the planner and the runtime need from one kernel."""

    name: str
    selectable: bool  # costed by select_kernel (baselines are not)
    # Serve-path flag: when a plan names this kernel inside the jitted
    # serving step (models.layers._packed_linear), should the step call this
    # impl's lower() on the packed-dict leaves?  False for the dense T-SAR
    # families, whose planes spelling inlined in _packed_linear IS their
    # exact realization (and stays SPMD-shardable); True for kernels whose
    # lowering genuinely differs (fp escape hatch, DRAM-LUT baseline,
    # padded-pool sparse).  Declared here so the registry stays the single
    # source of per-kernel dispatch knowledge.
    serve_via_registry: bool

    def cost(self, n: int, k: int, m: int, c: int = 4,
             density: float = DEFAULT_DENSITY,
             block_density: float | None = None,
             block_shape: tuple = SPARSE_BLOCK) -> tuple[float, float]:
        """(compute_s, memory_s) roofline estimate."""
        ...

    def supports(self, frozen) -> bool:
        """Can this kernel serve this frozen layer (encodings present)?"""
        ...

    def tiles(self, n: int, k: int, m: int, c: int = 4) -> tuple[int, ...]:
        """Default tile sizes the Pallas wrapper would pick for this shape."""
        ...

    def lower(self, frozen, x: jax.Array, *, use_pallas: bool | None = None,
              interpret: bool | None = None, lp=None) -> jax.Array:
        """Run the kernel on a frozen layer: x (..., K) -> (..., M) f32.

        ``lp`` (a ``repro.plan.LayerPlan``) carries the planned dataflow and
        tile sizes; Pallas-bound lowerings execute them (grid order + tiling),
        the jnp spellings ignore them (no grid to order)."""
        ...


def _int8_dot(frozen, x32):
    """Shared exact decode->int8-dot spelling (traceable realization of the
    decode-near-datapath kernels off-TPU; bit-equal to the Pallas output)."""
    from repro.core import lut, ternary

    packed = _packed_of(frozen, x32)
    a_q, a_scale = ternary.quantize_activations(x32)
    t = ternary.unpack(packed)
    return lut.dense_int8_matmul(a_q, a_scale, t, packed.scale)


def _ops_tiles(n: int, k: int, m: int) -> tuple[int, int, int]:
    from repro.kernels import ops

    return (ops._tile(n, 128, 8), ops._tile(k, 512, 128), ops._tile(m, 256, 128))


class TsarMXU:
    """Decode 2-bit planes to {-1,0,+1} int8 in VMEM, feed the MXU."""

    name = "tsar_mxu"
    selectable = True
    serve_via_registry = False

    def cost(self, n, k, m, c=4, density=DEFAULT_DENSITY, block_density=None,
             block_shape=SPARSE_BLOCK):
        hw = _hw()
        flops = 2.0 * n * k * m                      # int8 MACs on the MXU
        decode_ops = k * m * 4.0                     # bitplane unpack ALU ops
        compute = flops / hw.PEAK_FLOPS_INT8 + decode_ops / (hw.PEAK_FLOPS_INT8 / 2)
        bytes_moved = (
            k * m * 0.25                             # 2-bit packed weights
            + n * k * 1.0                            # int8 activations
            + n * m * 2.0                            # bf16 outputs
            + m * 4.0                                # scales
        )
        return compute, bytes_moved / hw.HBM_BW

    def supports(self, frozen):
        return has_planes(frozen)

    def tiles(self, n, k, m, c=4):
        return _ops_tiles(n, k, m)

    def lower(self, frozen, x, *, use_pallas=None, interpret=None, lp=None):
        x32 = x.astype(jnp.float32)
        if resolve_use_pallas(use_pallas, interpret):
            from repro.kernels import ops

            kw = {}
            if lp is not None:      # execute the planned grid order + tiling
                kw["dataflow"] = lp.dataflow
                if len(lp.tile_sizes) == 3:
                    kw["bn"], kw["bk"], kw["bm"] = lp.tile_sizes
            return ops.tsar_matmul(x32, _packed_of(frozen, x),
                                   interpret=interpret, **kw)
        return _int8_dot(frozen, x32)


class TsarLUT:
    """Paper-faithful in-VMEM shared-LUT kernel (TLUT build + TGEMV gather)."""

    name = "tsar_lut"
    selectable = True
    serve_via_registry = False

    def cost(self, n, k, m, c=4, density=DEFAULT_DENSITY, block_density=None,
             block_shape=SPARSE_BLOCK):
        hw = _hw()
        blocks = k / c
        lut_build = n * blocks * (2 ** c) * 1.0      # TLUT expansion ops
        # Each gather lowered as one-hot x LUT: 2^c MACs per (block, m) pair,
        # two gathers per block (pos/zero) fused into one 2^c-wide matmul.
        gather = 2.0 * n * blocks * m * (2 ** c) / 8.0
        compute = (lut_build + gather) / hw.PEAK_FLOPS_INT8
        bytes_moved = (
            2.0 * (k / c) * m * 1.0                  # idx_pos + idx_zero, 1B each
            + n * k * 1.0
            + n * m * 2.0
            + m * 4.0
        )
        return compute, bytes_moved / hw.HBM_BW

    def supports(self, frozen):
        return _leaf(frozen, "idx_pos") is not None

    def tiles(self, n, k, m, c=4):
        from repro.kernels import ops

        return (ops._tile(-(-k // c), 128, 8), ops._tile(m, 256, 128))

    def lower(self, frozen, x, *, use_pallas=None, interpret=None, lp=None):
        from repro.core import lut

        x32 = x.astype(jnp.float32)
        c = _c_of(frozen)
        scale = _packed_of(frozen, x).scale
        if resolve_use_pallas(use_pallas, interpret):
            from repro.kernels import ops

            kw = {}
            if lp is not None and len(lp.tile_sizes) == 2:
                kw["bb"], kw["bm"] = lp.tile_sizes
            return ops.tsar_lut_gemv(x32, _leaf(frozen, "idx_pos"),
                                     _leaf(frozen, "idx_zero"), scale,
                                     c=c, interpret=interpret, **kw)
        return lut.tsar_lut_matmul(x32, _leaf(frozen, "idx_pos"),
                                   _leaf(frozen, "idx_zero"), c, scale)


class TsarSparse:
    """Zero-block-skipping matmul over a compacted BlockSparseTernary pool."""

    name = "tsar_sparse"
    selectable = True
    serve_via_registry = False

    def cost(self, n, k, m, c=4, density=DEFAULT_DENSITY, block_density=None,
             block_shape=SPARSE_BLOCK):
        """MXU work and weight bytes scale with the LIVE-block fraction; the
        index map (int32 per block) and per-strip gather lists are the
        sparsity tax, which is why the dense kernel wins at density ~ 1."""
        hw = _hw()
        tax = hw.sparse_issue_tax()
        if block_density is None:
            block_density = estimate_block_density(density, block_shape)
        bk, bm = block_shape
        kb, mb = max(k / bk, 1.0), max(m / bm, 1.0)
        live = block_density * kb * mb
        flops = 2.0 * n * bk * bm * live             # int8 MACs, live blocks only
        decode_ops = bk * bm * live * 4.0            # bitplane unpack, live only
        compute = tax * (
            flops / hw.PEAK_FLOPS_INT8 + decode_ops / (hw.PEAK_FLOPS_INT8 / 2))
        bytes_moved = (
            tax * live * bk * bm * 0.25              # 2-bit planes, live blocks
            + kb * mb * 4.0                          # block-index map (int32)
            + 2.0 * live * 4.0                       # kids+slots gather lists
            + n * k * 1.0                            # int8 activations
            + n * m * 2.0                            # bf16 outputs
            + m * 4.0                                # scales
        )
        return compute, bytes_moved / hw.HBM_BW

    def supports(self, frozen):
        return _leaf(frozen, "sparse") is not None

    def tiles(self, n, k, m, c=4):
        from repro.kernels import ops

        bk, bm = SPARSE_BLOCK
        return (ops._tile(n, 128, 8), bk, bm)

    def lower(self, frozen, x, *, use_pallas=None, interpret=None, lp=None):
        sparse = _leaf(frozen, "sparse")
        if sparse is None:
            raise ValueError("layer was frozen without a block-sparse sidecar")
        x32 = x.astype(jnp.float32)
        if resolve_use_pallas(use_pallas, interpret):
            from repro.kernels import ops

            kw = {}
            if lp is not None and lp.tile_sizes:
                kw["bn"] = lp.tile_sizes[0]   # bk/bm are fixed by the format
            return ops.tsar_sparse_matmul(x32, sparse, interpret=interpret,
                                          **kw)
        # Traceable jnp fallback: identical math to the sparse kernel (the
        # planes decode to the same ternary matrix, and skipped blocks
        # contribute exact int32 zeros either way).  The zero-skip advantage
        # itself only materializes in the Pallas kernel.
        return _int8_dot(frozen, x32)


def _padded_of(frozen, x):
    """The layer's PaddedBlockSparseTernary: FrozenBitLinear carries the
    object; packed-param dicts (``layers.pack_linear`` sparse output) rebuild
    it from the ``sp_*`` leaves, taking the true K/M from activations and
    scales (pool shapes store only the block-padded grid)."""
    padded = _leaf(frozen, "padded")
    if padded is not None:
        return padded
    from repro.sparse import format as sparse_format

    sp = frozen["sp_sign"]
    from repro.core import ternary as _t

    bk, bm = sp.shape[-2] * _t.PACK, sp.shape[-1]
    kb, mb = frozen["sp_map"].shape
    return sparse_format.PaddedBlockSparseTernary(
        sign_pool=sp, zero_pool=frozen["sp_zero"],
        block_map=frozen["sp_map"],
        occupancy=jnp.zeros((kb, mb), jnp.float32),  # telemetry; not stored
        scale=frozen["scale"],
        kids=frozen["sp_kids"], slots=frozen["sp_slots"],
        counts=frozen["sp_counts"],
        shape=(x.shape[-1], frozen["scale"].shape[-1]),
        block_shape=(bk, bm),
        max_live=sp.shape[0], s_steps=frozen["sp_kids"].shape[-1])


class TsarSparsePadded(TsarSparse):
    """2-D zero-skip matmul over a PADDED (static-shape, vmappable) pool.

    Same live-block math as ``tsar_sparse``; the pool is padded to a static
    ``max_live`` and the walk to a static ``s_steps``, so stacked scan-layer
    weights carry per-layer pools through vmap — this is the sparse kernel
    the SERVING path can actually plan and dispatch (compacted pools are
    data-dependent and cannot ride a scanned params tree).
    """

    name = "tsar_sparse_padded"
    selectable = True
    serve_via_registry = True

    def cost(self, n, k, m, c=4, density=DEFAULT_DENSITY, block_density=None,
             block_shape=SPARSE_BLOCK):
        """Compacted cost + the pad-walk overhead: the static s_steps walk
        issues its masked (dead) steps too, at a calibratable fraction of a
        live block's compute.  Strictly above ``tsar_sparse`` at every
        density — when both formats are present, the compacted pool wins."""
        comp, mem = TsarSparse.cost(self, n, k, m, c, density=density,
                                    block_density=block_density,
                                    block_shape=block_shape)
        hw = _hw()
        if block_density is None:
            block_density = estimate_block_density(density, block_shape)
        bk, bm = block_shape
        kb, mb = max(k / bk, 1.0), max(m / bm, 1.0)
        dead = (1.0 - block_density) * kb * mb
        per_block = (2.0 * n * bk * bm / hw.PEAK_FLOPS_INT8
                     + bk * bm * 4.0 / (hw.PEAK_FLOPS_INT8 / 2))
        comp += hw.sparse_pad_step_frac() * dead * per_block
        return comp, mem

    def supports(self, frozen):
        if isinstance(frozen, dict):
            sp = frozen.get("sp_sign")
            return sp is not None and getattr(sp, "ndim", 0) == 3
        return _leaf(frozen, "padded") is not None

    def lower(self, frozen, x, *, use_pallas=None, interpret=None, lp=None):
        pbst = _padded_of(frozen, x)
        x32 = x.astype(jnp.float32)
        if resolve_use_pallas(use_pallas, interpret):
            from repro.kernels import ops

            kw = {}
            if lp is not None and lp.tile_sizes:
                kw["bn"] = lp.tile_sizes[0]   # bk/bm are fixed by the format
            return ops.tsar_sparse_padded_matmul(x32, pbst,
                                                 interpret=interpret, **kw)
        # Traceable spelling that decodes FROM THE POOL (so vmap-carried
        # pools are load-bearing in the jitted serving step) then runs the
        # exact int8 pipeline — bit-identical to the dense planes path
        # because the padded pool round-trips the ternary matrix exactly.
        from repro.core import lut, ternary
        from repro.sparse import format as sparse_format

        t = sparse_format.padded_to_ternary(pbst)
        a_q, a_scale = ternary.quantize_activations(x32)
        return lut.dense_int8_matmul(a_q, a_scale, t, pbst.scale)


class MemoryLUT:
    """DRAM-resident 3^c-entry LUT gather — the bitnet.cpp-style baseline the
    paper beats; kept servable for A/B runs, never chosen by the planner."""

    name = "memory_lut"
    selectable = False
    serve_via_registry = True

    def cost(self, n, k, m, c=4, density=DEFAULT_DENSITY, block_density=None,
             block_shape=SPARSE_BLOCK):
        hw = _hw()
        blocks = k / c
        compute = 2.0 * n * blocks * m / hw.PEAK_FLOPS_INT8
        bytes_moved = (
            n * blocks * (3 ** c) * 4.0              # DRAM-resident LUT tables
            + blocks * m * 1.0                       # index stream
            + n * k * 1.0 + n * m * 2.0 + m * 4.0
        )
        return compute, bytes_moved / hw.HBM_BW

    def supports(self, frozen):
        return has_planes(frozen)

    def tiles(self, n, k, m, c=4):
        return _ops_tiles(n, k, m)

    def lower(self, frozen, x, *, use_pallas=None, interpret=None, lp=None):
        from repro.core import lut, ternary

        packed = _packed_of(frozen, x)
        c = _c_of(frozen)
        x32 = x.astype(jnp.float32)
        t = ternary.unpack(packed)
        pad = (-t.shape[0]) % c   # ragged K: zero channels x zero weights = 0
        if pad:
            t = jnp.pad(t, ((0, pad), (0, 0)))
            x32 = jnp.pad(x32, [(0, 0)] * (x32.ndim - 1) + [(0, pad)])
        li = lut.ternary_lut_indices(t, c)
        return lut.memory_lut_matmul(x32, li, c, packed.scale)


class Dense:
    """Dequantize to fp and run a plain matmul — the correctness oracle and
    the escape hatch a hand-edited plan can force per layer."""

    name = "dense"
    selectable = False
    serve_via_registry = True

    def cost(self, n, k, m, c=4, density=DEFAULT_DENSITY, block_density=None,
             block_shape=SPARSE_BLOCK):
        hw = _hw()
        compute = 2.0 * n * k * m / hw.PEAK_FLOPS_BF16
        bytes_moved = k * m * 2.0 + n * k * 2.0 + n * m * 2.0
        return compute, bytes_moved / hw.HBM_BW

    def supports(self, frozen):
        return has_planes(frozen)

    def tiles(self, n, k, m, c=4):
        return _ops_tiles(n, k, m)

    def lower(self, frozen, x, *, use_pallas=None, interpret=None, lp=None):
        from repro.core import lut, ternary

        w = ternary.unpack_dequant(_packed_of(frozen, x))
        return lut.dense_matmul(x.astype(jnp.float32), w)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, KernelImpl] = {}


def register(impl: KernelImpl) -> KernelImpl:
    """Register a kernel implementation (later registrations override)."""
    _REGISTRY[impl.name] = impl
    return impl


def get(name: str) -> KernelImpl:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown kernel {name!r}; registered: {names()}") from None


def names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def selectable_names() -> tuple[str, ...]:
    return tuple(n for n in names() if _REGISTRY[n].selectable)


def available(frozen) -> tuple[str, ...]:
    """Kernel names whose encodings are present on this frozen layer."""
    return tuple(n for n in names() if _REGISTRY[n].supports(frozen))


def estimate_block_density(density: float, block_shape: tuple = SPARSE_BLOCK) -> float:
    """Live-block fraction under UNSTRUCTURED zeros at this density — which
    makes essentially every block live (``1 - (1-d)^(bk*bm) ~ 1``), so the
    sparse path is only chosen on *measured* structured sparsity."""
    bk, bm = block_shape
    return 1.0 - (1.0 - min(density, 1.0 - 1e-12)) ** (bk * bm)


def candidate_costs(n: int, k: int, m: int, c: int = 4,
                    density: float = DEFAULT_DENSITY,
                    block_density: float | None = None,
                    block_shape: tuple = SPARSE_BLOCK,
                    ) -> dict[str, tuple[float, float]]:
    """(compute_s, memory_s) per selectable kernel — the planner's input."""
    return {
        name: _REGISTRY[name].cost(n, k, m, c, density=density,
                                   block_density=block_density,
                                   block_shape=block_shape)
        for name in selectable_names()
    }


for _impl in (TsarMXU(), TsarLUT(), TsarSparse(), TsarSparsePadded(),
              MemoryLUT(), Dense()):
    register(_impl)
del _impl
