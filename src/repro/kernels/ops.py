"""Public jitted wrappers for the T-SAR Pallas kernels.

Handles activation quantization, shape padding to tile multiples, leading-dim
flattening, and interpret-mode fallback on non-TPU backends (this container is
CPU-only; TPU is the compilation target, interpret mode the validation path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ternary
from repro.kernels import tsar_lut as _lut_kernel
from repro.kernels import tsar_matmul as _mxu_kernel
from repro.kernels import tsar_sparse as _sparse_kernel
from repro.sparse import format as sparse_format


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _tile(n: int, pref: int, align: int) -> int:
    """Pick a tile size <= pref that keeps the padded dim a tile multiple."""
    if n >= pref:
        return pref
    return max(align, ((n + align - 1) // align) * align)


# ---------------------------------------------------------------------------
# Shared prologue/epilogue: every public wrapper flattens leading dims to one
# row axis, (maybe) quantizes + pads to tile multiples, and finally slices the
# padding off and restores the leading dims.
# ---------------------------------------------------------------------------

def _flatten_lead(x: jax.Array) -> tuple[jax.Array, tuple, int]:
    """(..., K) -> ((N, K) float32, lead_shape, N)."""
    lead = x.shape[:-1]
    n = 1
    for d in lead:
        n *= d
    return x.reshape((n, x.shape[-1])).astype(jnp.float32), lead, n


def _quantize_padded(x2: jax.Array, bn: int, k_mult: int) -> tuple[jax.Array, jax.Array]:
    """Per-token int8 quantization, rows padded to ``bn`` and the channel
    axis zero-padded to ``k_mult`` (zero rows/columns contribute nothing)."""
    a_q, a_scale = ternary.quantize_activations(x2)
    a_q = _pad_to(_pad_to(a_q, 0, bn), 1, k_mult)
    a_scale = _pad_to(a_scale, 0, bn)
    return a_q, a_scale


def _unflatten_lead(y: jax.Array, lead: tuple, n: int, m: int) -> jax.Array:
    """(N_padded, M_padded) -> (..., M): slice padding, restore lead dims."""
    return y[:n, :m].reshape(lead + (m,))


def tsar_matmul(
    x: jax.Array,
    tw: ternary.TernaryWeights,
    *,
    dataflow: str = "AP",
    bn: int = 128,
    bk: int = 512,
    bm: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """BitLinear matmul via the production packed-decode kernel.

    ``x`` (..., K) float -> (..., M) float32.  Full pipeline: per-token int8
    quant -> packed-ternary int8 matmul with VMEM decode -> fused dequant.
    """
    if interpret is None:
        interpret = _auto_interpret()
    k, m = tw.shape
    x2, lead, n = _flatten_lead(x)

    bn_ = _tile(n, bn, 8)
    bk_ = _tile(k, bk, 128)   # keeps plane tile rows (bk//8) a sublane multiple
    bm_ = _tile(m, bm, 128)

    a_q, a_scale = _quantize_padded(x2, bn_, bk_)
    # Padded K rows decode to sign=0,zero=0 => weight +1, but the matching
    # activation rows are zero-padded so they contribute nothing.  Padded M
    # columns are sliced off below.
    sign = _pad_to(_pad_to(tw.sign_plane, 0, bk_ // 8), 1, bm_)
    zero = _pad_to(_pad_to(tw.zero_plane, 0, bk_ // 8), 1, bm_)
    wsc = _pad_to(tw.scale, 0, bm_)

    y = _mxu_kernel.tsar_matmul_packed(
        a_q, a_scale, sign, zero, wsc,
        bn=bn_, bk=bk_, bm=bm_, dataflow=dataflow, interpret=interpret,
    )
    return _unflatten_lead(y, lead, n, m)


def tsar_sparse_matmul(
    x: jax.Array,
    bst: "sparse_format.BlockSparseTernary",
    *,
    bn: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """BitLinear matmul via the zero-block-skipping sparse kernel.

    ``x`` (..., K) float -> (..., M) float32.  Same pipeline as
    :func:`tsar_matmul` (per-token int8 quant -> int32 accumulate -> fused
    dequant) but weights come from a compacted :class:`BlockSparseTernary`
    pool and dead (bk, bm) blocks are skipped entirely — the inner grid runs
    over LIVE blocks per m-strip, so interpret-mode cost (and on TPU, HBM
    traffic + MXU issue) drops with block density.  Output is bit-identical
    to the dense path: skipped blocks contribute exactly 0 in int32.
    """
    if interpret is None:
        interpret = _auto_interpret()
    k, m = bst.shape
    bk, bm = bst.block_shape
    kb, mb = bst.grid
    x2, lead, n = _flatten_lead(x)

    bn_ = _tile(n, bn, 8)
    # Pad activations to the format's padded K (pad columns hit zero-padded
    # weight tails inside edge blocks — or dead blocks — so they are exact).
    a_q, a_scale = _quantize_padded(x2, bn_, kb * bk)
    wsc = _pad_to(bst.scale, 0, mb * bm)

    kids, slots, counts, s_max = sparse_format.strip_schedule(bst)
    y = _sparse_kernel.tsar_sparse_matmul_packed(
        a_q, a_scale, bst.sign_pool, bst.zero_pool, kids, slots, counts,
        wsc.reshape(1, mb * bm),
        bn=bn_, bk=bk, bm=bm, s_steps=max(s_max, 1), interpret=interpret,
    )
    return _unflatten_lead(y, lead, n, m)


def tsar_sparse_padded_matmul(
    x: jax.Array,
    pbst: "sparse_format.PaddedBlockSparseTernary",
    *,
    bn: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """BitLinear matmul via the padded-pool 2-D zero-skip kernel.

    ``x`` (..., K) float -> (..., M) float32.  Same pipeline as
    :func:`tsar_sparse_matmul`, but the weights are a static-shaped
    :class:`PaddedBlockSparseTernary` pool (so the call is vmappable over
    stacked scan layers) and the schedule is 2-D: dead weight blocks are
    skipped via ``counts`` AND all-zero (bn, bk) activation tiles via a
    per-(n-strip, k-block) liveness map computed here from the quantized
    activations.  Both skips drop exact int32 zeros — output is
    bit-identical to :func:`tsar_matmul`.
    """
    if interpret is None:
        interpret = _auto_interpret()
    k, m = pbst.shape
    bk, bm = pbst.block_shape
    kb, mb = pbst.grid
    x2, lead, n = _flatten_lead(x)

    bn_ = _tile(n, bn, 8)
    a_q, a_scale = _quantize_padded(x2, bn_, kb * bk)
    wsc = _pad_to(pbst.scale, 0, mb * bm)

    # Activation-side liveness: one flag per (n-strip, k-block) tile.  Padded
    # rows/channels are zero, so the map also encodes the shape padding.
    n_t = a_q.shape[0] // bn_
    act_live = jnp.any(
        a_q.reshape(n_t, bn_, kb, bk) != 0, axis=(1, 3)).astype(jnp.int32)

    y = _sparse_kernel.tsar_sparse_padded_matmul_packed(
        a_q, a_scale, pbst.sign_pool, pbst.zero_pool,
        pbst.kids, pbst.slots, pbst.counts, act_live,
        wsc.reshape(1, mb * bm),
        bn=bn_, bk=bk, bm=bm, s_steps=max(pbst.s_steps, 1),
        interpret=interpret,
    )
    return _unflatten_lead(y, lead, n, m)


def tsar_lut_gemv(
    x: jax.Array,
    idx_pos: jax.Array,
    idx_zero: jax.Array,
    w_scale: jax.Array,
    *,
    c: int = 4,
    bb: int = 128,
    bm: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """BitLinear GEMV via the paper-faithful in-VMEM LUT kernel.

    ``x`` (..., K) float -> (..., M) float32.
    """
    if interpret is None:
        interpret = _auto_interpret()
    blocks, m = idx_pos.shape
    x2, lead, n = _flatten_lead(x)  # true K; blocks*c >= k for ragged layers

    bb_ = _tile(blocks, bb, 8)
    bm_ = _tile(m, bm, 128)

    # Padded activation channels are zero, so padded-block LUT entries are all
    # zero and any index gathers 0 — padding is exact.  This also covers a
    # ragged tail block (pack_indices zero-padded K up to blocks*c).
    x2 = _pad_to(_pad_to(x2, 1, blocks * c), 1, bb_ * c)
    ip = _pad_to(_pad_to(idx_pos, 0, bb_), 1, bm_)
    iz = _pad_to(_pad_to(idx_zero, 0, bb_), 1, bm_)
    wsc = _pad_to(w_scale, 0, bm_)

    y = _lut_kernel.tsar_lut_gemv(
        x2, ip, iz, wsc, c=c, bb=bb_, bm=bm_, interpret=interpret
    )
    return _unflatten_lead(y, lead, n, m)
