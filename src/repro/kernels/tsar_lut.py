"""Paper-faithful T-SAR LUT kernel: in-VMEM TLUT build + TGEMV consume.

This kernel is the literal transcription of the paper's two-instruction
pipeline onto Pallas/TPU:

* **TLUT_cxs** (Fig. 6(b)) — for every activation block of size ``c``, build
  the shared binary LUT ``S[p] = sum_i bit_i(p) * a_i`` (2^c entries).  Here
  that is a tiny (c -> 2^c) matmul executed in VMEM scratch; the LUT never
  exists outside the kernel, exactly like the YMM-resident tables.
* **TGEMV_kxm** (Fig. 6(c)) — consume the LUTs against pre-encoded weight
  indices with fused accumulation.  A gather from a 2^c-entry table is, on
  TPU, a one-hot (2^c-wide) matmul — the MXU plays the role of the SIMD
  adder trees.  We fuse the paper's two gathers (dense/sparse planes) into a
  single combined one-hot operand: ``comb = 2*onehot(idx_pos) +
  onehot(idx_zero)`` so that ``y_block = S_b @ comb_b - sum(a_block)``
  (DESIGN.md Sec. 2.1 single-LUT identity).

Grid: (m_tiles, b_tiles) with the block axis innermost; the (N, bm) f32
accumulator lives in VMEM scratch and is written back once (fused
accumulation, no intermediate write-back — the OP dataflow of Fig. 7(b)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, ipos_ref, izero_ref, wsc_ref, o_ref, acc_ref, *,
            c: int, b_steps: int):
    bstep = pl.program_id(1)

    @pl.when(bstep == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    n = a_ref.shape[0]
    bb = ipos_ref.shape[0]          # blocks in this tile
    lut_w = 1 << c

    # ---- TLUT: build shared binary LUTs in VMEM -------------------------
    a_blocks = a_ref[...].reshape(n, bb, c)
    # bits[p, i] = bit_i(p), built in-kernel via iota (no captured constants).
    p_iota = jax.lax.broadcasted_iota(jnp.int32, (lut_w, c), 0)
    i_iota = jax.lax.broadcasted_iota(jnp.int32, (lut_w, c), 1)
    bits = ((p_iota >> i_iota) & 1).astype(jnp.float32)           # (2^c, c)
    s = jax.lax.dot_general(                                       # (n, bb, 2^c)
        a_blocks, bits,
        dimension_numbers=(((2,), (1,)), ((), ())),
    )
    tot = jnp.sum(a_blocks, axis=(1, 2))                           # (n,)

    # ---- TGEMV: combined one-hot gather + fused accumulation ------------
    iota = jax.lax.broadcasted_iota(jnp.int32, (bb, lut_w, 1), 1)
    ip = ipos_ref[...].astype(jnp.int32)[:, None, :]               # (bb, 1, bm)
    iz = izero_ref[...].astype(jnp.int32)[:, None, :]
    comb = (2.0 * (iota == ip) + 1.0 * (iota == iz)).astype(jnp.float32)
    # y[n, m] += sum_b S[n, b, :] @ comb[b, :, m]
    contrib = jax.lax.dot_general(
        s, comb,
        dimension_numbers=(((2,), (1,)), ((1,), (0,))),            # batch over b
    )                                                              # (bb, n, bm)
    acc_ref[...] += jnp.sum(contrib, axis=0) - tot[:, None]

    @pl.when(bstep == b_steps - 1)
    def _finish():
        o_ref[...] = acc_ref[...] * wsc_ref[...].astype(jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("c", "bb", "bm", "interpret")
)
def tsar_lut_gemv(
    a: jax.Array,          # f32 (N, K) — N small (decode batch)
    idx_pos: jax.Array,    # uint8 (K//c, M)
    idx_zero: jax.Array,   # uint8 (K//c, M)
    w_scale: jax.Array,    # f32 (M,)
    *,
    c: int = 4,
    bb: int = 128,         # blocks per tile (bb*c input channels)
    bm: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """(N, K) x encoded ternary (K, M) -> (N, M) f32 via in-VMEM LUTs.

    Caller guarantees (K//c) % bb == 0 and M % bm == 0 (ops.py pads).
    """
    n, k = a.shape
    blocks, m = idx_pos.shape
    assert blocks * c == k, (blocks, c, k)
    b_t, m_t = blocks // bb, m // bm

    out = pl.pallas_call(
        functools.partial(_kernel, c=c, b_steps=b_t),
        grid=(m_t, b_t),
        in_specs=[
            pl.BlockSpec((n, bb * c), lambda mi, bi: (0, bi)),
            pl.BlockSpec((bb, bm), lambda mi, bi: (bi, mi)),
            pl.BlockSpec((bb, bm), lambda mi, bi: (bi, mi)),
            pl.BlockSpec((1, bm), lambda mi, bi: (0, mi)),
        ],
        out_specs=pl.BlockSpec((n, bm), lambda mi, bi: (0, mi)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, bm), jnp.float32)],
        interpret=interpret,
    )(a.astype(jnp.float32), idx_pos, idx_zero, w_scale.reshape(1, m))
    return out
