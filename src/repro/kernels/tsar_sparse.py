"""Sparsity-aware T-SAR kernel: zero-block-skipping packed-ternary matmul.

Same inner tile as ``tsar_matmul`` (2-bit bitplanes decoded to {-1,0,+1} int8
in VMEM, consumed by the MXU, int32 accumulation, fused dequant) — but the
weight operand is a :class:`repro.sparse.format.BlockSparseTernary` compacted
pool, and the kernel *never touches dead blocks*:

* the grid's inner extent is ``s_max`` — the max number of LIVE k-blocks in
  any m-strip — not ``K / bk``.  A model whose FFN block columns are 30% dead
  runs a 30% shorter grid;
* per-step, scalar-prefetched index maps (``pltpu.PrefetchScalarGridSpec``)
  gather the s-th live block's activation k-slice and pool slot, so only live
  blocks' bytes ever cross HBM -> VMEM;
* strips with fewer live blocks than ``s_max`` mask the tail contributions
  with ``s < counts[j]`` (the padded DMA reads slot 0, a valid block, and the
  mask drops it).

Skipped blocks contribute exactly 0 to the int32 accumulator, so the output
is bit-identical to the dense ``tsar_matmul`` path.

The **padded-pool 2-D schedule** (:func:`tsar_sparse_padded_matmul_packed`)
extends the skip to the activation side: besides the weight-side
``s < counts[j]`` guard, a scalar-prefetched ``(n-strip, k-block)`` liveness
map — computed from the quantized activations before the call — drops the
dot for any (bn, bk) activation tile that is entirely zero (padded batch
rows, padded K channels, genuinely silent token tiles).  Both guards drop
exact int32 zeros, so the output stays bit-identical to ``tsar_matmul``.
``s_steps`` is STATIC here (the padded format's uniform walk width), which
is what lets stacked scan layers run this kernel with per-layer pools
carried through ``vmap``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Same in-VMEM bitplane decode as the dense kernel — one definition, so the
# two kernels can't drift from core/ternary._pack_bits's LSB-first layout.
from repro.kernels.tsar_matmul import PACK, _unpack_plane


def _kernel(kids_ref, slots_ref, counts_ref, a_ref, sign_ref, zero_ref,
            asc_ref, wsc_ref, o_ref, acc_ref, *, s_steps: int):
    """One (m_tile, n_tile, live-block step)."""
    j = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(s < counts_ref[j])
    def _accumulate():
        bk = a_ref.shape[-1]
        sign = _unpack_plane(sign_ref[0], bk)   # 1 => weight < 0
        zero = _unpack_plane(zero_ref[0], bk)   # 1 => weight == 0
        vals = ((1 - 2 * sign) * (1 - zero)).astype(jnp.int8)
        acc_ref[...] += jax.lax.dot_general(
            a_ref[...], vals,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )

    @pl.when(s == s_steps - 1)
    def _finish():
        o_ref[...] = (
            acc_ref[...].astype(jnp.float32)
            * asc_ref[...].astype(jnp.float32)          # (bn, 1) per-token
            * wsc_ref[...].astype(jnp.float32)          # (1, bm) per-channel
        )


@functools.partial(
    jax.jit,
    static_argnames=("bn", "bk", "bm", "s_steps", "interpret"),
)
def tsar_sparse_matmul_packed(
    a_q: jax.Array,        # int8 (N, Kp)  Kp = kb * bk (zero-padded)
    a_scale: jax.Array,    # f32  (N, 1)
    sign_pool: jax.Array,  # uint8 (n_slots, bk//8, bm)
    zero_pool: jax.Array,  # uint8 (n_slots, bk//8, bm)
    kids: jax.Array,       # int32 (mb, s_steps)  k-block index per live step
    slots: jax.Array,      # int32 (mb, s_steps)  pool slot per live step
    counts: jax.Array,     # int32 (mb,)          live blocks per m-strip
    w_scale: jax.Array,    # f32  (1, Mp)  Mp = mb * bm
    *,
    bn: int,
    bk: int,
    bm: int,
    s_steps: int,
    interpret: bool = False,
) -> jax.Array:
    """(N, Kp) int8 x block-sparse ternary pool -> (N, Mp) f32.

    Caller guarantees N % bn == 0, Kp == kb*bk, Mp == mb*bm, s_steps >= 1
    (ops.py pads / clamps).
    """
    n = a_q.shape[0]
    mb = kids.shape[0]
    n_t = n // bn
    grid = (mb, n_t, s_steps)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,          # kids, slots, counts
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda j, i, s, kids, slots, counts: (i, kids[j, s])),
            pl.BlockSpec((1, bk // PACK, bm),
                         lambda j, i, s, kids, slots, counts: (slots[j, s], 0, 0)),
            pl.BlockSpec((1, bk // PACK, bm),
                         lambda j, i, s, kids, slots, counts: (slots[j, s], 0, 0)),
            pl.BlockSpec((bn, 1), lambda j, i, s, kids, slots, counts: (i, 0)),
            pl.BlockSpec((1, bm), lambda j, i, s, kids, slots, counts: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda j, i, s, kids, slots, counts: (i, j)),
        scratch_shapes=[pltpu.VMEM((bn, bm), jnp.int32)],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, s_steps=s_steps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, mb * bm), jnp.float32),
        interpret=interpret,
    )(kids, slots, counts, a_q, sign_pool, zero_pool, a_scale, w_scale)
    return out


# ---------------------------------------------------------------------------
# Padded-pool kernel: 2-D (n-strip x m-strip) zero-skip schedule
# ---------------------------------------------------------------------------

def _kernel_2d(kids_ref, slots_ref, counts_ref, act_live_ref, a_ref, sign_ref,
               zero_ref, asc_ref, wsc_ref, o_ref, acc_ref, *, s_steps: int):
    """One (m_tile, n_tile, walk step) — dead WEIGHT blocks are masked by
    ``counts`` exactly like :func:`_kernel`; dead ACTIVATION tiles by the
    scalar-prefetched per-(n-strip, k-block) liveness map."""
    j = pl.program_id(0)
    i = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = (s < counts_ref[j]) & (act_live_ref[i, kids_ref[j, s]] > 0)

    @pl.when(live)
    def _accumulate():
        bk = a_ref.shape[-1]
        sign = _unpack_plane(sign_ref[0], bk)   # 1 => weight < 0
        zero = _unpack_plane(zero_ref[0], bk)   # 1 => weight == 0
        vals = ((1 - 2 * sign) * (1 - zero)).astype(jnp.int8)
        acc_ref[...] += jax.lax.dot_general(
            a_ref[...], vals,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )

    @pl.when(s == s_steps - 1)
    def _finish():
        o_ref[...] = (
            acc_ref[...].astype(jnp.float32)
            * asc_ref[...].astype(jnp.float32)          # (bn, 1) per-token
            * wsc_ref[...].astype(jnp.float32)          # (1, bm) per-channel
        )


@functools.partial(
    jax.jit,
    static_argnames=("bn", "bk", "bm", "s_steps", "interpret"),
)
def tsar_sparse_padded_matmul_packed(
    a_q: jax.Array,        # int8 (N, Kp)  Kp = kb * bk (zero-padded)
    a_scale: jax.Array,    # f32  (N, 1)
    sign_pool: jax.Array,  # uint8 (max_live, bk//8, bm)
    zero_pool: jax.Array,  # uint8 (max_live, bk//8, bm)
    kids: jax.Array,       # int32 (mb, s_steps)  k-block index per walk step
    slots: jax.Array,      # int32 (mb, s_steps)  pool slot per walk step
    counts: jax.Array,     # int32 (mb,)          live blocks per m-strip
    act_live: jax.Array,   # int32 (N//bn, kb)    1 = activation tile nonzero
    w_scale: jax.Array,    # f32  (1, Mp)  Mp = mb * bm
    *,
    bn: int,
    bk: int,
    bm: int,
    s_steps: int,
    interpret: bool = False,
) -> jax.Array:
    """(N, Kp) int8 x padded block-sparse ternary pool -> (N, Mp) f32.

    Identical contract to :func:`tsar_sparse_matmul_packed`, but ``s_steps``
    is the padded format's STATIC walk width and the extra ``act_live`` map
    adds the activation-side skip.  Caller guarantees N % bn == 0,
    Kp == kb*bk, Mp == mb*bm, s_steps >= 1 (ops.py pads / clamps).
    """
    n = a_q.shape[0]
    mb = kids.shape[0]
    n_t = n // bn
    grid = (mb, n_t, s_steps)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,          # kids, slots, counts, act_live
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk),
                         lambda j, i, s, kids, slots, counts, al: (i, kids[j, s])),
            pl.BlockSpec((1, bk // PACK, bm),
                         lambda j, i, s, kids, slots, counts, al: (slots[j, s], 0, 0)),
            pl.BlockSpec((1, bk // PACK, bm),
                         lambda j, i, s, kids, slots, counts, al: (slots[j, s], 0, 0)),
            pl.BlockSpec((bn, 1),
                         lambda j, i, s, kids, slots, counts, al: (i, 0)),
            pl.BlockSpec((1, bm),
                         lambda j, i, s, kids, slots, counts, al: (0, j)),
        ],
        out_specs=pl.BlockSpec(
            (bn, bm), lambda j, i, s, kids, slots, counts, al: (i, j)),
        scratch_shapes=[pltpu.VMEM((bn, bm), jnp.int32)],
    )
    out = pl.pallas_call(
        functools.partial(_kernel_2d, s_steps=s_steps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, mb * bm), jnp.float32),
        interpret=interpret,
    )(kids, slots, counts, act_live, a_q, sign_pool, zero_pool, a_scale,
      w_scale)
    return out
