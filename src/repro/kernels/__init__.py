"""T-SAR Pallas TPU kernels.

* ``tsar_matmul`` — production packed-ternary matmul (decode-in-VMEM -> MXU).
* ``tsar_lut`` — paper-faithful in-VMEM TLUT/TGEMV kernel.
* ``ops`` — jitted public wrappers (padding, quant, interpret fallback).
* ``ref`` — pure-jnp oracles.
"""
from repro.kernels import ops, ref  # noqa: F401
