"""T-SAR Pallas TPU kernels.

* ``tsar_matmul`` — production packed-ternary matmul (decode-in-VMEM -> MXU).
* ``tsar_lut`` — paper-faithful in-VMEM TLUT/TGEMV kernel.
* ``tsar_sparse`` — zero-block-skipping matmul over a compacted
  ``BlockSparseTernary`` pool (scalar-prefetched block-id gather), plus the
  padded-pool 2-D variant (static ``s_steps`` walk + activation-tile skip)
  that vmapped/stacked serving weights run.
* ``ops`` — jitted public wrappers (padding, quant, interpret fallback).
* ``ref`` — pure-jnp oracles.

See ``docs/kernels.md`` for the kernel zoo and when each path wins.
"""
from repro.kernels import ops, ref  # noqa: F401
