"""Production T-SAR kernel: packed-ternary matmul, decode-in-VMEM -> MXU.

This is the TPU-native realization of the paper's in-register dataflow
(DESIGN.md Sec. 2): the 2-bit weight bitplanes are the ONLY weight bytes that
cross HBM; they are expanded to {-1,0,+1} int8 values inside VMEM, right next
to the MXU, and consumed immediately — the exact analogue of TLUT/TGEMV
building and consuming tables inside the SIMD register file instead of DRAM.

Dataflow (paper Sec. III-D) maps to the grid iteration order:

* AP (activation-persistent): grid = (n, m, k) — the activation tile loaded
  for an ``n`` index is reused across all ``m`` tiles before moving on.
* OP (output-persistent): grid = (m, n, k) — the output accumulator for an
  ``m`` tile is completed before any other output tile is touched, and
  weight-plane tiles are reused across ``n``.

``k`` is always innermost: partial products accumulate in an int32 VMEM
scratch and the output is written once, on the final ``k`` step (the paper's
fused accumulation — no intermediate write-back).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

PACK = 8


def _unpack_plane(plane: jax.Array, bk: int) -> jax.Array:
    """(bk//8, bm) uint8 -> (bk, bm) int8 {0,1}, LSB-first (matches
    repro.core.ternary._pack_bits)."""
    shifts = jnp.arange(PACK, dtype=jnp.uint8)[None, :, None]
    bits = (plane[:, None, :] >> shifts) & jnp.uint8(1)
    return bits.reshape(bk, plane.shape[-1]).astype(jnp.int8)


def _kernel(a_ref, sign_ref, zero_ref, asc_ref, wsc_ref, o_ref, acc_ref, *,
            k_steps: int, k_axis: int):
    """One (bn, bm, bk) tile step."""
    kstep = pl.program_id(k_axis)

    @pl.when(kstep == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bk = a_ref.shape[-1]
    sign = _unpack_plane(sign_ref[...], bk)   # 1 => weight < 0
    zero = _unpack_plane(zero_ref[...], bk)   # 1 => weight == 0
    # vals = (1 - 2*sign) * (1 - zero) in {-1, 0, +1}
    vals = ((1 - 2 * sign) * (1 - zero)).astype(jnp.int8)
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], vals,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(kstep == k_steps - 1)
    def _finish():
        o_ref[...] = (
            acc_ref[...].astype(jnp.float32)
            * asc_ref[...].astype(jnp.float32)          # (bn, 1) per-token
            * wsc_ref[...].astype(jnp.float32)          # (1, bm) per-channel
        )


@functools.partial(
    jax.jit,
    static_argnames=("bn", "bk", "bm", "dataflow", "interpret"),
)
def tsar_matmul_packed(
    a_q: jax.Array,        # int8 (N, K)
    a_scale: jax.Array,    # f32  (N, 1)
    sign_plane: jax.Array, # uint8 (K//8, M)
    zero_plane: jax.Array, # uint8 (K//8, M)
    w_scale: jax.Array,    # f32  (M,)
    *,
    bn: int = 128,
    bk: int = 512,
    bm: int = 256,
    dataflow: str = "AP",
    interpret: bool = False,
) -> jax.Array:
    """(N, K) int8 x packed ternary (K, M) -> (N, M) f32.

    Caller guarantees N % bn == K % bk == M % bm == 0 (ops.py pads).
    """
    n, k = a_q.shape
    m = sign_plane.shape[1]
    n_t, k_t, m_t = n // bn, k // bk, m // bm

    if dataflow == "AP":
        grid = (n_t, m_t, k_t)
        nm = lambda i, j, s: (i, j)          # grid ids -> (n_idx, m_idx)
    elif dataflow == "OP":
        grid = (m_t, n_t, k_t)
        nm = lambda i, j, s: (j, i)
    else:
        raise ValueError(f"dataflow must be AP or OP, got {dataflow!r}")
    k_axis = 2

    def a_map(i, j, s):
        ni, _ = nm(i, j, s)
        return (ni, s)

    def plane_map(i, j, s):
        _, mi = nm(i, j, s)
        return (s, mi)

    def asc_map(i, j, s):
        ni, _ = nm(i, j, s)
        return (ni, 0)

    def wsc_map(i, j, s):
        _, mi = nm(i, j, s)
        return (0, mi)

    def o_map(i, j, s):
        return nm(i, j, s)

    out = pl.pallas_call(
        functools.partial(_kernel, k_steps=k_t, k_axis=k_axis),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), a_map),
            pl.BlockSpec((bk // PACK, bm), plane_map),
            pl.BlockSpec((bk // PACK, bm), plane_map),
            pl.BlockSpec((bn, 1), asc_map),
            pl.BlockSpec((1, bm), wsc_map),
        ],
        out_specs=pl.BlockSpec((bn, bm), o_map),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bm), jnp.int32)],
        interpret=interpret,
    )(a_q, sign_plane, zero_plane, a_scale, w_scale.reshape(1, m))
    return out
