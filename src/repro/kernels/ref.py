"""Pure-jnp oracles for the T-SAR Pallas kernels.

Every kernel in this package is validated against these references with
``interpret=True`` shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ternary


def ternary_matmul_ref(a: jax.Array, t: jax.Array, w_scale: jax.Array | None = None) -> jax.Array:
    """Dense fp32 oracle: (..., K) x ternary (K, M) -> (..., M)."""
    y = a.astype(jnp.float32) @ t.astype(jnp.float32)
    if w_scale is not None:
        y = y * w_scale.astype(jnp.float32)
    return y


def packed_matmul_ref(a: jax.Array, tw: ternary.TernaryWeights) -> jax.Array:
    """Oracle for the packed path: unpack bitplanes, dense matmul, dequant."""
    t = ternary.unpack(tw)
    return ternary_matmul_ref(a, t, tw.scale)


def quantized_matmul_ref(a: jax.Array, tw: ternary.TernaryWeights) -> jax.Array:
    """Oracle with the exact int8-quantized activation pipeline the production
    kernel implements (quant -> int32 matmul -> dequant)."""
    a_q, a_scale = ternary.quantize_activations(a.astype(jnp.float32))
    t = ternary.unpack(tw)
    acc = jax.lax.dot_general(
        a_q, t,
        dimension_numbers=(((a_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * a_scale * tw.scale


def block_sparse_matmul_ref(a: jax.Array, bst) -> jax.Array:
    """Oracle for the zero-block-skipping path: decompact the block pool back
    to a dense ternary matrix, then run the exact quantized pipeline.  The
    sparse Pallas kernel must match this bit-for-bit (skipped blocks are
    exact int32 zeros)."""
    from repro.sparse import format as sparse_format

    t = sparse_format.to_ternary(bst)
    a_q, a_scale = ternary.quantize_activations(a.astype(jnp.float32))
    acc = jax.lax.dot_general(
        a_q, t,
        dimension_numbers=(((a_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * a_scale * bst.scale
