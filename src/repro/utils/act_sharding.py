"""Activation sharding constraints (mesh-context aware, dependency-free).

Model code calls :func:`constrain` at key activation points (q/k/v, attention
context, residual stream, logits).  When no mesh is registered (CPU unit
tests, single-device runs) these are no-ops; the launch drivers register the
production mesh so XLA's sharding propagation is pinned instead of being left
to guess — leaving it free is how 50 GB replicated score tensors happen (see
EXPERIMENTS.md §Perf iteration log).

Head-axis fallback chain for attention tensors (B, S, H, Dh): shard H on
'model' when divisible, else Dh (head-dim sharding keeps the contraction
local and lets XLA insert one small psum per attention), else replicate.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Mesh | None = None


def set_mesh(mesh: Mesh | None):
    global _MESH
    _MESH = mesh


def get_mesh() -> Mesh | None:
    return _MESH


def _dax(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _dsize(mesh):
    n = 1
    for a in _dax(mesh):
        n *= mesh.shape[a]
    return n


def constrain(x: jax.Array, kind: str) -> jax.Array:
    """kind: 'qkv' (B,S,H,Dh) | 'residual' (B,S,D) | 'logits' (B,S,V)
    | 'vocab_rows' (V, D) | 'vocab_cols' (D, V)."""
    mesh = _MESH
    if mesh is None:
        return x
    dsz = _dsize(mesh)
    msz = mesh.shape["model"]
    dax = _dax(mesh)
    spec = [None] * x.ndim

    if kind in ("vocab_rows", "vocab_cols"):
        # Head weights at the matmul use site: vocab axis on 'model', the
        # d_model contraction axis REPLICATED.  Without this, FSDP-sharded
        # embeddings make XLA all-reduce full (tokens, V) logits over the
        # data axis (observed 4.3 GB/step/device on gemma3) instead of
        # all-gathering the ~170 MB weight shard.
        vax = 0 if kind == "vocab_rows" else 1
        if x.shape[vax] % msz == 0:
            spec[vax] = "model"
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))

    if x.shape[0] % dsz == 0:
        spec[0] = dax
    if kind in ("attn_q", "attn_kv") and x.ndim == 4:
        # Shard heads on 'model' when they divide.  Otherwise: shard the
        # QUERY sequence axis and replicate K/V over 'model' (sequence-
        # parallel attention — scores/softmax/context stay fully local).
        # Never shard Dh: a Dh-sharded contraction psums the full (S, T)
        # score matrix (observed 2.1 GB/layer/chunk all-reduces on gemma3).
        if x.shape[2] % msz == 0:
            spec[2] = "model"
        elif kind == "attn_q" and x.shape[1] > 1 and x.shape[1] % msz == 0:
            spec[1] = "model"
    elif kind == "logits":
        if x.shape[-1] % msz == 0:
            spec[-1] = "model"
    elif kind == "moe" and x.ndim == 4:
        # Expert-parallel compute tensors (G, E, C, D): pin the expert axis to
        # 'model' so dispatch lowers to an all-to-all instead of XLA gathering
        # the (huge) expert weight stacks to the tokens.
        if x.shape[1] % msz == 0:
            spec[1] = "model"
    elif kind == "expert_weights" and x.ndim == 3:
        # Decoded (E, K, M) expert weights: expert-sharded, replicated over
        # data — matches the packed storage, so the unpack stays local.
        spec[0] = "model" if x.shape[0] % msz == 0 else None
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
    # 'residual': batch-sharded, replicated on model (Megatron convention).
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
