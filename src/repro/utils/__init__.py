from repro.utils import act_sharding  # noqa: F401
