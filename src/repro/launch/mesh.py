"""Production mesh construction.

A FUNCTION, not a module constant — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import; smoke tests
see the single real CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16 data x 16 model).  Multi-pod: 2 x 256.

    The 'pod' axis stacks data parallelism across the DCN; gradient
    all-reduce is the only collective that crosses it (see sharding rules).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary mesh for scaling studies / tests."""
    return jax.make_mesh(shape, axes)
