"""Production training driver.

Composes every substrate layer: config resolution (--arch), mesh + sharding,
data pipeline, pjit'd train step (remat/accum/FSDP), checkpointing with
auto-resume, fault-tolerant supervision, straggler monitoring, and optional
int8 gradient compression for the DP axis.

On real hardware this runs under one process per host with
``jax.distributed.initialize()``; on this container it runs reduced configs
on the single CPU device (``--smoke``), exercising the identical code path.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --smoke --steps 20
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro import checkpoint as ckpt
from repro.data import DataConfig, PrefetchIterator, SyntheticLMStream
from repro.models import model_zoo as zoo
from repro.optim import OptConfig
from repro.runtime import Heartbeat, StepMonitor, run_with_restarts
from repro.train import init_state, jit_train_step, make_train_step
from repro.utils import act_sharding


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--smoke", action="store_true", help="reduced config on CPU")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--mesh", choices=["none", "single", "multi"], default="none",
                    help="'none' = default device placement (smoke runs)")
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 error-feedback DP gradient all-reduce (shard_map)")
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    seq = args.seq_len or (64 if args.smoke else 4096)
    gb = args.global_batch or (8 if args.smoke else 256)

    opt_cfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 2),
                        total_steps=args.steps)
    stream = SyntheticLMStream(DataConfig(cfg.vocab_size, seq, gb))
    ckdir = os.path.join(args.ckpt_dir, cfg.name)
    monitor = StepMonitor()
    hb = Heartbeat(os.path.join(ckdir, "heartbeat.json"))
    os.makedirs(ckdir, exist_ok=True)

    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        act_sharding.set_mesh(mesh)
    else:
        mesh = None

    def build_step(state):
        if args.compress_grads:
            from repro.train import make_compressed_dp_train_step
            dp_mesh = jax.make_mesh((jax.device_count(),), ("data",))
            return make_compressed_dp_train_step(cfg, opt_cfg, dp_mesh,
                                                 remat=args.remat)
        if mesh is not None:
            return jit_train_step(cfg, opt_cfg, mesh, state, stream.batch(0),
                                  fsdp=args.fsdp, remat=args.remat,
                                  accum_steps=args.accum_steps)
        return jax.jit(make_train_step(cfg, opt_cfg, remat=args.remat,
                                       accum_steps=args.accum_steps))

    def restore_fn():
        target = init_state(cfg, jax.random.PRNGKey(0), opt_cfg,
                            compressed=args.compress_grads)
        latest = ckpt.latest_step(ckdir)
        if latest is None:
            return target, 0
        print(f"[train] resuming from step {latest}")
        return ckpt.restore(ckdir, latest, target), latest

    def body(state, start):
        step_fn = build_step(state)
        it = PrefetchIterator(stream, start_step=start)
        try:
            for _ in range(start, args.steps):
                i, batch = next(it)
                monitor.start(i)
                state, metrics = step_fn(state, batch)
                dt = monitor.stop()
                hb.beat(i)
                if monitor.is_straggler(dt):
                    print(f"[straggler] step {i} took {dt:.2f}s "
                          f"(median {monitor.median():.2f}s)")
                if (i + 1) % args.ckpt_every == 0 or (i + 1) == args.steps:
                    ckpt.save(ckdir, i + 1, state, async_save=True)
                if i % 10 == 0:
                    print(f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                          f"gnorm {float(metrics['grad_norm']):.2f}  "
                          f"{dt*1e3:.0f} ms")
        finally:
            it.close()
        return args.steps

    report = run_with_restarts(body, restore_fn=restore_fn,
                               max_restarts=args.max_restarts)
    print(f"[train] completed={report.completed} restarts={report.restarts} "
          f"last_step={report.last_step}")
    return 0 if report.completed else 1


if __name__ == "__main__":
    raise SystemExit(main())
