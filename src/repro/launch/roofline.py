"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds (TPU v5e constants):

    compute    = HLO_FLOPs / peak_FLOP/s          (cost_analysis, per device)
    memory     = HLO_bytes / HBM_bw               (cost_analysis, per device)
    collective = collective_bytes / link_bw       (parsed from partitioned HLO)

``cost_analysis()``/the HLO text describe the per-device (post-SPMD) module,
so no further division by chip count is needed.  collective_bytes sums the
*operand* sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op, i.e. bytes ingested by the interconnect
per device per step — a lower bound on wire traffic (ring algorithms move
~2x for all-reduce; we report the raw operand sum and note the convention).
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass

# TPU v5e hardware constants (per chip) — shared with core/dataflow via
# core/hw so the dispatch cost model and the dry-run roofline can't drift.
from repro.core.hw import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16  # noqa: F401

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(\w[\w\d-]*)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'f32[128,256]' -> bytes.  Tuples handled by summing members."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# ---------------------------------------------------------------------------
# Trip-count-aware HLO program analysis
# ---------------------------------------------------------------------------
#
# XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, not
# multiplied by trip count (verified empirically on this backend) — a 62-layer
# scanned model would be under-counted ~62x.  This parser walks the optimized
# HLO computation graph, scales each while body by its
# ``backend_config known_trip_count`` (fallback: the loop condition's compare
# constant), and accumulates dot FLOPs and collective bytes exactly.

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_CALL_RE = re.compile(r"(?:calls=|to_apply=|body=)%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")


def _parse_computations(hlo_text: str) -> dict:
    """name -> list of instruction lines (including the header)."""
    comps, cur, name = {}, None, None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m and stripped.endswith("{"):
                name, cur = m.group(1), [stripped]
        else:
            if stripped == "}":
                comps[name] = cur
                cur, name = None, None
            else:
                cur.append(stripped)
    return comps


def _dims(shape_str: str) -> list:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def hlo_program_costs(hlo_text: str) -> dict:
    """Trip-count-aware totals: {'flops', 'collectives': {...}, 'dot_count'}."""
    comps = _parse_computations(hlo_text)
    memo: dict[str, dict] = {}

    def analyze_comp(name: str) -> dict:
        if name in memo:
            return memo[name]
        memo[name] = {"flops": 0.0, "coll": {}, "dots": 0}  # cycle guard
        lines = comps.get(name, [])
        shapes: dict[str, str] = {}
        # header params: "a: f32[2,3], b: s32[]"
        if lines:
            hdr = _COMP_HDR.match(lines[0])
            if hdr:
                for part in hdr.group(2).split(","):
                    if ":" in part:
                        pname, ptype = part.split(":", 1)
                        shapes[pname.strip().lstrip("%")] = ptype.strip()
        total = {"flops": 0.0, "coll": {}, "dots": 0}

        def add(sub, mult=1.0):
            total["flops"] += sub["flops"] * mult
            total["dots"] += sub["dots"]
            for k, v in sub["coll"].items():
                total["coll"][k] = total["coll"].get(k, 0.0) + v * mult

        for line in lines[1:]:
            d = _DEF_RE.match(line)
            if not d:
                continue
            var, rhs = d.group(1), d.group(2)
            shapes[var] = rhs
            if " dot(" in rhs or rhs.startswith("dot(") or "= dot(" in line:
                res = 1
                for x in _dims(rhs.split("dot(")[0]):
                    res *= x
                cm = _CONTRACT_RE.search(rhs)
                contract = 1
                ops = rhs.split("dot(", 1)[1].split(")")[0].split(",")
                lhs_name = ops[0].strip().lstrip("%")
                lhs_shape = _dims(shapes.get(lhs_name, ""))
                if cm and lhs_shape:
                    for idx in cm.group(1).split(","):
                        if idx and int(idx) < len(lhs_shape):
                            contract *= lhs_shape[int(idx)]
                total["flops"] += 2.0 * res * contract
                total["dots"] += 1
                continue
            cmatch = re.search(
                r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                r"collective-permute)(-start)?\(", rhs)
            if cmatch and "-done(" not in rhs:
                kind = cmatch.group(1)
                b = _shape_bytes(rhs.split(cmatch.group(0))[0])
                total["coll"][kind] = total["coll"].get(kind, 0.0) + b
            if " while(" in rhs:
                trip = 1.0
                tm = _TRIP_RE.search(rhs)
                if tm:
                    trip = float(tm.group(1))
                else:
                    cnd = _COND_RE.search(rhs)
                    if cnd and cnd.group(1) in comps:
                        for cl in comps[cnd.group(1)]:
                            km = re.search(r"constant\((\d+)\)", cl)
                            if km:
                                trip = float(km.group(1))
                bm = re.search(r"body=%?([\w.\-]+)", rhs)
                if bm and bm.group(1) in comps:
                    add(analyze_comp(bm.group(1)), trip)
                continue
            if "fusion(" in rhs or " call(" in rhs or rhs.startswith("call("):
                cm2 = _CALL_RE.search(rhs)
                if cm2 and cm2.group(1) in comps:
                    add(analyze_comp(cm2.group(1)), 1.0)
        memo[name] = total
        return total

    entry = None
    for raw in hlo_text.splitlines():
        if raw.strip().startswith("ENTRY"):
            m = _COMP_HDR.match(raw.strip())
            if m:
                entry = m.group(1)
                break
    if entry is None:
        return {"flops": 0.0, "collectives": {}, "dot_count": 0}
    t = analyze_comp(entry)
    return {"flops": t["flops"], "collectives": t["coll"], "dot_count": t["dots"]}


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective kind from (partitioned) HLO text.

    Optimized HLO references operands by name (no inline shapes), so we read
    the RESULT shape between '=' and the op name: for all-reduce result ==
    operand; for all-gather the result is the gathered tensor (bytes landing
    per device); for reduce-scatter it underestimates wire bytes by ~Nx —
    conventions noted in EXPERIMENTS.md.  '-done' halves of async pairs are
    skipped (counted at '-start').
    """
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    pat = re.compile(
        r"=\s*(.*?)\s*"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(-start)?\(")
    for line in hlo_text.splitlines():
        m = pat.search(line)
        if not m:
            continue
        if re.search(r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)-done\(", line):
            continue
        kind = m.group(2)
        b = _shape_bytes(m.group(1))
        out[kind] += b
        out["count"] += 1
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    bound: str
    model_flops: float            # 6*N(_active)*D global
    useful_flops_ratio: float     # model_flops / (flops_per_device * chips)
    peak_memory_bytes: float | None = None
    collectives: dict | None = None
    note: str = ""

    def to_json(self) -> dict:
        return asdict(self)


def analyze(arch: str, shape_name: str, mesh_name: str, chips: int,
            cost: dict, hlo_text: str, model_flops: float,
            memory_stats=None, note: str = "") -> Roofline:
    """Derive the three roofline terms from a compiled per-device module.

    FLOPs and collective bytes come from the trip-count-aware HLO walk
    (``hlo_program_costs``) — the raw ``cost_analysis()`` counts while bodies
    once and under-counts scanned models by ~n_layers.  The memory term uses
    ``max(bytes-accessed, argument+output sizes)``: the latter is a sound
    floor (every argument byte — weights, caches, batch — crosses HBM at
    least once per step) immune to the same while-body undercount.
    """
    prog = hlo_program_costs(hlo_text)
    flops = float(max(prog["flops"], cost.get("flops", 0.0)))
    coll = {k: float(v) for k, v in prog["collectives"].items()}
    coll_bytes = float(sum(coll.values()))

    arg_out = 0.0
    peak_mem = None
    if memory_stats is not None:
        arg_out = float(getattr(memory_stats, "argument_size_in_bytes", 0)
                        + getattr(memory_stats, "output_size_in_bytes", 0))
        peak_mem = float(
            getattr(memory_stats, "temp_size_in_bytes", 0)
            + getattr(memory_stats, "argument_size_in_bytes", 0)
            + getattr(memory_stats, "output_size_in_bytes", 0)
        ) or None
    in_bytes = float(max(cost.get("bytes accessed", 0.0), arg_out))

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = in_bytes / HBM_BW
    collective_s = coll_bytes / ICI_LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bound = max(terms, key=terms.get)

    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=in_bytes,
        collective_bytes_per_device=coll_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bound=bound, model_flops=model_flops,
        useful_flops_ratio=(model_flops / (flops * chips)) if flops else 0.0,
        peak_memory_bytes=peak_mem, collectives=coll, note=note,
    )


def model_flops_for_cell(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE), D = tokens/step.

    Decode cells process one token per sequence per step but attention reads
    the full KV cache; the 6ND convention counts only parameter FLOPs (the
    deliverable's definition) — attention-KV flops show up in HLO_FLOPs and
    therefore in the useful-flops ratio, as intended.
    """
    n = cfg.n_active_params()
    if shape.kind in ("train", "prefill"):
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0 if shape.kind == "train" else 2.0   # fwd-only for prefill
    else:
        tokens = shape.global_batch
        mult = 2.0
    return mult * n * tokens


def format_table(rows: list[dict]) -> str:
    hdr = (f"| {'arch':26s} | {'shape':11s} | {'mesh':6s} | {'bound':10s} "
           f"| compute_s | memory_s | collect_s | useful% | note |")
    sep = "|" + "-" * (len(hdr) - 2) + "|"
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']:26s} | {r['shape']:11s} | {r['mesh']:6s} "
            f"| {r['bound']:10s} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {100*r['useful_flops_ratio']:7.1f} "
            f"| {r.get('note','')} |"
        )
    return "\n".join(lines)
