import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any other import (jax locks the device count
on first init); 512 placeholder host devices let ``jax.make_mesh`` build the
production meshes.  Nothing here allocates full-size arrays — inputs and
params are ShapeDtypeStructs throughout.

Per cell this driver:
  1. builds the jitted step (train_step / prefill / serve_step per the
     shape's kind) with the sharding rules of repro.train.sharding,
  2. ``.lower(...)`` + ``.compile()`` — a failure here (sharding mismatch,
     OOM at compile, unsupported collective) is a bug in the system,
  3. prints ``memory_analysis()`` / ``cost_analysis()`` and extracts the
     three roofline terms (repro.launch.roofline) from the compiled HLO,
  4. appends the record to the output JSON (incremental — resumable).

Usage:
  python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun.json
  python -m repro.launch.dryrun --all --mesh single --weights dense   # baseline
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro.configs as configs
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models import model_zoo as zoo
from repro.optim import OptConfig
from repro.optim.optimizer import AdamWState
from repro.serving.engine import freeze_params
from repro.train import TrainState, init_state, make_train_step, sharding

BIG_PARAMS = 60e9  # above this, bf16 adam moments (fits 400B on one pod)


def cost_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions: newer jax
    returns one flat dict, older returns a per-device list of dicts."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def _named(mesh, specs):
    return sharding.to_named(mesh, specs)


def lower_cell(cfg, shape, mesh, *, weights: str = "packed", fsdp: bool = True,
               remat: bool = True, cache_dtype=jnp.bfloat16):
    """Build and lower the cell's step function.  Returns (lowered, meta)."""
    kind = shape.kind
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)

    if kind == "train":
        opt_cfg = OptConfig(
            moment_dtype="bfloat16" if cfg.n_params() > BIG_PARAMS else "float32")
        state_sds = jax.eval_shape(lambda k: init_state(cfg, k, opt_cfg), key_sds)
        pspecs = sharding.param_specs(state_sds.params, mesh, fsdp=fsdp)
        mspecs = sharding.param_specs(state_sds.opt.mu, mesh, fsdp=fsdp)
        state_specs = TrainState(params=pspecs,
                                 opt=AdamWState(mu=mspecs, nu=mspecs, count=P()),
                                 step=P(), err_buf=None)
        in_specs = zoo.input_specs(cfg, shape)
        batch_sds = {k: v for k, v in in_specs.items()}
        bspecs = sharding.batch_specs(mesh, batch_sds)
        step = make_train_step(cfg, opt_cfg, remat=remat)
        fn = jax.jit(step,
                     in_shardings=(_named(mesh, state_specs), _named(mesh, bspecs)),
                     out_shardings=(_named(mesh, state_specs), None),
                     donate_argnums=(0,))
        lowered = fn.lower(state_sds, batch_sds)
        return lowered, {"mode": "train_step"}

    # Inference cells: params in the requested weight format.
    # NOTE serve cells default to fsdp=False: packed 2-bit weights fit the TP
    # shards outright (qwen3-32B packed = 0.5 GB/shard), and FSDP would trade
    # that residency for per-layer weight all-gathers every decode step —
    # measured +2.7 s/step collective term on qwen3 decode_32k (§Perf iter 1).
    params_sds = jax.eval_shape(lambda k: zoo.init_params(cfg, k), key_sds)
    if weights == "packed":
        params_sds = jax.eval_shape(freeze_params, params_sds)
    elif weights == "dense":
        # fp16-kernel baseline: ternary values materialized in bf16.
        params_sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
            if x.dtype == jnp.float32 and x.ndim >= 2 else x, params_sds)
    bytes_per_w = 0.25 if weights == "packed" else 2.0
    shard_gb = cfg.n_params() * bytes_per_w / mesh.shape["model"] / 1e9
    serve_fsdp = shard_gb > 8.0  # only when TP shards alone would not fit
    pspecs = sharding.param_specs(params_sds, mesh, fsdp=serve_fsdp)
    pnamed = _named(mesh, pspecs)

    in_specs = zoo.input_specs(cfg, shape, cache_dtype=cache_dtype)
    cache_sds = in_specs.pop("cache")
    cspecs = sharding.cache_specs(mesh, cache_sds, cfg.n_kv_heads)
    cnamed = _named(mesh, cspecs)

    if kind == "prefill":
        batch_sds = in_specs
        bspecs = sharding.batch_specs(mesh, batch_sds)
        fn = jax.jit(
            lambda p, b, c: zoo.prefill(cfg, p, b, c, train=False),
            in_shardings=(pnamed, _named(mesh, bspecs), cnamed),
            out_shardings=(None, cnamed),
            donate_argnums=(2,))
        lowered = fn.lower(params_sds, batch_sds, cache_sds)
        return lowered, {"mode": "prefill"}

    # decode / serve_step
    tok_sds = in_specs["tokens"]
    tspec = sharding.batch_specs(mesh, {"tokens": tok_sds})["tokens"]
    fn = jax.jit(
        lambda p, tk, c, t: zoo.decode_step(cfg, p, tk, c, t, train=False),
        in_shardings=(pnamed, _named(mesh, {"tokens": tspec})["tokens"], cnamed,
                      None),
        out_shardings=(None, cnamed),
        donate_argnums=(2,))
    lowered = fn.lower(params_sds, tok_sds, cache_sds, in_specs["t"])
    return lowered, {"mode": "serve_step"}


def run_cell(cfg, shape, mesh, mesh_name: str, weights: str = "packed",
             verbose: bool = True, **kw) -> dict:
    from repro.utils import act_sharding

    act_sharding.set_mesh(mesh)  # pin activation layouts to this mesh
    chips = mesh.devices.size
    rec = {"arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
           "chips": int(chips), "weights": weights, "status": "ok"}
    t0 = time.time()
    try:
        lowered, meta = lower_cell(cfg, shape, mesh, weights=weights, **kw)
        rec.update(meta)
        compiled = lowered.compile()
        cost = cost_dict(compiled)
        try:
            mem = compiled.memory_analysis()
        except Exception:
            mem = None
        hlo = compiled.as_text()
        mf = rl.model_flops_for_cell(cfg, shape)
        roof = rl.analyze(cfg.name, shape.name, mesh_name, int(chips),
                          cost or {}, hlo, mf, memory_stats=mem)
        rec["roofline"] = roof.to_json()
        rec["cost"] = {k: float(v) for k, v in (cost or {}).items()
                       if isinstance(v, (int, float))}
        if mem is not None:
            rec["memory_analysis"] = {
                a: float(getattr(mem, a))
                for a in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, a)}
            if verbose:
                print(f"  memory_analysis: {rec['memory_analysis']}")
        if verbose:
            r = rec["roofline"]
            print(f"  flops/dev={r['flops_per_device']:.3e} "
                  f"bytes/dev={r['bytes_per_device']:.3e} "
                  f"coll/dev={r['collective_bytes_per_device']:.3e} -> "
                  f"bound={r['bound']}")
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"  FAILED: {rec['error']}")
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--weights", choices=["packed", "dense", "latent"], default="packed")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--cache-dtype", default="bfloat16")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already present in --out")
    args = ap.parse_args()

    if args.all:
        cells = [(cfg, shape) for cfg, shape, _ in configs.cells()]
    else:
        cfg = configs.get(args.arch)
        shapes = [configs.SHAPES[args.shape]] if args.shape else [
            s for _, s, skip in configs.cells() if _.name == cfg.name and not skip]
        cells = [(cfg, s) for s in shapes]

    mesh_list = []
    if args.mesh in ("single", "both"):
        mesh_list.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        mesh_list.append(("multi", make_production_mesh(multi_pod=True)))

    done = set()
    results = []
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
        if args.skip_done:
            done = {(r["arch"], r["shape"], r["mesh"], r.get("weights", "packed"))
                    for r in results if r.get("status") == "ok"}

    for cfg, shape in cells:
        for mesh_name, mesh in mesh_list:
            keyid = (cfg.name, shape.name, mesh_name, args.weights)
            if keyid in done:
                continue
            print(f"[dryrun] {cfg.name} x {shape.name} x {mesh_name} "
                  f"({args.weights})")
            rec = run_cell(cfg, shape, mesh, mesh_name, weights=args.weights,
                           fsdp=not args.no_fsdp, remat=not args.no_remat,
                           cache_dtype=jnp.dtype(args.cache_dtype))
            results.append(rec)
            if args.out:
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
            print(f"  -> {rec['status']} ({rec['wall_s']}s)")

    n_ok = sum(1 for r in results if r["status"] == "ok")
    print(f"\n{n_ok}/{len(results)} cells OK")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
