"""Production serving driver: packed 2-bit T-SAR weights, batched requests.

    PYTHONPATH=src python -m repro.launch.serve --arch bitnet-2b-4t --smoke \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse

import numpy as np
import jax

import repro.configs as configs
from repro.models import model_zoo as zoo
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--no-packed", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_len=args.max_len,
                           batch_slots=args.slots, packed=not args.no_packed)

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=4 + i % 8),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for i in range(args.requests)]
    engine.run(reqs)
    for r in reqs[:4]:
        print(f"req {r.uid}: {r.out_tokens}")
    lat = engine.latency_stats(reqs)
    print(f"prefill {engine.stats['prefill_s']:.2f}s | "
          f"decode {engine.stats['decode_s']:.2f}s | "
          f"{engine.throughput():.1f} tok/s steady-state "
          f"({'packed 2-bit' if not args.no_packed else 'latent fp'})")
    print(f"TTFT mean {lat['ttft_mean_s'] * 1e3:.0f}ms | "
          f"TPOT mean {lat['tpot_mean_s'] * 1e3:.2f}ms | policy={engine.policy}")


if __name__ == "__main__":
    main()
