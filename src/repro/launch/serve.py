"""Production serving driver: packed 2-bit T-SAR weights, batched requests.

    PYTHONPATH=src python -m repro.launch.serve --arch bitnet-2b-4t --smoke \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import os

import numpy as np
import jax

import repro.configs as configs
from repro.models import model_zoo as zoo
from repro.plan import ModelPlan, format_plan
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--no-packed", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--plan-file", default=None, metavar="PATH",
                    help="execution-plan JSON: loaded if it exists (skips "
                         "re-costing), otherwise the compiled plan is saved "
                         "there (compile-once/serve-many)")
    ap.add_argument("--save-plan", default=None, metavar="PATH",
                    help="also write the engine's plan JSON here after init")
    ap.add_argument("--print-plan", action="store_true",
                    help="print the per-layer, per-bucket plan table")
    ap.add_argument("--prefix-cache", nargs="?", const=True, default=False,
                    type=int, metavar="CAPACITY_BLOCKS",
                    help="enable prefix-caching KV reuse; optional value "
                         "caps the cached-block footprint (LRU-evicted)")
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    plan = None
    if args.plan_file and os.path.exists(args.plan_file):
        plan = ModelPlan.load(args.plan_file)
        print(f"plan: loaded {args.plan_file} ({len(plan.layers)} layers, "
              f"buckets {list(plan.buckets)})")
    engine = ServingEngine(cfg, params, max_len=args.max_len,
                           batch_slots=args.slots, packed=not args.no_packed,
                           plan=plan, prefix_cache=args.prefix_cache)
    if engine.plan is not None:
        if plan is None and args.plan_file:
            engine.plan.save(args.plan_file)
            print(f"plan: compiled and saved to {args.plan_file}")
        if args.save_plan:
            engine.plan.save(args.save_plan)
        s = engine.plan.summary()
        print(f"plan: {s['layers']} layers | decode -> {s['decode_kernel']} | "
              f"prefill -> {s['prefill_kernel']}")
        if args.print_plan:
            print(format_plan(engine.plan))

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=4 + i % 8),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for i in range(args.requests)]
    engine.run(reqs)
    for r in reqs[:4]:
        print(f"req {r.uid}: {r.out_tokens}")
    lat = engine.latency_stats(reqs)
    print(f"prefill {engine.stats['prefill_s']:.2f}s | "
          f"decode {engine.stats['decode_s']:.2f}s | "
          f"{engine.throughput():.1f} tok/s steady-state "
          f"({'packed 2-bit' if not args.no_packed else 'latent fp'})")
    print(f"TTFT mean {lat['ttft_mean_s'] * 1e3:.0f}ms | "
          f"TPOT mean {lat['tpot_mean_s'] * 1e3:.2f}ms | policy={engine.policy}")
    if engine.prefix is not None:
        print(f"prefix cache: hit rate {engine.stats['prefix_hit_rate']:.2f} | "
              f"{engine.stats['cached_blocks']} cached blocks | "
              f"{engine.stats['prefix_evictions']} evictions")


if __name__ == "__main__":
    main()
