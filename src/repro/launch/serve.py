"""Production serving driver: packed 2-bit T-SAR weights, batched requests.

    PYTHONPATH=src python -m repro.launch.serve --arch bitnet-2b-4t --smoke \
        --requests 8 --max-new 16

Latency is reported as p50/p90/p99 straight off the engine's metrics
registry (``repro.obs.metrics`` histograms).  ``--trace-out PATH`` records
the run as a Perfetto ``trace_event`` timeline (request lifecycle spans +
step/counter tracks) — inspect with ``python -m repro.obs.timeline PATH``
or load it in https://ui.perfetto.dev; see docs/observability.md.

Durable-telemetry flags (all composable):

* ``--trace-stream PATH``  — stream events to a rotated JSONL file with
  bounded memory (``repro.obs.trace.StreamingSink``); analyze with the
  same timeline CLI.  Combine with ``--trace-out`` to record both ways.
* ``--incident-dir DIR``   — arm incident snapshots (SLO breach,
  preemption, rejection, kv pressure, eviction storm); each dump carries
  the flight-recorder ring + a metrics snapshot.  Without another trace
  flag this attaches a ring-buffer tracer automatically.
* ``--metrics-port PORT``  — Prometheus scrape endpoint over the live
  registry (``/metrics`` text, ``/metrics.json`` snapshot); port 0 binds
  an ephemeral port and prints it.
* ``--metrics-textfile PATH`` — atomically rewrite a Prometheus textfile
  every ``--metrics-interval`` seconds (node-exporter textfile style).
"""
from __future__ import annotations

import argparse
import os
import sys

import numpy as np
import jax

import repro.configs as configs
from repro.models import model_zoo as zoo
from repro.obs import export as obs_export
from repro.obs.incident import IncidentMonitor
from repro.obs.trace import EventTracer, MemorySink, RingSink, StreamingSink, TeeSink
from repro.plan import ModelPlan, format_plan
from repro.serving import Request, ServingEngine


def _print_percentiles(engine) -> None:
    pct = engine.latency_percentiles()
    t, p = pct["ttft_s"], pct["tpot_s"]
    print(f"TTFT p50/p90/p99 {t['p50'] * 1e3:.0f}/{t['p90'] * 1e3:.0f}/"
          f"{t['p99'] * 1e3:.0f}ms | TPOT p50/p99 {p['p50'] * 1e3:.2f}/"
          f"{p['p99'] * 1e3:.2f}ms | "
          f"queue p99 {pct['queue_s']['p99'] * 1e3:.0f}ms | "
          f"policy={engine.policy}")


def _save_trace(tracer, path: str) -> None:
    doc = tracer.save(path)
    print(f"obs trace: {path} ({len(doc['traceEvents'])} events, "
          f"{doc['otherData']['fingerprint'][:23]}…) — analyze with "
          f"python -m repro.obs.timeline {path}", file=sys.stderr)


def _obs_setup(args) -> dict:
    """Build the tracer (sink composition per flags) + incident monitor.
    Returns the state dict the start/finish helpers thread through."""
    sinks, stream = [], None
    if args.trace_out:
        sinks.append(MemorySink())
    if args.trace_stream:
        stream = StreamingSink(args.trace_stream)
        sinks.append(stream)
    if not sinks and args.incident_dir:
        # Flight recorder: incidents need *some* recent-event source, and a
        # ring is cheap enough to attach implicitly.
        sinks.append(RingSink())
    tracer = None
    if sinks:
        tracer = EventTracer(sink=sinks[0] if len(sinks) == 1
                             else TeeSink(*sinks))
    monitor = IncidentMonitor(args.incident_dir) if args.incident_dir else None
    return {"tracer": tracer, "stream": stream, "monitor": monitor,
            "server": None, "textfile": None}


def _obs_start(args, engine, obs: dict) -> None:
    """Bring up the export surface once the engine (and its registry)
    exists."""
    if args.metrics_port is not None:
        obs["server"] = obs_export.start_server(engine.metrics,
                                                port=args.metrics_port)
        print(f"metrics: scrape endpoint at {obs['server'].url} "
              f"(and /metrics.json)", file=sys.stderr)
    if args.metrics_textfile:
        obs["textfile"] = obs_export.TextfileWriter(
            engine.metrics, args.metrics_textfile,
            interval_s=args.metrics_interval).start()


def _obs_finish(args, obs: dict) -> None:
    """Flush/close every durable-telemetry surface at end of run."""
    if obs["textfile"] is not None:
        obs["textfile"].stop()
        print(f"metrics: textfile {args.metrics_textfile} "
              f"({obs['textfile'].n_writes} writes)", file=sys.stderr)
    if obs["server"] is not None:
        obs["server"].stop()
    if obs["tracer"] is not None and args.trace_out:
        _save_trace(obs["tracer"], args.trace_out)
    if obs["stream"] is not None:
        info = obs["stream"].finalize()
        print(f"obs stream: {info['path']} ({info['n_events']} events, "
              f"{info['segments']} segment(s), "
              f"{info['fingerprint'][:23]}…) — analyze with "
              f"python -m repro.obs.timeline {info['path']}", file=sys.stderr)
    mon = obs["monitor"]
    if mon is not None:
        s = mon.summary()
        if s["n"]:
            by = ", ".join(f"{k}: {v}" for k, v in sorted(s["by_trigger"].items()))
            print(f"incidents: {s['n']} snapshot(s) in {args.incident_dir} "
                  f"({by}; {s['suppressed']} debounced)", file=sys.stderr)
        else:
            print(f"incidents: none fired ({s['suppressed']} debounced)",
                  file=sys.stderr)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--no-packed", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--plan-file", default=None, metavar="PATH",
                    help="execution-plan JSON: loaded if it exists (skips "
                         "re-costing), otherwise the compiled plan is saved "
                         "there (compile-once/serve-many)")
    ap.add_argument("--save-plan", default=None, metavar="PATH",
                    help="also write the engine's plan JSON here after init")
    ap.add_argument("--print-plan", action="store_true",
                    help="print the per-layer, per-bucket plan table")
    ap.add_argument("--prefix-cache", nargs="?", const=True, default=False,
                    type=int, metavar="CAPACITY_BLOCKS",
                    help="enable prefix-caching KV reuse; optional value "
                         "caps the cached-block footprint (LRU-evicted)")
    ap.add_argument("--workload", default=None, metavar="NAME",
                    help="serve a generated benchmark workload instead of "
                         "the built-in request list (see "
                         "benchmarks.workloads.WORKLOADS; requires running "
                         "from the repo root) and report percentile "
                         "TTFT/TPOT + goodput under the trace's SLOs")
    ap.add_argument("--trace-file", default=None, metavar="PATH",
                    help="workload trace JSON: loaded if it exists "
                         "(replayed verbatim), otherwise the trace "
                         "generated by --workload is saved there")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload generation seed (with --workload)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record the run and save a Perfetto trace_event "
                         "JSON timeline (python -m repro.obs.timeline PATH "
                         "to analyze; docs/observability.md)")
    ap.add_argument("--profile-steps", action="store_true",
                    help="wrap each jitted engine step in a jax.profiler "
                         "StepTraceAnnotation so XLA device traces align "
                         "with engine steps")
    ap.add_argument("--trace-stream", default=None, metavar="PATH",
                    help="stream trace events to a rotated JSONL file "
                         "(bounded memory; OBS_TRACE_STREAM schema v1) — "
                         "same timeline CLI analyzes it")
    ap.add_argument("--incident-dir", default=None, metavar="DIR",
                    help="write incident snapshots (ring buffer + metrics "
                         "snapshot) here when SLO/preemption/rejection/"
                         "kv-pressure/eviction-storm triggers fire")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve Prometheus text exposition at "
                         "http://127.0.0.1:PORT/metrics (0 = ephemeral)")
    ap.add_argument("--metrics-textfile", default=None, metavar="PATH",
                    help="periodically rewrite a Prometheus textfile "
                         "(atomic replace) for scrape-less environments")
    ap.add_argument("--metrics-interval", type=float, default=5.0,
                    metavar="SECONDS",
                    help="rewrite interval for --metrics-textfile")
    args = ap.parse_args()

    if args.workload or args.trace_file:
        return serve_workload(args)

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    plan = None
    if args.plan_file and os.path.exists(args.plan_file):
        plan = ModelPlan.load(args.plan_file)
        print(f"plan: loaded {args.plan_file} ({len(plan.layers)} layers, "
              f"buckets {list(plan.buckets)})")
    obs = _obs_setup(args)
    engine = ServingEngine(cfg, params, max_len=args.max_len,
                           batch_slots=args.slots, packed=not args.no_packed,
                           plan=plan, prefix_cache=args.prefix_cache,
                           tracer=obs["tracer"], incidents=obs["monitor"],
                           profiler_annotations=args.profile_steps)
    _obs_start(args, engine, obs)
    if engine.plan is not None:
        if plan is None and args.plan_file:
            engine.plan.save(args.plan_file)
            print(f"plan: compiled and saved to {args.plan_file}")
        if args.save_plan:
            engine.plan.save(args.save_plan)
        s = engine.plan.summary()
        print(f"plan: {s['layers']} layers | decode -> {s['decode_kernel']} | "
              f"prefill -> {s['prefill_kernel']}")
        if args.print_plan:
            print(format_plan(engine.plan))

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=4 + i % 8),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for i in range(args.requests)]
    engine.run(reqs)
    for r in reqs[:4]:
        print(f"req {r.uid}: {r.out_tokens}")
    print(f"prefill {engine.stats['prefill_s']:.2f}s | "
          f"decode {engine.stats['decode_s']:.2f}s | "
          f"{engine.throughput():.1f} tok/s steady-state "
          f"({'packed 2-bit' if not args.no_packed else 'latent fp'})")
    _print_percentiles(engine)
    if engine.prefix is not None:
        print(f"prefix cache: hit rate {engine.stats['prefix_hit_rate']:.2f} | "
              f"{engine.stats['cached_blocks']} cached blocks | "
              f"{engine.stats['prefix_evictions']} evictions")
    _obs_finish(args, obs)


def serve_workload(args):
    """Trace-driven serving: generate (or load) a benchmark workload trace,
    replay it in virtual time, and report percentile latencies + goodput.

    The ``benchmarks`` package lives at the repo root (not under ``src``),
    so this path requires launching from the repository root.
    """
    try:
        from benchmarks.workloads import generator, metrics, runner
        from benchmarks.workloads.trace import Trace
    except ImportError as e:
        raise SystemExit(
            "--workload/--trace-file need the benchmarks package on "
            "sys.path — run from the repository root "
            f"(import failed: {e})")

    if args.trace_file and os.path.exists(args.trace_file):
        trace = Trace.load(args.trace_file)
        spec = generator.WorkloadSpec.from_dict(trace.spec)
        print(f"trace: loaded {args.trace_file} ({trace.n_requests} requests,"
              f" workload {trace.name!r}, {trace.fingerprint()[:18]}…)")
    else:
        if not args.workload:
            raise SystemExit("--trace-file points at a missing file and no "
                             "--workload was given to generate one")
        spec = generator.preset(args.workload, quick=args.smoke,
                                seed=args.seed)
        trace = generator.generate(spec)
        print(f"trace: generated workload {spec.name!r} "
              f"({trace.n_requests} requests, seed {args.seed}, "
              f"{trace.fingerprint()[:18]}…)")
        if args.trace_file:
            trace.save(args.trace_file)
            print(f"trace: saved to {args.trace_file}")

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    obs = _obs_setup(args)
    engine = runner.build_engine(spec, cfg, params,
                                 packed=not args.no_packed,
                                 tracer=obs["tracer"],
                                 incidents=obs["monitor"])
    _obs_start(args, engine, obs)
    reqs, wall = runner.replay(engine, trace)
    m = metrics.latency_metrics(reqs, trace, wall)
    c = metrics.engine_counters(engine)
    t, p, g = m["ttft_s"], m["tpot_s"], m["goodput"]
    print(f"TTFT p50/p90/p99 {t['p50'] * 1e3:.0f}/{t['p90'] * 1e3:.0f}/"
          f"{t['p99'] * 1e3:.0f}ms | TPOT p50/p99 {p['p50'] * 1e3:.2f}/"
          f"{p['p99'] * 1e3:.2f}ms")
    print(f"goodput {g['good']}/{g['total']} ({g['slo_attained']:.0%}) "
          f"under SLO | {m['output_tok_s']:.1f} out tok/s | "
          f"wall {m['wall_s']:.2f}s")
    print(f"counters: steps={c['steps']} preemptions={c['preemptions']} "
          f"prefill_tokens={c['prefill_tokens']} "
          f"prefix_hit_rate={c.get('prefix_hit_rate', 0.0):.3f} "
          f"plan_kernel={c['plan_kernel']}")
    _obs_finish(args, obs)


if __name__ == "__main__":
    main()
