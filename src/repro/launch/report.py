"""Render EXPERIMENTS.md tables from dry-run result JSONs.

    PYTHONPATH=src python -m repro.launch.report results/dryrun_packed.json
"""
from __future__ import annotations

import json
import sys


def _fmt_bytes(b):
    if b >= 1e9:
        return f"{b/1e9:.2f}GB"
    if b >= 1e6:
        return f"{b/1e6:.1f}MB"
    return f"{b/1e3:.0f}KB"


def dryrun_table(recs, mesh="single") -> str:
    rows = [r for r in recs if r.get("status") == "ok" and r["mesh"] == mesh]
    out = [
        "| arch | shape | mode | chips | flops/dev | HBM bytes/dev | coll bytes/dev | args/dev | temp/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        f = r["roofline"]
        m = r.get("memory_analysis", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | {r.get('mode','')} | {r['chips']} "
            f"| {f['flops_per_device']:.2e} | {_fmt_bytes(f['bytes_per_device'])} "
            f"| {_fmt_bytes(f['collective_bytes_per_device'])} "
            f"| {_fmt_bytes(m.get('argument_size_in_bytes', 0))} "
            f"| {_fmt_bytes(m.get('temp_size_in_bytes', 0))} |")
    return "\n".join(out)


def roofline_table(recs, mesh="single") -> str:
    rows = [r for r in recs if r.get("status") == "ok" and r["mesh"] == mesh]
    out = [
        "| arch | shape | bound | compute_s | memory_s | collective_s | step_s | useful FLOPs |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        f = r["roofline"]
        step = max(f["compute_s"], f["memory_s"], f["collective_s"])
        useful = min(f["useful_flops_ratio"], 99.99)
        out.append(
            f"| {r['arch']} | {r['shape']} | **{f['bound']}** "
            f"| {f['compute_s']:.3e} | {f['memory_s']:.3e} "
            f"| {f['collective_s']:.3e} | {step:.3e} "
            f"| {100*useful:.0f}% |")
    return "\n".join(out)


def compare_weights(packed, dense) -> str:
    """Serve cells: packed 2-bit vs dense bf16 — the paper's memory claim."""
    key = lambda r: (r["arch"], r["shape"])
    dmap = {key(r): r for r in dense
            if r.get("status") == "ok" and r["mesh"] == "single"}
    out = [
        "| arch | shape | bf16 mem_s | T-SAR mem_s | mem reduction | bf16 step_s | T-SAR step_s | speedup |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in packed:
        if r.get("status") != "ok" or r["mesh"] != "single":
            continue
        if r["shape"] not in ("decode_32k", "long_500k", "prefill_32k"):
            continue
        d = dmap.get(key(r))
        if d is None:
            continue
        fp, fd = r["roofline"], d["roofline"]
        sp = max(fp["compute_s"], fp["memory_s"], fp["collective_s"])
        sd = max(fd["compute_s"], fd["memory_s"], fd["collective_s"])
        out.append(
            f"| {r['arch']} | {r['shape']} | {fd['memory_s']:.3e} "
            f"| {fp['memory_s']:.3e} | {fd['memory_s']/max(fp['memory_s'],1e-12):.2f}x "
            f"| {sd:.3e} | {sp:.3e} | {sd/max(sp,1e-12):.2f}x |")
    return "\n".join(out)


def dedup(recs):
    """Keep the LAST record per (arch, shape, mesh, weights) — re-runs of
    individual cells append to the JSON."""
    out = {}
    for r in recs:
        out[(r["arch"], r["shape"], r["mesh"], r.get("weights", ""))] = r
    return list(out.values())


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_packed.json"
    with open(path) as f:
        recs = dedup(json.load(f))
    print("## Dry-run (single-pod)\n")
    print(dryrun_table(recs, "single"))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(recs, "single"))
    print("\n## Multi-pod\n")
    print(roofline_table(recs, "multi"))
    if len(sys.argv) > 2:
        with open(sys.argv[2]) as f:
            dense = dedup(json.load(f))
        print("\n## Packed vs dense\n")
        print(compare_weights(recs, dense))


if __name__ == "__main__":
    main()
