"""Low-overhead structured event tracing for the serving engine, exported
as Chrome/Perfetto ``trace_event`` JSON.

Two recorders share one interface:

* :data:`NULL_TRACER` — the default.  ``enabled`` is False and every method
  is a no-op; emit sites in the engine guard on ``tracer.enabled`` before
  building argument dicts, so a tracing-off engine pays one attribute read
  per potential event (tested: step counters are bit-identical to an
  untraced engine).
* :class:`EventTracer` — emits events into a pluggable **sink**,
  timestamped from ``time.perf_counter`` relative to the tracer epoch, in
  microseconds (the ``trace_event`` clock unit).

Sinks decide what "record an event" means; the tracer never knows which
one it feeds:

* :class:`MemorySink` — the default: an in-memory list, exported whole via
  ``save()``/``to_perfetto()`` (the original PR 7 behavior).
* :class:`StreamingSink` — bounded-memory JSONL append to disk with
  size-based segment rotation, for runs far longer than RAM.  It maintains
  the structure fingerprint *incrementally* so the finalized stream
  fingerprints **byte-for-byte identically** to a ``MemorySink`` export of
  the same event sequence (see the stream format section below).
* :class:`RingSink` — fixed-capacity flight recorder (a ``deque``): cheap
  enough to leave always-on so incident snapshots
  (``repro.obs.incident``) can dump the last N events post-hoc.
* :class:`TeeSink` — fan-out to several sinks (e.g. memory + streaming,
  which is how the bench lane asserts fingerprint identity between the
  two paths on one run).

Event taxonomy (see docs/observability.md for the full contract):

* **request lifecycle** — async spans keyed by request uid (Perfetto
  groups async events by ``(cat, id)``, so each request renders as its own
  track): a ``req`` envelope span containing ``queued`` / ``prefill`` /
  ``decode`` sub-spans, with instants ``admitted`` (args: slot, cached_len,
  readmission), ``prefill_chunk``, ``prefix_hit``, ``first_token``,
  ``preempted``, ``finished``, ``cancelled``.  A preempted request closes
  its open phase span with ``preempted: true`` and re-opens ``queued`` —
  the span sequence is well-formed by construction (property-tested).
* **engine steps** — one complete (``X``) event per step on the dedicated
  engine thread, args carrying the deterministic step record: planned vs
  realized token budget, prefill/decode split, KV blocks in use, active
  slots, the plan kernel serving this step's row bucket.  The same record
  feeds three counter (``C``) tracks — ``step_tokens``, ``kv_blocks``,
  ``active_slots`` — so Perfetto draws budget utilization as a graph.
* **global instants** — allocator/cache causality: ``kv_pressure`` (the
  free list ran short and the evictor was consulted), ``prefix_evict``
  (args: n, cause ∈ {capacity, pressure}), ``prefix_insert``.

**Determinism.**  Event *structure* — order, names, phases, args — is a
pure function of (trace, code): wall-clock enters only through ``ts`` /
``dur`` fields, never args.  :func:`structure_fingerprint` hashes the
canonical JSON of events with ``ts``/``dur`` stripped; same-seed replays
fingerprint identically (property-tested), which is what lets CI smoke-
assert a trace artifact without pinning timings.

**Stream format** (kind ``OBS_TRACE_STREAM``, schema v1).  One JSON object
per line.  Line 1 is a header carrying kind, stream + trace schema
versions, git revision, clock, and segment index; the three Perfetto meta
events and every emitted event follow as ordinary event lines (full, with
``ts``/``dur``); a footer line (``{"footer": true, ...}``) closes each
segment with the running event count and — on ``finalize()`` — the final
structure fingerprint.  Rotation renames the active file to
``<path>.1``, ``<path>.2``, ... and reopens ``<path>`` fresh, so the active
path is always the newest segment and readers chain ``<path>.1 ..
<path>.N, <path>`` back into one logical stream.  The incremental hasher
feeds ``"["``, then comma-separated canonical JSON of each ts/dur-stripped
event, then ``"]"`` at fingerprint time — exactly the bytes
:func:`structure_fingerprint` hashes for the same sequence, which is the
byte-for-byte identity the bench lane asserts.

The exported document is schema-versioned like
``benchmarks/workloads/schema.py``: ``otherData`` carries kind, schema
version, git revision, and the structure fingerprint; :func:`validate`
walks the document and re-derives the fingerprint.  The JSON loads
directly in ``chrome://tracing`` / https://ui.perfetto.dev.
"""
from __future__ import annotations

import collections
import contextlib
import hashlib
import json
import os
import subprocess
import time

TRACE_KIND = "OBS_TRACE"
TRACE_SCHEMA_VERSION = 1

STREAM_KIND = "OBS_TRACE_STREAM"
STREAM_SCHEMA_VERSION = 1

DEFAULT_RING_CAPACITY = 4096

_PID = 1
_TID_ENGINE = 0          # engine-step track
_TID_REQUESTS = 1        # async request spans (grouped by id, not tid)

_ASYNC_PHASES = ("b", "e", "n")
_KNOWN_PHASES = _ASYNC_PHASES + ("X", "C", "i", "M")


def meta_events() -> list:
    """The Perfetto process/thread naming metadata every export carries.
    Module-level (not tracer state) so streaming sinks can seed their
    fingerprint with the same three events ``to_perfetto`` prepends."""
    return [
        {"ph": "M", "name": "process_name", "pid": _PID, "tid": 0,
         "args": {"name": "tsar-serving-engine"}},
        {"ph": "M", "name": "thread_name", "pid": _PID,
         "tid": _TID_ENGINE, "args": {"name": "engine steps"}},
        {"ph": "M", "name": "thread_name", "pid": _PID,
         "tid": _TID_REQUESTS, "args": {"name": "requests"}},
    ]


def _canon(obj) -> str:
    """Canonical one-line JSON (sorted keys, no spaces) — the byte
    representation both the fingerprint and the JSONL stream use."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


class NullTracer:
    """No-op recorder; the engine's default.  Emit sites guard on
    ``enabled`` so the disabled path never constructs event args."""

    enabled = False
    __slots__ = ()

    def begin(self, uid, name, **args):
        pass

    def end(self, uid, name, **args):
        pass

    def mark(self, uid, name, **args):
        pass

    def instant(self, name, **args):
        pass

    def step(self, dur_s, **args):
        pass

    def reset(self):
        pass


NULL_TRACER = NullTracer()


# ---------------------------------------------------------------------------
# sinks
# ---------------------------------------------------------------------------

class MemorySink:
    """Keep every event in a list (the PR 7 behavior).  ``events`` is the
    live list, so existing callers reading ``tracer.events`` see exactly
    what they always did."""

    kind = "memory"

    def __init__(self):
        self.events: list = []
        self.n_appended = 0

    def append(self, e: dict):
        self.events.append(e)
        self.n_appended += 1

    def recent(self, limit: int = 512) -> list:
        return self.events[-limit:] if limit else list(self.events)

    def reset(self):
        self.events = []


class RingSink:
    """Fixed-capacity flight recorder: a ``deque`` keeps the last
    ``capacity`` events and silently drops the oldest.  Cheap enough to
    leave always-on; incident snapshots dump ``recent()`` post-hoc."""

    kind = "ring"

    def __init__(self, capacity: int = DEFAULT_RING_CAPACITY):
        self.capacity = int(capacity)
        self.n_appended = 0
        self._buf: collections.deque = collections.deque(maxlen=self.capacity)

    @property
    def events(self) -> list:
        return list(self._buf)

    @property
    def n_dropped(self) -> int:
        return max(0, self.n_appended - len(self._buf))

    def append(self, e: dict):
        self._buf.append(e)
        self.n_appended += 1

    def recent(self, limit: int = 512) -> list:
        out = list(self._buf)
        return out[-limit:] if limit else out

    def reset(self):
        self._buf.clear()
        self.n_appended = 0


class TeeSink:
    """Fan one event stream out to several sinks.  ``events``/``recent``
    read from the *first* (primary) sink, so ``TeeSink(MemorySink(),
    StreamingSink(path))`` behaves like a memory tracer that also streams
    to disk."""

    kind = "tee"

    def __init__(self, *sinks):
        if not sinks:
            raise ValueError("TeeSink needs at least one sink")
        self.sinks = tuple(sinks)

    @property
    def events(self):
        return self.sinks[0].events

    def append(self, e: dict):
        for s in self.sinks:
            s.append(e)

    def recent(self, limit: int = 512) -> list:
        return self.sinks[0].recent(limit)

    def reset(self):
        for s in self.sinks:
            s.reset()


class StreamingSink:
    """Bounded-memory JSONL append to disk with size-based rotation.

    Memory never holds more than ``flush_every`` buffered lines plus a
    ``tail_events`` deque for incident snapshots — ``peak_resident_events``
    records the observed maximum so tests can assert the bound.  The
    structure fingerprint is maintained incrementally (see module
    docstring) and ``finalize()`` returns it alongside stream provenance;
    it matches :func:`structure_fingerprint` over the same sequence
    byte-for-byte, meta events included.

    ``reset()`` implements the warm-up contract: rotated segments are
    deleted, the active file is truncated back to a fresh header, and the
    hasher is re-seeded — so ``ServingEngine.reset_run_stats()`` leaves no
    warm-up events in the saved stream.
    """

    kind = "stream"

    def __init__(self, path, *, max_segment_bytes: int = 64 << 20,
                 flush_every: int = 256, tail_events: int = 512,
                 rev: str | None = None):
        self.path = str(path)
        self.max_segment_bytes = int(max_segment_bytes)
        self.flush_every = max(1, int(flush_every))
        self.peak_resident_events = 0
        self._rev = git_rev() if rev is None else rev
        self._tail: collections.deque = collections.deque(
            maxlen=max(1, int(tail_events)))
        self._f = None
        self._closed = False
        self._info: dict | None = None
        self._open_run()

    # -- lifecycle ----------------------------------------------------------

    def _open_run(self):
        self._hash = hashlib.sha256()
        self._hash.update(b"[")
        self._first = True
        self.n_events = 0
        self._buf: list = []
        self._segment = 0
        self._rotated: list = []      # closed segment paths, oldest first
        self._f = open(self.path, "w")
        self._seg_bytes = 0
        self._write_header()
        for m in meta_events():
            self.append(m)

    def _write_header(self):
        line = _canon({"kind": STREAM_KIND,
                       "stream_version": STREAM_SCHEMA_VERSION,
                       "schema_version": TRACE_SCHEMA_VERSION,
                       "git_rev": self._rev,
                       "clock": "perf_counter_rel_us",
                       "segment": self._segment}) + "\n"
        self._f.write(line)
        self._seg_bytes += len(line)

    @property
    def events(self):
        raise RuntimeError(
            "StreamingSink does not retain events in memory; read the "
            "stream back with repro.obs.trace.read_stream(path) / "
            "StreamReader, or tee through a MemorySink")

    def recent(self, limit: int = 512) -> list:
        out = list(self._tail)
        return out[-limit:] if limit else out

    def append(self, e: dict):
        if self._closed:
            raise RuntimeError(f"StreamingSink({self.path}) is finalized")
        s = _canon({k: v for k, v in e.items() if k not in ("ts", "dur")})
        if not self._first:
            self._hash.update(b",")
        self._first = False
        self._hash.update(s.encode("utf-8"))
        line = _canon(e) + "\n"
        self._buf.append(line)
        self._tail.append(e)
        self.n_events += 1
        self._seg_bytes += len(line)
        if len(self._buf) > self.peak_resident_events:
            self.peak_resident_events = len(self._buf)
        if len(self._buf) >= self.flush_every:
            self.flush()
        if self._seg_bytes >= self.max_segment_bytes:
            self._rotate()

    def flush(self):
        if self._buf:
            self._f.write("".join(self._buf))
            self._buf = []
        self._f.flush()

    def fingerprint(self) -> str:
        """Structure fingerprint over everything appended so far — equal to
        ``structure_fingerprint(meta_events() + events)`` byte-for-byte."""
        h = self._hash.copy()
        h.update(b"]")
        return "sha256:" + h.hexdigest()

    def _write_footer(self, final: bool):
        foot = {"footer": True, "segment": self._segment,
                "n_events": self.n_events}
        if final:
            foot["fingerprint"] = self.fingerprint()
            foot["complete"] = True
            foot["segments"] = self._segment + 1
        self._f.write(_canon(foot) + "\n")

    def _rotate(self):
        self.flush()
        self._write_footer(final=False)
        self._f.close()
        rotated = f"{self.path}.{len(self._rotated) + 1}"
        os.replace(self.path, rotated)
        self._rotated.append(rotated)
        self._segment += 1
        self._f = open(self.path, "w")
        self._seg_bytes = 0
        self._write_header()

    def finalize(self) -> dict:
        """Flush, write the closing footer (with the final fingerprint),
        close the file, and return stream provenance.  Idempotent."""
        if self._closed:
            return dict(self._info)
        self.flush()
        self._write_footer(final=True)
        self._f.close()
        self._closed = True
        self._info = {"path": self.path, "kind": STREAM_KIND,
                      "stream_version": STREAM_SCHEMA_VERSION,
                      "schema_version": TRACE_SCHEMA_VERSION,
                      "fingerprint": self.fingerprint(),
                      "n_events": self.n_events,
                      "segments": self._segment + 1}
        return dict(self._info)

    close = finalize

    def reset(self):
        """Truncate back to an empty stream: delete rotated segments,
        rewrite the header, re-seed the fingerprint (meta events included).
        Called via ``EventTracer.reset()`` so warm-up events never leak
        into the saved stream."""
        if self._closed:
            raise RuntimeError(
                f"StreamingSink({self.path}) is finalized; cannot reset")
        self._buf = []
        self._tail.clear()
        self._f.close()
        for p in self._rotated:
            try:
                os.remove(p)
            except OSError:
                pass
        self._open_run()


class EventTracer:
    """``trace_event`` recorder over a pluggable sink (see module
    docstring).  Default sink is :class:`MemorySink` — identical behavior
    to the original in-memory recorder, ``tracer.events`` included."""

    enabled = True

    def __init__(self, clock=time.perf_counter, sink=None):
        self._clock = clock
        self._t0 = clock()
        self.sink = MemorySink() if sink is None else sink

    @property
    def events(self) -> list:
        """The recorded events, when the sink retains them (memory/ring/
        tee-with-memory-primary).  Raises for streaming-only sinks."""
        return self.sink.events

    def reset(self):
        """Drop recorded events and rebase the epoch — called by
        ``ServingEngine.reset_run_stats`` so warm-up never pollutes the
        steady-state trace.  A streaming sink truncates its on-disk
        segments; a ring/memory sink clears."""
        self._t0 = self._clock()
        self.sink.reset()

    # -- emit primitives -----------------------------------------------------

    def _ts(self) -> float:
        return (self._clock() - self._t0) * 1e6

    def begin(self, uid: int, name: str, **args):
        """Open an async span on request ``uid``'s track."""
        self.sink.append({"ph": "b", "cat": "req", "id": int(uid),
                          "name": name, "pid": _PID, "tid": _TID_REQUESTS,
                          "ts": self._ts(), "args": args})

    def end(self, uid: int, name: str, **args):
        """Close the matching async span."""
        self.sink.append({"ph": "e", "cat": "req", "id": int(uid),
                          "name": name, "pid": _PID, "tid": _TID_REQUESTS,
                          "ts": self._ts(), "args": args})

    def mark(self, uid: int, name: str, **args):
        """Async instant on request ``uid``'s track."""
        self.sink.append({"ph": "n", "cat": "req", "id": int(uid),
                          "name": name, "pid": _PID, "tid": _TID_REQUESTS,
                          "ts": self._ts(), "args": args})

    def instant(self, name: str, **args):
        """Global instant (allocator pressure, cache eviction)."""
        self.sink.append({"ph": "i", "s": "g", "name": name, "pid": _PID,
                          "tid": _TID_ENGINE, "ts": self._ts(),
                          "args": args})

    def step(self, dur_s: float, **args):
        """One engine step: a complete event on the engine track (``ts`` is
        the step start) plus counter samples for the budget/occupancy
        tracks.  ``args`` must be deterministic (no wall-clock values)."""
        add = self.sink.append
        ts = self._ts() - dur_s * 1e6
        add({"ph": "X", "name": "step", "pid": _PID,
             "tid": _TID_ENGINE, "ts": ts,
             "dur": dur_s * 1e6, "args": args})
        ctr = {"ph": "C", "pid": _PID, "tid": _TID_ENGINE, "ts": ts}
        if "planned" in args:
            add({**ctr, "name": "step_tokens",
                 "args": {"planned": args["planned"],
                          "realized": args.get("realized", 0)}})
        if "kv_blocks" in args:
            add({**ctr, "name": "kv_blocks",
                 "args": {"in_use": args["kv_blocks"]}})
        if "active_slots" in args:
            add({**ctr, "name": "active_slots",
                 "args": {"slots": args["active_slots"]}})

    # -- export --------------------------------------------------------------

    def _meta_events(self) -> list:
        return meta_events()

    def to_perfetto(self, rev: str | None = None) -> dict:
        evs = meta_events() + list(self.events)
        return {
            "displayTimeUnit": "ms",
            "traceEvents": evs,
            "otherData": {
                "kind": TRACE_KIND,
                "schema_version": TRACE_SCHEMA_VERSION,
                "git_rev": git_rev() if rev is None else rev,
                "clock": "perf_counter_rel_us",
                "fingerprint": structure_fingerprint(evs),
            },
        }

    def save(self, path: str, rev: str | None = None) -> dict:
        doc = self.to_perfetto(rev=rev)
        save_doc(doc, path)
        return doc


# ---------------------------------------------------------------------------
# structure fingerprint + document IO/validation
# ---------------------------------------------------------------------------

def git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, check=True).stdout.strip()
    except Exception:
        return "unknown"


def structure(events: list) -> list:
    """Events with the wall-clock fields (``ts``/``dur``) stripped — the
    deterministic side of a trace."""
    return [{k: v for k, v in e.items() if k not in ("ts", "dur")}
            for e in events]


def structure_fingerprint(events: list) -> str:
    s = _canon(structure(events))
    return "sha256:" + hashlib.sha256(s.encode("utf-8")).hexdigest()


def dumps(doc: dict) -> str:
    """Canonical serialization (sorted keys, fixed separators, trailing
    newline)."""
    return _canon(doc) + "\n"


def save_doc(doc: dict, path: str) -> None:
    validate(doc)
    with open(path, "w") as f:
        f.write(dumps(doc))


def load(path: str) -> dict:
    with open(path) as f:
        return validate(json.load(f))


def _fail(path: str, msg: str):
    raise ValueError(f"{TRACE_KIND} schema: {path}: {msg}")


def _validate_event(e, p: str):
    if not isinstance(e, dict):
        _fail(p, "expected object")
    ph = e.get("ph")
    if ph not in _KNOWN_PHASES:
        _fail(f"{p}.ph", f"unknown phase {ph!r}")
    if not isinstance(e.get("name"), str):
        _fail(f"{p}.name", "expected string")
    if ph != "M" and not isinstance(e.get("ts"), (int, float)):
        _fail(f"{p}.ts", "expected number")
    if ph in _ASYNC_PHASES:
        if "id" not in e or not isinstance(e.get("cat"), str):
            _fail(p, "async event needs id + cat")
    if ph == "X" and not isinstance(e.get("dur"), (int, float)):
        _fail(f"{p}.dur", "complete event needs dur")
    if ph == "C" and not isinstance(e.get("args"), dict):
        _fail(f"{p}.args", "counter event needs args")


def validate(doc: dict) -> dict:
    """Structural validation + fingerprint re-derivation; returns ``doc``."""
    if not isinstance(doc, dict):
        _fail("$", "expected object")
    for k in ("traceEvents", "otherData"):
        if k not in doc:
            _fail("$", f"missing key {k!r}")
    od = doc["otherData"]
    if not isinstance(od, dict):
        _fail("$.otherData", "expected object")
    for k in ("kind", "schema_version", "git_rev", "fingerprint"):
        if k not in od:
            _fail("$.otherData", f"missing key {k!r}")
    if od["kind"] != TRACE_KIND:
        _fail("$.otherData.kind", f"{od['kind']!r} != {TRACE_KIND!r}")
    if od["schema_version"] != TRACE_SCHEMA_VERSION:
        _fail("$.otherData.schema_version",
              f"{od['schema_version']!r} != {TRACE_SCHEMA_VERSION}")
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        _fail("$.traceEvents", "expected list")
    for i, e in enumerate(evs):
        _validate_event(e, f"$.traceEvents[{i}]")
    fp = structure_fingerprint(evs)
    if od["fingerprint"] != fp:
        _fail("$.otherData.fingerprint",
              f"{od['fingerprint']!r} does not match event structure "
              f"({fp!r})")
    return doc


# ---------------------------------------------------------------------------
# stream reading
# ---------------------------------------------------------------------------

def _stream_fail(path: str, msg: str):
    raise ValueError(f"{STREAM_KIND} schema: {path}: {msg}")


def stream_segments(path: str) -> list:
    """Segment files of a (possibly rotated) stream, oldest first: the
    rotated ``<path>.1 .. <path>.N`` then the active ``<path>``."""
    out = []
    i = 1
    while os.path.exists(f"{path}.{i}"):
        out.append(f"{path}.{i}")
        i += 1
    if not os.path.exists(path):
        _stream_fail(path, "no such stream file")
    out.append(path)
    return out


class StreamReader:
    """Iterate events out of a JSONL stream (chaining rotated segments),
    re-deriving the structure fingerprint as it goes.

    After exhaustion: ``fingerprint`` holds the re-derived fingerprint,
    ``n_events`` the event count, ``complete`` whether a final footer was
    present — and, when it was, the recorded fingerprint has been checked
    against the re-derived one (a tampered or reordered stream raises).
    A footer-less stream (the writer died mid-run) is still readable;
    ``complete`` stays False and no fingerprint check applies.
    """

    def __init__(self, path: str):
        self.path = str(path)
        self.header: dict | None = None
        self.footer: dict | None = None
        self.fingerprint: str | None = None
        self.complete: bool | None = None
        self.n_events = 0

    def _check_header(self, obj: dict, where: str):
        if obj.get("kind") != STREAM_KIND:
            _stream_fail(where, f"kind {obj.get('kind')!r} != {STREAM_KIND!r}")
        if obj.get("stream_version") != STREAM_SCHEMA_VERSION:
            _stream_fail(where, f"stream_version {obj.get('stream_version')!r}"
                                f" != {STREAM_SCHEMA_VERSION}")
        if obj.get("schema_version") != TRACE_SCHEMA_VERSION:
            _stream_fail(where, f"schema_version {obj.get('schema_version')!r}"
                                f" != {TRACE_SCHEMA_VERSION}")

    def __iter__(self):
        h = hashlib.sha256()
        h.update(b"[")
        first = True
        n = 0
        segs = stream_segments(self.path)
        for seg in segs:
            active = seg == segs[-1]
            with open(seg) as f:
                for lineno, line in enumerate(f, 1):
                    line = line.strip()
                    if not line:
                        continue
                    where = f"{seg}:{lineno}"
                    try:
                        obj = json.loads(line)
                    except ValueError:
                        if active:
                            break    # truncated tail: writer died mid-line
                        _stream_fail(where, "not valid JSON")
                    if not isinstance(obj, dict):
                        _stream_fail(where, "expected object")
                    if "kind" in obj and "ph" not in obj:
                        self._check_header(obj, where)
                        if self.header is None:
                            self.header = obj
                        continue
                    if obj.get("footer"):
                        self.footer = obj
                        continue
                    _validate_event(obj, where)
                    if not first:
                        h.update(b",")
                    first = False
                    h.update(_canon({k: v for k, v in obj.items()
                                     if k not in ("ts", "dur")}).encode())
                    n += 1
                    yield obj
        if self.header is None:
            _stream_fail(self.path, "no stream header line")
        hc = h.copy()
        hc.update(b"]")
        self.fingerprint = "sha256:" + hc.hexdigest()
        self.n_events = n
        foot = self.footer
        self.complete = bool(foot and foot.get("complete")
                             and "fingerprint" in foot)
        if self.complete:
            if foot["fingerprint"] != self.fingerprint:
                _stream_fail(self.path,
                             f"recorded fingerprint {foot['fingerprint']!r} "
                             f"does not match event structure "
                             f"({self.fingerprint!r})")
            if foot.get("n_events") != n:
                _stream_fail(self.path,
                             f"footer n_events {foot.get('n_events')} != "
                             f"{n} events read")


def read_stream(path: str) -> tuple:
    """Read a whole stream into memory: ``(events, reader)`` with the
    reader's post-iteration provenance fields populated."""
    r = StreamReader(path)
    return list(r), r


def stream_to_perfetto(path: str) -> dict:
    """Re-assemble a JSONL stream into a validated ``OBS_TRACE`` Perfetto
    document (meta events are part of the stream, so this is just
    re-wrapping)."""
    evs, r = read_stream(path)
    return validate({
        "displayTimeUnit": "ms",
        "traceEvents": evs,
        "otherData": {
            "kind": TRACE_KIND,
            "schema_version": r.header["schema_version"],
            "git_rev": r.header.get("git_rev", "unknown"),
            "clock": r.header.get("clock", "perf_counter_rel_us"),
            "fingerprint": r.fingerprint,
        },
    })


def load_any(path: str) -> tuple:
    """Sniff a trace file: returns ``("stream", StreamReader)`` for JSONL
    streams, ``("doc", dict)`` for whole Perfetto documents (validated)."""
    with open(path) as f:
        head = f.readline()
    try:
        obj = json.loads(head)
    except ValueError:
        obj = None
    if isinstance(obj, dict) and obj.get("kind") == STREAM_KIND:
        return "stream", StreamReader(path)
    return "doc", load(path)


# ---------------------------------------------------------------------------
# optional jax.profiler alignment hooks
# ---------------------------------------------------------------------------

def step_annotation(step_num: int):
    """Context manager annotating one engine step in an XLA profiler trace
    (``jax.profiler.StepTraceAnnotation``), so device timelines captured
    with ``jax.profiler.trace(...)`` align with engine-step records.  Falls
    back to a null context when the profiler is unavailable."""
    try:
        from jax import profiler
        return profiler.StepTraceAnnotation("tsar_engine_step",
                                            step_num=step_num)
    except Exception:
        return contextlib.nullcontext()
