"""Low-overhead structured event tracing for the serving engine, exported
as Chrome/Perfetto ``trace_event`` JSON.

Two recorders share one interface:

* :data:`NULL_TRACER` — the default.  ``enabled`` is False and every method
  is a no-op; emit sites in the engine guard on ``tracer.enabled`` before
  building argument dicts, so a tracing-off engine pays one attribute read
  per potential event (tested: step counters are bit-identical to an
  untraced engine).
* :class:`EventTracer` — appends events to an in-memory list, timestamped
  from ``time.perf_counter`` relative to the tracer epoch, in microseconds
  (the ``trace_event`` clock unit).

Event taxonomy (see docs/observability.md for the full contract):

* **request lifecycle** — async spans keyed by request uid (Perfetto
  groups async events by ``(cat, id)``, so each request renders as its own
  track): a ``req`` envelope span containing ``queued`` / ``prefill`` /
  ``decode`` sub-spans, with instants ``admitted`` (args: slot, cached_len,
  readmission), ``prefill_chunk``, ``prefix_hit``, ``first_token``,
  ``preempted``, ``finished``, ``cancelled``.  A preempted request closes
  its open phase span with ``preempted: true`` and re-opens ``queued`` —
  the span sequence is well-formed by construction (property-tested).
* **engine steps** — one complete (``X``) event per step on the dedicated
  engine thread, args carrying the deterministic step record: planned vs
  realized token budget, prefill/decode split, KV blocks in use, active
  slots, the plan kernel serving this step's row bucket.  The same record
  feeds three counter (``C``) tracks — ``step_tokens``, ``kv_blocks``,
  ``active_slots`` — so Perfetto draws budget utilization as a graph.
* **global instants** — allocator/cache causality: ``kv_pressure`` (the
  free list ran short and the evictor was consulted), ``prefix_evict``
  (args: n, cause ∈ {capacity, pressure}), ``prefix_insert``.

**Determinism.**  Event *structure* — order, names, phases, args — is a
pure function of (trace, code): wall-clock enters only through ``ts`` /
``dur`` fields, never args.  :func:`structure_fingerprint` hashes the
canonical JSON of events with ``ts``/``dur`` stripped; same-seed replays
fingerprint identically (property-tested), which is what lets CI smoke-
assert a trace artifact without pinning timings.

The exported document is schema-versioned like
``benchmarks/workloads/schema.py``: ``otherData`` carries kind, schema
version, git revision, and the structure fingerprint; :func:`validate`
walks the document and re-derives the fingerprint.  The JSON loads
directly in ``chrome://tracing`` / https://ui.perfetto.dev.
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import subprocess
import time

TRACE_KIND = "OBS_TRACE"
TRACE_SCHEMA_VERSION = 1

_PID = 1
_TID_ENGINE = 0          # engine-step track
_TID_REQUESTS = 1        # async request spans (grouped by id, not tid)

_ASYNC_PHASES = ("b", "e", "n")
_KNOWN_PHASES = _ASYNC_PHASES + ("X", "C", "i", "M")


class NullTracer:
    """No-op recorder; the engine's default.  Emit sites guard on
    ``enabled`` so the disabled path never constructs event args."""

    enabled = False
    __slots__ = ()

    def begin(self, uid, name, **args):
        pass

    def end(self, uid, name, **args):
        pass

    def mark(self, uid, name, **args):
        pass

    def instant(self, name, **args):
        pass

    def step(self, dur_s, **args):
        pass

    def reset(self):
        pass


NULL_TRACER = NullTracer()


class EventTracer:
    """In-memory ``trace_event`` recorder (see module docstring)."""

    enabled = True

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._t0 = clock()
        self.events: list = []

    def reset(self):
        """Drop recorded events and rebase the epoch — called by
        ``ServingEngine.reset_run_stats`` so warm-up never pollutes the
        steady-state trace."""
        self._t0 = self._clock()
        self.events = []

    # -- emit primitives -----------------------------------------------------

    def _ts(self) -> float:
        return (self._clock() - self._t0) * 1e6

    def begin(self, uid: int, name: str, **args):
        """Open an async span on request ``uid``'s track."""
        self.events.append({"ph": "b", "cat": "req", "id": int(uid),
                            "name": name, "pid": _PID, "tid": _TID_REQUESTS,
                            "ts": self._ts(), "args": args})

    def end(self, uid: int, name: str, **args):
        """Close the matching async span."""
        self.events.append({"ph": "e", "cat": "req", "id": int(uid),
                            "name": name, "pid": _PID, "tid": _TID_REQUESTS,
                            "ts": self._ts(), "args": args})

    def mark(self, uid: int, name: str, **args):
        """Async instant on request ``uid``'s track."""
        self.events.append({"ph": "n", "cat": "req", "id": int(uid),
                            "name": name, "pid": _PID, "tid": _TID_REQUESTS,
                            "ts": self._ts(), "args": args})

    def instant(self, name: str, **args):
        """Global instant (allocator pressure, cache eviction)."""
        self.events.append({"ph": "i", "s": "g", "name": name, "pid": _PID,
                            "tid": _TID_ENGINE, "ts": self._ts(),
                            "args": args})

    def step(self, dur_s: float, **args):
        """One engine step: a complete event on the engine track (``ts`` is
        the step start) plus counter samples for the budget/occupancy
        tracks.  ``args`` must be deterministic (no wall-clock values)."""
        ts = self._ts() - dur_s * 1e6
        self.events.append({"ph": "X", "name": "step", "pid": _PID,
                            "tid": _TID_ENGINE, "ts": ts,
                            "dur": dur_s * 1e6, "args": args})
        ctr = {"ph": "C", "pid": _PID, "tid": _TID_ENGINE, "ts": ts}
        if "planned" in args:
            self.events.append({**ctr, "name": "step_tokens",
                                "args": {"planned": args["planned"],
                                         "realized": args.get("realized", 0)}})
        if "kv_blocks" in args:
            self.events.append({**ctr, "name": "kv_blocks",
                                "args": {"in_use": args["kv_blocks"]}})
        if "active_slots" in args:
            self.events.append({**ctr, "name": "active_slots",
                                "args": {"slots": args["active_slots"]}})

    # -- export --------------------------------------------------------------

    def _meta_events(self) -> list:
        return [
            {"ph": "M", "name": "process_name", "pid": _PID, "tid": 0,
             "args": {"name": "tsar-serving-engine"}},
            {"ph": "M", "name": "thread_name", "pid": _PID,
             "tid": _TID_ENGINE, "args": {"name": "engine steps"}},
            {"ph": "M", "name": "thread_name", "pid": _PID,
             "tid": _TID_REQUESTS, "args": {"name": "requests"}},
        ]

    def to_perfetto(self, rev: str | None = None) -> dict:
        evs = self._meta_events() + list(self.events)
        return {
            "displayTimeUnit": "ms",
            "traceEvents": evs,
            "otherData": {
                "kind": TRACE_KIND,
                "schema_version": TRACE_SCHEMA_VERSION,
                "git_rev": git_rev() if rev is None else rev,
                "clock": "perf_counter_rel_us",
                "fingerprint": structure_fingerprint(evs),
            },
        }

    def save(self, path: str, rev: str | None = None) -> dict:
        doc = self.to_perfetto(rev=rev)
        save_doc(doc, path)
        return doc


# ---------------------------------------------------------------------------
# structure fingerprint + document IO/validation
# ---------------------------------------------------------------------------

def git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, check=True).stdout.strip()
    except Exception:
        return "unknown"


def structure(events: list) -> list:
    """Events with the wall-clock fields (``ts``/``dur``) stripped — the
    deterministic side of a trace."""
    return [{k: v for k, v in e.items() if k not in ("ts", "dur")}
            for e in events]


def structure_fingerprint(events: list) -> str:
    s = json.dumps(structure(events), sort_keys=True,
                   separators=(",", ":"))
    return "sha256:" + hashlib.sha256(s.encode("utf-8")).hexdigest()


def dumps(doc: dict) -> str:
    """Canonical serialization (sorted keys, fixed separators, trailing
    newline)."""
    return json.dumps(doc, sort_keys=True, separators=(",", ":")) + "\n"


def save_doc(doc: dict, path: str) -> None:
    validate(doc)
    with open(path, "w") as f:
        f.write(dumps(doc))


def load(path: str) -> dict:
    with open(path) as f:
        return validate(json.load(f))


def _fail(path: str, msg: str):
    raise ValueError(f"{TRACE_KIND} schema: {path}: {msg}")


def validate(doc: dict) -> dict:
    """Structural validation + fingerprint re-derivation; returns ``doc``."""
    if not isinstance(doc, dict):
        _fail("$", "expected object")
    for k in ("traceEvents", "otherData"):
        if k not in doc:
            _fail("$", f"missing key {k!r}")
    od = doc["otherData"]
    if not isinstance(od, dict):
        _fail("$.otherData", "expected object")
    for k in ("kind", "schema_version", "git_rev", "fingerprint"):
        if k not in od:
            _fail("$.otherData", f"missing key {k!r}")
    if od["kind"] != TRACE_KIND:
        _fail("$.otherData.kind", f"{od['kind']!r} != {TRACE_KIND!r}")
    if od["schema_version"] != TRACE_SCHEMA_VERSION:
        _fail("$.otherData.schema_version",
              f"{od['schema_version']!r} != {TRACE_SCHEMA_VERSION}")
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        _fail("$.traceEvents", "expected list")
    for i, e in enumerate(evs):
        p = f"$.traceEvents[{i}]"
        if not isinstance(e, dict):
            _fail(p, "expected object")
        ph = e.get("ph")
        if ph not in _KNOWN_PHASES:
            _fail(f"{p}.ph", f"unknown phase {ph!r}")
        if not isinstance(e.get("name"), str):
            _fail(f"{p}.name", "expected string")
        if ph != "M" and not isinstance(e.get("ts"), (int, float)):
            _fail(f"{p}.ts", "expected number")
        if ph in _ASYNC_PHASES:
            if "id" not in e or not isinstance(e.get("cat"), str):
                _fail(p, "async event needs id + cat")
        if ph == "X" and not isinstance(e.get("dur"), (int, float)):
            _fail(f"{p}.dur", "complete event needs dur")
        if ph == "C" and not isinstance(e.get("args"), dict):
            _fail(f"{p}.args", "counter event needs args")
    fp = structure_fingerprint(evs)
    if od["fingerprint"] != fp:
        _fail("$.otherData.fingerprint",
              f"{od['fingerprint']!r} does not match event structure "
              f"({fp!r})")
    return doc


# ---------------------------------------------------------------------------
# optional jax.profiler alignment hooks
# ---------------------------------------------------------------------------

def step_annotation(step_num: int):
    """Context manager annotating one engine step in an XLA profiler trace
    (``jax.profiler.StepTraceAnnotation``), so device timelines captured
    with ``jax.profiler.trace(...)`` align with engine-step records.  Falls
    back to a null context when the profiler is unavailable."""
    try:
        from jax import profiler
        return profiler.StepTraceAnnotation("tsar_engine_step",
                                            step_num=step_num)
    except Exception:
        return contextlib.nullcontext()
