"""Typed metrics registry — the single owner of engine telemetry.

Three metric kinds, Prometheus-shaped and host-side; ``repro.obs.export``
renders the registry in Prometheus text-exposition format behind a
scrape endpoint (``launch/serve.py --metrics-port``) or a periodic
textfile writer:

* :class:`Counter`   — monotonically increasing value (``inc``); ``set`` is
  the reset/write-through escape hatch the legacy ``engine.stats`` dict
  API needs.
* :class:`Gauge`     — a current value plus a tracked **peak**.  The peak is
  what the legacy ``peak_kv_blocks`` / ``max_step_tokens`` stats keys
  report; ``reset_peak`` REBASES the peak to the current value (not to
  zero), so a run-stats reset on an engine that still holds blocks (e.g. a
  kept prefix cache) starts the new run's peak from reality instead of
  undercounting it.
* :class:`Histogram` — raw observations with nearest-rank percentile
  summaries ({p50, p90, p99, mean, max, n}).  TTFT/TPOT/queue live here,
  so serving drivers print tail latencies directly instead of replaying
  requests through an external runner.

Metrics may declare **labels** (``registry.counter("step_time_s",
labels=("phase",))``); ``.labels(phase="prefill")`` returns the child
metric for that label value, created on first use.  The registry is
*typed*: re-declaring a name as a different kind (or with different
labels) raises instead of silently aliasing.

:class:`StatsView` is the backward-compatibility surface: a mutable
mapping that reads and writes through to registry metrics under their
legacy key names, with a plain-dict side table for static entries
(plan/density telemetry).  ``dict(view)``, ``view.update(...)``,
``"key" in view`` all behave like the old ``engine.stats`` dict.
"""
from __future__ import annotations

from collections.abc import MutableMapping

import numpy as np

PERCENTILES = (50, 90, 99)

# Default latency bucket bounds (seconds) for the Prometheus histogram
# rendering in repro.obs.export — exact observations are kept, so buckets
# are derived at render time, not at observe time.
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def empty_summary() -> dict:
    """The explicit sentinel for a histogram with no observations: the
    usual summary shape with numeric zeros (NaN-free, so snapshots stay
    strict-JSON- and Prometheus-safe) plus ``"empty": True`` — callers
    that care distinguish on the flag or on ``n == 0``, format sites that
    multiply ``p50 * 1e3`` keep working."""
    return {**{f"p{p}": 0.0 for p in PERCENTILES},
            "mean": 0.0, "max": 0.0, "n": 0, "empty": True}


class Counter:
    """Monotonic counter (int or float increments)."""

    __slots__ = ("name", "help", "value")
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0

    def inc(self, v=1):
        self.value += v

    def set(self, v):
        """Write-through/reset hook for the legacy dict API."""
        self.value = v

    def reset(self):
        self.value = 0


class Gauge:
    """Current value + tracked peak."""

    __slots__ = ("name", "help", "value", "peak")
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0
        self.peak = 0

    def set(self, v):
        self.value = v
        if v > self.peak:
            self.peak = v

    def inc(self, v=1):
        self.set(self.value + v)

    def reset_peak(self):
        """Rebase the peak to the CURRENT value (see module docstring)."""
        self.peak = self.value

    def reset(self):
        self.value = 0
        self.peak = 0


class Histogram:
    """Raw-observation histogram with percentile summaries.

    Serving runs are bounded (thousands of requests, not billions), so the
    honest representation — keep every observation, compute exact
    percentiles — beats bucketed approximation; ``max_obs`` bounds memory
    for pathological loops by dropping the OLDEST half when exceeded (tail
    percentiles of a long run care about recent steady state).
    """

    __slots__ = ("name", "help", "_obs", "max_obs")
    kind = "histogram"

    def __init__(self, name: str, help: str = "", max_obs: int = 1 << 20):
        self.name = name
        self.help = help
        self.max_obs = max_obs
        self._obs: list = []

    def observe(self, v):
        if v is None:
            return
        self._obs.append(float(v))
        if len(self._obs) > self.max_obs:
            self._obs = self._obs[len(self._obs) // 2:]

    @property
    def count(self) -> int:
        return len(self._obs)

    @property
    def sum(self) -> float:
        return float(np.sum(self._obs)) if self._obs else 0.0

    def percentile(self, p: float) -> float:
        if not self._obs:
            return float("nan")
        return float(np.percentile(np.asarray(self._obs), p))

    def cumulative_buckets(self, bounds: tuple = DEFAULT_BUCKETS) -> list:
        """Prometheus-style cumulative buckets over ``bounds`` plus the
        implicit +Inf bucket: ``[(le, n_obs <= le), ...]``."""
        xs = np.sort(np.asarray(self._obs, dtype=float))
        out = [(float(b), int(np.searchsorted(xs, b, side="right")))
               for b in bounds]
        out.append((float("inf"), int(xs.size)))
        return out

    def summary(self) -> dict:
        """{p50, p90, p99, mean, max, n} — the same shape as
        ``benchmarks.workloads.metrics.percentile_summary``.  Empty
        histograms return :func:`empty_summary` (NaN-free, ``empty``
        flag) instead of NaN fields, so ``latency_percentiles()`` on a
        fresh engine is safe to JSON-encode and render."""
        obs = list(self._obs)    # snapshot: scrape threads read concurrently
        if not obs:
            return empty_summary()
        xs = np.asarray(obs)
        out = {f"p{p}": float(np.percentile(xs, p)) for p in PERCENTILES}
        out["mean"] = float(xs.mean())
        out["max"] = float(xs.max())
        out["n"] = int(xs.size)
        return out

    def reset(self):
        self._obs = []


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class _Family:
    """A labeled metric family: one child metric per label-value tuple."""

    __slots__ = ("name", "help", "labels_keys", "_cls", "_children")

    def __init__(self, name: str, cls, labels: tuple, help: str = ""):
        self.name = name
        self.help = help
        self.labels_keys = tuple(labels)
        self._cls = cls
        self._children: dict = {}

    @property
    def kind(self):
        return self._cls.kind

    def items(self):
        """``(labels_dict, child)`` pairs in creation order — the export
        renderer's iteration surface."""
        return [(dict(zip(self.labels_keys, key)), child)
                for key, child in self._children.items()]

    def labels(self, **kv):
        if set(kv) != set(self.labels_keys):
            raise ValueError(
                f"metric {self.name!r} declared labels {self.labels_keys}, "
                f"got {tuple(sorted(kv))}")
        key = tuple(str(kv[k]) for k in self.labels_keys)
        child = self._children.get(key)
        if child is None:
            lbl = ",".join(f"{k}={v}" for k, v in zip(self.labels_keys, key))
            child = self._cls(f"{self.name}{{{lbl}}}", self.help)
            self._children[key] = child
        return child

    def children(self):
        return list(self._children.values())


class MetricsRegistry:
    """Typed registry: declare-or-get by name, snapshot as a flat dict."""

    def __init__(self):
        self._metrics: dict = {}

    def _declare(self, name: str, kind: str, help: str, labels: tuple):
        existing = self._metrics.get(name)
        if existing is not None:
            ok = (existing.kind == kind
                  and isinstance(existing, _Family) == bool(labels)
                  and (not labels
                       or existing.labels_keys == tuple(labels)))
            if not ok:
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{existing.kind}{getattr(existing, 'labels_keys', ())} "
                    f"— cannot re-declare as {kind}{tuple(labels)}")
            return existing
        cls = _KINDS[kind]
        m = _Family(name, cls, labels, help) if labels else cls(name, help)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "", labels: tuple = ()):
        return self._declare(name, "counter", help, tuple(labels))

    def gauge(self, name: str, help: str = "", labels: tuple = ()):
        return self._declare(name, "gauge", help, tuple(labels))

    def histogram(self, name: str, help: str = "", labels: tuple = ()):
        return self._declare(name, "histogram", help, tuple(labels))

    def get(self, name: str):
        return self._metrics[name]

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> list:
        return list(self._metrics)

    def metrics(self) -> dict:
        """``name -> metric-or-family`` in declaration order; families
        expose ``items()``.  This is the surface ``repro.obs.export``
        renders from."""
        return dict(self._metrics)

    def _flat(self):
        for m in self._metrics.values():
            if isinstance(m, _Family):
                yield from m.children()
            else:
                yield m

    def snapshot(self) -> dict:
        """Flat name -> value dict: counters and gauge values verbatim,
        gauge peaks as ``<name>_peak``, histograms as their percentile
        summary dicts."""
        out = {}
        for m in self._flat():
            if m.kind == "histogram":
                out[m.name] = m.summary()
            elif m.kind == "gauge":
                out[m.name] = m.value
                out[f"{m.name}_peak"] = m.peak
            else:
                out[m.name] = m.value
        return out

    def reset_run(self):
        """Per-run reset: counters to zero, histograms cleared, gauge peaks
        REBASED to their current values (gauge values are live state — a
        reset must not pretend the engine holds nothing)."""
        for m in self._flat():
            if m.kind == "gauge":
                m.reset_peak()
            else:
                m.reset()


class StatsView(MutableMapping):
    """Legacy ``engine.stats`` dict API over registry metrics.

    ``mapping`` is ``key -> (getter, setter)``; unknown keys fall through
    to a plain side dict (static init-time telemetry like ``plan_layers``).
    Key ORDER is mapping order then side-dict insertion order, so printing
    ``dict(stats)`` stays stable across runs.
    """

    def __init__(self, mapping: dict | None = None):
        self._map: dict = dict(mapping or {})
        self._extra: dict = {}

    def bind(self, key: str, getter, setter=None):
        self._map[key] = (getter, setter)

    def __getitem__(self, key):
        if key in self._map:
            return self._map[key][0]()
        return self._extra[key]

    def __setitem__(self, key, value):
        if key in self._map:
            _, setter = self._map[key]
            if setter is None:
                raise KeyError(f"stats key {key!r} is read-only")
            setter(value)
        else:
            self._extra[key] = value

    def __delitem__(self, key):
        del self._extra[key]

    def __iter__(self):
        yield from self._map
        yield from self._extra

    def __len__(self):
        return len(self._map) + len(self._extra)

    def __repr__(self):
        return f"StatsView({dict(self)!r})"
