"""Incident snapshots: when the engine hits a bad moment, dump the flight
recorder + a metrics snapshot into a schema-versioned file (kind
``OBS_INCIDENT``, schema v1).

The always-on story: run the engine with a :class:`~repro.obs.trace.RingSink`
tracer (cheap, fixed memory) and an :class:`IncidentMonitor` bound to the
engine's registry.  When a trigger fires — an SLO breach, a preemption, an
admission rejection, KV allocator pressure, or an eviction storm — the
monitor writes ``<prefix>-<seq>-<trigger>-<stamp>.json`` into its output
directory containing:

* ``trigger`` + ``context`` — what fired and its site-specific details
  (uid, measured latency vs threshold, eviction counts, ...);
* ``metrics`` — ``MetricsRegistry.snapshot()`` at dump time;
* ``ring`` — the last events out of the tracer's sink (``recent()``),
  i.e. what the engine was doing leading up to the incident;
* provenance — schema version, git revision, engine step, sequence
  number, wall-clock stamp.

Debouncing keeps the always-on path from writing a file per decode token:
a per-trigger **cooldown** (engine steps), a global **max_incidents** cap
(suppressed firings are counted, not silently lost), and a sliding-window
eviction-storm detector (``eviction_storm_n`` evictions within
``eviction_window_steps`` steps) instead of per-eviction dumps.

The monitor deliberately owns no metrics in the engine's registry and the
engine's hook sites sit outside the ``tracer.enabled`` guards: incidents
fire with tracing on or off, and attaching a monitor cannot perturb the
deterministic counters the bench baseline exact-gates (tested).

``ServingEngine.reset_run_stats()`` calls :meth:`IncidentMonitor.reset_run`,
which discards incident files written so far (they came from warm-up) and
re-arms — the same warm-up contract the tracer and registry follow.
"""
from __future__ import annotations

import collections
import json
import os
import time

INCIDENT_KIND = "OBS_INCIDENT"
INCIDENT_SCHEMA_VERSION = 1

TRIGGERS = ("slo_breach", "preemption", "rejection", "kv_pressure",
            "eviction_storm")

_REQUIRED_KEYS = ("kind", "schema_version", "trigger", "context", "seq",
                  "step", "created_unix", "git_rev", "metrics", "ring")


class IncidentMonitor:
    """Trigger-driven incident snapshot writer (see module docstring).

    Bind to an engine implicitly (``ServingEngine(incidents=monitor)``
    calls :meth:`bind`) or explicitly for standalone use.  ``clock`` is
    injectable for deterministic tests; it only stamps files, never enters
    trigger decisions.
    """

    def __init__(self, out_dir: str, *, triggers: tuple = TRIGGERS,
                 prefix: str = "incident",
                 slo_ttft_s: float | None = None,
                 slo_tpot_s: float | None = None,
                 eviction_storm_n: int = 8, eviction_window_steps: int = 16,
                 cooldown_steps: int = 32, max_incidents: int = 16,
                 ring_limit: int = 512, clock=time.time,
                 rev: str | None = None):
        unknown = set(triggers) - set(TRIGGERS)
        if unknown:
            raise ValueError(f"unknown incident triggers {sorted(unknown)}; "
                             f"known: {TRIGGERS}")
        self.out_dir = str(out_dir)
        os.makedirs(self.out_dir, exist_ok=True)
        self.triggers = tuple(triggers)
        self.prefix = prefix
        self.slo_ttft_s = slo_ttft_s
        self.slo_tpot_s = slo_tpot_s
        self.eviction_storm_n = int(eviction_storm_n)
        self.eviction_window_steps = int(eviction_window_steps)
        self.cooldown_steps = int(cooldown_steps)
        self.max_incidents = int(max_incidents)
        self.ring_limit = int(ring_limit)
        self._clock = clock
        self._rev = rev
        self.paths: list = []
        self.fired: dict = {}       # trigger -> count actually dumped
        self.suppressed = 0         # firings debounced/capped away
        self._seq = 0
        self._step = 0
        self._last_fire: dict = {}  # trigger -> step of last dump
        self._evict_window: collections.deque = collections.deque()
        self._registry = None
        self._tracer = None

    def bind(self, *, registry=None, tracer=None):
        """Attach the metrics registry and tracer whose state dumps
        capture.  Either may be None (sections come out empty)."""
        if registry is not None:
            self._registry = registry
        if tracer is not None:
            self._tracer = tracer
        return self

    # -- engine hook surface -------------------------------------------------

    def step_tick(self, *, evictions: int = 0):
        """Called once per engine step.  Advances the debounce clock and
        feeds the sliding-window eviction-storm detector."""
        self._step += 1
        if evictions > 0:
            self._evict_window.append((self._step, int(evictions)))
        horizon = self._step - self.eviction_window_steps
        while self._evict_window and self._evict_window[0][0] <= horizon:
            self._evict_window.popleft()
        total = sum(n for _, n in self._evict_window)
        if total >= self.eviction_storm_n:
            if self.observe("eviction_storm", evictions=total,
                            window_steps=self.eviction_window_steps):
                self._evict_window.clear()

    def request_first_token(self, req):
        """TTFT SLO check at first-token emission."""
        t = getattr(req, "ttft", None)
        if self.slo_ttft_s is not None and t is not None \
                and t > self.slo_ttft_s:
            self.observe("slo_breach", kind="ttft", uid=req.uid,
                         measured_s=float(t), threshold_s=self.slo_ttft_s)

    def request_finished(self, req):
        """TPOT SLO check at request completion."""
        t = getattr(req, "tpot", None)
        if self.slo_tpot_s is not None and t is not None \
                and t > self.slo_tpot_s:
            self.observe("slo_breach", kind="tpot", uid=req.uid,
                         measured_s=float(t), threshold_s=self.slo_tpot_s)

    # -- trigger + dump ------------------------------------------------------

    def observe(self, trigger: str, **context):
        """Report a trigger firing.  Returns the incident file path when a
        dump was written, else None (trigger unconfigured, in cooldown, or
        over the cap)."""
        if trigger not in self.triggers:
            return None
        if self._seq >= self.max_incidents:
            self.suppressed += 1
            return None
        last = self._last_fire.get(trigger)
        if last is not None and self._step - last < self.cooldown_steps:
            self.suppressed += 1
            return None
        self._last_fire[trigger] = self._step
        return self._dump(trigger, context)

    def _dump(self, trigger: str, context: dict) -> str:
        from repro.obs import trace as _trace
        sink = getattr(self._tracer, "sink", None)
        ring = sink.recent(self.ring_limit) if hasattr(sink, "recent") else []
        now = float(self._clock())
        doc = {
            "kind": INCIDENT_KIND,
            "schema_version": INCIDENT_SCHEMA_VERSION,
            "trigger": trigger,
            "context": context,
            "seq": self._seq,
            "step": self._step,
            "created_unix": now,
            "git_rev": _trace.git_rev() if self._rev is None else self._rev,
            "metrics": (self._registry.snapshot()
                        if self._registry is not None else {}),
            "ring": {
                "n_events": len(ring),
                "n_dropped": getattr(sink, "n_dropped", 0),
                "events": ring,
            },
        }
        stamp = time.strftime("%Y%m%dT%H%M%S", time.gmtime(now))
        path = os.path.join(
            self.out_dir, f"{self.prefix}-{self._seq:03d}-{trigger}-{stamp}.json")
        with open(path, "w") as f:
            json.dump(doc, f, sort_keys=True, separators=(",", ":"))
            f.write("\n")
        self.paths.append(path)
        self.fired[trigger] = self.fired.get(trigger, 0) + 1
        self._seq += 1
        return path

    def reset_run(self, *, discard: bool = True):
        """Warm-up reset: re-arm all debouncing and (by default) delete the
        incident files written so far — they describe warm-up, not the
        run.  Only files this monitor itself wrote are touched."""
        if discard:
            for p in self.paths:
                try:
                    os.remove(p)
                except OSError:
                    pass
        self.paths = []
        self.fired = {}
        self.suppressed = 0
        self._seq = 0
        self._step = 0
        self._last_fire = {}
        self._evict_window.clear()

    def summary(self) -> dict:
        """Provenance block for reports: counts + file paths."""
        return {"n": len(self.paths), "by_trigger": dict(self.fired),
                "suppressed": self.suppressed, "paths": list(self.paths)}


# ---------------------------------------------------------------------------
# incident document IO/validation
# ---------------------------------------------------------------------------

def _fail(path: str, msg: str):
    raise ValueError(f"{INCIDENT_KIND} schema: {path}: {msg}")


def validate_incident(doc: dict) -> dict:
    """Structural validation; returns ``doc``."""
    if not isinstance(doc, dict):
        _fail("$", "expected object")
    for k in _REQUIRED_KEYS:
        if k not in doc:
            _fail("$", f"missing key {k!r}")
    if doc["kind"] != INCIDENT_KIND:
        _fail("$.kind", f"{doc['kind']!r} != {INCIDENT_KIND!r}")
    if doc["schema_version"] != INCIDENT_SCHEMA_VERSION:
        _fail("$.schema_version",
              f"{doc['schema_version']!r} != {INCIDENT_SCHEMA_VERSION}")
    if doc["trigger"] not in TRIGGERS:
        _fail("$.trigger", f"unknown trigger {doc['trigger']!r}")
    if not isinstance(doc["context"], dict):
        _fail("$.context", "expected object")
    if not isinstance(doc["metrics"], dict):
        _fail("$.metrics", "expected object")
    ring = doc["ring"]
    if not isinstance(ring, dict) or "events" not in ring:
        _fail("$.ring", "expected object with events")
    if not isinstance(ring["events"], list):
        _fail("$.ring.events", "expected list")
    return doc


def load_incident(path: str) -> dict:
    with open(path) as f:
        return validate_incident(json.load(f))
