"""Timeline analysis over a saved observability trace.

``python -m repro.obs.timeline trace.json`` loads + validates a Perfetto
document written by :class:`repro.obs.trace.EventTracer` and summarizes
what the raw event stream actually says about the run:

* **step-budget utilization** — Σ realized / Σ planned tokens across step
  records.  ``planned`` is the padded B×C step width (the rows the jitted
  kernel really multiplies), so ``1 - utilization`` is exactly the padding
  waste the ROADMAP's flat token-packing item targets.
* **batch occupancy** — mean active slots per step, against the slot count.
* **per-phase time** — wall time split into prefill-carrying vs pure-decode
  steps (from complete-event durations) plus per-request queued/prefill/
  decode span totals.
* **preemption/eviction causality** — for each ``preempted`` mark: the
  nearest preceding ``kv_pressure`` / ``prefix_evict`` instants (why),
  and whether the victim was later re-admitted or never finished (what
  happened next).
* **prefix reuse** — hit marks with cached token counts, insert/evict
  instants grouped by cause.

``--require`` turns the CLI into a CI smoke gate: exit nonzero unless the
trace contains the named features (used by the bench lane on the
shared-prefix workload).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs import trace as _trace


def _span_durations(events: list) -> dict:
    """Total duration per async span name, matching b/e pairs per (id,
    name).  Unclosed spans are ignored (a truncated run is still
    analyzable)."""
    open_ts: dict = {}
    totals: dict = {}
    counts: dict = {}
    for e in events:
        ph = e.get("ph")
        if ph not in ("b", "e"):
            continue
        key = (e.get("id"), e["name"])
        if ph == "b":
            open_ts[key] = e["ts"]
        elif key in open_ts:
            totals[e["name"]] = totals.get(e["name"], 0.0) \
                + (e["ts"] - open_ts.pop(key))
            counts[e["name"]] = counts.get(e["name"], 0) + 1
    return {name: {"total_us": totals[name], "n": counts[name]}
            for name in totals}


def analyze(doc: dict) -> dict:
    """Pure analysis: Perfetto document -> summary dict (JSON-safe)."""
    evs = doc["traceEvents"]
    steps = [e for e in evs if e.get("ph") == "X" and e["name"] == "step"]
    marks = [e for e in evs if e.get("ph") == "n"]
    instants = [e for e in evs if e.get("ph") == "i"]

    # -- step budget + occupancy + phase split ------------------------------
    planned = sum(s["args"].get("planned", 0) for s in steps)
    realized = sum(s["args"].get("realized", 0) for s in steps)
    occ = [s["args"]["active_slots"] for s in steps
           if "active_slots" in s["args"]]
    prefill_steps = [s for s in steps if s["args"].get("prefill_tokens", 0) > 0]
    decode_steps = [s for s in steps if s["args"].get("prefill_tokens", 0) == 0]
    kernels: dict = {}
    for s in steps:
        k = s["args"].get("kernel")
        if k is not None:
            kernels[k] = kernels.get(k, 0) + 1

    # -- preemption causality ----------------------------------------------
    admitted: dict = {}       # uid -> list of admitted marks (ts order)
    for m in marks:
        if m["name"] == "admitted":
            admitted.setdefault(m["id"], []).append(m)
    pressure = [e for e in instants
                if e["name"] in ("kv_pressure", "prefix_evict")]
    chains = []
    for m in marks:
        if m["name"] != "preempted":
            continue
        uid, ts = m["id"], m["ts"]
        before = [p for p in pressure if p["ts"] <= ts]
        cause = before[-1] if before else None
        readmit = next((a for a in admitted.get(uid, ())
                        if a["ts"] > ts and a["args"].get("readmission")),
                       None)
        finished = any(x["name"] == "finished" and x["id"] == uid
                       and x["ts"] > ts for x in marks)
        chains.append({
            "uid": uid,
            "cause": None if cause is None else
                     {"event": cause["name"], **cause["args"]},
            "readmitted": readmit is not None,
            "finished": finished,
        })

    # -- prefix reuse -------------------------------------------------------
    hits = [m for m in marks if m["name"] == "prefix_hit"]
    evicts = [e for e in instants if e["name"] == "prefix_evict"]
    evict_by_cause: dict = {}
    for e in evicts:
        c = e["args"].get("cause", "unknown")
        evict_by_cause[c] = evict_by_cause.get(c, 0) + 1

    spans = _span_durations(evs)
    n_req = len({e["id"] for e in evs
                 if e.get("ph") in ("b", "e", "n") and e["name"] == "req"})

    return {
        "schema_version": doc["otherData"]["schema_version"],
        "fingerprint": doc["otherData"]["fingerprint"],
        "n_events": len(evs),
        "n_requests": n_req,
        "steps": {
            "n": len(steps),
            "prefill": len(prefill_steps),
            "decode": len(decode_steps),
            "planned_tokens": planned,
            "realized_tokens": realized,
            "budget_utilization": (realized / planned) if planned else
                                  float("nan"),
            "mean_active_slots": (sum(occ) / len(occ)) if occ else
                                 float("nan"),
            "wall_us": {
                "prefill": sum(s["dur"] for s in prefill_steps),
                "decode": sum(s["dur"] for s in decode_steps),
            },
            "kernel_steps": kernels,
        },
        "spans_us": spans,
        "preemptions": {
            "n": len(chains),
            "readmitted": sum(c["readmitted"] for c in chains),
            "chains": chains,
        },
        "prefix": {
            "hits": len(hits),
            "hit_tokens": sum(h["args"].get("cached_len", 0) for h in hits),
            "inserts": sum(e["name"] == "prefix_insert" for e in instants),
            "evictions_by_cause": evict_by_cause,
        },
        "kv_pressure_events": sum(e["name"] == "kv_pressure"
                                  for e in instants),
    }


def _pct(x: float) -> str:
    return "n/a" if x != x else f"{100.0 * x:.1f}%"


def format_summary(s: dict) -> str:
    st = s["steps"]
    lines = [
        f"trace: {s['n_events']} events, {s['n_requests']} requests, "
        f"schema v{s['schema_version']}",
        f"  fingerprint: {s['fingerprint'][:23]}...",
        f"steps: {st['n']} ({st['prefill']} prefill-carrying, "
        f"{st['decode']} pure-decode)",
        f"  step-budget utilization: {_pct(st['budget_utilization'])} "
        f"({st['realized_tokens']}/{st['planned_tokens']} tokens; "
        f"rest is padded batch width)",
        f"  mean active slots: {st['mean_active_slots']:.2f}"
        if st["mean_active_slots"] == st["mean_active_slots"]
        else "  mean active slots: n/a",
        f"  wall time: prefill {st['wall_us']['prefill'] / 1e3:.1f} ms, "
        f"decode {st['wall_us']['decode'] / 1e3:.1f} ms",
    ]
    if st["kernel_steps"]:
        ks = ", ".join(f"{k}: {v}" for k, v in
                       sorted(st["kernel_steps"].items()))
        lines.append(f"  steps by plan kernel: {ks}")
    if s["spans_us"]:
        lines.append("request phases (total across requests):")
        for name in ("queued", "prefill", "decode"):
            if name in s["spans_us"]:
                d = s["spans_us"][name]
                lines.append(f"  {name:8s} {d['total_us'] / 1e3:9.1f} ms "
                             f"across {d['n']} spans")
    pre = s["preemptions"]
    lines.append(f"preemptions: {pre['n']} "
                 f"({pre['readmitted']} later re-admitted); "
                 f"kv-pressure events: {s['kv_pressure_events']}")
    for c in pre["chains"]:
        cause = "no prior pressure event" if c["cause"] is None else \
            ", ".join(f"{k}={v}" for k, v in c["cause"].items())
        fate = "finished" if c["finished"] else "unfinished"
        re = "re-admitted" if c["readmitted"] else "not re-admitted"
        lines.append(f"  req {c['uid']}: cause [{cause}] -> {re}, {fate}")
    px = s["prefix"]
    ev = ", ".join(f"{k}: {v}" for k, v in
                   sorted(px["evictions_by_cause"].items())) or "none"
    lines.append(f"prefix cache: {px['hits']} hits "
                 f"({px['hit_tokens']} cached tokens), "
                 f"{px['inserts']} inserts, evictions by cause: {ev}")
    return "\n".join(lines)


_REQUIRE_CHECKS = {
    "prefill-span": lambda s: s["spans_us"].get("prefill", {}).get("n", 0) > 0,
    "decode-span": lambda s: s["spans_us"].get("decode", {}).get("n", 0) > 0,
    "prefix-hit": lambda s: s["prefix"]["hits"] > 0,
    "preemption": lambda s: s["preemptions"]["n"] > 0,
    "step": lambda s: s["steps"]["n"] > 0,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.timeline",
        description="Summarize an engine observability trace "
                    "(Perfetto trace_event JSON).")
    ap.add_argument("trace", help="path to a --trace-out JSON document")
    ap.add_argument("--json", action="store_true",
                    help="emit the analysis as JSON instead of text")
    ap.add_argument("--require", nargs="+", choices=sorted(_REQUIRE_CHECKS),
                    default=(), metavar="FEATURE",
                    help="exit 1 unless the trace contains these features "
                         f"(choices: {', '.join(sorted(_REQUIRE_CHECKS))})")
    ap.add_argument("--min-step-utilization", type=float, default=None,
                    metavar="FRACTION",
                    help="exit 1 unless step-budget utilization "
                         "(realized/planned over all steps) is >= FRACTION "
                         "— the CI gate keeping the flat token layout's "
                         "padding-waste win from regressing")
    args = ap.parse_args(argv)

    doc = _trace.load(args.trace)
    summary = analyze(doc)

    if args.json:
        print(json.dumps(summary, indent=2, sort_keys=True))
    else:
        print(format_summary(summary))

    missing = [r for r in args.require if not _REQUIRE_CHECKS[r](summary)]
    if missing:
        print(f"MISSING required trace features: {', '.join(missing)}",
              file=sys.stderr)
        return 1
    if args.min_step_utilization is not None:
        util = summary["steps"]["budget_utilization"]
        if util is None or util < args.min_step_utilization:
            print(f"step-budget utilization {util} below required "
                  f"{args.min_step_utilization}", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
