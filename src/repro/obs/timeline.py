"""Timeline analysis over a saved observability trace.

``python -m repro.obs.timeline trace.json`` loads + validates a Perfetto
document written by :class:`repro.obs.trace.EventTracer`;
``python -m repro.obs.timeline trace.jsonl`` stream-parses a rotated
``OBS_TRACE_STREAM`` JSONL file (``StreamingSink`` output) one event at a
time — the analysis is single-pass with O(requests + preemptions) state,
so it never materializes a long run's event list.  Both paths summarize
what the raw event stream actually says about the run:

* **step-budget utilization** — Σ realized / Σ planned tokens across step
  records.  ``planned`` is the padded B×C step width (the rows the jitted
  kernel really multiplies), so ``1 - utilization`` is exactly the padding
  waste the ROADMAP's flat token-packing item targets.  A zero-step trace
  reports ``None`` (JSON null) rather than NaN — and fails a
  ``--min-step-utilization`` gate with a clear message instead of a
  silent pass (``nan < x`` is always False) or a traceback.
* **batch occupancy** — mean active slots per step, against the slot count.
* **per-phase time** — wall time split into prefill-carrying vs pure-decode
  steps (from complete-event durations) plus per-request queued/prefill/
  decode span totals.
* **preemption/eviction causality** — for each ``preempted`` mark: the
  nearest preceding ``kv_pressure`` / ``prefix_evict`` instants (why),
  and whether the victim was later re-admitted or never finished (what
  happened next).
* **prefix reuse** — hit marks with cached token counts, insert/evict
  instants grouped by cause.

``--require`` turns the CLI into a CI smoke gate: exit nonzero unless the
trace contains the named features (used by the bench lane on the
shared-prefix workload).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs import trace as _trace

_PRESSURE_NAMES = ("kv_pressure", "prefix_evict")


class _Accumulator:
    """Single-pass analysis state over a trace-event stream.  Relies only
    on stream order (events are appended as they happen; ``ts`` is
    monotone in emission order), so it works identically over an in-memory
    document and a disk-backed JSONL stream."""

    def __init__(self):
        # steps
        self.n_steps = 0
        self.n_prefill_steps = 0
        self.n_decode_steps = 0
        self.planned = 0
        self.realized = 0
        self.occ_sum = 0
        self.occ_n = 0
        self.wall_prefill = 0.0
        self.wall_decode = 0.0
        self.kernels: dict = {}
        # spans
        self._open_ts: dict = {}
        self._span_totals: dict = {}
        self._span_counts: dict = {}
        # requests + preemption causality
        self.req_ids: set = set()
        self._admitted: dict = {}    # uid -> [(ts, readmission), ...]
        self._finished: dict = {}    # uid -> [ts, ...]
        self._last_pressure = None   # most recent pressure instant
        self._preempts: list = []    # (uid, ts, cause-snapshot) in order
        # prefix reuse + instants
        self.hits = 0
        self.hit_tokens = 0
        self.inserts = 0
        self.evict_by_cause: dict = {}
        self.kv_pressure_events = 0

    def feed(self, e: dict):
        ph = e.get("ph")
        name = e.get("name")
        if ph == "X" and name == "step":
            args = e["args"]
            self.n_steps += 1
            self.planned += args.get("planned", 0)
            self.realized += args.get("realized", 0)
            if "active_slots" in args:
                self.occ_sum += args["active_slots"]
                self.occ_n += 1
            if args.get("prefill_tokens", 0) > 0:
                self.n_prefill_steps += 1
                self.wall_prefill += e["dur"]
            else:
                self.n_decode_steps += 1
                self.wall_decode += e["dur"]
            k = args.get("kernel")
            if k is not None:
                self.kernels[k] = self.kernels.get(k, 0) + 1
        elif ph in ("b", "e"):
            if name == "req":
                self.req_ids.add(e["id"])
            key = (e.get("id"), name)
            if ph == "b":
                self._open_ts[key] = e["ts"]
            elif key in self._open_ts:
                self._span_totals[name] = self._span_totals.get(name, 0.0) \
                    + (e["ts"] - self._open_ts.pop(key))
                self._span_counts[name] = self._span_counts.get(name, 0) + 1
        elif ph == "n":
            if name == "req":
                self.req_ids.add(e["id"])
            if name == "admitted":
                self._admitted.setdefault(e["id"], []).append(
                    (e["ts"], bool(e["args"].get("readmission"))))
            elif name == "finished":
                self._finished.setdefault(e["id"], []).append(e["ts"])
            elif name == "preempted":
                p = self._last_pressure
                cause = None
                if p is not None and p["ts"] <= e["ts"]:
                    cause = {"event": p["name"], **p["args"]}
                self._preempts.append((e["id"], e["ts"], cause))
            elif name == "prefix_hit":
                self.hits += 1
                self.hit_tokens += e["args"].get("cached_len", 0)
        elif ph == "i":
            if name in _PRESSURE_NAMES:
                self._last_pressure = e
            if name == "kv_pressure":
                self.kv_pressure_events += 1
            elif name == "prefix_evict":
                c = e["args"].get("cause", "unknown")
                self.evict_by_cause[c] = self.evict_by_cause.get(c, 0) + 1
            elif name == "prefix_insert":
                self.inserts += 1

    def summary(self) -> dict:
        chains = []
        for uid, ts, cause in self._preempts:
            readmit = any(a_ts > ts and re_adm
                          for a_ts, re_adm in self._admitted.get(uid, ()))
            finished = any(f > ts for f in self._finished.get(uid, ()))
            chains.append({"uid": uid, "cause": cause,
                           "readmitted": readmit, "finished": finished})
        spans = {name: {"total_us": self._span_totals[name],
                        "n": self._span_counts[name]}
                 for name in self._span_totals}
        return {
            "n_requests": len(self.req_ids),
            "steps": {
                "n": self.n_steps,
                "prefill": self.n_prefill_steps,
                "decode": self.n_decode_steps,
                "planned_tokens": self.planned,
                "realized_tokens": self.realized,
                # None (JSON null), not NaN: a zero-step trace must be
                # distinguishable in strict JSON and must not silently
                # pass a numeric gate.
                "budget_utilization": (self.realized / self.planned)
                                      if self.planned else None,
                "mean_active_slots": (self.occ_sum / self.occ_n)
                                     if self.occ_n else None,
                "wall_us": {
                    "prefill": self.wall_prefill,
                    "decode": self.wall_decode,
                },
                "kernel_steps": self.kernels,
            },
            "spans_us": spans,
            "preemptions": {
                "n": len(chains),
                "readmitted": sum(c["readmitted"] for c in chains),
                "chains": chains,
            },
            "prefix": {
                "hits": self.hits,
                "hit_tokens": self.hit_tokens,
                "inserts": self.inserts,
                "evictions_by_cause": self.evict_by_cause,
            },
            "kv_pressure_events": self.kv_pressure_events,
        }


def analyze_events(events) -> dict:
    """Pure single-pass analysis over an event iterable (document list or
    stream reader) — everything but the provenance fields."""
    acc = _Accumulator()
    for e in events:
        acc.feed(e)
    return acc.summary()


def analyze(doc: dict) -> dict:
    """Perfetto document -> summary dict (JSON-safe)."""
    evs = doc["traceEvents"]
    out = analyze_events(evs)
    out["schema_version"] = doc["otherData"]["schema_version"]
    out["fingerprint"] = doc["otherData"]["fingerprint"]
    out["n_events"] = len(evs)
    return out


def analyze_stream(reader) -> dict:
    """JSONL stream (path or :class:`repro.obs.trace.StreamReader`) ->
    the same summary :func:`analyze` produces for the equivalent document,
    plus a ``stream`` provenance block — without ever holding the event
    list in memory."""
    if isinstance(reader, str):
        reader = _trace.StreamReader(reader)
    out = analyze_events(iter(reader))
    out["schema_version"] = reader.header["schema_version"]
    out["fingerprint"] = reader.fingerprint
    out["n_events"] = reader.n_events
    out["stream"] = {"complete": reader.complete,
                     "segments": (reader.footer or {}).get("segments")}
    return out


def _pct(x) -> str:
    return "n/a" if x is None or x != x else f"{100.0 * x:.1f}%"


def format_summary(s: dict) -> str:
    st = s["steps"]
    lines = [
        f"trace: {s['n_events']} events, {s['n_requests']} requests, "
        f"schema v{s['schema_version']}",
        f"  fingerprint: {s['fingerprint'][:23]}...",
        f"steps: {st['n']} ({st['prefill']} prefill-carrying, "
        f"{st['decode']} pure-decode)",
        f"  step-budget utilization: {_pct(st['budget_utilization'])} "
        f"({st['realized_tokens']}/{st['planned_tokens']} tokens; "
        f"rest is padded batch width)",
        f"  mean active slots: {st['mean_active_slots']:.2f}"
        if st["mean_active_slots"] is not None
        else "  mean active slots: n/a",
        f"  wall time: prefill {st['wall_us']['prefill'] / 1e3:.1f} ms, "
        f"decode {st['wall_us']['decode'] / 1e3:.1f} ms",
    ]
    if s.get("stream"):
        state = "complete" if s["stream"]["complete"] else \
            "INCOMPLETE (no final footer — writer died mid-run?)"
        lines.insert(1, f"  stream: {state}, "
                        f"{s['stream'].get('segments') or '?'} segment(s)")
    if st["kernel_steps"]:
        ks = ", ".join(f"{k}: {v}" for k, v in
                       sorted(st["kernel_steps"].items()))
        lines.append(f"  steps by plan kernel: {ks}")
    if s["spans_us"]:
        lines.append("request phases (total across requests):")
        for name in ("queued", "prefill", "decode"):
            if name in s["spans_us"]:
                d = s["spans_us"][name]
                lines.append(f"  {name:8s} {d['total_us'] / 1e3:9.1f} ms "
                             f"across {d['n']} spans")
    pre = s["preemptions"]
    lines.append(f"preemptions: {pre['n']} "
                 f"({pre['readmitted']} later re-admitted); "
                 f"kv-pressure events: {s['kv_pressure_events']}")
    for c in pre["chains"]:
        cause = "no prior pressure event" if c["cause"] is None else \
            ", ".join(f"{k}={v}" for k, v in c["cause"].items())
        fate = "finished" if c["finished"] else "unfinished"
        re = "re-admitted" if c["readmitted"] else "not re-admitted"
        lines.append(f"  req {c['uid']}: cause [{cause}] -> {re}, {fate}")
    px = s["prefix"]
    ev = ", ".join(f"{k}: {v}" for k, v in
                   sorted(px["evictions_by_cause"].items())) or "none"
    lines.append(f"prefix cache: {px['hits']} hits "
                 f"({px['hit_tokens']} cached tokens), "
                 f"{px['inserts']} inserts, evictions by cause: {ev}")
    return "\n".join(lines)


_REQUIRE_CHECKS = {
    "prefill-span": lambda s: s["spans_us"].get("prefill", {}).get("n", 0) > 0,
    "decode-span": lambda s: s["spans_us"].get("decode", {}).get("n", 0) > 0,
    "prefix-hit": lambda s: s["prefix"]["hits"] > 0,
    "preemption": lambda s: s["preemptions"]["n"] > 0,
    "step": lambda s: s["steps"]["n"] > 0,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.timeline",
        description="Summarize an engine observability trace "
                    "(Perfetto trace_event JSON document, or a "
                    "StreamingSink JSONL stream).")
    ap.add_argument("trace", help="path to a --trace-out JSON document or "
                                  "a --trace-stream JSONL stream")
    ap.add_argument("--json", action="store_true",
                    help="emit the analysis as JSON instead of text")
    ap.add_argument("--require", nargs="+", choices=sorted(_REQUIRE_CHECKS),
                    default=(), metavar="FEATURE",
                    help="exit 1 unless the trace contains these features "
                         f"(choices: {', '.join(sorted(_REQUIRE_CHECKS))})")
    ap.add_argument("--min-step-utilization", type=float, default=None,
                    metavar="FRACTION",
                    help="exit 1 unless step-budget utilization "
                         "(realized/planned over all steps) is >= FRACTION "
                         "— the CI gate keeping the flat token layout's "
                         "padding-waste win from regressing")
    args = ap.parse_args(argv)

    kind, obj = _trace.load_any(args.trace)
    summary = analyze_stream(obj) if kind == "stream" else analyze(obj)

    if args.json:
        print(json.dumps(summary, indent=2, sort_keys=True))
    else:
        print(format_summary(summary))

    missing = [r for r in args.require if not _REQUIRE_CHECKS[r](summary)]
    if missing:
        print(f"MISSING required trace features: {', '.join(missing)}",
              file=sys.stderr)
        return 1
    if args.min_step_utilization is not None:
        util = summary["steps"]["budget_utilization"]
        if util is None:
            print("trace contains no step records (planned tokens == 0): "
                  "cannot evaluate --min-step-utilization "
                  f"{args.min_step_utilization}", file=sys.stderr)
            return 1
        if util < args.min_step_utilization:
            print(f"step-budget utilization {util} below required "
                  f"{args.min_step_utilization}", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
