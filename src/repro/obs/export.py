"""Metrics export surface: Prometheus text exposition over the typed
registry, a stdlib scrape endpoint, and a textfile writer.

:func:`render` turns a :class:`~repro.obs.metrics.MetricsRegistry` into
Prometheus text-exposition format (version 0.0.4).  The mapping keeps an
exact correspondence with ``registry.snapshot()`` so a scrape can be
checked against the in-process snapshot sample-for-sample:

* **counters** — one sample per (family child), value verbatim.  No
  ``_total`` suffix is appended: the registry names are the contract the
  snapshot/baseline machinery already pins, and renaming on export would
  break the snapshot == scrape identity the tests assert.
* **gauges** — the live value, plus a second ``<name>_peak`` gauge for the
  tracked peak (mirroring the ``<name>_peak`` snapshot key).
* **histograms** — exact observations rendered as cumulative
  ``<name>_bucket{le="..."}`` samples over
  :data:`~repro.obs.metrics.DEFAULT_BUCKETS` plus ``+Inf``, with
  ``<name>_sum`` / ``<name>_count``, and — because the registry keeps raw
  observations, not buckets — *exact* quantiles as
  ``<name>_quantile{quantile="0.5|0.9|0.99"}`` plus ``<name>_mean`` /
  ``<name>_max`` gauges matching the summary dict.

:class:`MetricsServer` serves ``/metrics`` (text) and ``/metrics.json``
(the raw snapshot) from a daemon-threaded stdlib ``http.server`` — no
dependency on a Prometheus client library, per the no-new-deps rule.
:class:`TextfileWriter` atomically rewrites a ``.prom`` file on an
interval for scrape-less environments (node-exporter textfile collector
style).  Both read the registry live; metric mutation is single-threaded
(the engine loop) and reads take list-copies, so a scrape mid-step sees a
consistent-enough view without locks.
"""
from __future__ import annotations

import json
import math
import os
import threading

from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.obs.metrics import DEFAULT_BUCKETS

NAMESPACE = "tsar"

_QUANTILES = (("0.5", 50), ("0.9", 90), ("0.99", 99))

_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def _fmt(v) -> str:
    """Prometheus sample-value formatting: ints verbatim, floats via
    ``repr`` (shortest round-trip), infinities as +Inf/-Inf."""
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    f = float(v)
    if math.isnan(f):
        return "NaN"
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    return repr(f)


def _escape(v) -> str:
    return str(v).replace("\\", r"\\").replace("\n", r"\n").replace('"', r'\"')


def _sample(name: str, labels: dict, value) -> str:
    if labels:
        lbl = ",".join(f'{k}="{_escape(v)}"' for k, v in labels.items())
        return f"{name}{{{lbl}}} {_fmt(value)}"
    return f"{name} {_fmt(value)}"


def _help_line(name: str, help_text: str) -> str:
    text = (help_text or name).replace("\\", r"\\").replace("\n", " ")
    return f"# HELP {name} {text}"


def render(registry, namespace: str = NAMESPACE,
           buckets: tuple = DEFAULT_BUCKETS) -> str:
    """Registry -> Prometheus text exposition (see module docstring for
    the sample mapping)."""
    lines: list = []

    def emit(name, kind, help_text, samples):
        lines.append(_help_line(name, help_text))
        lines.append(f"# TYPE {name} {kind}")
        lines.extend(samples)

    for name, m in registry.metrics().items():
        full = f"{namespace}_{name}" if namespace else name
        children = m.items() if hasattr(m, "items") else [({}, m)]
        if m.kind == "counter":
            emit(full, "counter", m.help,
                 [_sample(full, lb, c.value) for lb, c in children])
        elif m.kind == "gauge":
            emit(full, "gauge", m.help,
                 [_sample(full, lb, c.value) for lb, c in children])
            emit(f"{full}_peak", "gauge", f"peak of {name}",
                 [_sample(f"{full}_peak", lb, c.peak) for lb, c in children])
        elif m.kind == "histogram":
            hist_samples: list = []
            gauge_specs = [("_mean", "mean"), ("_max", "max")]
            extra: dict = {suffix: [] for suffix, _ in gauge_specs}
            quantile_samples: list = []
            for lb, c in children:
                s = c.summary()
                for le, n in c.cumulative_buckets(buckets):
                    le_s = "+Inf" if math.isinf(le) else _fmt(le)
                    hist_samples.append(
                        _sample(f"{full}_bucket", {**lb, "le": le_s}, n))
                hist_samples.append(_sample(f"{full}_sum", lb, c.sum))
                hist_samples.append(_sample(f"{full}_count", lb, c.count))
                for q, p in _QUANTILES:
                    quantile_samples.append(
                        _sample(f"{full}_quantile", {**lb, "quantile": q},
                                s[f"p{p}"]))
                for suffix, key in gauge_specs:
                    extra[suffix].append(_sample(f"{full}{suffix}", lb, s[key]))
            emit(full, "histogram", m.help, hist_samples)
            emit(f"{full}_quantile", "gauge",
                 f"exact quantiles of {name}", quantile_samples)
            for suffix, key in gauge_specs:
                emit(f"{full}{suffix}", "gauge", f"{key} of {name}",
                     extra[suffix])
    return "\n".join(lines) + "\n"


def parse_samples(text: str) -> dict:
    """Exposition text -> ``{'name{label=\"v\"}' : float}`` — the inverse
    of :func:`render` at sample granularity, for tests that assert a
    scrape matches ``registry.snapshot()``."""
    out: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        key, _, val = line.rpartition(" ")
        out[key] = float(val)
    return out


# ---------------------------------------------------------------------------
# scrape endpoint + textfile writer
# ---------------------------------------------------------------------------

class _Handler(BaseHTTPRequestHandler):
    registry = None
    namespace = NAMESPACE

    def do_GET(self):
        path = self.path.split("?", 1)[0]
        if path in ("/", "/metrics"):
            body = render(self.registry, self.namespace).encode("utf-8")
            ctype = _CONTENT_TYPE
        elif path == "/metrics.json":
            body = json.dumps(self.registry.snapshot(),
                              sort_keys=True).encode("utf-8")
            ctype = "application/json"
        else:
            self.send_error(404, "try /metrics or /metrics.json")
            return
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, fmt, *args):
        pass    # scrapes must not spam the engine's stdout


class MetricsServer:
    """Daemon-threaded scrape endpoint over a live registry.

    ``port=0`` binds an ephemeral port (``.port`` reports the real one) —
    what tests use; ``launch/serve.py --metrics-port`` passes a fixed one.
    """

    def __init__(self, registry, *, port: int = 0, host: str = "127.0.0.1",
                 namespace: str = NAMESPACE):
        handler = type("BoundMetricsHandler", (_Handler,),
                       {"registry": registry, "namespace": namespace})
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self.host = self._httpd.server_address[0]
        self.port = self._httpd.server_address[1]
        self._thread: threading.Thread | None = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def start(self):
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="tsar-metrics",
            daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()


def start_server(registry, port: int = 0, **kw) -> MetricsServer:
    """Convenience: construct + start a :class:`MetricsServer`."""
    return MetricsServer(registry, port=port, **kw).start()


class TextfileWriter:
    """Periodically render the registry into a textfile (atomic
    tmp + ``os.replace``) for scrape-less environments.  ``write_once``
    is the synchronous core; ``start()`` spins a daemon thread that
    rewrites every ``interval_s`` and ``stop()`` joins it after one final
    write, so the file always ends at the run's last state."""

    def __init__(self, registry, path: str, *, interval_s: float = 5.0,
                 namespace: str = NAMESPACE):
        self.registry = registry
        self.path = str(path)
        self.interval_s = float(interval_s)
        self.namespace = namespace
        self.n_writes = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def write_once(self) -> str:
        tmp = f"{self.path}.tmp"
        with open(tmp, "w") as f:
            f.write(render(self.registry, self.namespace))
        os.replace(tmp, self.path)
        self.n_writes += 1
        return self.path

    def _loop(self):
        while not self._stop.wait(self.interval_s):
            self.write_once()

    def start(self):
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._loop, name="tsar-metrics-textfile", daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
        self.write_once()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
