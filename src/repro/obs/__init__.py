"""Engine observability: typed metrics registry, structured event tracing,
and Perfetto-exportable timelines.

Three modules, layered bottom-up:

* ``metrics``  — :class:`MetricsRegistry`: Counter/Gauge/Histogram with
  labels, the single owner of engine telemetry.  ``ServingEngine.stats``
  is a backward-compatible :class:`StatsView` over it.
* ``trace``    — :class:`EventTracer`: low-overhead per-request lifecycle
  spans + per-step records, exported as Chrome/Perfetto ``trace_event``
  JSON (schema-versioned, structure-fingerprinted).  ``NULL_TRACER`` is
  the no-op recorder the engine runs with by default.
* ``timeline`` — analysis CLI over a saved trace
  (``python -m repro.obs.timeline trace.json``): step-budget utilization,
  batch occupancy, preemption/eviction causality, per-phase breakdown.

See docs/observability.md for the event taxonomy and workflow.
"""
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry, StatsView
from repro.obs.trace import NULL_TRACER, EventTracer, NullTracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "StatsView",
    "EventTracer", "NullTracer", "NULL_TRACER",
]
