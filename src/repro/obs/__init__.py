"""Engine observability: typed metrics registry, structured event tracing
with pluggable sinks, incident snapshots, a metrics export surface, and
Perfetto-exportable timelines.

Five modules, layered bottom-up:

* ``metrics``  — :class:`MetricsRegistry`: Counter/Gauge/Histogram with
  labels, the single owner of engine telemetry.  ``ServingEngine.stats``
  is a backward-compatible :class:`StatsView` over it.
* ``trace``    — :class:`EventTracer`: low-overhead per-request lifecycle
  spans + per-step records, exported as Chrome/Perfetto ``trace_event``
  JSON (schema-versioned, structure-fingerprinted).  Events flow into a
  pluggable sink: :class:`MemorySink` (export whole), :class:`StreamingSink`
  (bounded-memory JSONL to disk with rotation), :class:`RingSink`
  (always-on flight recorder), :class:`TeeSink` (fan-out).  ``NULL_TRACER``
  is the no-op recorder the engine runs with by default.
* ``incident`` — :class:`IncidentMonitor`: trigger-driven snapshots (SLO
  breach, preemption, rejection, kv pressure, eviction storm) dumping the
  flight-recorder ring + a metrics snapshot into schema-versioned files.
* ``export``   — Prometheus text exposition over the registry, behind a
  stdlib scrape endpoint (:class:`MetricsServer`) or a periodic
  :class:`TextfileWriter`.
* ``timeline`` — analysis CLI over a saved trace — whole document or JSONL
  stream (``python -m repro.obs.timeline trace.json|trace.jsonl``):
  step-budget utilization, batch occupancy, preemption/eviction causality,
  per-phase breakdown.

See docs/observability.md for the event taxonomy and workflow.
"""
from repro.obs.export import MetricsServer, TextfileWriter, start_server
from repro.obs.incident import IncidentMonitor
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry, StatsView
from repro.obs.trace import (NULL_TRACER, EventTracer, MemorySink, NullTracer,
                             RingSink, StreamingSink, TeeSink)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "StatsView",
    "EventTracer", "NullTracer", "NULL_TRACER",
    "MemorySink", "StreamingSink", "RingSink", "TeeSink",
    "IncidentMonitor", "MetricsServer", "TextfileWriter", "start_server",
]
