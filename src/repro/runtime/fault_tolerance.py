"""Fault tolerance + elasticity primitives for long multi-pod runs.

At 1000+ nodes the design assumptions are: (i) some host WILL fail during any
multi-day run, (ii) stragglers are common (shared fabric, background daemons),
(iii) capacity changes — you lose a pod and must keep training on what's left.
The corresponding mechanisms here:

* ``run_with_restarts`` — supervision loop: the train driver body is a
  function of (state, start_step); on failure the loop restores the latest
  checkpoint and re-enters.  Combined with checkpoint/restore's resharding
  this covers both restart-in-place and restart-on-fewer-pods (elastic).
* ``StepMonitor`` — per-step wall-time tracker with straggler detection
  (step > factor x rolling median flags it; at scale this signal feeds the
  scheduler to evict slow hosts; here it triggers logging + is unit-tested).
* ``Heartbeat`` — liveness file another process can watch (the k8s/Borg
  pattern); missed deadline = assume dead, trigger restart.
* ``elastic_remesh_plan`` — given remaining device count, choose the largest
  valid (data, model) submesh that keeps TP intact (shrink DP first: model
  shards must stay complete, data replicas are fungible).
"""
from __future__ import annotations

import json
import os
import time
from collections import deque
from dataclasses import dataclass


class StepMonitor:
    def __init__(self, window: int = 32, straggler_factor: float = 2.5):
        self.times = deque(maxlen=window)
        self.factor = straggler_factor
        self.straggler_steps: list[int] = []
        self._t0 = None
        self._step = 0

    def start(self, step: int):
        self._step = step
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        dt = time.perf_counter() - self._t0
        is_straggler = False
        if len(self.times) >= 8:
            med = sorted(self.times)[len(self.times) // 2]
            if dt > self.factor * med:
                is_straggler = True
                self.straggler_steps.append(self._step)
        self.times.append(dt)
        return dt if not is_straggler else dt

    def is_straggler(self, dt: float) -> bool:
        if len(self.times) < 8:
            return False
        med = sorted(self.times)[len(self.times) // 2]
        return dt > self.factor * med

    def median(self) -> float:
        if not self.times:
            return 0.0
        return sorted(self.times)[len(self.times) // 2]


class Heartbeat:
    def __init__(self, path: str, interval_s: float = 10.0):
        self.path = path
        self.interval = interval_s
        self._last = 0.0

    def beat(self, step: int):
        now = time.time()
        if now - self._last >= self.interval:
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"step": step, "time": now}, f)
            os.replace(tmp, self.path)
            self._last = now

    @staticmethod
    def is_alive(path: str, deadline_s: float = 60.0) -> bool:
        try:
            with open(path) as f:
                beat = json.load(f)
            return (time.time() - beat["time"]) < deadline_s
        except (OSError, ValueError):
            return False


@dataclass
class RestartReport:
    restarts: int
    completed: bool
    last_step: int
    failures: list


def run_with_restarts(body, *, restore_fn, max_restarts: int = 3) -> RestartReport:
    """Supervision loop.

    ``body(state, start_step) -> final_step`` runs the training segment and
    may raise; ``restore_fn() -> (state, step)`` reloads the latest
    checkpoint.  Used directly by launch/train.py and by the fault-injection
    tests (which raise at a chosen step to simulate a node loss).
    """
    failures = []
    restarts = 0
    state, step = restore_fn()
    while True:
        try:
            final = body(state, step)
            return RestartReport(restarts, True, final, failures)
        except Exception as e:  # noqa: BLE001 — any worker failure
            failures.append(repr(e))
            restarts += 1
            if restarts > max_restarts:
                return RestartReport(restarts, False, step, failures)
            state, step = restore_fn()


def elastic_remesh_plan(n_devices: int, model_parallel: int) -> tuple[int, int]:
    """Largest (data, model) mesh on ``n_devices`` keeping TP width intact.

    TP shards hold complementary weight slices — a partial model group is
    useless — so shrink data parallelism first.  Returns (data, model).
    """
    if n_devices < model_parallel:
        raise ValueError(
            f"cannot keep TP={model_parallel} with {n_devices} devices; "
            "re-plan with smaller model parallelism")
    data = n_devices // model_parallel
    return data, model_parallel
