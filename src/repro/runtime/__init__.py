from repro.runtime.fault_tolerance import (  # noqa: F401
    Heartbeat, RestartReport, StepMonitor, elastic_remesh_plan,
    run_with_restarts,
)
