"""Pure-JAX optimizers (optax is not available offline): AdamW + schedules.

``(init, update)`` pairs over arbitrary param pytrees, with global-norm
clipping and decoupled weight decay.  Designed for pjit: the optimizer state
mirrors the param sharding (same tree structure, same PartitionSpecs).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # 'bfloat16' halves optimizer-state HBM (needed to fit 400B-class train
    # state on a single 256-chip pod); moments are accumulated in f32 then
    # stored compressed.
    moment_dtype: str = "float32"


class AdamWState(NamedTuple):
    mu: dict
    nu: dict
    count: jax.Array


def cosine_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    progress = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(math.pi * progress))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, tree), norm


def adamw_init(params, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros_like(x, moment_dtype), t)
    return AdamWState(mu=zeros(params), nu=zeros(params), count=jnp.zeros((), jnp.int32))


def adamw_update(cfg: OptConfig, grads, state: AdamWState, params):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state.count + 1
    b1, b2 = cfg.betas
    lr = cosine_schedule(cfg, count)
    mdtype = jnp.dtype(cfg.moment_dtype)

    mu = jax.tree.map(
        lambda m, g: (b1 * m.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)).astype(mdtype),
        state.mu, grads)
    nu = jax.tree.map(
        lambda v, g: (b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(mdtype),
        state.nu, grads)
    c = count.astype(jnp.float32)
    mu_hat = jax.tree.map(lambda m: m.astype(jnp.float32) / (1 - b1 ** c), mu)
    nu_hat = jax.tree.map(lambda v: v.astype(jnp.float32) / (1 - b2 ** c), nu)

    def upd(p, m, v):
        step = m / (jnp.sqrt(v) + cfg.eps)
        # Decoupled weight decay on matrices only (ndim >= 2).
        wd = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        return (p.astype(jnp.float32) - lr * (step + wd)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu_hat, nu_hat)
    return new_params, AdamWState(mu=mu, nu=nu, count=count), {"grad_norm": gnorm, "lr": lr}
