from repro.optim.optimizer import (  # noqa: F401
    AdamWState, OptConfig, adamw_init, adamw_update, cosine_schedule,
    global_norm,
)
from repro.optim import compression  # noqa: F401
