"""Int8 error-feedback gradient compression for the data-parallel all-reduce.

Distributed-optimization trick for the 1000+-node regime: the DP gradient
all-reduce (which crosses the slow 'pod' DCN axis in multi-pod meshes) is the
dominant cross-pod collective.  A psum of int32 would not save wire bytes, so
the all-reduce is decomposed explicitly:

    reduce-scatter phase:  all_to_all of int8 chunks   (1 byte/elem on wire)
    local reduction:       dequant + f32 sum
    all-gather phase:      bf16 re-broadcast           (2 bytes/elem on wire)

Total wire traffic ~= 3 bytes/elem vs 8 for a f32 ring all-reduce (2.7x), or
vs 4 for bf16 (1.3x) — with the int8 quantization error carried in a
per-shard error-feedback buffer (EF-SGD) so convergence is preserved.  The
buffer lives in the optimizer state, sharded like params.

Used inside a ``shard_map`` train step over the DP axis; see
repro.train.train_loop.make_compressed_dp_train_step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_leaf(g: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(g + err) -> (int8 q, scale, new_err)."""
    target = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(target)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
    new_err = target - q.astype(jnp.float32) * scale
    return q, scale, new_err


def _allreduce_int8(q: jax.Array, scale: jax.Array, axis: str) -> jax.Array:
    """Mean over the axis via int8 reduce-scatter + bf16 all-gather.

    Returns the dequantized mean (f32), same shape as q.
    """
    # psum of a static 1 folds to the concrete axis size (works on jax
    # versions without jax.lax.axis_size, and stays a Python int so the
    # reshape below keeps static shapes).
    n = jax.lax.psum(1, axis)
    flat = q.reshape(-1)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)                                  # (N, C)
    # reduce-scatter phase: all_to_all moves int8 on the wire; afterwards this
    # device holds everyone's copy of its chunk: (N, C).
    recv = jax.lax.all_to_all(chunks, axis, split_axis=0, concat_axis=0, tiled=True)
    recv = recv.reshape(n, -1)
    scales = jax.lax.all_gather(scale, axis)                      # (N,) f32 scalars
    summed = jnp.sum(recv.astype(jnp.float32) * scales[:, None], axis=0) / n
    # all-gather phase in bf16.
    gathered = jax.lax.all_gather(summed.astype(jnp.bfloat16), axis, tiled=True)
    out = gathered.astype(jnp.float32)[: q.size]
    return out.reshape(q.shape)


def psum_compressed(grads, err_buf, axis: str) -> tuple[dict, dict]:
    """Compressed mean-all-reduce over the named DP axis (inside shard_map)."""
    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_buf)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        if g.size < 1024:  # tiny leaves: plain f32 psum, not worth compressing
            out_g.append(jax.lax.pmean(g, axis))
            out_e.append(e)
            continue
        q, scale, new_err = compress_leaf(g, e)
        g_hat = _allreduce_int8(q, scale, axis)
        out_g.append(g_hat.astype(g.dtype))
        out_e.append(new_err)
    return jax.tree.unflatten(tree, out_g), jax.tree.unflatten(tree, out_e)


def init_error_buffer(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
