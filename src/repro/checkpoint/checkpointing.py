"""Sharded checkpointing with cross-mesh resharding restore (no orbax offline).

Format: one directory per step, ``step_<N>/``:
  * ``manifest.json`` — tree structure, per-leaf shape/dtype, step, and the
    PartitionSpec each leaf was saved under (informational; restore reshapes
    to ANY target sharding).
  * ``arrays.npz`` — the global (unsharded) arrays, addressed by flat key.

Writes are atomic (tmp dir + rename) and optionally asynchronous (background
thread; ``wait()`` joins).  ``latest_step``/GC give restart-on-failure
semantics; restore accepts a different mesh than the one saved from —
elastic restart is just restore-with-new-shardings (tested in
tests/test_fault_tolerance.py).

At true multi-pod scale this module's npz writer would be swapped for a
parallel object-store writer per host; the manifest/reshard logic is the part
that carries over unchanged.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict[str, Any]:
    flat = {}

    def visit(path, node):
        if isinstance(node, dict):
            for k, v in node.items():
                visit(path + [str(k)], v)
        elif isinstance(node, (tuple, list)) and not hasattr(node, "_fields"):
            for i, v in enumerate(node):
                visit(path + [f"#{i}"], v)
        elif hasattr(node, "_fields"):  # NamedTuple
            for k in node._fields:
                v = getattr(node, k)
                if v is not None:
                    visit(path + [k], v)
        elif node is None:
            pass
        else:
            flat[_SEP.join(path)] = node

    visit([], tree)
    return flat


def save(ckpt_dir: str, step: int, tree, keep: int = 3, async_save: bool = False):
    """Checkpoint ``tree`` at ``step``.  Returns a handle with .wait()."""
    flat = _flatten(tree)
    # device_get BEFORE the background thread: grab a consistent snapshot.
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in host.items()},
    }

    def _write():
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    if async_save:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return _Handle(t)
    _write()
    return _Handle(None)


class _Handle:
    def __init__(self, thread):
        self._t = thread

    def wait(self):
        if self._t is not None:
            self._t.join()


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_"):
            # Only completed (renamed) checkpoints count.
            if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
                out.append(int(d.split("_", 1)[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, target_tree, shardings=None):
    """Restore into the structure of ``target_tree``.

    ``shardings``: optional pytree of NamedShardings (same structure) — this
    is where cross-mesh elastic resharding happens: the saved global array is
    simply device_put with the NEW sharding.
    """
    path = os.path.join(ckpt_dir, f"step_{step}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        host = {k: z[k] for k in z.files}

    flat_target = _flatten(target_tree)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    missing = set(flat_target) - set(host)
    if missing:
        raise ValueError(f"checkpoint at {path} missing leaves: {sorted(missing)[:5]}...")

    restored = {}
    for k, tgt in flat_target.items():
        arr = host[k]
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(f"shape mismatch for {k}: ckpt {arr.shape} vs target {tgt.shape}")
        arr = arr.astype(tgt.dtype)
        if k in flat_shard:
            restored[k] = jax.device_put(arr, flat_shard[k])
        else:
            restored[k] = jnp.asarray(arr)
    return _unflatten_like(target_tree, restored)


def _unflatten_like(tree, flat: dict, path=()):
    if isinstance(tree, dict):
        return {k: _unflatten_like(v, flat, path + (str(k),)) for k, v in tree.items()}
    if hasattr(tree, "_fields"):
        vals = {}
        for k in tree._fields:
            v = getattr(tree, k)
            vals[k] = None if v is None else _unflatten_like(v, flat, path + (k,))
        return type(tree)(**vals)
    if isinstance(tree, (tuple, list)):
        return type(tree)(
            _unflatten_like(v, flat, path + (f"#{i}",)) for i, v in enumerate(tree)
        )
    if tree is None:
        return None
    return flat[_SEP.join(path)]
