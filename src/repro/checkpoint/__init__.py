from repro.checkpoint.checkpointing import (  # noqa: F401
    all_steps, latest_step, restore, save,
)
