"""Encoder-decoder backbone (whisper-tiny family).

Audio conv frontend is a STUB per the assignment: the model consumes
precomputed frame embeddings (B, enc_seq, d_model) from ``input_specs()``.
Encoder = non-causal self-attention stack; decoder = causal self-attention +
cross-attention + MLP.  Decode caches: self-attn KV (ring of max_len) plus
cross-attn KV precomputed once at prefill from the encoder output.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers


def _init_enc_block(key, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": layers.init_rmsnorm(cfg.d_model),
        "attn": layers.init_attention(k1, cfg),
        "ln2": layers.init_rmsnorm(cfg.d_model),
        "mlp": layers.init_mlp(k2, cfg),
    }


def _init_dec_block(key, cfg) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": layers.init_rmsnorm(cfg.d_model),
        "attn": layers.init_attention(k1, cfg),
        "ln_x": layers.init_rmsnorm(cfg.d_model),
        "xattn": layers.init_attention(k2, cfg, cross=True),
        "ln2": layers.init_rmsnorm(cfg.d_model),
        "mlp": layers.init_mlp(k3, cfg),
    }


def init_params(cfg, key) -> dict:
    ke, kd, kemb = jax.random.split(key, 3)
    enc = [_init_enc_block(k, cfg) for k in jax.random.split(ke, cfg.n_enc_layers)]
    dec = [_init_dec_block(k, cfg) for k in jax.random.split(kd, cfg.n_layers)]
    return {
        "enc_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "embed": jax.random.normal(kemb, (cfg.padded_vocab, cfg.d_model), jnp.float32) * 0.02,
        "ln_enc": layers.init_rmsnorm(cfg.d_model),
        "ln_f": layers.init_rmsnorm(cfg.d_model),
    }


def encode(cfg, params, frames: jax.Array, train: bool = True) -> jax.Array:
    """frames (B, T, D) stub embeddings -> encoder states (B, T, D)."""
    x = frames.astype(jnp.float32)
    pos = jnp.arange(x.shape[1])

    def body(x, p):
        h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
        a, _ = layers.attention(cfg, p["attn"], h, pos=pos, is_global=True,
                                causal=False, train=train)
        x = x + a
        h = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
        return x + layers.mlp(p["mlp"], h, train), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layers.rmsnorm(params["ln_enc"], x, cfg.norm_eps)


def _dec_block(cfg, p, x, enc_out, *, pos, train, mode, cache=None, cache_len=None):
    new_cache: dict = {}
    h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if mode == "decode":
        a, ac = layers.attention(cfg, p["attn"], h, pos=pos, is_global=True,
                                 cache={"k": cache["k"], "v": cache["v"]},
                                 cache_len=cache_len, train=train)
        new_cache.update(ac)
    elif mode == "prefill":
        a, (k, v) = layers.attention(cfg, p["attn"], h, pos=pos, is_global=True,
                                     train=train, return_kv=True)
        new_cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        new_cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
    else:
        a, _ = layers.attention(cfg, p["attn"], h, pos=pos, is_global=True, train=train)
    x = x + a

    h = layers.rmsnorm(p["ln_x"], x, cfg.norm_eps)
    if mode == "decode":
        # Cross-KV was computed at prefill; attend directly (no update).
        xa = _cross_from_cache(cfg, p["xattn"], h, cache["xk"], cache["xv"], train)
        new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]
    else:
        xa, (xk, xv) = layers.attention(cfg, p["xattn"], h, pos=pos, is_global=True,
                                        kv_x=enc_out, causal=False, train=train,
                                        return_kv=True)
        if mode == "prefill":
            new_cache["xk"], new_cache["xv"] = (
                xk.astype(cache["xk"].dtype), xv.astype(cache["xv"].dtype))
    x = x + xa

    h = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + layers.mlp(p["mlp"], h, train), (new_cache or None)


def _cross_from_cache(cfg, p, x, xk, xv, train):
    """Cross-attention against precomputed encoder K/V."""
    h_, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h_ // hk
    b, s, _ = x.shape
    q = layers.linear(p["wq"], x, train).reshape(b, s, hk, g, dh)
    scores = jnp.einsum("bshgd,bthd->bhgst", q, xk.astype(q.dtype)) / math.sqrt(dh)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhgst,bthd->bshgd", probs, xv.astype(probs.dtype))
    return layers.linear(p["wo"], ctx.reshape(b, s, h_ * dh), train)


def forward(cfg, params, batch, train: bool = True, remat: bool = False):
    enc_out = encode(cfg, params, batch["frames"], train)
    x = (params["embed"][batch["tokens"]] * math.sqrt(cfg.d_model)).astype(jnp.float32)
    pos = jnp.arange(x.shape[1])

    def body(x, p):
        x, _ = _dec_block(cfg, p, x, enc_out, pos=pos, train=train, mode="fwd")
        return x, None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    return _head(cfg, params, x), jnp.float32(0.0)


def _head(cfg, params, x):
    from repro.utils.act_sharding import constrain

    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = constrain(x @ constrain(params["embed"], "vocab_rows").T, "logits")
    if cfg.padded_vocab != cfg.vocab_size:
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(valid, logits, jnp.finfo(logits.dtype).min)
    return logits


def loss_fn(cfg, params, batch, train: bool = True, remat: bool = False):
    logits, aux = forward(cfg, params, batch, train, remat=remat)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return nll, {"nll": nll, "aux": aux}


def init_cache(cfg, batch_size: int, max_len: int, dtype=jnp.float32) -> dict:
    l, hk, dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    t = cfg.enc_seq
    return {
        "k": jnp.zeros((l, batch_size, max_len, hk, dh), dtype),
        "v": jnp.zeros((l, batch_size, max_len, hk, dh), dtype),
        "xk": jnp.zeros((l, batch_size, t, hk, dh), dtype),
        "xv": jnp.zeros((l, batch_size, t, hk, dh), dtype),
    }


def prefill(cfg, params, batch, cache: dict, train: bool = False):
    enc_out = encode(cfg, params, batch["frames"], train)
    x = (params["embed"][batch["tokens"]] * math.sqrt(cfg.d_model)).astype(jnp.float32)
    pos = jnp.arange(x.shape[1])

    def body(x, xs):
        p, cache_l = xs
        x, nc = _dec_block(cfg, p, x, enc_out, pos=pos, train=train,
                           mode="prefill", cache=cache_l)
        return x, nc

    x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], cache))
    return _head(cfg, params, x[:, -1:, :]), new_cache


def decode_step(cfg, params, tokens, cache: dict, t, train: bool = False):
    x = (params["embed"][tokens] * math.sqrt(cfg.d_model)).astype(jnp.float32)
    pos = jnp.asarray(t)[None]

    def body(x, xs):
        p, cache_l = xs
        x, nc = _dec_block(cfg, p, x, None, pos=pos, train=train,
                           mode="decode", cache=cache_l, cache_len=t)
        return x, nc

    x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], cache))
    return _head(cfg, params, x), new_cache


def chunk_step(cfg, params, tokens, pos, cache: dict, lengths, train: bool = False):
    """Per-slot decode step for the paged serving engine: tokens (B, C),
    pos (B, C) absolute positions, lengths (B,) per-slot KV write offsets.
    Cross-attention K/V were cached at prefill and are reused unchanged."""
    x = (params["embed"][tokens] * math.sqrt(cfg.d_model)).astype(jnp.float32)

    def body(x, xs):
        p, cache_l = xs
        x, nc = _dec_block(cfg, p, x, None, pos=pos, train=train,
                           mode="decode", cache=cache_l, cache_len=lengths)
        return x, nc

    x, new_cache = jax.lax.scan(body, x, (params["dec_blocks"], cache))
    return _head(cfg, params, x), new_cache
