"""Generic decoder-only LM covering the dense / moe / ssm / hybrid / vlm
families, with lax.scan over stacked layer parameters.

Heterogeneous local/global attention stacks (gemma2/gemma3/hymba) are scanned
homogeneously: a per-layer ``is_global`` flag array rides along the scan and
is blended into the attention mask (DESIGN.md §6), so HLO size stays O(1) in
depth — essential for compiling 62-layer configs 40 times in the dry-run.

Three lowered entry points per model:
* ``forward``      — full-sequence teacher-forced logits (train/eval).
* ``prefill``      — full-sequence forward that also fills the KV/SSM caches.
* ``decode_step``  — one-token autoregressive step against the caches.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import layers, moe as moe_lib, ssm as ssm_lib


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(key, cfg) -> dict:
    ks = jax.random.split(key, 8)
    fam = cfg.family
    p: dict = {}
    if fam in ("dense", "moe", "vlm", "hybrid"):
        p["ln1"] = layers.init_rmsnorm(cfg.d_model)
        p["attn"] = layers.init_attention(ks[0], cfg)
        p["ln2"] = layers.init_rmsnorm(cfg.d_model)
        if cfg.is_moe:
            p["moe"] = moe_lib.init_moe(ks[1], cfg)
        else:
            p["mlp"] = layers.init_mlp(ks[1], cfg)
        if fam == "hybrid":
            p["ssm"] = ssm_lib.init_ssm(ks[2], cfg)
            p["ln_attn"] = layers.init_rmsnorm(cfg.d_model)
            p["ln_ssm"] = layers.init_rmsnorm(cfg.d_model)
    elif fam == "ssm":
        p["ln1"] = layers.init_rmsnorm(cfg.d_model)
        p["ssm"] = ssm_lib.init_ssm(ks[0], cfg)
    else:
        raise ValueError(fam)
    return p


def init_params(cfg, key) -> dict:
    kl, ke, kh, kf = jax.random.split(key, 4)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    blocks = [_init_block(k, cfg) for k in layer_keys]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    params = {
        "blocks": stacked,
        "embed": jax.random.normal(ke, (cfg.padded_vocab, cfg.d_model), jnp.float32) * 0.02,
        "ln_f": layers.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "wd": jax.random.normal(kh, (cfg.d_model, cfg.padded_vocab), jnp.float32)
            * (1.0 / math.sqrt(cfg.d_model))
        }
    if cfg.frontend == "vision":
        params["frontend_proj"] = {
            "wd": jax.random.normal(kf, (cfg.frontend_dim, cfg.d_model), jnp.float32)
            * (1.0 / math.sqrt(cfg.frontend_dim))
        }
    return params


def global_flags(cfg) -> jnp.ndarray:
    return jnp.array([cfg.layer_is_global(i) for i in range(cfg.n_layers)], jnp.bool_)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _block(cfg, p, x, *, flag, pos, train, mode, cache=None, cache_len=None,
           slot=None):
    """One layer.  mode: 'fwd' | 'prefill' | 'decode'.

    Returns (x, aux_loss, new_cache_or_None).
    """
    fam = cfg.family
    aux = jnp.float32(0.0)
    new_cache: dict = {}

    if fam == "ssm":
        h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
        if mode == "decode":
            y, sc = ssm_lib.ssm_decode_step(cfg, p["ssm"], h, cache, train)
            new_cache.update(sc)
        else:
            y, final = ssm_lib.ssm_forward(cfg, p["ssm"], h, train)
            if mode == "prefill":
                new_cache.update(_ssm_prefill_cache(cfg, p["ssm"], h, final, train))
        return x + y, aux, (new_cache or None)

    h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if mode == "decode":
        attn_out, ac = layers.attention(
            cfg, p["attn"], h, pos=pos, is_global=flag,
            cache={"k": cache["k"], "v": cache["v"]}, cache_len=cache_len,
            slot=slot, train=train,
        )
        new_cache.update(ac)
    elif mode == "prefill":
        attn_out, (k, v) = layers.attention(
            cfg, p["attn"], h, pos=pos, is_global=flag, train=train, return_kv=True,
        )
        s_max = cache["k"].shape[1]
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        new_cache.update({"k": ck, "v": cv})
    else:
        attn_out, _ = layers.attention(
            cfg, p["attn"], h, pos=pos, is_global=flag, train=train,
        )

    if fam == "hybrid":
        if mode == "decode":
            ssm_out, sc = ssm_lib.ssm_decode_step(cfg, p["ssm"], h, cache, train)
            new_cache.update(sc)
        else:
            ssm_out, final = ssm_lib.ssm_forward(cfg, p["ssm"], h, train)
            if mode == "prefill":
                new_cache.update(_ssm_prefill_cache(cfg, p["ssm"], h, final, train))
        # Hymba: mean of per-branch normalized outputs.
        attn_out = 0.5 * (
            layers.rmsnorm(p["ln_attn"], attn_out, cfg.norm_eps)
            + layers.rmsnorm(p["ln_ssm"], ssm_out, cfg.norm_eps)
        )
    x = x + attn_out

    h2 = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.is_moe:
        y, aux = moe_lib.moe_forward(cfg, p["moe"], h2, train)
    else:
        y = layers.mlp(p["mlp"], h2, train)
    x = x + y
    return x, aux, (new_cache or None)


def _ssm_prefill_cache(cfg, p, h, final_state, train) -> dict:
    """Conv tail + final SSD state so decode can continue the recurrence."""
    # Recompute the pre-conv xBC tail (cheap: one projection on the last W-1
    # positions) to seed the rolling conv window.
    w = cfg.ssm_conv_width
    tail = h[:, -(w - 1):, :]
    z, xs, bs, cs, dt = ssm_lib._split_in(cfg, layers.linear(p["in_proj"], tail, train))
    conv = jnp.concatenate([xs, bs, cs], axis=-1)  # (B, W-1, conv_dim)
    return {"conv": conv, "state": final_state}


# ---------------------------------------------------------------------------
# Model entry points
# ---------------------------------------------------------------------------

def _embed_inputs(cfg, params, batch, train):
    """tokens (+ optional stub-frontend embeddings) -> x (B, S, D)."""
    tokens = batch["tokens"]
    x = params["embed"][tokens] * math.sqrt(cfg.d_model)
    if cfg.frontend == "vision" and "patches" in batch:
        proj = layers.linear(params["frontend_proj"], batch["patches"], train)
        x = jnp.concatenate([proj, x], axis=1)
    return x.astype(jnp.float32)


def _head(cfg, params, x):
    from repro.utils.act_sharding import constrain

    x = layers.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ constrain(params["embed"], "vocab_rows").T
    else:
        logits = x @ constrain(params["lm_head"]["wd"], "vocab_cols").astype(x.dtype)
    logits = constrain(logits, "logits")
    logits = layers.softcap(logits, cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:  # mask the padding columns
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(valid, logits, jnp.finfo(logits.dtype).min)
    return logits


def forward(cfg, params, batch, train: bool = True, remat: bool = False):
    """Teacher-forced logits (B, S_total, V); aux is the MoE balance loss.

    ``remat=True`` checkpoints each scanned block (activation rematerialization
    — the standard memory/compute trade for long-sequence training).
    """
    x = _embed_inputs(cfg, params, batch, train)
    s = x.shape[1]
    pos = jnp.arange(s)
    flags = global_flags(cfg)

    def body(carry, xs):
        xv, aux = carry
        p, flag = xs
        xv, a, _ = _block(cfg, p, xv, flag=flag, pos=pos, train=train, mode="fwd")
        return (xv, aux + a), None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), (params["blocks"], flags))
    return _head(cfg, params, x), aux


def loss_fn(cfg, params, batch, train: bool = True, remat: bool = False):
    logits, aux = forward(cfg, params, batch, train, remat=remat)
    labels = batch["labels"]
    # VLM prepends patch positions; only score the token tail.
    logits = logits[:, -labels.shape[1]:, :]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return nll + 0.01 * aux, {"nll": nll, "aux": aux}


# ---- caches ----------------------------------------------------------------

def init_cache(cfg, batch_size: int, max_len: int, dtype=jnp.float32) -> dict:
    """Stacked per-layer decode caches (leading axis = layer)."""
    l = cfg.n_layers
    c: dict = {}
    if cfg.family in ("dense", "moe", "vlm", "hybrid", "encdec"):
        hk, dh = cfg.n_kv_heads, cfg.head_dim
        c["k"] = jnp.zeros((l, batch_size, max_len, hk, dh), dtype)
        c["v"] = jnp.zeros((l, batch_size, max_len, hk, dh), dtype)
    if cfg.family in ("ssm", "hybrid"):
        di, n, nh, conv_dim = ssm_lib._dims(cfg)
        c["conv"] = jnp.zeros((l, batch_size, cfg.ssm_conv_width - 1, conv_dim), dtype)
        c["state"] = jnp.zeros((l, batch_size, nh, cfg.ssm_head_dim, n), dtype)
    return c


def prefill(cfg, params, batch, cache: dict, train: bool = False):
    """Run the prompt, fill caches.  Returns (last-position logits, caches)."""
    x = _embed_inputs(cfg, params, batch, train)
    s = x.shape[1]
    pos = jnp.arange(s)
    flags = global_flags(cfg)

    def body(carry, xs):
        xv = carry
        p, flag, cache_l = xs
        xv, _, nc = _block(cfg, p, xv, flag=flag, pos=pos, train=train,
                           mode="prefill", cache=cache_l)
        return xv, nc

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], flags, cache))
    logits = _head(cfg, params, x[:, -1:, :])
    return logits, new_cache


def decode_step(cfg, params, tokens, cache: dict, t, train: bool = False):
    """One decode step.  tokens (B, 1) int32; t = current length (scalar).

    Returns (logits (B, 1, V), updated caches).
    """
    x = params["embed"][tokens] * math.sqrt(cfg.d_model)
    x = x.astype(jnp.float32)
    pos = jnp.asarray(t)[None]
    flags = global_flags(cfg)

    def body(carry, xs):
        xv = carry
        p, flag, cache_l = xs
        xv, _, nc = _block(cfg, p, xv, flag=flag, pos=pos, train=train,
                           mode="decode", cache=cache_l, cache_len=t)
        return xv, nc

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], flags, cache))
    return _head(cfg, params, x), new_cache


def flat_step(cfg, params, tokens, slot, pos, cache: dict, emit_row,
              train: bool = False):
    """Flat token-packed step for the paged serving engine (``flat`` policy).

    tokens (T,) int32 — ONE ragged batch of real tokens from many slots
    packed along the sequence axis: several concurrent prefill chunks plus
    every decode token, budgeted purely in tokens (no per-slot padding
    rows);
    slot (T,) int32 — per-token cache slot; padding rows carry the sentinel
    ``B`` (== cache batch size) and are fully masked / scattered to a
    scratch row;
    pos (T,) int32 — per-token absolute position (== its KV write offset);
    emit_row (B,) int32 — for each slot, the flat row whose logits it
    samples (its last real token this step; engine masks non-emitting
    slots).

    Returns (logits (B, V) gathered at ``emit_row``, updated caches).  The
    head runs on B rows, not T — emit-row selection happens before the
    vocab matmul, so a wide prefill step never pays a (T, V) head.

    Like ``chunk_step``, a slot's rows may start at a nonzero position
    against a pre-populated cache (prefix-cache fork); attention masks by
    absolute position within the slot's segment.
    """
    assert cfg.family not in ("ssm", "hybrid"), \
        "SSM recurrence: flat layout needs KV-cache attention"
    x = params["embed"][tokens][None, :, :] * math.sqrt(cfg.d_model)
    x = x.astype(jnp.float32)
    flags = global_flags(cfg)

    def body(carry, xs):
        xv = carry
        p, flag, cache_l = xs
        xv, _, nc = _block(cfg, p, xv, flag=flag, pos=pos, train=train,
                           mode="decode", cache=cache_l, slot=slot)
        return xv, nc

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], flags, cache))
    sel = x[0, emit_row]                       # (B, D) emitting rows only
    logits = _head(cfg, params, sel[None])     # (1, B, V)
    return logits[0], new_cache


def chunk_step(cfg, params, tokens, pos, cache: dict, lengths, train: bool = False):
    """Chunked-append step for the paged serving engine.

    tokens (B, C) int32 — per-slot token rows: a prefill chunk, a single
    decode token, or padding (slots advance independently);
    pos (B, C) int32 — absolute positions of each token (padding clamped);
    lengths (B,) int32 — per-slot KV write offsets (current live length).

    Returns (logits (B, C, V), updated caches).  C == 1 reduces to a decode
    step with per-slot positions; C > 1 interleaves up to C prompt tokens of
    a prefilling slot with the other slots' single decode tokens.

    A slot's FIRST chunk may start at a nonzero offset (``lengths[i] > 0``
    with ``pos`` continuing from there) against a pre-populated cache — the
    prefix-cache hit path, where the leading positions were forked from
    another request's blocks: attention masks by absolute position
    (``kpos <= pos``), so the chunk attends over the pre-populated prefix
    exactly as if this slot had prefilled it (asserted in
    ``tests/test_prefix_cache.py::test_chunk_step_accepts_nonzero_start``).

    SSM/hybrid recurrences only support C == 1 (their prefill goes through
    ``prefill``; ``ssm_lib.ssm_forward`` now takes ``initial_state`` /
    ``initial_conv``, the building block for lifting this — engine wiring is
    an open ROADMAP item).
    """
    if cfg.family in ("ssm", "hybrid"):
        assert tokens.shape[1] == 1, "SSM recurrence: chunked path is C == 1 only"
    x = params["embed"][tokens] * math.sqrt(cfg.d_model)
    x = x.astype(jnp.float32)
    flags = global_flags(cfg)

    def body(carry, xs):
        xv = carry
        p, flag, cache_l = xs
        xv, _, nc = _block(cfg, p, xv, flag=flag, pos=pos, train=train,
                           mode="decode", cache=cache_l, cache_len=lengths)
        return xv, nc

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], flags, cache))
    return _head(cfg, params, x), new_cache
