"""Model zoo: composable ternary-LLM architectures (dense / MoE / SSM /
hybrid / enc-dec / VLM) built on BitLinear."""
from repro.models import model_zoo  # noqa: F401
