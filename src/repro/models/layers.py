"""Shared transformer building blocks (pure functional JAX).

Conventions:
* Params are plain dict pytrees; ``init_*`` builds them, ``*_forward`` applies.
* Every projection goes through :func:`linear`, which dispatches on the param
  dict: ``{'w'}`` = ternary BitLinear latent weights (QAT fake-quant forward),
  ``{'sign','zero','scale'}`` = frozen packed T-SAR weights (2-bit HBM
  residency — the inference path), ``{'wd'}`` = plain dense fp (embeddings,
  router, frontends, and all weights when cfg.ternary=False).
* Attention supports GQA, RoPE, sliding-window vs global masking (blended by
  a per-layer flag so heterogeneous stacks can be lax.scan'ed), qk-norm,
  attention/logit softcaps, cross-attention, and single-token decode against
  a KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitlinear, lut, ternary
from repro.utils.act_sharding import constrain


# ---------------------------------------------------------------------------
# Linear dispatch
# ---------------------------------------------------------------------------

def init_linear(key, k: int, m: int, ternary_layer: bool = True, dtype=jnp.float32) -> dict:
    if ternary_layer:
        return bitlinear.init(key, k, m, dtype)
    w = jax.random.normal(key, (k, m), dtype) * (1.0 / jnp.sqrt(k))
    return {"wd": w}


def linear(p: dict, x: jax.Array, train: bool = True) -> jax.Array:
    if "wd" in p:
        return x @ p["wd"].astype(x.dtype)
    if "w" in p:  # BitLinear latent weights
        if train:
            return bitlinear.apply_train(p, x)
        t, scale = ternary.absmean_ternarize(p["w"])
        return (lut.bitlinear_matmul_exact_int(x, t, scale)).astype(x.dtype)
    if "sign" in p:  # frozen packed planes: decode-in-fast-memory path
        return _packed_linear(p, x).astype(x.dtype)
    raise ValueError(f"unrecognized linear params: {list(p)}")


def _packed_linear(p: dict, x: jax.Array) -> jax.Array:
    """Inference forward from 2-bit planes, dispatched through the active
    execution plan (``repro.plan.runtime``).

    When a ``ModelPlan`` is active (the serving engine activates its plan
    around every jitted step) the planned kernel for this layer's (k, m) at
    the step's token count decides the realization — a trace-time constant
    table lookup, never a ``select_kernel`` call.  Off-TPU the dense T-SAR
    kernel families realize as the same exact decode->int8-dot spelling
    below (the Pallas grids differ on TPU, the integer math does not), so
    planned ``tsar_mxu``/``tsar_lut`` are bit-identical here; a planned
    ``tsar_sparse_padded`` runs the registry lowering over the layer's
    ``sp_*`` padded-pool leaves — the weights decoded in the jitted step
    come FROM THE POOL (vmap-stacked per scan layer), bit-identical to the
    planes decode because the pool round-trips exactly; and the baselines
    genuinely switch: planned ``dense`` runs the dequantized fp matmul and
    planned ``memory_lut`` the DRAM-LUT gather (both via the registry
    lowering), so A/B plans measure what their label says.  A planned
    ``tsar_sparse`` (compacted — unserveable from a params tree, its pool
    size is data-dependent) degrades to the padded lowering when the leaves
    are present, else to the planes spelling — same math either way.

    The only weight bytes read are the two uint8 bitplanes (+ per-channel
    scales): this is what makes the serve-path HBM traffic 8x smaller than
    bf16 and what the dry-run roofline measures.  On TPU the same math runs
    in the fused Pallas kernel (repro.kernels); this jnp spelling lowers to
    the identical decode->MXU dataflow and is SPMD-shardable.
    """
    from repro.plan import runtime as plan_runtime

    k = x.shape[-1]
    m = p["scale"].shape[-1]
    n = 1
    for d in x.shape[:-1]:   # static at trace time
        n *= d
    lp = plan_runtime.planned(k, m, n)
    if lp is not None:
        from repro.plan import registry

        kern = lp.kernel
        if kern in registry.SPARSE_KERNELS:
            # Compacted pools can't ride a params tree (data-dependent
            # size): remap within the sparse family to whatever format the
            # leaves actually carry, else fall through to the planes
            # spelling (same math).
            kern = next((kn for kn in registry.SPARSE_KERNELS
                         if registry.get(kn).supports(p)), kern)
        impl = registry.get(kern)
        # serve_via_registry is each impl's own declaration that its
        # lowering differs from the planes spelling below (see the
        # KernelImpl protocol) — the registry stays the source of truth.
        if getattr(impl, "serve_via_registry", False) and impl.supports(p):
            return impl.lower(p, x, lp=lp)
    sign = _unpack_plane_nd(p["sign"], k)   # int8 {0,1}
    zero = _unpack_plane_nd(p["zero"], k)
    t = ((1 - 2 * sign) * (1 - zero)).astype(jnp.int8)
    a_q, a_scale = ternary.quantize_activations(x.astype(jnp.float32))
    acc = jax.lax.dot_general(
        a_q, t,
        dimension_numbers=(((a_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * a_scale * p["scale"]


def _unpack_plane_nd(plane: jax.Array, k: int) -> jax.Array:
    shifts = jnp.arange(8, dtype=jnp.uint8).reshape((1, 8) + (1,) * (plane.ndim - 1))
    bits = (plane[:, None] >> shifts) & jnp.uint8(1)
    kp = plane.shape[0] * 8   # ragged K: planes carry zero-padded tail bits
    return bits.reshape((kp,) + plane.shape[1:])[:k].astype(jnp.int8)


def pack_linear(p: dict, lp=None, *, name: str | None = None,
                sparse: bool = False, block_shape: tuple | None = None,
                max_live: int | None = None,
                s_steps: int | None = None) -> dict:
    """Freeze one linear layer's latent weights to 2-bit planes (+ scale).

    Also stamps the measured nonzero-weight ``density`` — a scalar leaf that
    rides the params tree (vmap-stacked for scan layers / experts) so the
    density profiler (``sparse.stats.profile_params``, surfaced as the
    serving engine's init telemetry) reads the freeze-time measurement
    instead of re-deriving it from the planes.  The forward path
    (:func:`_packed_linear`) ignores it.

    ``lp`` directs the packing: a ``repro.plan.LayerPlan`` / kernel name, or
    a whole ``repro.plan.ModelPlan`` (resolved through ``name``).  A layer
    the plan pins to ``dense`` at every bucket keeps fp weights (``{'wd'}``)
    instead of 2-bit planes, so the dense escape hatch costs no decode at
    serve time.  All T-SAR kernels share the plane packing, so any other
    plan packs identically.

    ``sparse=True`` additionally emits the PADDED block-sparse pool
    (``repro.sparse.format.pad_from_ternary``) as ``sp_*`` leaves plus a
    measured ``block_density`` leaf.  The construction is pure ``jnp`` and
    the leaf shapes are static (``max_live``/``s_steps`` bound the pool, the
    full block grid by default), so this works under ``vmap`` — which is how
    ``serving.freeze_params`` stacks per-scan-layer pools that ride a
    ``lax.scan`` through the jitted serving step.  The serve-path dispatch
    (:func:`_packed_linear`) runs the ``tsar_sparse_padded`` lowering from
    these leaves when the active plan says so.
    """
    if "w" not in p:
        return p
    if hasattr(lp, "layers"):        # ModelPlan: dense only if EVERY bucket is
        by_bucket = lp.layers.get(name, {}) if name else {}
        kerns = {e.kernel for e in by_bucket.values()}
        kern = "dense" if kerns == {"dense"} else None
    else:
        kern = getattr(lp, "kernel", lp)
    t, scale = ternary.absmean_ternarize(p["w"])
    if kern == "dense":
        return {"wd": (t * scale[..., None, :]).astype(p["w"].dtype)}
    tw = ternary.pack(t, scale)
    out = {"sign": tw.sign_plane, "zero": tw.zero_plane, "scale": tw.scale,
           "density": ternary.ternary_density(t)}
    if sparse:
        from repro.sparse import format as sparse_format

        bk, bm = block_shape or sparse_format.DEFAULT_BLOCK_SHAPE
        pbst = sparse_format.pad_from_ternary(
            t.astype(jnp.int8), scale, bk=bk, bm=bm,
            max_live=max_live, s_steps=s_steps)
        out.update({
            "sp_sign": pbst.sign_pool, "sp_zero": pbst.zero_pool,
            "sp_map": pbst.block_map, "sp_kids": pbst.kids,
            "sp_slots": pbst.slots, "sp_counts": pbst.counts,
            "block_density": jnp.mean((pbst.occupancy > 0.0)
                                      .astype(jnp.float32)),
        })
    return out


# ---------------------------------------------------------------------------
# Norms / activations / RoPE
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int) -> dict:
    return {"g": jnp.zeros((d,), jnp.float32)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["g"])).astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x (..., S, H, Dh), pos (..., S) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = pos[..., None].astype(jnp.float32) * freqs           # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                           # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap > 0 else x


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

# Query-block size for the scanned long-sequence attention path; bounds the
# transient (Sq, T) score tile at B*H*Q_CHUNK*T elements per layer.
Q_CHUNK = 1024


def init_attention(key, cfg, cross: bool = False) -> dict:
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    tern = cfg.ternary
    p = {
        "wq": init_linear(ks[0], d, h * dh, tern),
        "wk": init_linear(ks[1], d, hk * dh, tern),
        "wv": init_linear(ks[2], d, hk * dh, tern),
        "wo": init_linear(ks[3], h * dh, d, tern),
    }
    if cfg.qk_norm and not cross:
        p["qn"] = init_rmsnorm(dh)
        p["kn"] = init_rmsnorm(dh)
    return p


def _split_heads(x, n_heads, dh):
    return x.reshape(x.shape[:-1] + (n_heads, dh))


def attention(
    cfg,
    p: dict,
    x: jax.Array,                    # (B, S, D) queries' residual stream
    *,
    pos: jax.Array,                  # (S,) absolute positions of the queries
    is_global,                       # bool / 0-1 scalar; blends window mask
    kv_x: jax.Array | None = None,   # cross-attention source (B, T, D)
    causal: bool = True,
    cache: dict | None = None,       # {'k','v'} (B, S_max, Hkv, Dh) decode cache
    cache_len: jax.Array | None = None,  # valid prefix length (== pos of new tok)
    slot: jax.Array | None = None,   # (T,) per-token slot index (flat layout)
    train: bool = True,
    return_kv: bool = False,
) -> tuple[jax.Array, dict | None]:
    """Returns (out (B,S,D), updated cache / (k, v) / None)."""
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // hk
    b, s, _ = x.shape

    q = _split_heads(linear(p["wq"], x, train), h, dh)       # (B,S,H,Dh)
    if kv_x is None:
        k = _split_heads(linear(p["wk"], x, train), hk, dh)  # (B,S,Hk,Dh)
        v = _split_heads(linear(p["wv"], x, train), hk, dh)
    else:
        k = _split_heads(linear(p["wk"], kv_x, train), hk, dh)
        v = _split_heads(linear(p["wv"], kv_x, train), hk, dh)

    if "qn" in p:
        q = rmsnorm(p["qn"], q, cfg.norm_eps)
        k = rmsnorm(p["kn"], k, cfg.norm_eps)

    use_rope = kv_x is None  # no RoPE on cross-attention
    if use_rope:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)  # new token(s) at absolute pos in decode
    # Pin head-sharded layouts: without this XLA's propagation is free to
    # replicate batch / split heads unevenly (observed 50 GB score temps).
    q = constrain(q, "attn_q")
    k = constrain(k, "attn_kv")
    v = constrain(v, "attn_kv")

    new_cache = None
    if cache is not None and slot is not None:
        # Flat token-packed decode (paged serving engine, ``flat`` policy):
        # x is (1, T, D) — a ragged batch of T tokens from many slots packed
        # along the sequence axis.  ``slot``/``pos`` are (T,) per-token
        # coordinates into the (B, Vtok) cache view; padding rows carry the
        # slot sentinel B.  Each token's K/V row is scattered to its own
        # (slot, pos) cell; attention is segment-masked so a token sees
        # exactly its own slot's causal prefix.
        nb, vtok = cache["k"].shape[0], cache["k"].shape[1]
        # Scatter by explicit flat index.  Padding rows are routed to a
        # dump row appended past the live cells: JAX scatter DROPS
        # out-of-bounds indices only in some modes and clamps in others, so
        # the pad destination must be explicit, never "off the end".
        widx = jnp.where(slot < nb, slot * vtok + pos, nb * vtok)

        def flat_write(c, u):
            flat = c.reshape((nb * vtok,) + c.shape[2:])
            flat = jnp.concatenate([flat, jnp.zeros_like(flat[:1])], axis=0)
            flat = flat.at[widx].set(u[0].astype(c.dtype))
            return flat[:nb * vtok].reshape(c.shape)

        ck = flat_write(cache["k"], k)
        cv = flat_write(cache["v"], v)
        new_cache = {"k": ck, "v": cv}
        # Keys/values: the whole updated view flattened to one (B*Vtok,)
        # key axis; the segment mask keeps cross-slot rows invisible.
        k = ck.reshape((1, nb * vtok) + ck.shape[2:])
        v = cv.reshape((1, nb * vtok) + cv.shape[2:])
        t = nb * vtok
        kidx = jnp.arange(t)
        kslot = kidx // vtok
        kpos = kidx % vtok
        valid = (kslot[None, :] == slot[:, None]) \
            & (kpos[None, :] <= pos[:, None])               # (T, B*Vtok)
        if cfg.window_pattern:
            in_win = kpos[None, :] > (pos[:, None] - cfg.window_size)
            valid = valid & (jnp.asarray(is_global, bool) | in_win)
        # Padding queries (slot == B) match no key: their softmax row is a
        # uniform distribution over masked scores — finite garbage, never
        # emitted (same contract as rectangular padding rows).
        mask = valid[None, None, None, :, :]                # (1,1,1,T,B*Vtok)
    elif cache is not None and jnp.ndim(cache_len) == 0:
        # Legacy synchronous decode: write new K/V at position cache_len
        # (shared by the whole batch), attend over the prefix.
        start = cache_len
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, start, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, start, 0, 0))
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        t = k.shape[1]
        kpos = jnp.arange(t)
        valid = kpos <= cache_len                           # causal over prefix+new
        if cfg.window_pattern:
            in_win = kpos > (cache_len - cfg.window_size)
            valid = valid & (jnp.asarray(is_global, bool) | in_win)
        mask = valid[None, None, None, None, :]             # (1,1,1,S=1,T)
    elif cache is not None:
        # Chunked-append decode (paged serving engine): ``cache_len`` is a
        # (B,) vector of per-slot write offsets and ``pos`` carries per-slot
        # absolute query positions (B, S).  Each slot's S new K/V rows are
        # written contiguously at its own offset; the mask is causal in
        # absolute position, so cache rows beyond a slot's live length
        # (scratch garbage / this chunk's padding tail) are never attended.
        upd = jax.vmap(
            lambda c, u, s0: jax.lax.dynamic_update_slice(c, u, (s0, 0, 0)))
        ck = upd(cache["k"], k.astype(cache["k"].dtype), cache_len)
        cv = upd(cache["v"], v.astype(cache["v"].dtype), cache_len)
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        t = k.shape[1]
        kpos = jnp.arange(t)
        qabs = pos if pos.ndim == 2 else jnp.broadcast_to(pos[None, :], (b, s))
        valid = kpos[None, None, :] <= qabs[:, :, None]     # (B, S, T)
        if cfg.window_pattern:
            in_win = kpos[None, None, :] > (qabs[:, :, None] - cfg.window_size)
            valid = valid & (jnp.asarray(is_global, bool) | in_win)
        mask = valid[:, None, None, :, :]                   # (B,1,1,S,T)
    else:
        t = k.shape[1]
        if causal and kv_x is None:
            qpos = pos[:, None]
            kpos = pos[None, :]
            m = kpos <= qpos
            if cfg.window_pattern:
                in_win = kpos > (qpos - cfg.window_size)
                m = m & (jnp.asarray(is_global, bool) | in_win)
            mask = m[None, None, None, :, :]
        else:
            mask = None

    qg = q.reshape(b, s, hk, g, dh)

    def attend(qc, maskc):
        """One query block against the full K/V.  qc (B,Sq,Hk,G,Dh).

        The query block is re-constrained INSIDE the scan body: the scanned
        chunk axis cannot be sharded (scan iterates it), so without this the
        whole attention replicates across 'model' whenever heads < |model|
        (measured 16x wasted compute on whisper/gemma prefill — §Perf iter 2).
        """
        sq = qc.shape[1]
        qc = constrain(qc.reshape(b, sq, hk * g, dh), "attn_q").reshape(qc.shape)
        scores = jnp.einsum("bshgd,bthd->bhgst", qc, k.astype(qc.dtype)) / jnp.sqrt(
            jnp.float32(dh)).astype(x.dtype)
        scores = softcap(scores, cfg.attn_softcap)
        if maskc is not None:
            scores = jnp.where(maskc, scores, jnp.finfo(scores.dtype).min)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
        ctxc = jnp.einsum("bhgst,bthd->bshgd", probs, v.astype(probs.dtype))
        return constrain(ctxc.reshape(b, sq, hk * g, dh), "attn_q").reshape(ctxc.shape)

    # Long sequences: scan over query blocks so the (Sq, T) score tile is
    # bounded (flash-attention-style working set; exact math since each query
    # block sees its full key row).  Peak scores memory: B*H*Q_CHUNK*T.
    # The mask's leading dim is 1 (shared causal mask) or B (per-slot chunked
    # decode mask); both chunk along the query axis the same way.
    if s > Q_CHUNK and s % Q_CHUNK == 0 and mask is not None:
        nq = s // Q_CHUNK
        qb = qg.reshape(b, nq, Q_CHUNK, hk, g, dh)
        mb = mask.reshape(mask.shape[0], 1, 1, nq, Q_CHUNK, t)

        # Per-chunk remat: without it the scan saves every chunk's (QC, T)
        # score tile for backward, reconstituting the full S x T matrix.
        @jax.checkpoint
        def body(_, inp):
            qc, mc = inp
            return None, attend(qc, mc)

        # mask chunk (B|1,1,1,Q_CHUNK,T): moveaxis the nq dim to scan over.
        qb_s = jnp.moveaxis(qb, 1, 0)                    # (nq, B, QC, Hk, G, Dh)
        mb_s = jnp.moveaxis(mb, 3, 0)                    # (nq, B|1, 1, 1, QC, T)
        _, ctxs = jax.lax.scan(body, None, (qb_s, mb_s))
        ctx = jnp.moveaxis(ctxs, 0, 1).reshape(b, s, hk, g, dh)
    else:
        ctx = attend(qg, mask)
    ctx = constrain(ctx.reshape(b, s, hk * g, dh), "attn_q").reshape(b, s, hk, g, dh)
    out = linear(p["wo"], ctx.reshape(b, s, h * dh), train)
    if return_kv:
        return out, (k, v)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    tern = cfg.ternary
    if cfg.mlp_gated:
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": init_linear(k1, d, f, tern),
            "w_up": init_linear(k2, d, f, tern),
            "w_down": init_linear(k3, f, d, tern),
        }
    k1, k2 = jax.random.split(key, 2)
    return {"w_up": init_linear(k1, d, f, tern), "w_down": init_linear(k2, f, d, tern)}


def mlp(p: dict, x: jax.Array, train: bool = True) -> jax.Array:
    if "w_gate" in p:
        return linear(p["w_down"], silu(linear(p["w_gate"], x, train)) * linear(p["w_up"], x, train), train)
    return linear(p["w_down"], jax.nn.gelu(linear(p["w_up"], x, train)), train)
