"""Config -> model dispatch + input spec construction for every cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every model
input — the dry-run lowers against these (no allocation ever happens for the
full-size configs).  Modality frontends are stubs per the assignment: audio
supplies frame embeddings, vision supplies patch embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, transformer
from repro.plan import runtime as plan_runtime


def _mod(cfg: ModelConfig):
    return encdec if cfg.family == "encdec" else transformer


def init_params(cfg: ModelConfig, key) -> dict:
    return _mod(cfg).init_params(cfg, key)


# Inference entry points accept an optional compiled ``repro.plan.ModelPlan``:
# the plan is activated around the model call, so every packed BitLinear
# inside dispatches through the plan's trace-time table lookup instead of any
# per-step kernel selection.  ``plan=None`` keeps whatever plan an enclosing
# context (e.g. the serving engine) already activated.

def forward(cfg, params, batch, train=True, remat=False, plan=None):
    with plan_runtime.activate(plan):
        return _mod(cfg).forward(cfg, params, batch, train, remat=remat)


def loss_fn(cfg, params, batch, train=True, remat=False):
    return _mod(cfg).loss_fn(cfg, params, batch, train, remat=remat)


def init_cache(cfg, batch_size, max_len, dtype=jnp.float32):
    return _mod(cfg).init_cache(cfg, batch_size, max_len, dtype)


def prefill(cfg, params, batch, cache, train=False, plan=None):
    with plan_runtime.activate(plan):
        return _mod(cfg).prefill(cfg, params, batch, cache, train)


def decode_step(cfg, params, tokens, cache, t, train=False, plan=None):
    with plan_runtime.activate(plan):
        return _mod(cfg).decode_step(cfg, params, tokens, cache, t, train)


def chunk_step(cfg, params, tokens, pos, cache, lengths, train=False, plan=None):
    """Per-slot chunked-append step (paged serving engine): tokens/pos (B, C),
    lengths (B,) per-slot write offsets.  A slot's first chunk may start at a
    nonzero ``lengths[i]`` against a pre-populated block table (prefix-cache
    fork).  See transformer.chunk_step."""
    with plan_runtime.activate(plan):
        return _mod(cfg).chunk_step(cfg, params, tokens, pos, cache, lengths,
                                    train)


def flat_step(cfg, params, tokens, slot, pos, cache, emit_row, train=False,
              plan=None):
    """Flat token-packed step (paged serving engine, ``flat`` policy):
    tokens/slot/pos (T,) per-token triples — multiple concurrent prefill
    chunks plus all decode tokens in one call — and emit_row (B,) selecting
    each slot's logit row before the head.  See transformer.flat_step."""
    with plan_runtime.activate(plan):
        return _mod(cfg).flat_step(cfg, params, tokens, slot, pos, cache,
                                   emit_row, train)


# ---------------------------------------------------------------------------
# Block-paged KV cache plumbing (serving engine)
#
# The attention K/V leaves ("k"/"v") are stored as a pool of fixed-size token
# blocks, (L, num_blocks, block_size, Hkv, Dh); per-slot block tables map a
# slot's logical token positions onto pool blocks.  Everything else (SSM
# conv/state, enc-dec cross K/V) is O(1)-per-slot state and stays dense with a
# leading slot axis.  Block 0 is a reserved scratch block: table padding
# points at it, so gather/scatter of unallocated table entries read/write
# garbage that the causal mask guarantees is never attended.
# ---------------------------------------------------------------------------

PAGED_LEAVES = ("k", "v")


def init_paged_cache(cfg, slots: int, num_blocks: int, block_size: int,
                     dtype=jnp.float32) -> dict:
    """Pool-shaped decode caches: paged K/V pools + dense per-slot state."""
    proto = jax.eval_shape(lambda: init_cache(cfg, slots, block_size, dtype))
    pools = {}
    for name, leaf in proto.items():
        if name in PAGED_LEAVES:
            l, _, bs = leaf.shape[:3]
            pools[name] = jnp.zeros((l, num_blocks, bs) + leaf.shape[3:], dtype)
        else:
            pools[name] = jnp.zeros(leaf.shape, leaf.dtype)
    return pools


def gather_cache_view(pools: dict, block_table) -> dict:
    """Materialize a contiguous per-slot cache view through block tables.

    block_table (B, VB) int32 — each slot's first VB blocks (0-padded).
    Paged leaves (L, NB, bs, ...) -> (L, B, VB*bs, ...); dense leaves pass
    through.  The result is shaped exactly like ``init_cache(cfg, B, VB*bs)``
    so the model's prefill/decode/chunk entry points run on it unchanged.
    """
    view = {}
    for name, leaf in pools.items():
        if name in PAGED_LEAVES:
            l, _, bs = leaf.shape[:3]
            b, vb = block_table.shape
            g = leaf[:, block_table]                      # (L, B, VB, bs, ...)
            view[name] = g.reshape((l, b, vb * bs) + leaf.shape[3:])
        else:
            view[name] = leaf
    return view


def scatter_cache_view(pools: dict, block_table, view: dict) -> dict:
    """Write an updated contiguous view back into the block pools.

    Table entries may repeat block 0 (scratch); duplicate scatters there are
    benign because scratch contents are never read as live data.
    """
    out = {}
    for name, leaf in pools.items():
        if name in PAGED_LEAVES:
            l, _, bs = leaf.shape[:3]
            b, vb = block_table.shape
            blk = view[name].reshape((l, b, vb, bs) + leaf.shape[3:])
            out[name] = leaf.at[:, block_table].set(blk)
        else:
            out[name] = view[name]
    return out


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, dry-run contract)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, cache_dtype=jnp.bfloat16) -> dict:
    """Model inputs for one (arch x shape) cell.

    * train/prefill cells: full-sequence token batches (+ frontend stubs).
      For VLM the patch tokens occupy the first ``frontend_seq`` positions of
      the cell's seq_len budget, so total backbone length == shape.seq_len.
    * decode cells: one new token per sequence + the KV/SSM caches sized to
      shape.seq_len (``serve_step`` contract).
    """
    b, s = shape.global_batch, shape.seq_len
    kind = shape.kind
    specs: dict = {}

    if kind in ("train", "prefill"):
        s_text = s
        if cfg.family == "vlm":
            s_text = s - cfg.frontend_seq
            specs["patches"] = _sds((b, cfg.frontend_seq, cfg.frontend_dim), jnp.float32)
        if cfg.family == "encdec":
            specs["frames"] = _sds((b, cfg.enc_seq, cfg.d_model), jnp.float32)
        specs["tokens"] = _sds((b, s_text), jnp.int32)
        if kind == "train":
            specs["labels"] = _sds((b, s_text), jnp.int32)
        else:
            # prefill also takes the cache it fills
            specs["cache"] = jax.eval_shape(
                lambda: init_cache(cfg, b, s, cache_dtype))
    else:  # decode
        specs["tokens"] = _sds((b, 1), jnp.int32)
        specs["cache"] = jax.eval_shape(lambda: init_cache(cfg, b, s, cache_dtype))
        specs["t"] = _sds((), jnp.int32)
    return specs


def param_specs(cfg: ModelConfig, key=None) -> dict:
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    key = jax.random.PRNGKey(0) if key is None else key
    return jax.eval_shape(lambda k: init_params(cfg, k), key)
