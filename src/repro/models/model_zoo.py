"""Config -> model dispatch + input spec construction for every cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every model
input — the dry-run lowers against these (no allocation ever happens for the
full-size configs).  Modality frontends are stubs per the assignment: audio
supplies frame embeddings, vision supplies patch embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, transformer


def _mod(cfg: ModelConfig):
    return encdec if cfg.family == "encdec" else transformer


def init_params(cfg: ModelConfig, key) -> dict:
    return _mod(cfg).init_params(cfg, key)


def forward(cfg, params, batch, train=True, remat=False):
    return _mod(cfg).forward(cfg, params, batch, train, remat=remat)


def loss_fn(cfg, params, batch, train=True, remat=False):
    return _mod(cfg).loss_fn(cfg, params, batch, train, remat=remat)


def init_cache(cfg, batch_size, max_len, dtype=jnp.float32):
    return _mod(cfg).init_cache(cfg, batch_size, max_len, dtype)


def prefill(cfg, params, batch, cache, train=False):
    return _mod(cfg).prefill(cfg, params, batch, cache, train)


def decode_step(cfg, params, tokens, cache, t, train=False):
    return _mod(cfg).decode_step(cfg, params, tokens, cache, t, train)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, dry-run contract)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, cache_dtype=jnp.bfloat16) -> dict:
    """Model inputs for one (arch x shape) cell.

    * train/prefill cells: full-sequence token batches (+ frontend stubs).
      For VLM the patch tokens occupy the first ``frontend_seq`` positions of
      the cell's seq_len budget, so total backbone length == shape.seq_len.
    * decode cells: one new token per sequence + the KV/SSM caches sized to
      shape.seq_len (``serve_step`` contract).
    """
    b, s = shape.global_batch, shape.seq_len
    kind = shape.kind
    specs: dict = {}

    if kind in ("train", "prefill"):
        s_text = s
        if cfg.family == "vlm":
            s_text = s - cfg.frontend_seq
            specs["patches"] = _sds((b, cfg.frontend_seq, cfg.frontend_dim), jnp.float32)
        if cfg.family == "encdec":
            specs["frames"] = _sds((b, cfg.enc_seq, cfg.d_model), jnp.float32)
        specs["tokens"] = _sds((b, s_text), jnp.int32)
        if kind == "train":
            specs["labels"] = _sds((b, s_text), jnp.int32)
        else:
            # prefill also takes the cache it fills
            specs["cache"] = jax.eval_shape(
                lambda: init_cache(cfg, b, s, cache_dtype))
    else:  # decode
        specs["tokens"] = _sds((b, 1), jnp.int32)
        specs["cache"] = jax.eval_shape(lambda: init_cache(cfg, b, s, cache_dtype))
        specs["t"] = _sds((), jnp.int32)
    return specs


def param_specs(cfg: ModelConfig, key=None) -> dict:
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    key = jax.random.PRNGKey(0) if key is None else key
    return jax.eval_shape(lambda k: init_params(cfg, k), key)
