"""Mixture-of-Experts layer (shared + routed experts, top-k, capacity-based).

GShard/Switch-style dispatch expressed entirely as einsums so XLA SPMD can
shard it: tokens stay sharded on the batch ('data') axis, expert weight
stacks are sharded on the expert axis ('model' — expert parallelism), and the
token->expert redistribution materializes as the canonical all-to-all in the
compiled collective schedule.

Per the T-SAR applicability analysis (DESIGN.md §Arch-applicability): expert
FFN weights are ternary BitLinear; the router stays fp (it is <0.1 % of
parameters and accuracy-critical — same choice BitNet makes for norms).

Capacity grouping: each batch row dispatches independently with capacity
``C = ceil(S * top_k * capacity_factor / E)`` so the dispatch tensor is
(B, S, E, C) — sharded over both B and E it stays small at any scale.
Overflow tokens are dropped (standard), handled by the residual connection.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import bitlinear, ternary
from repro.models import layers


def init_moe(key, cfg) -> dict:
    d = cfg.d_model
    de = cfg.d_expert or cfg.d_ff
    e = cfg.n_experts
    ks = jax.random.split(key, 5)
    tern = cfg.ternary

    def expert_stack(k, kin, kout, n):
        # Stacked expert weights (n, kin, kout); BitLinear latent or dense.
        w = jax.random.normal(k, (n, kin, kout), jnp.float32) * (1.0 / math.sqrt(kin))
        return {"w": w} if tern else {"wd": w}

    p = {
        "router": {"wd": jax.random.normal(ks[0], (d, e), jnp.float32) * 0.02},
        "w_gate": expert_stack(ks[1], d, de, e),
        "w_up": expert_stack(ks[2], d, de, e),
        "w_down": expert_stack(ks[3], de, d, e),
    }
    if cfg.n_shared_experts:
        kk = jax.random.split(ks[4], 3)
        ds = de * cfg.n_shared_experts
        p["shared"] = {
            "w_gate": layers.init_linear(kk[0], d, ds, tern),
            "w_up": layers.init_linear(kk[1], d, ds, tern),
            "w_down": layers.init_linear(kk[2], ds, d, tern),
        }
    return p


def _expert_weights(p: dict, train: bool) -> jax.Array:
    """Materialize effective expert weights (E, K, M) from latent/packed.

    The packed branch decodes to bf16 (not f32 — the materialized decode is
    transient and feeds bf16 einsums) and is pinned to the expert-sharded
    layout: without the constraint XLA data-shards the unpack then
    all-gathers 1.3 GB/layer of decoded weights (§Perf iter 4).
    """
    from repro.utils.act_sharding import constrain

    if "wd" in p:
        return p["wd"]
    if "w" in p:
        if train:
            return bitlinear.ste_ternarize(p["w"])
        t, scale = ternary.absmean_ternarize(p["w"])
        return t * scale[..., None, :]
    if "sign" in p:  # packed (E, K//8, M) planes — decode on the fly
        e, kb, m = p["sign"].shape
        k = kb * 8
        unpack = jax.vmap(lambda s: layers._unpack_plane_nd(s, k))
        sign = unpack(p["sign"])
        zero = unpack(p["zero"])
        t = ((1 - 2 * sign) * (1 - zero)).astype(jnp.bfloat16)
        return constrain(t * p["scale"][:, None, :].astype(jnp.bfloat16),
                         "expert_weights")
    raise ValueError(f"unrecognized expert params: {list(p)}")


# Dispatch-group size: tokens are regrouped into windows of at most this many
# so the (groups, G, E, C) dispatch tensor stays O(tokens * G * cf) at any
# sequence length (32k prefill would otherwise blow up quadratically).
MAX_GROUP = 4096


def _expert_ffn(cfg, p: dict, xe: jax.Array, train: bool) -> jax.Array:
    """Routed-expert FFN on dispatched tokens xe (B, E, C, D) -> (B, E, C, D).

    For frozen packed experts on a registered mesh, the unpack + matmuls run
    inside a shard_map over 'model' (the EP axis): the 2-bit planes are
    decoded strictly LOCALLY per expert shard.  Constraint hints alone lose
    to the SPMD partitioner's cost model on 128-expert stacks — it data-
    shards the decode then all-gathers 1.3 GB/layer of decoded weights
    (§Perf iter 4 open item; this is the fix).
    """
    from repro.utils.act_sharding import _dax, _dsize, get_mesh

    mesh = get_mesh()
    packed = "sign" in p["w_gate"]
    e = xe.shape[1]
    use_local = (mesh is not None and packed and not train
                 and e % mesh.shape["model"] == 0
                 and xe.shape[0] % _dsize(mesh) == 0)

    if not use_local:
        wg = _expert_weights(p["w_gate"], train).astype(jnp.bfloat16)
        wu = _expert_weights(p["w_up"], train).astype(jnp.bfloat16)
        wd = _expert_weights(p["w_down"], train).astype(jnp.bfloat16)
        h = layers.silu(jnp.einsum("becd,edf->becf", xe, wg)) * jnp.einsum(
            "becd,edf->becf", xe, wu)
        return jnp.einsum("becf,efd->becd", h, wd)

    from jax.sharding import PartitionSpec as P

    def local_block(xe_l, gs, gz, gsc, us, uz, usc, ds, dz, dsc):
        dec = lambda s, z, sc: _decode_planes(s, z, sc)
        wg, wu, wd = dec(gs, gz, gsc), dec(us, uz, usc), dec(ds, dz, dsc)
        h = layers.silu(jnp.einsum("becd,edf->becf", xe_l, wg)) * jnp.einsum(
            "becd,edf->becf", xe_l, wu)
        return jnp.einsum("becf,efd->becd", h, wd)

    # FULLY manual over (data..., model): with the data axes left 'auto' the
    # partitioner still data-shards the weight decode inside the body and
    # all-gathers 1.3 GB/layer of decoded weights at the dots.
    dax = _dax(mesh)
    ew = P("model", None, None)
    esc = P("model", None)
    fn = jax.shard_map(
        local_block, mesh=mesh,
        in_specs=(P(dax, "model", None, None),
                  ew, ew, esc, ew, ew, esc, ew, ew, esc),
        out_specs=P(dax, "model", None, None),
        axis_names={"model", *dax}, check_vma=False)
    return fn(xe,
              p["w_gate"]["sign"], p["w_gate"]["zero"], p["w_gate"]["scale"],
              p["w_up"]["sign"], p["w_up"]["zero"], p["w_up"]["scale"],
              p["w_down"]["sign"], p["w_down"]["zero"], p["w_down"]["scale"])


def _decode_planes(sign: jax.Array, zero: jax.Array, scale: jax.Array) -> jax.Array:
    """(E_local, K//8, M) planes -> (E_local, K, M) bf16 effective weights."""
    k = sign.shape[1] * 8
    unpack = jax.vmap(lambda s: layers._unpack_plane_nd(s, k))
    t = ((1 - 2 * unpack(sign)) * (1 - unpack(zero))).astype(jnp.bfloat16)
    return t * scale[:, None, :].astype(jnp.bfloat16)


def moe_forward(cfg, p: dict, x: jax.Array, train: bool = True) -> tuple[jax.Array, jax.Array]:
    """x (B, S, D) -> (y (B, S, D), aux_loss scalar)."""
    b0, s0, d = x.shape
    if s0 > MAX_GROUP and s0 % MAX_GROUP == 0:
        x = x.reshape(b0 * (s0 // MAX_GROUP), MAX_GROUP, d)
    b, s, _ = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    cap = max(1, math.ceil(s * k * cfg.capacity_factor / e))

    gates = jax.nn.softmax(layers.linear(p["router"], x.astype(jnp.float32)), axis=-1)  # (B,S,E)
    topw, topi = jax.lax.top_k(gates, k)                       # (B,S,k)
    topw = topw / (jnp.sum(topw, axis=-1, keepdims=True) + 1e-9)

    # Load-balancing auxiliary loss (Switch): E * sum_e f_e * P_e.
    me = jnp.mean(gates, axis=(0, 1))                          # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(topi, e, dtype=gates.dtype), axis=2), axis=(0, 1)
    )
    aux = e * jnp.sum(me * ce)

    # Position-in-expert via per-slot cumsum (slots processed in priority order).
    dispatch = jnp.zeros((b, s, e, cap), jnp.bfloat16)
    combine = jnp.zeros((b, s, e, cap), jnp.float32)
    counts = jnp.zeros((b, 1, e), jnp.int32)
    for j in range(k):
        oh = jax.nn.one_hot(topi[..., j], e, dtype=jnp.int32)  # (B,S,E)
        pos_in_e = jnp.cumsum(oh, axis=1) - 1 + counts         # (B,S,E)
        counts = counts + jnp.sum(oh, axis=1, keepdims=True)
        keep = (pos_in_e < cap) & (oh > 0)
        slot = jax.nn.one_hot(jnp.where(keep, pos_in_e, -1), cap, dtype=jnp.bfloat16)
        sel = slot * oh.astype(jnp.bfloat16)[..., None]        # (B,S,E,cap)
        dispatch = dispatch + sel
        combine = combine + sel.astype(jnp.float32) * topw[..., j, None, None]

    from repro.utils.act_sharding import constrain

    xe = jnp.einsum("bsec,bsd->becd", dispatch, x.astype(jnp.bfloat16))  # (B,E,C,D)
    xe = constrain(xe, "moe")   # expert axis on 'model' => dispatch = all-to-all
    out_e = _expert_ffn(cfg, p, xe, train)                     # (B,E,C,D)
    out_e = constrain(out_e, "moe")
    y = jnp.einsum("bsec,becd->bsd", combine.astype(jnp.bfloat16), out_e)
    y = y.astype(x.dtype)

    if cfg.n_shared_experts:
        y = y + layers.mlp(p["shared"], x, train)
    y = y.reshape(b0, s0, d)
    return y, aux.astype(jnp.float32)
