"""Mamba-2 (SSD — state-space duality) block, chunked, pure JAX.

Implements the exact chunked SSD algorithm of arXiv:2405.21060: within-chunk
terms are dense matmuls (MXU-friendly — the 'duality' with attention), the
across-chunk recurrence is a short ``lax.scan`` over chunk states.  Decode is
the O(1)-per-token recurrent step with a rolling depthwise-conv state.

T-SAR applicability (DESIGN.md §Arch-applicability): the in/out projections
are ternary BitLinear; the SSD recurrence itself involves no weight matrices
(A is a per-head scalar decay, B/C are data-dependent) so the paper's
technique does not apply there — it stays fp, as noted.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def _dims(cfg):
    di = cfg.d_inner
    n = cfg.ssm_state
    nh = cfg.ssm_heads
    conv_dim = di + 2 * n          # conv over (x, B, C), ngroups = 1
    return di, n, nh, conv_dim


def init_ssm(key, cfg) -> dict:
    d = cfg.d_model
    di, n, nh, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 5)
    tern = cfg.ternary
    # in_proj emits [z (di), x (di), B (n), C (n), dt (nh)]
    d_in = 2 * di + 2 * n + nh
    return {
        "in_proj": layers.init_linear(ks[0], d, d_in, tern),
        "out_proj": layers.init_linear(ks[1], di, d, tern),
        "conv_w": jax.random.normal(ks[2], (cfg.ssm_conv_width, conv_dim), jnp.float32) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 0.01, jnp.float32))),  # softplus^-1
        "norm": layers.init_rmsnorm(di),
    }


def _split_in(cfg, zxbcdt):
    di, n, nh, _ = _dims(cfg)
    z = zxbcdt[..., :di]
    xs = zxbcdt[..., di:2 * di]
    bs = zxbcdt[..., 2 * di:2 * di + n]
    cs = zxbcdt[..., 2 * di + n:2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n:]
    return z, xs, bs, cs, dt


def _segsum(x: jax.Array) -> jax.Array:
    """(..., L) -> (..., L, L) lower-triangular cumulative segment sums:
    out[i, j] = sum_{j < t <= i} x[t], -inf above the diagonal."""
    ln = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(ln)
    tri = i[:, None] >= i[None, :]
    return jnp.where(tri, diff, -jnp.inf)


def ssd_chunked(xd, a_dt, bmat, cmat, chunk: int, init_state=None):
    """Chunked SSD scan.

    xd   (B, S, H, P)  inputs pre-multiplied by dt
    a_dt (B, S, H)     log-decay per step (= dt * A, negative)
    bmat (B, S, N), cmat (B, S, N)  shared across heads (ngroups=1)
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    b, s, h, p = xd.shape
    n = bmat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    xc = xd.reshape(b, nc, chunk, h, p)
    ac = a_dt.reshape(b, nc, chunk, h)
    bc = bmat.reshape(b, nc, chunk, n)
    cc = cmat.reshape(b, nc, chunk, n)

    a_cum = jnp.cumsum(ac, axis=2)                              # (B,C,L,H)
    # Intra-chunk (diagonal) term: attention-like dense matmuls.
    lmat = jnp.exp(_segsum(jnp.moveaxis(ac, -1, 2)))            # (B,C,H,L,L)
    scores = jnp.einsum("bcln,bcsn->bcls", cc, bc)              # (B,C,L,S)
    y_diag = jnp.einsum("bcls,bchls,bcshp->bclhp", scores, lmat, xc)

    # Chunk-final states: state_c = sum_l B_l x_l * exp(Acum_last - Acum_l)
    decay_states = jnp.exp(a_cum[:, :, -1:, :] - a_cum)         # (B,C,L,H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", bc, decay_states, xc)

    # Inter-chunk recurrence over the nc chunks.
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])                   # (B,C,H)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), xd.dtype)

    def step(carry, inp):
        st, dec = inp                                           # (B,H,P,N), (B,H)
        new = carry * dec[..., None, None] + st
        return new, carry                                       # emit state *entering* chunk

    final, prev_states = jax.lax.scan(
        step, init_state,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)               # (B,C,H,P,N)

    # Off-diagonal contribution from the state entering each chunk.
    state_decay = jnp.exp(a_cum)                                # (B,C,L,H)
    y_off = jnp.einsum("bcln,bclh,bchpn->bclhp", cc, state_decay, prev_states)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final


def ssm_forward(cfg, p: dict, u: jax.Array, train: bool = True, *,
                initial_state: jax.Array | None = None,
                initial_conv: jax.Array | None = None):
    """Full-sequence forward. u (B, S, D) -> (y (B, S, D), final_ssm_state).

    ``initial_state`` (B, H, P, N) seeds the SSD recurrence and
    ``initial_conv`` (B, W-1, conv_dim) seeds the depthwise-conv window with
    the PRE-activation xBC tail of the preceding segment (the same layout
    the decode cache's ``conv`` leaf and ``_ssm_prefill_cache`` carry).
    With both supplied, running a sequence in segments is exact: the outputs
    and final state equal the unsegmented call (asserted in
    ``tests/test_models.py::test_ssm_forward_initial_state_chunks_exactly``)
    — the building block that lets SSM/hybrid families join chunked prefill.
    Prefix-cache hits still cannot apply to state-carrying layers (an SSD
    state is not block-addressable), so those families degrade to
    ``cached_len = 0``; see docs/serving.md.
    """
    b, s, _ = u.shape
    di, n, nh, conv_dim = _dims(cfg)
    hd = cfg.ssm_head_dim

    z, xs, bs, cs, dt = _split_in(cfg, layers.linear(p["in_proj"], u, train))
    xbc = jnp.concatenate([xs, bs, cs], axis=-1)                # (B,S,conv_dim)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"], init=initial_conv)
    xbc = layers.silu(xbc)
    xs, bs, cs = xbc[..., :di], xbc[..., di:di + n], xbc[..., di + n:]

    dt = jax.nn.softplus(dt + p["dt_bias"])                     # (B,S,H)
    a = -jnp.exp(p["A_log"])                                    # (H,) negative
    xh = xs.reshape(b, s, nh, hd)
    xd = xh * dt[..., None]
    y, final = ssd_chunked(xd, dt * a, bs, cs, min(cfg.ssm_chunk, s),
                           init_state=initial_state)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(b, s, di)
    y = layers.rmsnorm(p["norm"], y * layers.silu(z), cfg.norm_eps)
    return layers.linear(p["out_proj"], y, train), final


def _causal_conv(x: jax.Array, w: jax.Array, bias: jax.Array,
                 init: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv1d. x (B,S,C), w (W,C).  ``init`` (B,W-1,C)
    replaces the zero left-padding with the previous segment's tail so
    segmented runs continue the window exactly."""
    width = w.shape[0]
    if init is None:
        xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        if init.shape[1] != width - 1:
            # A wrong-length tail would silently shift every conv window.
            raise ValueError(
                f"initial_conv carries {init.shape[1]} positions, need "
                f"conv_width-1 = {width - 1}")
        xp = jnp.concatenate([init.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(width))
    return out + bias


# ---------------------------------------------------------------------------
# Decode (recurrent) path
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    di, n, nh, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, nh, cfg.ssm_head_dim, n), dtype),
    }


def ssm_decode_step(cfg, p: dict, u: jax.Array, cache: dict, train: bool = False):
    """Single-token step. u (B, 1, D) -> (y (B, 1, D), new cache)."""
    b = u.shape[0]
    di, n, nh, conv_dim = _dims(cfg)
    hd = cfg.ssm_head_dim

    z, xs, bs, cs, dt = _split_in(cfg, layers.linear(p["in_proj"], u[:, 0, :], train))
    xbc = jnp.concatenate([xs, bs, cs], axis=-1)                # (B,conv_dim)

    # Rolling conv state: window = [conv_state ; x_t]
    win = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # (B,W,C)
    conv_out = jnp.sum(win * p["conv_w"][None, :, :], axis=1) + p["conv_b"]
    xbc = layers.silu(conv_out)
    xs, bs, cs = xbc[..., :di], xbc[..., di:di + n], xbc[..., di + n:]

    dt = jax.nn.softplus(dt + p["dt_bias"])                     # (B,H)
    a = jnp.exp(dt * (-jnp.exp(p["A_log"])))                    # (B,H) decay
    xh = xs.reshape(b, nh, hd)
    state = cache["state"] * a[..., None, None] + jnp.einsum(
        "bn,bhp->bhpn", bs, xh * dt[..., None]
    )
    y = jnp.einsum("bn,bhpn->bhp", cs, state) + xh * p["D"][None, :, None]
    y = y.reshape(b, di)
    y = layers.rmsnorm(p["norm"], y * layers.silu(z), cfg.norm_eps)
    out = layers.linear(p["out_proj"], y, train)[:, None, :]
    return out, {"conv": win[:, 1:, :], "state": state}
