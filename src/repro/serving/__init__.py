from repro.serving.engine import Request, ServingEngine, freeze_params  # noqa: F401
