from repro.serving.engine import (  # noqa: F401
    Request,
    ServingEngine,
    freeze_params,
    packed_fraction,
)
from repro.serving.kv_cache import PagedKVCache  # noqa: F401
from repro.serving.prefix_cache import PrefixCache  # noqa: F401
from repro.serving.scheduler import ChunkedScheduler, SlotState, StepPlan  # noqa: F401
