"""Admission + chunked-prefill step planning (Sarathi-style stall-free
batching).

Every engine step is ONE static-shape batched model call of width C:

* each *decoding* slot contributes its single last-sampled token,
* at most ONE *prefilling* slot advances by up to ``prefill_chunk`` prompt
  tokens (round-robin by admission order),
* empty slots ride along as padding (their writes land in the scratch block
  and are never attended).

So a long prompt can never stall the decode loop for more than one step, and
per-step real work is bounded by ``prefill_chunk + slots`` tokens (the
acceptance bound).  When no slot is prefilling the step width collapses to
C == 1 — a pure decode step, exactly as cheap as the classic decode loop.

The planner also reserves KV blocks with the :class:`PagedKVCache` allocator;
if the pool cannot cover this step's growth it returns a :class:`Preempt`
directive naming a victim (youngest admission first, vLLM's recompute-style
preemption) instead of a plan.  Preemption frees the victim THROUGH the
refcount API (``kv.free_slot`` -> ``release``): blocks the victim forked
from the prefix cache — or that the cache registered from the victim — are
shared, and a direct free-list append would hand another request's live
blocks to new writers.

With a :class:`~repro.serving.prefix_cache.PrefixCache` attached (see
``admit``), each admitted prompt starts its prefill at ``cached_len``: the
fully-cached leading blocks are forked, the partial last block (and always
at least the final prompt token) is recomputed, and the skipped tokens are
accounted in both the admission block budget and the step token budget.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.obs import NULL_TRACER


@dataclass
class SlotState:
    """Engine-side per-slot request progress."""
    req: object                       # serving.engine.Request
    prompt: np.ndarray                # tokens still to prefill (incl. resume)
    cursor: int = 0                   # prompt tokens already in the cache
    last_tok: int = 0                 # feeds the next decode step
    admitted_at: int = 0              # admission counter (preemption order)
    extra: int = 0                    # non-token cache positions (VLM patches)
    cached_len: int = 0               # prompt tokens served by the prefix cache

    @property
    def prefilling(self) -> bool:
        return self.cursor < len(self.prompt)


@dataclass
class StepPlan:
    """One static-shape batched step, host-side arrays ready for device."""
    tokens: np.ndarray                # (B, C) int32
    pos: np.ndarray                   # (B, C) int32 absolute positions
    lengths: np.ndarray               # (B,) int32 pre-step write offsets
    n_real: np.ndarray                # (B,) real (non-padding) tokens per slot
    emit: np.ndarray                  # (B,) bool — slot samples a token
    emit_idx: np.ndarray              # (B,) row offset of the emitting logit
    chunk: int                        # C, static step width
    view_blocks: int                  # block-table view width for this step
    prefill_slot: int = -1            # slot advancing its prefill (-1: none)
    prefill_tokens: int = 0
    decode_tokens: int = 0

    @property
    def real_tokens(self) -> int:
        return int(self.n_real.sum())


@dataclass
class Preempt:
    """Free ``slot`` (recompute-style) so the step can get KV blocks."""
    slot: int


@dataclass
class ChunkedScheduler:
    prefill_chunk: int = 16
    _admissions: int = field(default=0, init=False)
    # Cumulative planning telemetry: chunk-tokens actually scheduled for
    # prefill vs prompt tokens the prefix cache served without scheduling.
    # The acceptance contract for prefix caching is asserted against these —
    # a warm cache must schedule strictly fewer prefill chunk-tokens.
    prefill_tokens_planned: int = field(default=0, init=False)
    cached_tokens_skipped: int = field(default=0, init=False)
    # Admissions of previously-preempted requests (recompute re-admissions);
    # the workload harness reports this alongside ``preemptions`` so a
    # preemption storm's recompute churn is visible per run.
    readmissions: int = field(default=0, init=False)
    # Event tracer (repro.obs.trace); the owning engine swaps in its own.
    # Admission events are emitted HERE because only the scheduler sees the
    # decision and its inputs (slot, cached fork length, rejections).
    tracer: object = field(default=NULL_TRACER, init=False, repr=False)

    # -- admission -----------------------------------------------------------

    def admit(self, slots: list, queue: list, kv, extra_positions: int = 0,
              reserve_full: bool = False,
              prefix_cache=None) -> list[tuple[int, SlotState]]:
        """Fill empty slots from the FIFO queue.

        ``reserve_full`` (whole-prefill policy) reserves the full prompt's KV
        blocks (+1 headroom token) at admission; the chunked policy instead
        allocates block-by-block as chunks land (``plan`` below), so blocks
        in use track live tokens, and only a first chunk's worth is gated
        here.  ``extra_positions`` are non-token cache positions every
        request carries (VLM patch tokens).  Returns the newly admitted
        (slot, state) pairs; the engine decides whether each prefills chunked
        or whole.

        ``prefix_cache`` (chunked policy only, and only when the request
        carries no non-token positions — a cached block's absolute positions
        must mean the same thing to every consumer): the longest cached
        full-block prefix of the prompt is FORKED into the slot at
        admission.  The slot starts with ``cached_len`` tokens already live
        (``cursor`` advanced past them), so ``plan`` below schedules only
        the uncached tail — cache hits are accounted in the admission block
        budget (the gate shrinks by the forked prefix) and in the step token
        budget (skipped tokens never occupy chunk width)."""
        admitted = []
        for i in range(len(slots)):
            if slots[i] is None:
                while queue:
                    req = queue[0]
                    prompt = np.concatenate(
                        [np.asarray(req.prompt, np.int32),
                         np.asarray(req.out_tokens, np.int32)])  # resume after preempt
                    total = len(prompt) + extra_positions + 1
                    if total > kv.max_len:
                        # Finished-ignored (vLLM semantics): can never fit.
                        # Retry this slot with the next queued request.
                        queue.pop(0)
                        req.done = True
                        if self.tracer.enabled:
                            self.tracer.end(req.uid, "queued")
                            self.tracer.mark(req.uid, "cancelled",
                                             reason="prompt_too_long",
                                             total_positions=total)
                            self.tracer.end(req.uid, "req")
                        continue
                    use_prefix = (prefix_cache is not None and not reserve_full
                                  and extra_positions == 0)
                    cached = prefix_cache.match(prompt)[0] if use_prefix else 0
                    gate = (total if reserve_full
                            else min(total - cached, self.prefill_chunk + 1))
                    if not kv.can_allocate(gate):
                        # FIFO: don't let short requests starve long ones.
                        return admitted
                    queue.pop(0)
                    st = SlotState(req=req, prompt=prompt, extra=extra_positions,
                                   admitted_at=self._admissions)
                    self._admissions += 1
                    if getattr(req, "n_preempted", 0) > 0:
                        self.readmissions += 1
                    if reserve_full:
                        kv.ensure(i, total)
                    if use_prefix:
                        # Fork takes the block references and advances
                        # kv.lengths; telemetry (hit/miss tokens) is counted
                        # exactly once per admission inside fork().
                        st.cached_len = prefix_cache.fork(i, prompt)
                        st.cursor = st.cached_len
                        self.cached_tokens_skipped += st.cached_len
                    if self.tracer.enabled:
                        self.tracer.end(req.uid, "queued")
                        self.tracer.mark(
                            req.uid, "admitted", slot=i,
                            cached_len=st.cached_len,
                            readmission=getattr(req, "n_preempted", 0) > 0)
                        if st.cached_len:
                            self.tracer.mark(req.uid, "prefix_hit",
                                             cached_len=st.cached_len)
                    slots[i] = st
                    admitted.append((i, st))
                    break
        return admitted

    # -- step planning -------------------------------------------------------

    def plan(self, slots: list, kv) -> StepPlan | Preempt | None:
        b = len(slots)
        active = [i for i in range(b) if slots[i] is not None]
        if not active:
            return None

        prefillers = sorted((i for i in active if slots[i].prefilling),
                            key=lambda i: slots[i].admitted_at)
        pf = prefillers[0] if prefillers else -1
        chunk = self.prefill_chunk if pf >= 0 else 1

        tokens = np.zeros((b, chunk), np.int32)
        pos = np.zeros((b, chunk), np.int32)
        lengths = np.zeros(b, np.int32)
        n_real = np.zeros(b, np.int32)
        emit = np.zeros(b, bool)
        emit_idx = np.zeros(b, np.int32)
        n_prefill = n_decode = 0

        for i in active:
            st = slots[i]
            ln = int(kv.lengths[i])
            lengths[i] = ln
            if i == pf:
                c = min(chunk, len(st.prompt) - st.cursor)
                if not kv.ensure(i, ln + c):
                    return Preempt(self._victim(slots, active))
                tokens[i, :c] = st.prompt[st.cursor:st.cursor + c]
                pos[i] = ln + np.minimum(np.arange(chunk), c - 1)
                n_real[i] = c
                emit[i] = st.cursor + c == len(st.prompt)  # prompt done: TTFT
                emit_idx[i] = c - 1
                n_prefill += c
            elif st.prefilling:
                # Waits its turn; padding row (writes land past its live
                # length / in scratch, never attended).
                pos[i] = max(ln - 1, 0)
            else:
                if not kv.ensure(i, ln + 1):
                    return Preempt(self._victim(slots, active))
                tokens[i, 0] = st.last_tok
                pos[i] = ln
                n_real[i] = 1
                emit[i] = True
                n_decode += 1

        needed = int(max(kv.lengths[i] for i in active)) + chunk
        self.prefill_tokens_planned += n_prefill
        return StepPlan(tokens=tokens, pos=pos, lengths=lengths, n_real=n_real,
                        emit=emit, emit_idx=emit_idx, chunk=chunk,
                        view_blocks=kv.view_blocks(needed),
                        prefill_slot=pf, prefill_tokens=n_prefill,
                        decode_tokens=n_decode)

    @staticmethod
    def _victim(slots: list, active: list[int]) -> int:
        if len(active) <= 1:
            raise RuntimeError(
                "KV block pool too small for a single request; "
                "raise num_blocks / lower max_len")
        return max(active, key=lambda i: slots[i].admitted_at)
