"""Admission + token-budgeted step planning (Sarathi-style stall-free
batching).

Two planners share the admission/preemption machinery:

``plan_flat`` (the default ``flat`` engine policy) packs every step into ONE
flat ``(T,)`` token vector with per-token slot/position indices, budgeted
purely in tokens (``T = token_budget``, static):

* each *decoding* slot contributes its single last-sampled token first
  (decode is never starved — the TPOT side of the knob),
* the REMAINING budget is fair-shared across ALL concurrent prefilling
  slots — each live prefiller gets ``max(1, budget_left // n_live)`` tokens
  per round, oldest admission first, until the budget or the prompts run
  out (the TTFT side: no prefiller waits for an earlier one to finish),
* leftover rows are padding (slot sentinel ``B``; their KV writes are
  routed to a scratch row and never attended).

``token_budget`` is therefore the TTFT-vs-TPOT knob: a larger budget lands
more prefill tokens per step (lower TTFT) at the cost of a wider — slower —
step for the decoders riding along (higher TPOT).  When no slot is
prefilling the width collapses to ``T == slots``, a pure decode step.

``plan`` (the legacy ``chunked`` policy, kept as the equivalence reference)
is the rectangular ``(B, C)`` layout: each decoding slot contributes one
token, and at most ONE prefilling slot — strict FIFO by admission order,
served until its prompt is done — advances by up to ``prefill_chunk``
tokens; other prefillers wait as padding rows.  Per-step real work is
bounded by ``prefill_chunk + slots`` tokens, but every idle row is padding
the jitted matmuls multiply for nothing — the padding waste the flat
layout removes.

The planner also reserves KV blocks with the :class:`PagedKVCache` allocator;
if the pool cannot cover this step's growth it returns a :class:`Preempt`
directive naming a victim (youngest admission first, vLLM's recompute-style
preemption) instead of a plan.  Preemption frees the victim THROUGH the
refcount API (``kv.free_slot`` -> ``release``): blocks the victim forked
from the prefix cache — or that the cache registered from the victim — are
shared, and a direct free-list append would hand another request's live
blocks to new writers.

With a :class:`~repro.serving.prefix_cache.PrefixCache` attached (see
``admit``), each admitted prompt starts its prefill at ``cached_len``: the
fully-cached leading blocks are forked, the partial last block (and always
at least the final prompt token) is recomputed, and the skipped tokens are
accounted in both the admission block budget and the step token budget.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.obs import NULL_TRACER


@dataclass
class SlotState:
    """Engine-side per-slot request progress."""
    req: object                       # serving.engine.Request
    prompt: np.ndarray                # tokens still to prefill (incl. resume)
    cursor: int = 0                   # prompt tokens already in the cache
    last_tok: int = 0                 # feeds the next decode step
    admitted_at: int = 0              # admission counter (preemption order)
    extra: int = 0                    # non-token cache positions (VLM patches)
    cached_len: int = 0               # prompt tokens served by the prefix cache

    @property
    def prefilling(self) -> bool:
        return self.cursor < len(self.prompt)


@dataclass
class StepPlan:
    """One static-shape batched step, host-side arrays ready for device."""
    tokens: np.ndarray                # (B, C) int32
    pos: np.ndarray                   # (B, C) int32 absolute positions
    lengths: np.ndarray               # (B,) int32 pre-step write offsets
    n_real: np.ndarray                # (B,) real (non-padding) tokens per slot
    emit: np.ndarray                  # (B,) bool — slot samples a token
    emit_idx: np.ndarray              # (B,) row offset of the emitting logit
    chunk: int                        # C, static step width
    view_blocks: int                  # block-table view width for this step
    prefill_slot: int = -1            # slot advancing its prefill (-1: none)
    prefill_tokens: int = 0
    decode_tokens: int = 0

    @property
    def real_tokens(self) -> int:
        return int(self.n_real.sum())

    def advances_prefill(self, i: int) -> bool:
        """Did slot ``i`` land prefill tokens this step?"""
        return i == self.prefill_slot


@dataclass
class FlatStepPlan:
    """One flat token-packed step: ``width`` rows, each a (token, slot, pos)
    triple.  Rows are grouped per slot in ascending slot order, positions
    ascending within a slot; padding rows carry the slot sentinel ``B``
    (their KV writes are routed to a scratch row and they are fully masked
    in attention)."""
    tokens: np.ndarray                # (T,) int32
    slot: np.ndarray                  # (T,) int32; padding rows == n_slots
    pos: np.ndarray                   # (T,) int32 absolute positions
    lengths: np.ndarray               # (B,) int32 pre-step write offsets
    n_real: np.ndarray                # (B,) real tokens landed per slot
    emit: np.ndarray                  # (B,) bool — slot samples a token
    emit_row: np.ndarray              # (B,) flat row of the emitting logit
    width: int                        # T, static step width (== planned)
    view_blocks: int                  # block-table view width for this step
    prefill_mask: np.ndarray = None   # (B,) bool — slot landed prefill tokens
    prefill_tokens: int = 0
    decode_tokens: int = 0

    @property
    def real_tokens(self) -> int:
        return int(self.n_real.sum())

    def advances_prefill(self, i: int) -> bool:
        """Did slot ``i`` land prefill tokens this step?"""
        return bool(self.prefill_mask[i])


@dataclass
class Preempt:
    """Free ``slot`` (recompute-style) so the step can get KV blocks."""
    slot: int


@dataclass
class ChunkedScheduler:
    prefill_chunk: int = 16
    _admissions: int = field(default=0, init=False)
    # Cumulative planning telemetry: chunk-tokens actually scheduled for
    # prefill vs prompt tokens the prefix cache served without scheduling.
    # The acceptance contract for prefix caching is asserted against these —
    # a warm cache must schedule strictly fewer prefill chunk-tokens.
    prefill_tokens_planned: int = field(default=0, init=False)
    cached_tokens_skipped: int = field(default=0, init=False)
    # Admissions of previously-preempted requests (recompute re-admissions);
    # the workload harness reports this alongside ``preemptions`` so a
    # preemption storm's recompute churn is visible per run.
    readmissions: int = field(default=0, init=False)
    # Prompt-too-long rejections (finished-ignored at admission).  Counted
    # here — not just trace-marked — so goodput denominators stay honest:
    # the engine mirrors this into its metrics registry and ``stats``.
    rejections: int = field(default=0, init=False)
    # Event tracer (repro.obs.trace); the owning engine swaps in its own.
    # Admission events are emitted HERE because only the scheduler sees the
    # decision and its inputs (slot, cached fork length, rejections).
    tracer: object = field(default=NULL_TRACER, init=False, repr=False)

    # -- admission -----------------------------------------------------------

    def admit(self, slots: list, queue: list, kv, extra_positions: int = 0,
              reserve_full: bool = False,
              prefix_cache=None) -> list[tuple[int, SlotState]]:
        """Fill empty slots from the FIFO queue.

        ``reserve_full`` (whole-prefill policy) reserves the full prompt's KV
        blocks (+1 headroom token) at admission; the chunked policy instead
        allocates block-by-block as chunks land (``plan`` below), so blocks
        in use track live tokens, and only a first chunk's worth is gated
        here.  ``extra_positions`` are non-token cache positions every
        request carries (VLM patch tokens).  Returns the newly admitted
        (slot, state) pairs; the engine decides whether each prefills chunked
        or whole.

        ``prefix_cache`` (chunked policy only, and only when the request
        carries no non-token positions — a cached block's absolute positions
        must mean the same thing to every consumer): the longest cached
        full-block prefix of the prompt is FORKED into the slot at
        admission.  The slot starts with ``cached_len`` tokens already live
        (``cursor`` advanced past them), so ``plan`` below schedules only
        the uncached tail — cache hits are accounted in the admission block
        budget (the gate shrinks by the forked prefix) and in the step token
        budget (skipped tokens never occupy chunk width)."""
        admitted = []
        for i in range(len(slots)):
            if slots[i] is None:
                while queue:
                    req = queue[0]
                    prompt = np.concatenate(
                        [np.asarray(req.prompt, np.int32),
                         np.asarray(req.out_tokens, np.int32)])  # resume after preempt
                    total = len(prompt) + extra_positions + 1
                    if total > kv.max_len:
                        # Finished-ignored (vLLM semantics): can never fit.
                        # Retry this slot with the next queued request.
                        queue.pop(0)
                        req.done = True
                        req.t_done = time.perf_counter()
                        self.rejections += 1
                        if self.tracer.enabled:
                            self.tracer.end(req.uid, "queued")
                            self.tracer.mark(req.uid, "cancelled",
                                             reason="prompt_too_long",
                                             total_positions=total)
                            self.tracer.end(req.uid, "req")
                        continue
                    use_prefix = (prefix_cache is not None and not reserve_full
                                  and extra_positions == 0)
                    cached = prefix_cache.match(prompt)[0] if use_prefix else 0
                    gate = (total if reserve_full
                            else min(total - cached, self.prefill_chunk + 1))
                    if not kv.can_allocate(gate):
                        # FIFO: don't let short requests starve long ones.
                        return admitted
                    queue.pop(0)
                    st = SlotState(req=req, prompt=prompt, extra=extra_positions,
                                   admitted_at=self._admissions)
                    self._admissions += 1
                    if getattr(req, "n_preempted", 0) > 0:
                        self.readmissions += 1
                    if reserve_full:
                        kv.ensure(i, total)
                    if use_prefix:
                        # Fork takes the block references and advances
                        # kv.lengths; telemetry (hit/miss tokens) is counted
                        # exactly once per admission inside fork().
                        st.cached_len = prefix_cache.fork(i, prompt)
                        st.cursor = st.cached_len
                        self.cached_tokens_skipped += st.cached_len
                    if self.tracer.enabled:
                        self.tracer.end(req.uid, "queued")
                        self.tracer.mark(
                            req.uid, "admitted", slot=i,
                            cached_len=st.cached_len,
                            readmission=getattr(req, "n_preempted", 0) > 0)
                        if st.cached_len:
                            self.tracer.mark(req.uid, "prefix_hit",
                                             cached_len=st.cached_len)
                    slots[i] = st
                    admitted.append((i, st))
                    break
        return admitted

    # -- step planning -------------------------------------------------------

    def plan(self, slots: list, kv) -> StepPlan | Preempt | None:
        b = len(slots)
        active = [i for i in range(b) if slots[i] is not None]
        if not active:
            return None

        prefillers = sorted((i for i in active if slots[i].prefilling),
                            key=lambda i: slots[i].admitted_at)
        pf = prefillers[0] if prefillers else -1
        chunk = self.prefill_chunk if pf >= 0 else 1

        tokens = np.zeros((b, chunk), np.int32)
        pos = np.zeros((b, chunk), np.int32)
        lengths = np.zeros(b, np.int32)
        n_real = np.zeros(b, np.int32)
        emit = np.zeros(b, bool)
        emit_idx = np.zeros(b, np.int32)
        n_prefill = n_decode = 0

        for i in active:
            st = slots[i]
            ln = int(kv.lengths[i])
            lengths[i] = ln
            if i == pf:
                c = min(chunk, len(st.prompt) - st.cursor)
                if not kv.ensure(i, ln + c):
                    return Preempt(self._victim(slots, active))
                tokens[i, :c] = st.prompt[st.cursor:st.cursor + c]
                pos[i] = ln + np.minimum(np.arange(chunk), c - 1)
                n_real[i] = c
                emit[i] = st.cursor + c == len(st.prompt)  # prompt done: TTFT
                emit_idx[i] = c - 1
                n_prefill += c
            elif st.prefilling:
                # Waits its turn; padding row (writes land past its live
                # length / in scratch, never attended).
                pos[i] = max(ln - 1, 0)
            else:
                if not kv.ensure(i, ln + 1):
                    return Preempt(self._victim(slots, active))
                tokens[i, 0] = st.last_tok
                pos[i] = ln
                n_real[i] = 1
                emit[i] = True
                n_decode += 1

        needed = int(max(kv.lengths[i] for i in active)) + chunk
        self.prefill_tokens_planned += n_prefill
        return StepPlan(tokens=tokens, pos=pos, lengths=lengths, n_real=n_real,
                        emit=emit, emit_idx=emit_idx, chunk=chunk,
                        view_blocks=kv.view_blocks(needed),
                        prefill_slot=pf, prefill_tokens=n_prefill,
                        decode_tokens=n_decode)

    def plan_flat(self, slots: list, kv,
                  token_budget: int) -> FlatStepPlan | Preempt | None:
        """Token-budget fair-share planning into a flat ``(T,)`` layout.

        Decode slots are served first (one token each, never starved); the
        remaining budget is split across ALL concurrent prefillers in
        fair-share rounds (``max(1, left // n_live)`` each, oldest admission
        first) until the budget or the prompts run out.  Waiting prefillers
        simply contribute zero rows — the flat layout has no per-slot
        padding.  Invariant (property-tested): ``sum(n_real) ==
        min(token_budget, available tokens)`` and each slot's rows appear in
        ascending position order."""
        b = len(slots)
        active = [i for i in range(b) if slots[i] is not None]
        if not active:
            return None

        decoders = [i for i in active if not slots[i].prefilling]
        prefillers = sorted((i for i in active if slots[i].prefilling),
                            key=lambda i: slots[i].admitted_at)

        take = dict.fromkeys(active, 0)
        for i in decoders:
            take[i] = 1
        left = token_budget - len(decoders)
        need = {i: len(slots[i].prompt) - slots[i].cursor for i in prefillers}
        live = [i for i in prefillers if need[i] > 0]
        while left > 0 and live:
            share = max(1, left // len(live))
            for i in list(live):
                c = min(share, need[i], left)
                take[i] += c
                need[i] -= c
                left -= c
                if need[i] == 0:
                    live.remove(i)
                if left == 0:
                    break

        n_prefill = sum(take[i] for i in prefillers)
        # Pure-decode steps collapse to T == slots (the cheap second trace);
        # any prefill work runs at the full static budget width.
        width = token_budget if n_prefill else b

        tokens = np.zeros(width, np.int32)
        slot = np.full(width, b, np.int32)        # sentinel: padding row
        pos = np.zeros(width, np.int32)
        lengths = np.zeros(b, np.int32)
        n_real = np.zeros(b, np.int32)
        emit = np.zeros(b, bool)
        emit_row = np.zeros(b, np.int32)
        prefill_mask = np.zeros(b, bool)

        row = 0
        for i in active:
            st = slots[i]
            ln = int(kv.lengths[i])
            lengths[i] = ln
            c = take[i]
            if c == 0:
                continue                          # prefiller waiting its turn
            if not kv.ensure(i, ln + c):
                return Preempt(self._victim(slots, active))
            if st.prefilling:
                tokens[row:row + c] = st.prompt[st.cursor:st.cursor + c]
                emit[i] = st.cursor + c == len(st.prompt)  # prompt done: TTFT
                prefill_mask[i] = True
            else:
                tokens[row] = st.last_tok
                emit[i] = True
            slot[row:row + c] = i
            pos[row:row + c] = ln + np.arange(c)
            n_real[i] = c
            emit_row[i] = row + c - 1
            row += c

        needed = max(int(kv.lengths[i]) + take[i] for i in active)
        self.prefill_tokens_planned += n_prefill
        return FlatStepPlan(tokens=tokens, slot=slot, pos=pos,
                            lengths=lengths, n_real=n_real, emit=emit,
                            emit_row=emit_row, width=width,
                            view_blocks=kv.view_blocks(needed),
                            prefill_mask=prefill_mask,
                            prefill_tokens=n_prefill,
                            decode_tokens=len(decoders))

    @staticmethod
    def _victim(slots: list, active: list[int]) -> int:
        if len(active) <= 1:
            raise RuntimeError(
                "KV block pool too small for a single request; "
                "raise num_blocks / lower max_len")
        return max(active, key=lambda i: slots[i].admitted_at)
