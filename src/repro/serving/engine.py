"""Batched serving engine: prefill + steady-state decode with slot-based
continuous batching.

The engine mirrors the paper's inference protocol (Sec. IV-A): prefill builds
the KV cache (GEMM-heavy), decode measures steady-state throughput (GEMV-
heavy).  Requests are assigned to fixed batch slots; finished slots are
refilled from the queue without stopping the decode loop (continuous
batching 'lite' — slot-synchronous, which is what static-shape SPMD wants).

Weight modes:
* ``qat``    — latent fp weights, exact-int8 eval math.
* ``packed`` — weights frozen to 2-bit T-SAR planes; every BitLinear matmul
  streams 8x fewer weight bytes (the paper's core claim, visible in the
  dry-run roofline memory term).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers, model_zoo


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: list = field(default_factory=list)
    done: bool = False


def freeze_params(params) -> dict:
    """Pack every BitLinear latent weight to 2-bit planes (tree-wide).

    Stacked (scan-layer / expert) weights are packed with vmap over leading
    dims; dense fp leaves pass through untouched.
    """

    def freeze_leafdict(node):
        if isinstance(node, dict) and set(node) == {"w"}:
            w = node["w"]
            fn = layers.pack_linear
            for _ in range(w.ndim - 2):
                fn = jax.vmap(fn)
            return fn({"w": w})
        return node

    def walk(node):
        if isinstance(node, dict):
            out = freeze_leafdict(node)
            if out is not node:
                return out
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(params)


def packed_fraction(params) -> float:
    """Diagnostic: fraction of param bytes in 2-bit packed form."""
    packed, total = 0, 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        names = [getattr(k, "key", "") for k in path]
        nb = leaf.size * leaf.dtype.itemsize
        total += nb
        if any(n in ("sign", "zero") for n in names):
            packed += nb * 8  # each packed byte stands for 8 weights
    return packed / max(total, 1)


class ServingEngine:
    def __init__(self, cfg, params, *, max_len: int = 512, batch_slots: int = 4,
                 packed: bool = False, cache_dtype=jnp.float32, seed: int = 0):
        self.cfg = cfg
        self.params = freeze_params(params) if packed else params
        self.max_len = max_len
        self.slots = batch_slots
        self.key = jax.random.PRNGKey(seed)
        self._queue: list[Request] = []
        self._active: list[Request | None] = [None] * batch_slots
        self._cache = model_zoo.init_cache(cfg, batch_slots, max_len, cache_dtype)
        self._lengths = np.zeros(batch_slots, np.int32)
        self._last_tok = np.zeros((batch_slots, 1), np.int32)
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0, "decode_tokens": 0}

        self._prefill = jax.jit(
            lambda p, b, c: model_zoo.prefill(cfg, p, b, c, train=False))
        self._decode = jax.jit(
            lambda p, t, c, n: model_zoo.decode_step(cfg, p, t, c, n, train=False))

    # -- request management --------------------------------------------------

    def submit(self, req: Request):
        self._queue.append(req)

    def _admit(self):
        """Fill empty slots; prefill each new request individually (per-slot
        cache splice keeps the decode batch static)."""
        for i in range(self.slots):
            if self._active[i] is None and self._queue:
                req = self._queue.pop(0)
                self._active[i] = req
                self._prefill_slot(i, req)

    def _prefill_slot(self, i: int, req: Request):
        cfg = self.cfg
        s = len(req.prompt)
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None, :]}
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros((1, cfg.frontend_seq, cfg.frontend_dim), jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((1, cfg.enc_seq, cfg.d_model), jnp.float32)
        slot_cache = jax.tree.map(lambda c: c[:, i:i + 1], self._cache)
        t0 = time.perf_counter()
        logits, slot_cache = self._prefill(self.params, batch, slot_cache)
        logits.block_until_ready()
        self.stats["prefill_s"] += time.perf_counter() - t0
        self._cache = jax.tree.map(
            lambda full, sl: jax.lax.dynamic_update_index_in_dim(full, sl[:, 0], i, 1),
            self._cache, slot_cache)
        tok = self._sample(logits[:, -1, :], req.temperature)
        extra = cfg.frontend_seq if cfg.family == "vlm" else 0
        self._lengths[i] = s + extra
        self._last_tok[i, 0] = int(tok[0])
        req.out_tokens.append(int(tok[0]))

    def _sample(self, logits, temperature):
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / temperature, axis=-1)

    # -- main loop ------------------------------------------------------------

    def step(self):
        """One synchronous decode step across all active slots."""
        if not any(self._active):
            return
        # Static-shape decode at the max active length; per-slot masks are
        # implicit because finished/inactive slots are ignored on readback.
        t = int(self._lengths.max())
        t0 = time.perf_counter()
        logits, self._cache = self._decode(
            self.params, jnp.asarray(self._last_tok), self._cache, jnp.int32(t))
        logits.block_until_ready()
        self.stats["decode_s"] += time.perf_counter() - t0
        toks = np.asarray(self._sample(logits[:, 0, :], 0.0))
        for i, req in enumerate(self._active):
            if req is None:
                continue
            self._lengths[i] += 1
            self.stats["decode_tokens"] += 1
            tok = int(toks[i])
            req.out_tokens.append(tok)
            if len(req.out_tokens) >= req.max_new_tokens or self._lengths[i] >= self.max_len - 1:
                req.done = True
                self._active[i] = None
            else:
                self._last_tok[i, 0] = tok

    def run(self, requests: list[Request]) -> list[Request]:
        for r in requests:
            self.submit(r)
        while self._queue or any(self._active):
            self._admit()
            self.step()
        return requests

    def throughput(self) -> float:
        return self.stats["decode_tokens"] / max(self.stats["decode_s"], 1e-9)
