"""Serving engine: chunked-prefill continuous batching over a block-paged KV
cache.

The engine mirrors the paper's inference protocol (Sec. IV-A) — a GEMM-heavy
prefill filling the KV cache and a GEMV-heavy steady-state decode — but
serves them Sarathi-style: instead of blocking the decode loop on whole-
prompt prefills, every engine step is ONE jitted static-shape model call that
mixes up to ``prefill_chunk`` prompt tokens from the admitted request with
one decode token per running request (see ``scheduler.ChunkedScheduler``).
KV memory is a pool of fixed-size blocks reached through per-slot block
tables (``kv_cache.PagedKVCache``), so resident cache bytes track live
tokens, not ``slots * max_len``.

The module splits four ways:

* ``kv_cache.py``     — block pool, ref-counted free-list allocator, per-slot
  block tables;
* ``scheduler.py``    — admission + chunked-prefill step planning + preemption;
* ``prefix_cache.py`` — block-granular radix tree over token-ID prefixes:
  admitted prompts fork the cached leading blocks of an earlier request
  instead of recomputing them (``ServingEngine(prefix_cache=True)``);
* this file           — the ``ServingEngine``/``Request`` API, the jitted
  gather -> model -> scatter step, sampling, prefix registration, and
  latency stats (per-request TTFT/TPOT).

Policies: ``flat`` (default for dense/MoE attention families) packs every
step into one flat ``(T,)`` token vector — multiple concurrent prefill
chunks plus all decode tokens, budgeted purely in tokens
(``token_budget``), so the jitted matmuls multiply almost no padding;
``chunked`` is the rectangular ``(B, C)`` predecessor (one prefill chunk
per step, kept as the equivalence reference); ``whole`` prefills each
admitted prompt in a single per-slot call (required for SSM/hybrid
recurrences, enc-dec and VLM frontends).  All three run the same
per-slot-position decode math, so their greedy outputs are identical.

Weight modes:
* ``qat``    — latent fp weights, exact-int8 eval math.
* ``packed`` — weights frozen to 2-bit T-SAR planes; every BitLinear matmul
  streams 8x fewer weight bytes (the paper's core claim).
"""
from __future__ import annotations

import contextlib
import functools
import time
import warnings
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers, model_zoo
from repro.obs import NULL_TRACER, MetricsRegistry, StatsView
from repro.obs import trace as obs_trace
from repro.plan import BatchProfile, ModelPlan, compile_plan
from repro.plan import runtime as plan_runtime
from repro.serving.kv_cache import PagedKVCache
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import ChunkedScheduler, Preempt, SlotState


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: list = field(default_factory=list)
    done: bool = False
    # -- latency stats (stamped by the engine) --
    t_submit: float | None = None
    t_admit: float | None = None  # first admission into a slot
    t_first: float | None = None
    t_done: float | None = None
    n_preempted: int = 0          # recompute-preemptions suffered

    @property
    def ttft(self) -> float | None:
        """Time to first token (s)."""
        if self.t_submit is None or self.t_first is None:
            return None
        return self.t_first - self.t_submit

    @property
    def queue_s(self) -> float | None:
        """Submit -> first admission into a slot (s)."""
        if self.t_submit is None or self.t_admit is None:
            return None
        return self.t_admit - self.t_submit

    @property
    def tpot(self) -> float | None:
        """Mean time per output token after the first (s/token)."""
        if self.t_first is None or self.t_done is None or len(self.out_tokens) < 2:
            return None
        return (self.t_done - self.t_first) / (len(self.out_tokens) - 1)


def _measure_stack(w, block_shape: tuple) -> tuple[int, int, float]:
    """Host-side occupancy measurement of one (possibly stacked) latent
    weight: (stack-wide max live blocks, stack-wide max live per strip,
    mean live-block fraction over slices).

    This re-ternarizes (pack_linear ternarizes again inside the vmap — an
    accepted freeze-time-only double cost; the vmapped construction cannot
    see across the stack, so the bounds must be measured out here).
    """
    import numpy as np

    from repro.core import ternary
    from repro.sparse import stats as sparse_stats

    bk, bm = block_shape
    t, _ = ternary.absmean_ternarize(w)
    tn = np.asarray(t, np.int8).reshape((-1,) + t.shape[-2:])
    max_live = s_steps = 0
    bds = []
    for i in range(tn.shape[0]):
        occ = sparse_stats.block_occupancy(tn[i], bk, bm)
        live = occ > 0
        max_live = max(max_live, int(live.sum()))
        s_steps = max(s_steps, int(live.sum(axis=0).max()))
        bds.append(float(live.mean()))
    return max_live, s_steps, float(np.mean(bds)) if bds else 1.0


def _sparse_prepass(w, block_shape: tuple, max_live: int | None = None,
                    s_steps: int | None = None) -> dict | None:
    """Sizing pass for ``sparse="auto"``: when the MEAN live-block fraction
    over the stack sits below the freeze threshold, returns the pack_linear
    kwargs that emit a padded pool sized to the STACK-WIDE maxima
    (``max_live``/``s_steps`` must be uniform across the stack or the pools
    can't ride a vmap/scan).  The mean is the same signal ``compile_plan``
    costs with (the stamped ``block_density`` leaves, averaged) — a single
    sparse outlier slice in an otherwise-dense stack must not stamp
    near-full-grid pools the planner will never pick.  The gate is
    ``SPARSE_SIDE_CAR_THRESHOLD`` (0.95), deliberately a notch ABOVE the
    ~0.9 dispatch break-even — same rationale as the compacted sidecar at
    freeze time: borderline layers keep the option (a plan recompiled with
    a calibrated tax, or a different n-bucket profile, may cross the line),
    while clearly-dense stacks don't carry dead pool bytes.  Caller-supplied
    ``max_live``/``s_steps`` act as FLOORS on the measured values (to keep
    ALL ``sp_*`` leaf shapes — pools and kids/slots schedules alike —
    uniform across re-freezes for a saved plan).  Returns None when the
    checkpoint is too dense to bother (pad slots would dominate).
    """
    from repro.core import bitlinear

    measured_live, measured_steps, mean_bd = _measure_stack(w, block_shape)
    if mean_bd >= bitlinear.SPARSE_SIDE_CAR_THRESHOLD:
        return None
    return {"sparse": True, "block_shape": block_shape,
            "max_live": max(measured_live, max_live or 0, 1),
            "s_steps": max(measured_steps, s_steps or 0, 1)}


def freeze_params(params, *, sparse: str | bool = "auto",
                  block_shape: tuple | None = None,
                  max_live: int | None = None,
                  s_steps: int | None = None) -> dict:
    """Pack every BitLinear latent weight to 2-bit planes (tree-wide).

    Stacked (scan-layer / expert) weights are packed with vmap over leading
    dims; dense fp leaves pass through untouched.

    ``sparse`` controls the padded-pool sidecars (the serveable sparse
    format — see ``repro.sparse.format.PaddedBlockSparseTernary``):

    * ``"auto"`` (default) — on concrete weights, a host-side pre-pass
      measures each layer's block occupancy and emits pools only for layers
      below the freeze threshold, sized to the measured stack-wide
      ``max_live``/``s_steps`` (tight pools, real memory savings);
      caller-supplied ``max_live``/``s_steps`` act as floors (uniform
      ``sp_*`` leaf shapes — pools AND schedules — across re-freezes).
      Under tracing nothing is measurable, so no pools are emitted.
    * ``True`` — always emit pools.  The pool pads to ``max_live`` and the
      schedule to ``s_steps`` (full block grid / K-per-block when None) —
      fully traceable, so ``freeze_params`` itself can run under
      ``jit``/``eval_shape`` and the vmapped per-layer construction works
      on stacked scan weights either way (this is "freeze emits padded
      pools under tracing").  On concrete weights undersized bounds raise
      (checked host-side — the vmap would otherwise silently drop live
      blocks); under tracing the bounds are the caller's promise.
    * ``False`` — planes only (PR 3 behavior).
    """
    from repro.sparse import format as sparse_format

    if sparse not in (True, False, "auto"):
        # A typo ('Auto', 'true') silently freezing planes-only would leave
        # the operator believing the sparse path is active — reject loudly,
        # like hw.set_calibration does for unknown keys.
        raise ValueError(
            f"freeze_params: sparse={sparse!r} must be True, False, or "
            "'auto'")
    bshape = block_shape or sparse_format.DEFAULT_BLOCK_SHAPE

    def freeze_leafdict(node):
        if isinstance(node, dict) and set(node) == {"w"}:
            w = node["w"]
            kw = {}
            if sparse is True:
                kw = {"sparse": True, "block_shape": bshape,
                      "max_live": max_live, "s_steps": s_steps}
                bounded = max_live is not None or s_steps is not None
                if bounded and not isinstance(w, jax.core.Tracer):
                    # The vmapped construction below traces even concrete
                    # stacks, which silences format.py's undersized-bound
                    # checks — enforce the caller's bounds host-side here so
                    # an overflowing layer raises instead of silently
                    # dropping live blocks.
                    m_live, m_steps, _ = _measure_stack(w, bshape)
                    if max_live is not None and m_live > max_live:
                        raise ValueError(
                            f"freeze_params: max_live={max_live} < {m_live}"
                            f" live blocks in a {tuple(w.shape)} layer stack;"
                            " pass a larger bound (or None for the full"
                            " grid)")
                    if s_steps is not None and m_steps > s_steps:
                        raise ValueError(
                            f"freeze_params: s_steps={s_steps} < {m_steps} "
                            f"live blocks in the fullest strip of a "
                            f"{tuple(w.shape)} layer stack; pass a larger "
                            "bound (or None for K/bk)")
            elif sparse == "auto" and not isinstance(w, jax.core.Tracer):
                kw = _sparse_prepass(w, bshape, max_live=max_live,
                                     s_steps=s_steps) or {}
            fn = layers.pack_linear
            if kw:
                fn = functools.partial(fn, **kw)
            for _ in range(w.ndim - 2):
                fn = jax.vmap(fn)
            return fn({"w": w})
        return node

    def walk(node):
        if isinstance(node, dict):
            out = freeze_leafdict(node)
            if out is not node:
                return out
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(params)


def density_telemetry(params) -> dict | None:
    """Per-layer weight-density profile of a packed params tree (host-side).

    Returns ``sparse.stats.summarize`` output plus the full per-layer
    profile, or None when the tree has no packed/latent BitLinear leaves or
    is abstract (``jax.eval_shape``).  This is the serving-side surface of
    the density signal: operators see, per deployment, how far the
    checkpoint sits from the ``tsar_sparse`` break-even.
    """
    from repro.sparse import stats as sparse_stats

    try:
        profile = sparse_stats.profile_params(params)
    except (jax.errors.TracerArrayConversionError, TypeError):
        return None
    if not profile:
        return None
    out = sparse_stats.summarize(profile)
    out["profile"] = profile
    return out


def packed_fraction(params) -> float:
    """Diagnostic: fraction of param bytes in 2-bit packed form."""
    packed, total = 0, 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        names = [getattr(k, "key", "") for k in path]
        nb = leaf.size * leaf.dtype.itemsize
        total += nb
        if any(n in ("sign", "zero") for n in names):
            packed += nb * 8  # each packed byte stands for 8 weights
    return packed / max(total, 1)


# ---------------------------------------------------------------------------
# Jitted step bodies (gather -> model -> scatter, fused in one XLA program)
# ---------------------------------------------------------------------------

def _chunk_call(cfg, params, pools, table, tokens, pos, lengths, emit_idx):
    view = model_zoo.gather_cache_view(pools, table)
    logits, view = model_zoo.chunk_step(cfg, params, tokens, pos, view,
                                        lengths, train=False)
    pools = model_zoo.scatter_cache_view(pools, table, view)
    sel = jnp.take_along_axis(logits, emit_idx[:, None, None], axis=1)[:, 0]
    return sel, pools


def _flat_call(cfg, params, pools, table, tokens, slot, pos, emit_row):
    view = model_zoo.gather_cache_view(pools, table)
    sel, view = model_zoo.flat_step(cfg, params, tokens, slot, pos, view,
                                    emit_row, train=False)
    pools = model_zoo.scatter_cache_view(pools, table, view)
    return sel, pools


def _whole_prefill_call(cfg, params, pools, table, batch, slot):
    view = model_zoo.gather_cache_view(pools, table)
    slot_view = jax.tree.map(
        lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, 1), view)
    logits, slot_view = model_zoo.prefill(cfg, params, batch, slot_view,
                                          train=False)
    view = jax.tree.map(
        lambda full, sl: jax.lax.dynamic_update_index_in_dim(full, sl[:, 0], slot, 1),
        view, slot_view)
    pools = model_zoo.scatter_cache_view(pools, table, view)
    return logits[:, -1, :], pools


_CHUNKABLE_FAMILIES = ("dense", "moe")


class ServingEngine:
    def __init__(self, cfg, params, *, max_len: int = 512, batch_slots: int = 4,
                 packed: bool = False, cache_dtype=jnp.float32, seed: int = 0,
                 prefill_chunk: int = 16, block_size: int = 16,
                 kv_blocks: int | None = None, policy: str | None = None,
                 token_budget: int | None = None,
                 profile_density: bool = True,
                 plan: ModelPlan | None = None,
                 sparse: str | bool = "auto",
                 sparse_block: tuple | None = None,
                 prefix_cache: bool | int = False,
                 tracer=None, profiler_annotations: bool = False,
                 incidents=None, flight_recorder: bool | int = False):
        self.cfg = cfg
        self.params = (freeze_params(params, sparse=sparse,
                                     block_shape=sparse_block)
                       if packed else params)
        self.max_len = max_len
        self.slots = batch_slots
        self.key = jax.random.PRNGKey(seed)
        self.prefill_chunk = prefill_chunk
        if policy is None:
            policy = "flat" if cfg.family in _CHUNKABLE_FAMILIES else "whole"
        elif (policy in ("flat", "chunked")
              and cfg.family not in _CHUNKABLE_FAMILIES):
            # SSM recurrences / frontend prefills need the whole-prompt path;
            # refusing (rather than silently downgrading) keeps benchmark
            # labels honest.
            raise ValueError(
                f"policy={policy!r} is unsupported for family {cfg.family!r}; "
                "pass policy=None (auto) or 'whole'")
        self.policy = policy
        # TTFT-vs-TPOT knob for the flat policy: the static per-step token
        # budget T.  The default matches the rectangular bound
        # (prefill_chunk + slots), so flat serves the same worst-case real
        # work per step with almost none of the padding.
        if token_budget is None:
            token_budget = prefill_chunk + batch_slots
        if token_budget < batch_slots + 1:
            raise ValueError(
                f"token_budget={token_budget} < batch_slots + 1 "
                f"({batch_slots + 1}): every decode slot needs a row plus "
                "at least one prefill token")
        self.token_budget = token_budget
        self._extra = cfg.frontend_seq if cfg.family == "vlm" else 0

        self.kv = PagedKVCache(cfg, batch_slots, max_len, block_size=block_size,
                               num_blocks=kv_blocks, dtype=cache_dtype)
        self.sched = ChunkedScheduler(prefill_chunk=prefill_chunk)
        # Prefix-caching KV reuse (``serving.prefix_cache``): ``True`` turns
        # it on, an int additionally caps the cached-block footprint (LRU
        # evicted above it).  Reuse requires a chunk-capable path (a prefill
        # must be able to START at the fork boundary); for whole-prefill families
        # — SSM/hybrid recurrences carry non-block state, enc-dec/VLM
        # frontends carry non-token positions — hits cannot apply, so the
        # config degrades gracefully to a disabled cache whose telemetry
        # reports a 0.0 hit rate instead of refusing to serve.
        self.prefix: PrefixCache | None = None
        if prefix_cache and self.policy in ("flat", "chunked"):
            cap = (prefix_cache
                   if isinstance(prefix_cache, int)
                   and not isinstance(prefix_cache, bool) else None)
            self.prefix = PrefixCache(self.kv, capacity_blocks=cap)
        self._queue: list[Request] = []
        self._slots: list[SlotState | None] = [None] * batch_slots

        # -- observability (repro.obs) ----------------------------------------
        # The typed registry OWNS all run telemetry; ``stats`` below is a
        # write-through view over it under the legacy key names, so every
        # pre-existing key keeps its name, meaning, and mutability.  The
        # tracer defaults to the no-op recorder: every emit site guards on
        # ``tracer.enabled``, so an untraced engine pays one attribute read
        # per potential event and its counters stay bit-identical.
        if tracer is None and flight_recorder:
            # Always-on flight recorder: a ring-buffered tracer cheap enough
            # to leave enabled, so incident snapshots can dump the last N
            # events post-hoc.  An int picks the ring capacity.
            cap = (flight_recorder
                   if isinstance(flight_recorder, int)
                   and not isinstance(flight_recorder, bool)
                   else obs_trace.DEFAULT_RING_CAPACITY)
            tracer = obs_trace.EventTracer(sink=obs_trace.RingSink(cap))
        self.tracer = NULL_TRACER if tracer is None else tracer
        self._profile_steps = bool(profiler_annotations)
        self._phase: dict[int, str] = {}  # uid -> open lifecycle span (traced)
        self.sched.tracer = self.tracer
        self.kv.tracer = self.tracer
        if self.prefix is not None:
            self.prefix.tracer = self.tracer
        reg = self.metrics = MetricsRegistry()
        # Incident snapshots (repro.obs.incident): the monitor hooks sit
        # OUTSIDE the tracer.enabled guards and own no registry metrics, so
        # attaching one perturbs neither traced-vs-untraced bit-identity nor
        # the exact-gated benchmark counters.
        self.incidents = incidents
        self._evictions_seen = 0
        if incidents is not None:
            incidents.bind(registry=reg, tracer=self.tracer)
        self.kv.incidents = incidents
        t_step = reg.counter("step_time_s",
                             "wall seconds in jitted step calls, by phase",
                             labels=("phase",))
        self._t_prefill = t_step.labels(phase="prefill")
        self._t_decode = t_step.labels(phase="decode")
        self._c_steps = reg.counter("steps", "mixed chunk/decode engine steps")
        self._c_decode_tokens = reg.counter(
            "decode_tokens", "tokens emitted by pure-decode steps")
        self._c_total_tokens = reg.counter("total_tokens",
                                           "all emitted tokens")
        self._c_prefill_tokens = reg.counter(
            "prefill_tokens", "prompt tokens scheduled into chunks")
        self._c_whole_prefills = reg.counter(
            "whole_prefills", "single-call whole-prompt prefills")
        self._c_preemptions = reg.counter(
            "preemptions", "recompute-style slot preemptions")
        self._c_admissions = reg.counter(
            "admissions", "slot admissions (including re-admissions)")
        self._c_rejections = reg.counter(
            "rejections",
            "requests rejected at admission (prompt can never fit)")
        self._c_planned = reg.counter(
            "planned_tokens",
            "step-width rows the jitted call multiplies (flat: T; "
            "rectangular: padded B*C)")
        self._c_realized = reg.counter(
            "realized_tokens", "real (non-padding) tokens across steps")
        self._c_prefill_steps = reg.counter(
            "prefill_steps", "steps carrying a prefill chunk")
        self._c_decode_steps = reg.counter("decode_steps", "pure-decode steps")
        self._g_kv = reg.gauge(
            "kv_blocks", "pool blocks in use (peak -> peak_kv_blocks)")
        self._g_step_tokens = reg.gauge(
            "step_tokens", "real tokens of the last step "
                           "(peak -> max_step_tokens)")
        self._h_ttft = reg.histogram("ttft_s", "time to first token (s)")
        self._h_tpot = reg.histogram(
            "tpot_s", "mean time per output token after the first (s)")
        self._h_queue = reg.histogram(
            "queue_s", "submit -> first slot admission (s)")

        def _cv(m):
            # counter/gauge value with the legacy dict's write-through
            return (lambda: m.value, m.set)

        def _peak(g):
            # Legacy peak keys read the gauge's tracked peak; an external
            # write (the old reset idiom) rebases both value and peak.
            def setter(v):
                g.value = v
                g.peak = v
            return (lambda: g.peak, setter)

        self.stats = StatsView({
            "prefill_s": _cv(self._t_prefill),
            "decode_s": _cv(self._t_decode),
            "decode_tokens": _cv(self._c_decode_tokens),
            "total_tokens": _cv(self._c_total_tokens),
            "prefill_tokens": _cv(self._c_prefill_tokens),
            "steps": _cv(self._c_steps),
            "whole_prefills": _cv(self._c_whole_prefills),
            "preemptions": _cv(self._c_preemptions),
            "peak_kv_blocks": _peak(self._g_kv),
            "max_step_tokens": _peak(self._g_step_tokens),
        })
        # Bound AFTER the base view: the first ten legacy keys keep their
        # pinned order (tests assert it) while rejections still write
        # through to the registry like every other stat.
        self.stats.bind("rejections", *_cv(self._c_rejections))
        if prefix_cache:
            # Keys (and their registry metrics) exist whenever the cache was
            # REQUESTED (including the whole-policy degrade, where they stay
            # at zero) and never when it wasn't — a cache-off engine's stats
            # are unchanged.
            for key, m in (
                ("prefix_hit_rate", reg.gauge(
                    "prefix_hit_rate",
                    "fraction of admitted prompt tokens served from cache")),
                ("cached_blocks", reg.gauge(
                    "cached_blocks", "blocks held by the prefix-cache tree")),
                ("prefix_hit_tokens", reg.counter(
                    "prefix_hit_tokens", "prompt tokens served from cache")),
                ("prefix_lookups", reg.counter(
                    "prefix_lookups", "prefix-cache forks attempted")),
                ("prefix_evictions", reg.counter(
                    "prefix_evictions", "cached blocks evicted")),
            ):
                self.stats.bind(key, *_cv(m))
        # Density telemetry: measured once at init from the packed planes so
        # the sparse-dispatch signal is visible per deployment.  The profile
        # decodes one stacked layer slice at a time (bounded host transient)
        # but still walks every plane — pass profile_density=False to skip it
        # for latency-critical starts on very large models.
        self.density = (density_telemetry(self.params)
                        if packed and profile_density else None)
        if self.density is not None:
            self.stats["weight_density_mean"] = self.density["density_mean"]
            self.stats["block_density_mean"] = self.density["block_density_mean"]

        # Execution plan (paper Fig. 5 offline phase): compiled — or loaded,
        # when the caller passes a ``ModelPlan`` saved next to the checkpoint
        # — exactly once at init.  Every jitted step below runs inside
        # ``plan_runtime.activate(self.plan)``, so the packed BitLinear
        # dispatch is a trace-time plan lookup and ZERO ``select_kernel``
        # calls happen after this constructor returns.
        supplied = plan is not None
        if plan is None and packed:
            plan = compile_plan(self.params, BatchProfile(
                decode_ns=(1, batch_slots),
                prefill_ns=(prefill_chunk,
                            batch_slots * (prefill_chunk + 1),
                            token_budget)))
        self.plan = plan
        if self.plan is not None:
            self.stats["plan_layers"] = len(self.plan.layers)
            # Shapes shared by layers with conflicting plans fall back to the
            # default realization (the shape-keyed serve lookup can't tell
            # them apart) — surface the count so operators notice.
            self.stats["plan_shape_conflicts"] = len(self.plan.shape_conflicts())
        if supplied:
            # A loaded plan is only as good as its match to THIS model: a
            # plan saved for another config resolves nothing and would
            # silently serve every layer un-planned while telemetry claims
            # otherwise.
            if not packed:
                warnings.warn(
                    "repro.serving.ServingEngine: a ModelPlan was supplied "
                    "but packed=False — qat serving never consults the plan",
                    UserWarning, stacklevel=2)
            else:
                matched, total = self.plan.coverage(self.params)
                self.stats["plan_matched_layers"] = matched
                if matched < total:
                    warnings.warn(
                        f"repro.serving.ServingEngine: supplied plan resolves "
                        f"only {matched}/{total} BitLinear layers of this "
                        f"model; unmatched layers run the default realization "
                        f"(was the plan compiled for a different config?)",
                        UserWarning, stacklevel=2)

        # Donating the pools lets XLA update the block pools in place instead
        # of holding input + output copies alive across the step (on backends
        # without aliasing support jax falls back to a copy with a warning).
        chunk_jit = jax.jit(
            lambda p, pools, tbl, tk, ps, ln, ei:
            _chunk_call(cfg, p, pools, tbl, tk, ps, ln, ei),
            donate_argnums=(1,))
        flat_jit = jax.jit(
            lambda p, pools, tbl, tk, sl, ps, er:
            _flat_call(cfg, p, pools, tbl, tk, sl, ps, er),
            donate_argnums=(1,))
        prefill_jit = jax.jit(
            lambda p, pools, tbl, b, i:
            _whole_prefill_call(cfg, p, pools, tbl, b, i),
            donate_argnums=(1,))

        def _planned(fn):
            def call(*args):
                with plan_runtime.activate(self.plan):
                    return fn(*args)
            return call

        self._chunk_fn = _planned(chunk_jit)
        self._flat_fn = _planned(flat_jit)
        self._prefill_fn = _planned(prefill_jit)

    # -- request management --------------------------------------------------

    def submit(self, req: Request):
        if req.t_submit is None:
            req.t_submit = time.perf_counter()
        tr = self.tracer
        if tr.enabled:
            tr.begin(req.uid, "req", prompt_len=len(req.prompt),
                     max_new_tokens=req.max_new_tokens)
            tr.begin(req.uid, "queued")
            self._phase[req.uid] = "queued"
        self._queue.append(req)

    def _admit(self):
        rej0 = self.sched.rejections
        admitted = self.sched.admit(self._slots, self._queue, self.kv,
                                    extra_positions=self._extra,
                                    reserve_full=self.policy == "whole",
                                    prefix_cache=self.prefix)
        if self.sched.rejections > rej0:
            # Mirror scheduler rejections (prompt-too-long, finished-ignored
            # at admission) into the registry so goodput denominators and
            # ``stats["rejections"]`` stay honest.
            n_rej = self.sched.rejections - rej0
            self._c_rejections.inc(n_rej)
            if self.incidents is not None:
                self.incidents.observe("rejection", n=n_rej,
                                       queue_len=len(self._queue))
        tr = self.tracer
        for i, st in admitted:
            self._c_admissions.inc()
            if st.req.t_admit is None:
                # First admission only: queueing latency measures the wait
                # for a slot, not re-admission churn after preemption.
                st.req.t_admit = time.perf_counter()
                self._h_queue.observe(st.req.queue_s)
            if tr.enabled:
                # The scheduler already closed the queued span and marked
                # the admission; the prefill phase starts here.
                tr.begin(st.req.uid, "prefill", slot=i,
                         cached_len=st.cached_len)
                self._phase[st.req.uid] = "prefill"
            if self.policy == "whole":
                self._prefill_slot(i, st)
            # chunked: the scheduler interleaves this prompt's chunks with
            # running decodes from the next step() on; a prefix-cache hit
            # already forked the cached leading blocks and advanced the
            # slot's cursor to the fork boundary.

    # -- prefix-cache registration -------------------------------------------

    def _register_prefix(self, i: int, st: SlotState):
        """Register slot ``i``'s current cache content with the prefix
        cache.  The content is exactly ``req.prompt + out_tokens[:-1]``
        truncated to the live length (the final sampled token is emitted but
        its KV row is never written); only FULL blocks are registered, so a
        later writer of the slot's partial tail block never mutates a cached
        block."""
        if self.prefix is None:
            return
        req = st.req
        content = np.concatenate([np.asarray(req.prompt, np.int32),
                                  np.asarray(req.out_tokens, np.int32)])
        content = content[:int(self.kv.lengths[i])]
        self.prefix.insert(content, self.kv.table[i])

    def _sync_prefix_stats(self):
        if self.prefix is not None:
            self.stats.update(self.prefix.stats())

    def _prefill_slot(self, i: int, st: SlotState):
        """Whole-prompt prefill of one slot through the paged cache."""
        cfg = self.cfg
        batch = {"tokens": jnp.asarray(st.prompt, jnp.int32)[None, :]}
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (1, cfg.frontend_seq, cfg.frontend_dim), jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((1, cfg.enc_seq, cfg.d_model), jnp.float32)
        table = self.kv.table_view(self.kv.max_blocks)
        t0 = time.perf_counter()
        sel, self.kv.pools = self._prefill_fn(
            self.params, self.kv.pools, table, batch, jnp.int32(i))
        sel.block_until_ready()
        dt = time.perf_counter() - t0
        self._t_prefill.inc(dt)
        self._c_whole_prefills.inc()
        self._g_step_tokens.set(len(st.prompt) + st.extra)
        if self.tracer.enabled:
            self.tracer.mark(st.req.uid, "prefill_chunk",
                             n=len(st.prompt), start=0, whole=True)
        self.kv.lengths[i] = len(st.prompt) + st.extra
        st.cursor = len(st.prompt)
        tok = int(self._sample(sel, np.array([st.req.temperature]))[0])
        self._emit_token(i, st, tok)

    # -- sampling -------------------------------------------------------------

    def _sample(self, logits, temps: np.ndarray) -> np.ndarray:
        """Per-slot sampling: greedy rows stay deterministic argmax, rows with
        ``temperature > 0`` draw from the tempered categorical (this fixes the
        seed engine's decode path, which ignored request temperatures)."""
        greedy = jnp.argmax(logits, axis=-1)
        if not (temps > 0).any():
            return np.asarray(greedy)
        self.key, sub = jax.random.split(self.key)
        t = jnp.asarray(np.where(temps > 0, temps, 1.0), jnp.float32)
        samp = jax.random.categorical(sub, logits / t[:, None], axis=-1)
        return np.asarray(jnp.where(jnp.asarray(temps > 0), samp, greedy))

    def _emit_token(self, i: int, st: SlotState, tok: int):
        req = st.req
        req.out_tokens.append(tok)
        tr = self.tracer
        first = req.t_first is None
        if first:
            req.t_first = time.perf_counter()
            self._h_ttft.observe(req.ttft)
            if self.incidents is not None:
                self.incidents.request_first_token(req)
        if tr.enabled:
            # A token emission always means the prompt is fully in cache —
            # close the prefill phase (also after a re-prefill following
            # preemption, where it isn't the request's first token).
            if self._phase.get(req.uid) == "prefill":
                tr.end(req.uid, "prefill")
                tr.begin(req.uid, "decode")
                self._phase[req.uid] = "decode"
            if first:
                tr.mark(req.uid, "first_token")
        self._c_total_tokens.inc()
        if (len(req.out_tokens) >= req.max_new_tokens
                or self.kv.lengths[i] >= self.max_len - 1):
            req.done = True
            req.t_done = time.perf_counter()
            self._h_tpot.observe(req.tpot)
            if self.incidents is not None:
                self.incidents.request_finished(req)
            if tr.enabled:
                tr.end(req.uid, "decode")
                tr.mark(req.uid, "finished", n_out=len(req.out_tokens),
                        preemptions=req.n_preempted)
                tr.end(req.uid, "req")
                self._phase.pop(req.uid, None)
            # Register prompt + generated tokens (multi-turn reuse: a
            # follow-up request quoting this conversation hits them) while
            # the slot still holds its block references.
            self._register_prefix(i, st)
            self.kv.free_slot(i)
            self._slots[i] = None
        else:
            st.last_tok = tok

    # -- main loop ------------------------------------------------------------

    def step(self) -> bool:
        """One engine step: admit, then one mixed prefill-chunk/decode call.
        Returns False when there was nothing to do."""
        self._admit()
        flat = self.policy == "flat"

        def _plan():
            if flat:
                return self.sched.plan_flat(self._slots, self.kv,
                                            self.token_budget)
            return self.sched.plan(self._slots, self.kv)

        plan = _plan()
        while isinstance(plan, Preempt):
            self._preempt(plan.slot)
            plan = _plan()
        if plan is None:
            return False

        table = self.kv.table_view(plan.view_blocks)
        step_no = self._c_steps.value
        # planned = the static step width: the rows the jitted matmuls
        # actually multiply (flat: T; rectangular: the padded B*C).
        # realized/planned is the step-budget utilization the timeline CLI
        # reports; 1 - it is exactly the padding waste the flat layout
        # removes.
        planned = plan.width if flat else self.slots * plan.chunk
        ann = (obs_trace.step_annotation(step_no) if self._profile_steps
               else contextlib.nullcontext())
        t0 = time.perf_counter()
        with ann:
            if flat:
                sel, self.kv.pools = self._flat_fn(
                    self.params, self.kv.pools, table,
                    jnp.asarray(plan.tokens), jnp.asarray(plan.slot),
                    jnp.asarray(plan.pos), jnp.asarray(plan.emit_row))
            else:
                sel, self.kv.pools = self._chunk_fn(
                    self.params, self.kv.pools, table,
                    jnp.asarray(plan.tokens), jnp.asarray(plan.pos),
                    jnp.asarray(plan.lengths), jnp.asarray(plan.emit_idx))
            sel.block_until_ready()
        dt = time.perf_counter() - t0

        self._c_steps.inc()
        self._c_planned.inc(planned)
        self._c_realized.inc(plan.real_tokens)
        self._g_step_tokens.set(plan.real_tokens)
        self._g_kv.set(int(self.kv.blocks_in_use))
        self._c_prefill_tokens.inc(plan.prefill_tokens)
        if plan.prefill_tokens > 0:
            self._t_prefill.inc(dt)
            self._c_prefill_steps.inc()
        else:
            self._t_decode.inc(dt)
            self._c_decode_steps.inc()
            self._c_decode_tokens.inc(plan.decode_tokens)

        tr = self.tracer
        if tr.enabled:
            tr.step(dt, step=step_no, planned=planned,
                    realized=plan.real_tokens,
                    prefill_tokens=plan.prefill_tokens,
                    decode_tokens=plan.decode_tokens,
                    kv_blocks=int(self.kv.blocks_in_use),
                    active_slots=sum(1 for s in self._slots if s is not None),
                    kernel=(self.plan.dominant_kernel(planned)
                            if self.plan is not None else None))

        toks = None
        if plan.emit.any():
            temps = np.array([
                self._slots[i].req.temperature if plan.emit[i] else 0.0
                for i in range(self.slots)], np.float32)
            toks = self._sample(sel, temps)
        for i in range(self.slots):
            st = self._slots[i]
            if st is None or plan.n_real[i] == 0:
                continue
            self.kv.lengths[i] += int(plan.n_real[i])
            advanced = plan.advances_prefill(i)
            if advanced:
                if tr.enabled:
                    tr.mark(st.req.uid, "prefill_chunk",
                            n=int(plan.n_real[i]), start=st.cursor)
                st.cursor += int(plan.n_real[i])
            if plan.emit[i]:
                self._emit_token(i, st, int(toks[i]))
            if advanced and not st.prefilling and self._slots[i] is not None:
                # Prompt fully in cache and the request is still live:
                # register its full blocks NOW so requests sharing this
                # prefix hit it while this one is still decoding
                # (system-prompt sharing, the dominant multi-tenant
                # pattern).  Checked AFTER the emit: a request finishing on
                # its first sampled token was already registered by
                # ``_emit_token`` — registering here too would walk the tree
                # twice for the same content (satellite fix, pinned in
                # tests/test_prefix_cache.py).
                self._register_prefix(i, st)
        self._sync_prefix_stats()
        if self.incidents is not None:
            ev = (int(self.stats["prefix_evictions"])
                  if self.prefix is not None else 0)
            self.incidents.step_tick(
                evictions=max(0, ev - self._evictions_seen))
            self._evictions_seen = ev
        return True

    def _preempt(self, i: int):
        """Recompute-style preemption (vLLM): return the youngest request to
        the queue head; its prompt + generated tokens re-prefill later.

        Before the victim's blocks are released, its already-computed FULL
        blocks are registered into the prefix cache (when one is attached):
        the blocks exist and are correct whether or not the prefill ever
        finished, so recompute-preemption's re-admission forks them back and
        re-prefills only the partial tail — preempting a request no longer
        throws away the prefill work it already paid for (the cached blocks
        stay evictable, so under real pressure the allocator can still
        reclaim them before any live request is preempted)."""
        st = self._slots[i]
        tr = self.tracer
        if tr.enabled:
            uid = st.req.uid
            ph = self._phase.get(uid)
            if ph in ("prefill", "decode"):
                tr.end(uid, ph, preempted=True)
            tr.mark(uid, "preempted", slot=i, cursor=st.cursor,
                    cached_len=st.cached_len)
            tr.begin(uid, "queued")
            self._phase[uid] = "queued"
        self._register_prefix(i, st)
        self.kv.free_slot(i)
        self._slots[i] = None
        self._queue.insert(0, st.req)
        self._c_preemptions.inc()
        st.req.n_preempted += 1
        if self.incidents is not None:
            self.incidents.observe("preemption", uid=st.req.uid, slot=i,
                                   cursor=st.cursor,
                                   n_preempted=st.req.n_preempted)

    @property
    def busy(self) -> bool:
        """True while any request is queued or resident in a slot."""
        return bool(self._queue) or any(s is not None for s in self._slots)

    @property
    def queue_len(self) -> int:
        return len(self._queue)

    def run(self, requests: list[Request]) -> list[Request]:
        for r in requests:
            self.submit(r)
        while self.busy:
            if not self.step() and self._queue:
                # Every slot is free yet the head-of-queue request still
                # failed the admission gate: the pool can never cover it.
                raise RuntimeError(
                    f"request uid={self._queue[0].uid} cannot be admitted: "
                    f"KV pool ({self.kv.num_blocks - 1} blocks of "
                    f"{self.kv.block_size}) smaller than the admission gate; "
                    "raise kv_blocks or lower prefill_chunk/max_len")
        return requests

    # -- benchmarking hooks ---------------------------------------------------

    def warmup(self, seq_len: int | None = None) -> None:
        """Compile the jitted step paths (prefill-chunk width, pure decode,
        and the block-table view buckets up to ``seq_len`` total positions)
        on a throwaway request, then :meth:`reset_run_stats` — so benchmark
        percentiles measure steady-state serving rather than XLA compile
        time.  Must be called on an idle engine."""
        if self.busy:
            raise RuntimeError("warmup() requires an idle engine")
        total = seq_len or (self.prefill_chunk + 3)
        # Leave room for the generated tokens + the headroom position.
        plen = max(2, min(total - 2, self.max_len - self._extra - 3))
        rng = np.random.default_rng(0x7e57)
        prompt = rng.integers(0, self.cfg.vocab_size, size=plen,
                              dtype=np.int32)
        self.run([Request(uid=-1, prompt=prompt, max_new_tokens=2)])
        self.reset_run_stats()

    def reset_run_stats(self) -> None:
        """Zero the per-run counters, drop any prefix-cache state, and clear
        recorded trace events, keeping init-time telemetry (plan/density
        keys).  Peak gauges (``peak_kv_blocks``/``max_step_tokens``) are
        REBASED to the post-reset live values rather than blindly zeroed, so
        warm-up can never leak into steady-state peaks while state the
        engine genuinely still holds is never undercounted.  Requires an
        idle engine; used by the workload runner after :meth:`warmup`."""
        if self.busy:
            raise RuntimeError("reset_run_stats() requires an idle engine")
        if self.prefix is not None:
            # All slots are free, so every cached block is evictable; a
            # fresh tree also resets the hit/miss telemetry.
            self.prefix.evict(self.prefix.cached_blocks, cause="reset")
            self.prefix = PrefixCache(self.kv,
                                      capacity_blocks=self.prefix.capacity)
            self.prefix.tracer = self.tracer
        self.sched.prefill_tokens_planned = 0
        self.sched.cached_tokens_skipped = 0
        self.sched.readmissions = 0
        self.sched.rejections = 0
        # Refresh gauge values to post-reset reality FIRST, then let the
        # registry reset counters/histograms and rebase every gauge peak to
        # its current value.
        self._g_kv.set(int(self.kv.blocks_in_use))
        self._g_step_tokens.set(0)
        self.metrics.reset_run()
        self._sync_prefix_stats()
        # A streaming sink truncates its on-disk segments here too, so
        # warm-up events never leak into saved long-run traces.
        self.tracer.reset()
        self._evictions_seen = 0
        if self.incidents is not None:
            # Warm-up incidents (e.g. a compile-inflated TTFT breach) are
            # noise: discard their files and re-arm the debouncing.
            self.incidents.reset_run()

    # -- metrics --------------------------------------------------------------

    def throughput(self) -> float:
        """Steady-state decode tokens/s (pure-decode steps only)."""
        return self.stats["decode_tokens"] / max(self.stats["decode_s"], 1e-9)

    def max_step_tokens(self) -> int:
        return self.stats["max_step_tokens"]

    def latency_stats(self, requests: list[Request]) -> dict:
        """Aggregate TTFT/TPOT over finished requests (seconds)."""
        ttfts = [r.ttft for r in requests if r.ttft is not None]
        tpots = [r.tpot for r in requests if r.tpot is not None]
        mean = lambda xs: sum(xs) / len(xs) if xs else float("nan")
        return {
            "ttft_mean_s": mean(ttfts),
            "ttft_max_s": max(ttfts, default=float("nan")),
            "tpot_mean_s": mean(tpots),
            "n": len(ttfts),
        }

    def latency_percentiles(self) -> dict:
        """{ttft_s, tpot_s, queue_s} -> {p50, p90, p99, mean, max, n} from
        the registry histograms — tail latencies straight off the engine,
        no external runner replay required."""
        return {name: self.metrics.get(name).summary()
                for name in ("ttft_s", "tpot_s", "queue_s")}
