"""Block-paged KV cache for the serving engine (vLLM-style paged attention,
adapted to static-shape JAX).

Device memory holds one *pool* of fixed-size token blocks per attention K/V
leaf, (L, num_blocks, block_size, Hkv, Dh), instead of a dense
(slots, max_len) cache — so resident KV memory is proportional to live
tokens, not to ``slots * max_len``.  A host-side free-list allocator hands
blocks to slots; each slot's logical token positions map onto pool blocks
through a per-slot block table.

Before each model call the engine gathers the active slots' blocks into a
contiguous (L, B, V, Hkv, Dh) view (V is a power-of-two bucket of block
counts, so the jitted step re-traces only O(log max_len) times), runs the
step, and scatters the view's blocks back.  Gather/scatter live in
``model_zoo.gather_cache_view`` / ``scatter_cache_view`` and are fused into
the engine's jitted step.

Block 0 is reserved scratch: unallocated table entries point at it, so the
static-shape gather/scatter of a short slot's padding reads/writes garbage
that the causal mask guarantees is never attended.  O(1)-per-slot state (SSM
conv tail + SSD state, enc-dec cross K/V) is not paged; it stays dense with a
leading slot axis inside the same cache pytree.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from repro.models import model_zoo


class PagedKVCache:
    """Free-list block allocator + block tables over ``model_zoo`` pools."""

    def __init__(self, cfg, slots: int, max_len: int, *, block_size: int = 16,
                 num_blocks: int | None = None, dtype=jnp.float32):
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.block_size = block_size
        self.max_blocks = max(1, math.ceil(max_len / block_size))
        if num_blocks is None:
            # Safe default: every slot can grow to max_len (+1 scratch block).
            num_blocks = slots * self.max_blocks + 1
        if num_blocks < 2:
            raise ValueError("need at least one scratch + one real block")
        self.num_blocks = num_blocks
        self.pools = model_zoo.init_paged_cache(cfg, slots, num_blocks,
                                                block_size, dtype)
        # Host-side allocator state.  Block 0 is reserved scratch.
        self._free: list[int] = list(range(num_blocks - 1, 0, -1))
        self.table = np.zeros((slots, self.max_blocks), np.int32)
        self.n_blocks = np.zeros(slots, np.int32)     # allocated blocks / slot
        self.lengths = np.zeros(slots, np.int32)      # live tokens / slot

    # -- allocator ----------------------------------------------------------

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.block_size)

    def can_allocate(self, n_tokens: int) -> bool:
        return len(self._free) >= self.blocks_for(n_tokens)

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot``'s table to cover ``n_tokens`` positions.  Returns
        False (allocating nothing) if the free list cannot cover the growth."""
        need = self.blocks_for(n_tokens)
        if need > self.max_blocks:
            raise ValueError(
                f"slot {slot}: {n_tokens} tokens > max_len {self.max_len}")
        grow = need - int(self.n_blocks[slot])
        if grow <= 0:
            return True
        if grow > len(self._free):
            return False
        for j in range(int(self.n_blocks[slot]), need):
            self.table[slot, j] = self._free.pop()
        self.n_blocks[slot] = need
        return True

    def free_slot(self, slot: int) -> None:
        """Return a finished slot's blocks to the free list.  Block contents
        are recycled dirty — safe because a new request starts at length 0 and
        the causal mask never reads past a slot's live length."""
        for j in range(int(self.n_blocks[slot])):
            self._free.append(int(self.table[slot, j]))
        self.table[slot, :] = 0
        self.n_blocks[slot] = 0
        self.lengths[slot] = 0

    # -- step views ---------------------------------------------------------

    def view_blocks(self, n_tokens: int) -> int:
        """Power-of-two bucket of blocks covering ``n_tokens`` positions
        (bounds jit re-traces of the engine step to O(log max_blocks)).

        May exceed ``max_blocks``: a chunk-wide write starting near max_len
        must fit inside the view, otherwise ``dynamic_update_slice`` would
        clamp the start and overwrite live positions.  ``table_view`` pads
        the extra columns with scratch-block entries."""
        need = max(1, self.blocks_for(max(1, n_tokens)))
        vb = 1
        while vb < need:
            vb *= 2
        return vb

    def table_view(self, view_blocks: int) -> jnp.ndarray:
        if view_blocks <= self.max_blocks:
            return jnp.asarray(self.table[:, :view_blocks])
        pad = np.zeros((self.slots, view_blocks - self.max_blocks), np.int32)
        return jnp.asarray(np.concatenate([self.table, pad], axis=1))

    def live_tokens(self) -> int:
        return int(self.lengths.sum())
