"""Block-paged KV cache for the serving engine (vLLM-style paged attention,
adapted to static-shape JAX).

Device memory holds one *pool* of fixed-size token blocks per attention K/V
leaf, (L, num_blocks, block_size, Hkv, Dh), instead of a dense
(slots, max_len) cache — so resident KV memory is proportional to live
tokens, not to ``slots * max_len``.  A host-side free-list allocator hands
blocks to slots; each slot's logical token positions map onto pool blocks
through a per-slot block table.

Before each model call the engine gathers the active slots' blocks into a
contiguous (L, B, V, Hkv, Dh) view (V is a power-of-two bucket of block
counts, so the jitted step re-traces only O(log max_len) times), runs the
step, and scatters the view's blocks back.  Gather/scatter live in
``model_zoo.gather_cache_view`` / ``scatter_cache_view`` and are fused into
the engine's jitted step.

Block 0 is reserved scratch: unallocated table entries point at it, so the
static-shape gather/scatter of a short slot's padding reads/writes garbage
that the causal mask guarantees is never attended.  O(1)-per-slot state (SSM
conv tail + SSD state, enc-dec cross K/V) is not paged; it stays dense with a
leading slot axis inside the same cache pytree.

Blocks are **ref-counted** so the prefix cache (``serving.prefix_cache``) can
share one physical block between several slots and its own radix tree:

* ``ensure`` allocates exclusive blocks (refcount 1);
* ``fork_blocks`` installs existing blocks into an empty slot's table and
  takes a reference each — the block-sharing primitive behind prefix reuse
  (the forked region is read-only by construction: every write lands at
  offsets >= the fork boundary, which is block-aligned);
* ``release`` / ``free_slot`` drop references; a block returns to the free
  list only when its LAST holder lets go, so recompute-preemption of one
  request can never corrupt blocks another request (or the prefix cache)
  still reads;
* ``acquire`` takes an extra reference on an already-owned block (the prefix
  cache registering a finished prefix);
* ``evictor`` — an optional object with ``evictable() -> int`` and
  ``evict(n) -> int`` — is consulted by ``ensure``/``can_allocate`` when the
  free list runs short, so cached-but-unreferenced blocks are reclaimed
  before the scheduler resorts to preempting a live request.

``block_hash`` carries the prefix cache's chained content hash per cached
block (stamped at registration, dropped when the block is freed) — purely
introspective, but it lets tests assert the tree and the pool agree.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from repro.models import model_zoo
from repro.obs import NULL_TRACER


class PagedKVCache:
    """Free-list block allocator + block tables over ``model_zoo`` pools."""

    def __init__(self, cfg, slots: int, max_len: int, *, block_size: int = 16,
                 num_blocks: int | None = None, dtype=jnp.float32):
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.block_size = block_size
        self.max_blocks = max(1, math.ceil(max_len / block_size))
        if num_blocks is None:
            # Safe default: every slot can grow to max_len (+1 scratch block).
            num_blocks = slots * self.max_blocks + 1
        if num_blocks < 2:
            raise ValueError("need at least one scratch + one real block")
        self.num_blocks = num_blocks
        self.pools = model_zoo.init_paged_cache(cfg, slots, num_blocks,
                                                block_size, dtype)
        # Host-side allocator state.  Block 0 is reserved scratch.
        self._free: list[int] = list(range(num_blocks - 1, 0, -1))
        self.table = np.zeros((slots, self.max_blocks), np.int32)
        self.n_blocks = np.zeros(slots, np.int32)     # allocated blocks / slot
        self.lengths = np.zeros(slots, np.int32)      # live tokens / slot
        self.refcount = np.zeros(num_blocks, np.int32)
        self.refcount[0] = 1                          # scratch: pinned forever
        self.block_hash: dict[int, int] = {}          # cached-content hashes
        self.evictor = None                           # set by PrefixCache
        self.tracer = NULL_TRACER                     # set by ServingEngine
        self.incidents = None                         # set by ServingEngine

    # -- allocator ----------------------------------------------------------

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.block_size)

    def can_allocate(self, n_tokens: int) -> bool:
        """Can the pool cover ``n_tokens`` of fresh blocks?  Counts blocks the
        evictor could reclaim (cached, referenced by nobody else) alongside
        the free list — a pool full of stale cached prefixes is still
        allocatable, the eviction just happens inside :meth:`ensure`."""
        avail = len(self._free)
        if self.evictor is not None:
            avail += self.evictor.evictable()
        return avail >= self.blocks_for(n_tokens)

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot``'s table to cover ``n_tokens`` positions.  Returns
        False (allocating nothing) if the free list — after asking the
        evictor to reclaim unreferenced cached blocks — cannot cover the
        growth."""
        need = self.blocks_for(n_tokens)
        if need > self.max_blocks:
            raise ValueError(
                f"slot {slot}: {n_tokens} tokens > max_len {self.max_len}")
        grow = need - int(self.n_blocks[slot])
        if grow <= 0:
            return True
        if grow > len(self._free) and self.evictor is not None:
            if self.tracer.enabled:
                # Allocator pressure: the free list alone can't cover this
                # growth and the evictor is being consulted — the causal
                # precursor of prefix evictions and (if those fall short)
                # preemptions in the timeline analysis.
                self.tracer.instant("kv_pressure", slot=slot, need=grow,
                                    free=len(self._free))
            if self.incidents is not None:
                # Outside the tracer guard: incident snapshots fire with
                # tracing on or off.
                self.incidents.observe("kv_pressure", slot=slot, need=grow,
                                       free=len(self._free))
            self.evictor.evict(grow - len(self._free))
        if grow > len(self._free):
            return False
        for j in range(int(self.n_blocks[slot]), need):
            b = self._free.pop()
            self.refcount[b] = 1
            self.table[slot, j] = b
        self.n_blocks[slot] = need
        return True

    def acquire(self, block: int) -> None:
        """Take an extra reference on an already-referenced block (prefix-
        cache registration of a live slot's block)."""
        if block == 0 or self.refcount[block] < 1:
            raise ValueError(f"acquire of unowned block {block}")
        self.refcount[block] += 1

    def release(self, block: int) -> None:
        """Drop one reference; the last holder returns the block to the free
        list.  Contents are recycled dirty — safe because a new owner starts
        writing at offset 0 of its logical positions and the causal mask
        never reads past a slot's live length."""
        if block == 0:
            raise ValueError("release of the scratch block")
        self.refcount[block] -= 1
        if self.refcount[block] < 0:
            raise AssertionError(f"refcount underflow on block {block}")
        if self.refcount[block] == 0:
            self._free.append(block)
            self.block_hash.pop(block, None)

    def fork_blocks(self, slot: int, blocks: list[int]) -> None:
        """Install shared ``blocks`` as the leading entries of an EMPTY
        slot's table, taking one reference each.  The caller (prefix cache)
        guarantees the slot only ever writes at positions >= the forked
        region, so no copy is needed until/unless content diverges — and
        divergence is handled at block granularity by simply not sharing the
        diverging block (recompute instead of copy)."""
        if int(self.n_blocks[slot]) != 0:
            raise ValueError(f"fork into non-empty slot {slot}")
        if len(blocks) > self.max_blocks:
            raise ValueError(f"fork of {len(blocks)} blocks > max_blocks")
        for j, b in enumerate(blocks):
            if b == 0 or self.refcount[b] < 1:
                raise ValueError(f"fork of unowned block {b}")
            self.refcount[b] += 1
            self.table[slot, j] = b
        self.n_blocks[slot] = len(blocks)

    def free_slot(self, slot: int) -> None:
        """Release a finished slot's block references.  Blocks shared with
        the prefix cache (or another slot) survive with their remaining
        holders; exclusively-owned blocks return to the free list."""
        for j in range(int(self.n_blocks[slot])):
            self.release(int(self.table[slot, j]))
        self.table[slot, :] = 0
        self.n_blocks[slot] = 0
        self.lengths[slot] = 0

    # -- step views ---------------------------------------------------------

    def view_blocks(self, n_tokens: int) -> int:
        """Power-of-two bucket of blocks covering ``n_tokens`` positions
        (bounds jit re-traces of the engine step to O(log max_blocks)).

        May exceed ``max_blocks``: a chunk-wide write starting near max_len
        must fit inside the view, otherwise ``dynamic_update_slice`` would
        clamp the start and overwrite live positions.  ``table_view`` pads
        the extra columns with scratch-block entries."""
        need = max(1, self.blocks_for(max(1, n_tokens)))
        vb = 1
        while vb < need:
            vb *= 2
        return vb

    def table_view(self, view_blocks: int) -> jnp.ndarray:
        if view_blocks <= self.max_blocks:
            return jnp.asarray(self.table[:, :view_blocks])
        pad = np.zeros((self.slots, view_blocks - self.max_blocks), np.int32)
        return jnp.asarray(np.concatenate([self.table, pad], axis=1))

    def live_tokens(self) -> int:
        return int(self.lengths.sum())

    # -- invariants ----------------------------------------------------------

    def check(self) -> None:
        """Allocator invariants (test/debug hook): refcounts never negative,
        the free list holds exactly the zero-refcount blocks, and every live
        table entry references a held block."""
        assert (self.refcount >= 0).all(), "negative refcount"
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate free-list entry"
        for b in range(1, self.num_blocks):
            if b in free:
                assert self.refcount[b] == 0, f"free block {b} still referenced"
            else:
                assert self.refcount[b] >= 1, f"leaked block {b} (refcount 0)"
        for s in range(self.slots):
            for j in range(int(self.n_blocks[s])):
                b = int(self.table[s, j])
                assert b != 0 and self.refcount[b] >= 1, (s, j, b)
