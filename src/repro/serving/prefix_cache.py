"""Prefix-caching KV reuse: a block-granular radix tree over token-ID
prefixes, with ref-counted KV blocks and LRU eviction.

T-SAR's in-register GEMV makes decode compute nearly free, so at serving
scale the cost center shifts to prefill work and KV memory traffic.  Real
multi-tenant traffic is dominated by shared prefixes — system prompts,
few-shot templates, multi-turn history — and the block-paged KV cache
already stores K/V in fixed-size blocks, the natural substrate for
automatic reuse: if two requests share their first ``k * block_size``
tokens, their first ``k`` KV blocks are bit-identical (RoPE is applied at
absolute positions, and a shared prefix starts at position 0), so the
second request can *fork* the first one's blocks instead of recomputing
them.

Data structure
--------------

A radix tree keyed by **full blocks** of token IDs: each node stands for one
pool block whose ``block_size`` tokens extend its parent's prefix.  Nodes
carry a chained content hash (``hash(parent_hash, block_tokens)``) stamped
into ``PagedKVCache.block_hash`` so the tree and the pool can be
cross-checked.  The tree holds one pool reference per cached block
(``kv.acquire`` at registration), on top of whatever references live slots
hold — so the pool-level refcount is the single source of truth for "may
this block be freed".

Correctness invariants (enforced by construction, asserted in
``tests/test_prefix_cache.py``):

* **no block is freed while referenced** — blocks only return to the free
  list through ``kv.release`` when the last holder lets go;
* **eviction never touches live slots** — a node is evictable only when it
  is a leaf and the cache holds the block's ONLY reference
  (``refcount == 1``); interior nodes become evictable leaf-by-leaf, so a
  chain a slot still reads is never broken mid-path;
* **the hit path is token-identical to the cold path** — a fork installs
  blocks whose contents equal what the slot's own prefill would have
  written (same tokens, same absolute positions, same deterministic math),
  and the fork boundary is block-aligned and <= ``len(prompt) - 1``, so the
  partial last block and at least one real token are always recomputed
  (the recomputed chunk produces the first logit; copy-on-write divergence
  therefore reduces to "don't share the diverging block").

Eviction is LRU over evictable leaves: every match/registration touch
stamps a monotone tick along the path, and ``evict`` removes the
least-recently-used evictable leaf first — either on demand when the
allocator runs short (``kv.evictor`` hook, consulted by
``PagedKVCache.ensure`` *before* the scheduler resorts to preempting a live
request) or eagerly when a ``capacity_blocks`` bound is exceeded.
"""
from __future__ import annotations

import numpy as np

from repro.obs import NULL_TRACER

_ROOT_HASH = hash("tsar-prefix-root")


def chain_hash(parent_hash: int, key: tuple) -> int:
    """Chained content hash of one block extending ``parent_hash``."""
    return hash((parent_hash, key))


class _Node:
    __slots__ = ("key", "hash", "block", "parent", "children", "last_used")

    def __init__(self, key, h, block, parent):
        self.key = key          # tuple of this block's token IDs
        self.hash = h           # chain_hash(parent.hash, key)
        self.block = block      # pool block id holding the KV rows
        self.parent = parent
        self.children: dict = {}
        self.last_used = 0


class PrefixCache:
    """Ref-counted radix cache over a :class:`PagedKVCache` block pool.

    The cache registers itself as ``kv.evictor`` so allocator pressure
    reclaims stale cached blocks before any live request is preempted.
    """

    def __init__(self, kv, capacity_blocks: int | None = None):
        if capacity_blocks is not None and capacity_blocks < 1:
            raise ValueError(f"capacity_blocks={capacity_blocks} must be >= 1")
        self.kv = kv
        self.block_size = kv.block_size
        self.capacity = capacity_blocks   # None: bounded only by the pool
        self.root = _Node((), _ROOT_HASH, -1, None)
        self._size = 0
        self._tick = 0
        # -- telemetry --
        self.lookups = 0          # fork() calls (one per chunked admission)
        self.hits = 0             # forks that reused >= 1 block
        self.hit_tokens = 0       # prompt tokens served from cache
        self.miss_tokens = 0      # prompt tokens that had to be prefilled
        self.evictions = 0
        # insert() CALLS (not blocks added): the engine registers each
        # request's content exactly once per lifecycle event — the
        # double-registration regression (prefill-end + finish in the same
        # step) is pinned against this in tests/test_prefix_cache.py.
        self.inserts = 0
        self.tracer = NULL_TRACER   # set by ServingEngine
        kv.evictor = self

    # -- properties ----------------------------------------------------------

    @property
    def cached_blocks(self) -> int:
        return self._size

    @property
    def hit_rate(self) -> float:
        """Fraction of admitted prompt tokens served from cache."""
        total = self.hit_tokens + self.miss_tokens
        return self.hit_tokens / total if total else 0.0

    # -- lookup / fork -------------------------------------------------------

    def _walk(self, tokens) -> list[_Node]:
        """Longest cached full-block path matching ``tokens``, capped so the
        last token (and any partial last block) is always recomputed."""
        bs = self.block_size
        cap_blocks = max(0, (len(tokens) - 1) // bs)
        node, path = self.root, []
        for j in range(cap_blocks):
            key = tuple(int(t) for t in tokens[j * bs:(j + 1) * bs])
            nxt = node.children.get(key)
            if nxt is None:
                break
            path.append(nxt)
            node = nxt
        return path

    def match(self, tokens) -> tuple[int, list[int]]:
        """(cached_len, block_ids) for the longest reusable prefix.  Pure
        query: no references taken, no stats or LRU updates — the admission
        gate uses this to size its block budget before committing."""
        path = self._walk(tokens)
        return len(path) * self.block_size, [n.block for n in path]

    def fork(self, slot: int, tokens) -> int:
        """Install the longest cached prefix of ``tokens`` into empty
        ``slot`` (one pool reference per block, ``kv.lengths`` advanced to
        the fork boundary) and return ``cached_len``.  Counts hit/miss
        telemetry — call exactly once per chunked admission."""
        self.lookups += 1
        path = self._walk(tokens)
        self._tick += 1
        for n in path:
            n.last_used = self._tick
        cached = len(path) * self.block_size
        self.hit_tokens += cached
        self.miss_tokens += len(tokens) - cached
        if path:
            self.hits += 1
            self.kv.fork_blocks(slot, [n.block for n in path])
            self.kv.lengths[slot] = cached
        return cached

    # -- registration --------------------------------------------------------

    def insert(self, tokens, table_row) -> int:
        """Register a slot's finished prefix: every FULL block of ``tokens``
        (whose KV rows live at ``table_row[j]``) joins the tree.  Existing
        nodes are touched, not replaced — concurrent cold prefills of the
        same prompt produce bit-identical blocks, so first-writer-wins is
        sound and the loser's blocks simply stay exclusive to its slot.
        Returns the number of newly cached blocks."""
        bs = self.block_size
        self.inserts += 1
        n_full = len(tokens) // bs
        node, added = self.root, 0
        self._tick += 1
        for j in range(n_full):
            key = tuple(int(t) for t in tokens[j * bs:(j + 1) * bs])
            child = node.children.get(key)
            if child is None:
                blk = int(table_row[j])
                child = _Node(key, chain_hash(node.hash, key), blk, node)
                self.kv.acquire(blk)              # cache's own reference
                self.kv.block_hash[blk] = child.hash
                node.children[key] = child
                self._size += 1
                added += 1
            child.last_used = self._tick
            node = child
        if added and self.tracer.enabled:
            self.tracer.instant("prefix_insert", added=added,
                                cached_blocks=self._size)
        if self.capacity is not None and self._size > self.capacity:
            self.evict(self._size - self.capacity, cause="capacity")
        return added

    # -- eviction (the kv.evictor protocol) ----------------------------------

    def evictable(self) -> int:
        """Blocks the cache could free right now: nodes whose whole subtree
        is unreferenced outside the cache (leaf-first eviction reaches them
        all)."""

        def rec(n: _Node) -> tuple[int, bool]:
            cnt, all_ok = 0, True
            for c in n.children.values():
                c_cnt, c_ok = rec(c)
                cnt += c_cnt
                all_ok = all_ok and c_ok
            if n is self.root:
                return cnt, True
            ok = all_ok and int(self.kv.refcount[n.block]) == 1
            return cnt + (1 if ok else 0), ok

        return rec(self.root)[0]

    def evict(self, n: int, cause: str = "pressure") -> int:
        """Free up to ``n`` cached blocks, least-recently-used evictable
        leaf first.  Never touches a block any slot still references.
        ``cause`` labels the traced eviction event: ``"pressure"`` (the
        allocator ran short — the ``kv.evictor`` hook's default),
        ``"capacity"`` (the ``capacity_blocks`` bound), ``"reset"``."""
        freed = 0
        while freed < n:
            leaf = None
            stack = [self.root]
            while stack:
                nd = stack.pop()
                for c in nd.children.values():
                    if c.children:
                        stack.append(c)
                    elif int(self.kv.refcount[c.block]) == 1:
                        if leaf is None or c.last_used < leaf.last_used:
                            leaf = c
            if leaf is None:
                break                      # everything left is still live
            del leaf.parent.children[leaf.key]
            self.kv.release(leaf.block)
            self._size -= 1
            self.evictions += 1
            freed += 1
        if freed and self.tracer.enabled:
            self.tracer.instant("prefix_evict", n=freed, cause=cause)
        return freed

    # -- invariants ----------------------------------------------------------

    def check(self) -> None:
        """Tree/pool consistency (test hook): every cached block is held,
        hashes chain correctly, and the size counter matches the tree."""
        n = 0
        stack = [self.root]
        while stack:
            nd = stack.pop()
            for c in nd.children.values():
                assert c.block != 0, "cache holds the scratch block"
                assert int(self.kv.refcount[c.block]) >= 1, c.block
                assert c.hash == chain_hash(nd.hash, c.key)
                assert self.kv.block_hash.get(c.block) == c.hash
                assert len(c.key) == self.block_size
                n += 1
                stack.append(c)
        assert n == self._size, (n, self._size)
        self.kv.check()

    def stats(self) -> dict:
        return {
            "cached_blocks": self.cached_blocks,
            "prefix_hit_rate": self.hit_rate,
            "prefix_hit_tokens": self.hit_tokens,
            "prefix_lookups": self.lookups,
            "prefix_evictions": self.evictions,
        }
