"""``repro.analysis`` — AST-based invariant linter for the repo's unwritten
contracts.

Three subsystems rest on conventions no runtime test can fully enforce:

* the jitted serving step must stay **pure and retrace-stable** (host
  side effects inside a traced body run once at trace time and silently
  disappear from every later call; unhashable jit statics retrace forever);
* every observability emit site must guard on ``tracer.enabled`` so
  traced and untraced runs stay **bit-identical** (the PR 7 contract);
* the kernel registry promises every ``KernelImpl`` an oracle and a
  conformance row, and schema-versioned artifacts promise their
  validators and docs **agree on the version**.

This package checks those invariants statically, on the stdlib ``ast``
only (no third-party deps, so the CI lint lane needs no installs):

* :mod:`repro.analysis.engine` — source loading, suppression comments
  (``# repro: ignore[rule-name]``), finding model, rule driver;
* :mod:`repro.analysis.callgraph` — best-effort project call graph rooted
  at ``jax.jit`` call sites / ``chunk_step`` entry points;
* :mod:`repro.analysis.rules_jit` — ``jit-purity``, ``retrace-hazard``,
  ``traced-branch``;
* :mod:`repro.analysis.rules_obs` — ``tracer-guard``;
* :mod:`repro.analysis.rules_project` — ``registry-completeness``,
  ``schema-drift`` (cross-module rules);
* :mod:`repro.analysis.inventory` — the shared AST inventory (kernel
  names, conformance rows, schema-version constants) that
  ``tests/test_conformance.py`` also imports, so the static check and the
  runtime completeness gate can never disagree on the kernel list;
* :mod:`repro.analysis.baseline` — committed-findings baseline with an
  add/expire workflow;
* :mod:`repro.analysis.cli` — ``python -m repro.analysis [--json]
  [--baseline FILE] [--update-baseline]``.

See docs/static-analysis.md for the rule catalog and workflows.
"""
from repro.analysis.engine import (  # noqa: F401
    DEFAULT_PATHS,
    Finding,
    Project,
    all_rules,
    analyze,
)
