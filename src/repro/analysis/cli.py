"""``python -m repro.analysis`` — run the invariant linter.

Exit status: 0 when every finding is baselined and no baseline entry has
expired; 1 otherwise (new findings, expired baseline entries, or parse
errors).  ``--json`` prints a machine-readable report (schema below);
``--update-baseline`` rewrites the baseline to the current findings and
exits 0.

JSON report schema (``report_version`` 1)::

    {
      "report_version": 1,
      "root": "<abs path>",
      "paths": ["src", "benchmarks", "examples"],
      "rules": [{"name": ..., "summary": ...}, ...],
      "findings": [{"rule", "path", "line", "message", "baselined"}, ...],
      "counts": {"total": N, "new": N, "baselined": N, "expired": N},
      "expired": ["<baseline key>", ...],
      "ok": true|false
    }
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import baseline as baseline_mod
from repro.analysis.engine import DEFAULT_PATHS, all_rules, analyze

REPORT_VERSION = 1


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-based invariant linter (jit purity, tracer "
                    "guards, registry/schema completeness) — see "
                    "docs/static-analysis.md")
    ap.add_argument("--root", default=".",
                    help="repo root to analyze (default: cwd)")
    ap.add_argument("--paths", nargs="+", default=list(DEFAULT_PATHS),
                    metavar="DIR",
                    help=f"subtrees to walk (default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--rule", action="append", default=None, metavar="NAME",
                    help="run only this rule (repeatable)")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="committed-findings baseline (JSON)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --baseline to the current findings and "
                         "exit 0")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable report on stdout")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    rules = all_rules()
    if args.list_rules:
        for r in rules:
            print(f"{r.name:24s} {r.summary}")
        return 0
    if args.rule:
        known = {r.name for r in rules}
        unknown = [n for n in args.rule if n not in known]
        if unknown:
            print(f"unknown rule(s): {', '.join(unknown)}; "
                  f"known: {', '.join(sorted(known))}", file=sys.stderr)
            return 2
        rules = [r for r in rules if r.name in set(args.rule)]
    if args.update_baseline and not args.baseline:
        print("--update-baseline requires --baseline", file=sys.stderr)
        return 2

    root = Path(args.root).resolve()
    findings = analyze(root, paths=args.paths, rules=rules)

    baseline_keys: list[str] = []
    if args.baseline:
        try:
            baseline_keys = baseline_mod.load(args.baseline)
        except (ValueError, json.JSONDecodeError) as e:
            print(f"bad baseline: {e}", file=sys.stderr)
            return 2
    new, old, expired = baseline_mod.split(findings, baseline_keys)

    if args.update_baseline:
        baseline_mod.save(args.baseline, findings)
        print(f"baseline updated: {len(findings)} finding(s) "
              f"({len(expired)} expired entr{'y' if len(expired) == 1 else 'ies'} dropped)")
        return 0

    ok = not new and not expired
    if args.as_json:
        print(json.dumps({
            "report_version": REPORT_VERSION,
            "root": str(root),
            "paths": list(args.paths),
            "rules": [{"name": r.name, "summary": r.summary}
                      for r in rules],
            "findings": [dict(f.to_dict(), baselined=(f in old))
                         for f in findings],
            "counts": {"total": len(findings), "new": len(new),
                       "baselined": len(old), "expired": len(expired)},
            "expired": expired,
            "ok": ok,
        }, indent=2, sort_keys=True))
        return 0 if ok else 1

    for f in new:
        print(f.format())
    if old:
        print(f"({len(old)} baselined finding(s) not shown; "
              "run --json to list them)")
    for k in expired:
        print(f"expired baseline entry (fixed? run --update-baseline): {k}")
    if ok:
        n = len(findings)
        print(f"repro.analysis: clean "
              f"({n} baselined finding(s))" if n else
              "repro.analysis: clean")
        return 0
    print(f"repro.analysis: {len(new)} new finding(s), "
          f"{len(expired)} expired baseline entr"
          f"{'y' if len(expired) == 1 else 'ies'}", file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
