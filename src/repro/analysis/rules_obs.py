"""``tracer-guard`` — every observability emit site must be dominated by a
``tracer.enabled`` check.

The PR 7 contract (docs/observability.md): a tracing-off engine pays one
attribute read per potential event and its counters stay **bit-identical**
to an untraced run.  That only holds if no emit call's *argument dicts*
are ever built on the disabled path — so every call to an emit method
(``begin``/``end``/``mark``/``instant``/``step``) on a tracer must sit
under an ``if <tracer>.enabled:`` guard (or after an
``if not <tracer>.enabled: return`` early exit).

What counts as "a tracer" is resolved per function, by name shape:

* an attribute chain ending ``.tracer`` (``self.tracer``, ``engine.kv
  .tracer``);
* a parameter or local named ``tracer``;
* a local alias assigned from either (``tr = self.tracer``), including
  through a conditional expression (``NULL_TRACER if x is None else x``
  does **not** alias — only reads OF a tracer do).

Guard recognition (dominance, approximated syntactically):

* ``if <guard>:`` where the test is an ``.enabled`` read on a recognized
  tracer, possibly inside an ``and`` conjunction (``if added and
  tr.enabled:``) — the body is guarded, the ``else`` is NOT;
* ``if not <guard>: return/continue/raise/break`` — statements after the
  ``if`` in the same block are guarded.

``or``-disjunctions do not guard (either side may be false).  Non-emit
methods (``reset``, ``save``, ``to_perfetto``) are exempt: they are
lifecycle/export calls, no-ops or explicit on the null tracer.  Classes
whose name contains ``Tracer`` (the recorder implementations themselves)
are skipped.  Suppress intentional unguarded emits with
``# repro: ignore[tracer-guard]``.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.engine import Finding, Project, SourceModule

EMIT_METHODS = ("begin", "end", "mark", "instant", "step")


def _is_tracer_expr(node: ast.AST, aliases: set[str]) -> bool:
    if isinstance(node, ast.Name):
        return node.id in aliases or node.id == "tracer"
    if isinstance(node, ast.Attribute):
        return node.attr == "tracer"
    return False


def _enabled_read(node: ast.AST, aliases: set[str]) -> bool:
    """``<tracer>.enabled``"""
    return (isinstance(node, ast.Attribute) and node.attr == "enabled"
            and _is_tracer_expr(node.value, aliases))


def _test_guards(test: ast.AST, aliases: set[str]) -> bool:
    """Does this if-test establish the guard?  ``.enabled`` directly or as
    one operand of an ``and`` conjunction (recursively); ``or`` never."""
    if _enabled_read(test, aliases):
        return True
    if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.And):
        return any(_test_guards(v, aliases) for v in test.values)
    return False


def _test_rejects(test: ast.AST, aliases: set[str]) -> bool:
    """``not <tracer>.enabled`` (early-exit spelling)."""
    return (isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not)
            and _test_guards(test.operand, aliases))


def _exits(stmt: ast.stmt) -> bool:
    return isinstance(stmt, (ast.Return, ast.Continue, ast.Break, ast.Raise))


class TracerGuard:
    name = "tracer-guard"
    summary = "tracer emit sites not dominated by a tracer.enabled check"

    def check(self, project: Project) -> Iterator[Finding]:
        for mod in project.modules:
            yield from self._check_module(mod)

    def _check_module(self, mod: SourceModule) -> Iterator[Finding]:
        for fn in self._functions(mod.tree):
            aliases = self._aliases(fn)
            yield from self._scan_block(mod, fn.body, aliases, guarded=False)

    def _functions(self, node: ast.AST):
        """Every function/method — except inside ``*Tracer*`` classes (the
        recorder implementations ARE the emit machinery)."""
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef) and "Tracer" in child.name:
                continue
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield child
            yield from self._functions(child)

    def _aliases(self, fn: ast.AST) -> set[str]:
        """Local names that hold a tracer in this function."""
        aliases: set[str] = set()
        for _ in range(2):       # transitive aliases (rare but cheap)
            for node in ast.walk(fn):
                if not isinstance(node, ast.Assign):
                    continue
                v = node.value
                src = (_is_tracer_expr(v, aliases)
                       or (isinstance(v, ast.IfExp)
                           and (_is_tracer_expr(v.body, aliases)
                                or _is_tracer_expr(v.orelse, aliases))))
                if src:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            aliases.add(t.id)
        return aliases

    def _scan_block(self, mod: SourceModule, body: list, aliases: set[str],
                    guarded: bool) -> Iterator[Finding]:
        rest_guarded = guarded
        for stmt in body:
            if isinstance(stmt, ast.If):
                body_guarded = rest_guarded or _test_guards(stmt.test, aliases)
                yield from self._scan_block(mod, stmt.body, aliases,
                                            body_guarded)
                yield from self._scan_block(mod, stmt.orelse, aliases,
                                            rest_guarded)
                if (_test_rejects(stmt.test, aliases) and stmt.body
                        and _exits(stmt.body[-1])):
                    rest_guarded = True
                continue
            # expressions of this statement (incl. loop/with headers)
            yield from self._scan_exprs(mod, stmt, aliases, rest_guarded)
            for child_body in self._nested_blocks(stmt):
                yield from self._scan_block(mod, child_body, aliases,
                                            rest_guarded)

    def _nested_blocks(self, stmt: ast.stmt):
        # nested defs/classes are separate entries in _functions()
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return
        for attr in ("body", "orelse", "finalbody"):
            blk = getattr(stmt, attr, None)
            if blk:
                yield blk
        for h in getattr(stmt, "handlers", ()) or ():
            yield h.body

    def _scan_exprs(self, mod: SourceModule, stmt: ast.stmt,
                    aliases: set[str], guarded: bool) -> Iterator[Finding]:
        if guarded or isinstance(stmt, (ast.FunctionDef,
                                        ast.AsyncFunctionDef, ast.ClassDef)):
            return
        # this statement's own expressions only, not nested blocks
        for node in ast.iter_child_nodes(stmt):
            if isinstance(node, ast.stmt):
                continue
            yield from self._scan_call_tree(mod, node, aliases)

    def _scan_call_tree(self, mod: SourceModule, node: ast.AST,
                        aliases: set[str]) -> Iterator[Finding]:
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            f = sub.func
            if isinstance(f, ast.Attribute) and f.attr in EMIT_METHODS \
                    and _is_tracer_expr(f.value, aliases):
                tgt = ast.unparse(f) if hasattr(ast, "unparse") else f.attr
                yield mod.finding(
                    self.name, sub,
                    f"tracer emit `{tgt}(...)` not guarded by "
                    "`tracer.enabled`: builds event args on the disabled "
                    "path and breaks traced/untraced bit-identity")
