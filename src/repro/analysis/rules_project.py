"""Cross-module rules: registry completeness and schema-version drift.

Unlike the jit/tracer rules these reason about *pairs* of files — the
kernel registry vs its conformance suite and oracle module, and each
schema-version constant vs the validators and docs that cite it.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Iterator

from repro.analysis import inventory
from repro.analysis.engine import Finding, Project

_VERSION_FIELD_RE = re.compile(r"version", re.IGNORECASE)

# docs mentions like "BENCH_e2e schema v2" / "OBS_TRACE ... schema_version
# 1": a kind token followed (within the same sentence-ish window) by a
# version literal.
_DOC_VERSION_RE = re.compile(
    r"schema[ _-]v(?:ersion)?[:= ]*(\d+)", re.IGNORECASE)
_DOC_WINDOW = 160   # chars back from the version literal to find the kind


class RegistryCompleteness:
    """Every registered ``KernelImpl`` must have a conformance row and a
    resolvable oracle.

    Statically cross-checks three files (paths in
    :mod:`repro.analysis.inventory`):

    * every kernel class with a ``name``/``lower`` is actually
      ``register(...)``-ed (a defined-but-unregistered kernel silently
      vanishes from plans);
    * the registry's kernel names == ``KERNEL_CASES`` rows in
      ``tests/test_conformance.py`` (missing row = kernel ships without an
      equivalence contract; stale row = the suite tests a ghost);
    * every ``ref.<fn>`` oracle the suite binds to exists in
      ``src/repro/kernels/ref.py``.

    ``tests/test_conformance.py`` imports the same inventory and asserts
    it against the *imported* registry, so this static check and the
    runtime completeness gate cannot disagree on the kernel list.
    """

    name = "registry-completeness"
    summary = "kernel registry vs conformance rows vs oracles"

    def check(self, project: Project) -> Iterator[Finding]:
        root = project.root
        reg_mod = project.module_at(inventory.REGISTRY_PATH)
        conf_mod = project.module_at(inventory.CONFORMANCE_PATH)
        if reg_mod is None or conf_mod is None:
            # nothing to cross-check in this tree (fixture projects)
            return
        classes = inventory.registry_kernel_classes(root)
        registered = inventory.registry_registered_classes(root)
        kernels = set(inventory.registry_kernel_names(root))
        rows = inventory.conformance_kernel_rows(root)

        for kname, cls in sorted(classes.items()):
            if cls not in registered:
                yield reg_mod.finding(
                    self.name, self._class_line(reg_mod, cls),
                    f"kernel class `{cls}` (name={kname!r}) defines the "
                    "KernelImpl shape but is never register()-ed: it can "
                    "never be planned or served")
        for kname in sorted(kernels - set(rows)):
            yield conf_mod.finding(
                self.name, 1,
                f"registered kernel {kname!r} has no KERNEL_CASES row in "
                "tests/test_conformance.py: it ships without an "
                "equivalence contract")
        for kname in sorted(set(rows) - kernels):
            yield conf_mod.finding(
                self.name, rows[kname],
                f"KERNEL_CASES row {kname!r} matches no registered kernel: "
                "stale conformance row")
        oracles = inventory.oracle_functions(root)
        for fn, line in sorted(inventory.conformance_oracle_refs(root)
                               .items()):
            if fn not in oracles:
                yield conf_mod.finding(
                    self.name, line,
                    f"conformance suite binds oracle `ref.{fn}` but "
                    f"{inventory.ORACLES_PATH} does not define it")

    def _class_line(self, mod, cls: str) -> int:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef) and node.name == cls:
                return node.lineno
        return 1


class SchemaDrift:
    """Schema-version constants must match their validators and docs.

    For every constant in :data:`repro.analysis.inventory
    .VERSION_CONSTANTS`:

    * the constant exists in its module as a plain int literal;
    * no walked module compares a ``*version*``-named field against a
      **bare int literal** (``doc["schema_version"] != 2``) — validators
      must compare against the named constant, which is what makes a bump
      a one-line change;
    * any ``docs/*.md`` mention of the artifact's kind token followed by a
      ``schema v<N>`` literal must cite the current version.
    """

    name = "schema-drift"
    summary = "schema-version constants vs validators and docs"

    def check(self, project: Project) -> Iterator[Finding]:
        root = project.root
        tokens: dict[str, tuple[str, int]] = {}
        present = 0
        for relpath, const, doc_token in inventory.VERSION_CONSTANTS:
            mod = project.module_at(relpath)
            if mod is None:
                continue
            present += 1
            value, line = inventory.version_constant(root, relpath, const)
            if value is None:
                yield mod.finding(
                    self.name, line or 1,
                    f"expected module-level int constant `{const}` in "
                    f"{relpath} (schema-versioned artifact)")
            else:
                tokens[doc_token] = (const, value)
        if not present:
            return
        yield from self._literal_comparisons(project)
        yield from self._doc_mentions(project, tokens)

    def _literal_comparisons(self, project: Project) -> Iterator[Finding]:
        for mod in project.modules:
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Compare):
                    continue
                sides = [node.left] + list(node.comparators)
                named = [s for s in sides if self._is_version_field(s)]
                literals = [s for s in sides
                            if isinstance(s, ast.Constant)
                            and isinstance(s.value, int)
                            and not isinstance(s.value, bool)]
                if named and literals:
                    yield mod.finding(
                        self.name, node,
                        "version field compared against a bare int literal "
                        f"({literals[0].value}): compare against the named "
                        "schema-version constant so a bump is one edit")

    def _is_version_field(self, node: ast.AST) -> bool:
        """``x["schema_version"]`` / ``x.schema_version`` — but not a
        bare Name (locals named `version` compare against ints
        legitimately)."""
        if isinstance(node, ast.Subscript):
            sl = node.slice
            return (isinstance(sl, ast.Constant)
                    and isinstance(sl.value, str)
                    and _VERSION_FIELD_RE.search(sl.value) is not None)
        if isinstance(node, ast.Attribute):
            return _VERSION_FIELD_RE.search(node.attr) is not None
        return False

    def _doc_mentions(self, project: Project,
                      tokens: dict[str, tuple[str, int]]
                      ) -> Iterator[Finding]:
        docs = sorted((project.root / "docs").glob("*.md")) \
            if (project.root / "docs").is_dir() else []
        for doc in docs:
            text = doc.read_text()
            rel = doc.relative_to(project.root).as_posix()
            for m in _DOC_VERSION_RE.finditer(text):
                cited = int(m.group(1))
                window = text[max(0, m.start() - _DOC_WINDOW):m.start()]
                for token, (const, value) in tokens.items():
                    if token in window and cited != value:
                        line = text.count("\n", 0, m.start()) + 1
                        yield Finding(
                            path=rel, line=line, rule=self.name,
                            message=f"doc cites {token} schema v{cited} "
                                    f"but {const} is {value}")
