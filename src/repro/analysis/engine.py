"""Linter core: source model, suppression comments, findings, rule driver.

The engine is deliberately small.  A :class:`Project` owns parsed
:class:`SourceModule` objects for every ``.py`` file under the walked
roots (``src/``, ``benchmarks/``, ``examples/`` by default) plus any file
a cross-module rule asks for explicitly (e.g. ``tests/test_conformance.py``).
Rules are plain objects with a ``name``, a one-line ``summary``, and a
``check(project)`` generator of :class:`Finding`; the driver runs every
rule, drops findings suppressed by ``# repro: ignore[rule-name]``
comments, and returns them sorted.

Suppression syntax (see docs/static-analysis.md):

* ``# repro: ignore[rule-a]`` / ``# repro: ignore[rule-a, rule-b]`` on the
  finding's line suppresses those rules there;
* ``# repro: ignore-file[rule-a]`` anywhere in a file suppresses the rule
  for the whole file (use sparingly — prefer line-level).
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Iterable, Iterator

DEFAULT_PATHS = ("src", "benchmarks", "examples")

_IGNORE_RE = re.compile(r"#\s*repro:\s*ignore\[([^\]]+)\]")
_IGNORE_FILE_RE = re.compile(r"#\s*repro:\s*ignore-file\[([^\]]+)\]")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at one source location."""

    path: str      # repo-relative posix path
    line: int      # 1-based line of the offending node
    rule: str
    message: str

    @property
    def key(self) -> str:
        """Line-independent identity used for baseline matching — findings
        survive unrelated line churn but not a change to what they say."""
        return f"{self.rule}::{self.path}::{self.message}"

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message}

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class SourceModule:
    """One parsed source file plus its suppression table."""

    def __init__(self, root: Path, relpath: str):
        self.relpath = relpath                       # posix, repo-relative
        self.path = root / relpath
        self.source = self.path.read_text()
        self.tree = ast.parse(self.source, filename=relpath)
        self.lines = self.source.splitlines()
        self._line_ignores: dict[int, set[str]] = {}
        self._file_ignores: set[str] = set()
        for i, text in enumerate(self.lines, start=1):
            if "#" not in text:
                continue
            m = _IGNORE_FILE_RE.search(text)
            if m:
                self._file_ignores |= _split_rules(m.group(1))
                continue
            m = _IGNORE_RE.search(text)
            if m:
                self._line_ignores[i] = _split_rules(m.group(1))

    @property
    def name(self) -> str:
        """Dotted module name (``src/repro/obs/trace.py`` → ``repro.obs
        .trace``) — what an ``import`` of this file binds."""
        parts = Path(self.relpath).with_suffix("").parts
        if parts[0] == "src":
            parts = parts[1:]
        if parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)

    def suppressed(self, rule: str, line: int) -> bool:
        if rule in self._file_ignores or "*" in self._file_ignores:
            return True
        rules = self._line_ignores.get(line, ())
        return rule in rules or "*" in rules

    def finding(self, rule: str, node: ast.AST | int, message: str) -> Finding:
        line = node if isinstance(node, int) else getattr(node, "lineno", 0)
        return Finding(path=self.relpath, line=line, rule=rule,
                       message=message)


def _split_rules(spec: str) -> set[str]:
    return {r.strip() for r in spec.split(",") if r.strip()}


class Project:
    """The analyzed tree: walked modules + on-demand extra files."""

    def __init__(self, root: Path | str, paths: Iterable[str] = DEFAULT_PATHS):
        self.root = Path(root).resolve()
        self.paths = tuple(paths)
        self.modules: list[SourceModule] = []
        self._by_path: dict[str, SourceModule] = {}
        self._by_name: dict[str, SourceModule] = {}
        self.parse_errors: list[Finding] = []
        self._caches: dict[str, object] = {}   # cross-rule memos (callgraph)
        for sub in self.paths:
            base = self.root / sub
            if not base.is_dir():
                continue
            for p in sorted(base.rglob("*.py")):
                if "__pycache__" in p.parts:
                    continue
                self._load(p.relative_to(self.root).as_posix())

    def _load(self, relpath: str) -> SourceModule | None:
        try:
            mod = SourceModule(self.root, relpath)
        except (SyntaxError, UnicodeDecodeError) as e:
            line = getattr(e, "lineno", 0) or 0
            self.parse_errors.append(Finding(
                path=relpath, line=line, rule="parse-error",
                message=f"could not parse: {e.msg if hasattr(e, 'msg') else e}"))
            return None
        self.modules.append(mod)
        self._by_path[relpath] = mod
        self._by_name[mod.name] = mod
        return mod

    def module_at(self, relpath: str) -> SourceModule | None:
        """Module by repo-relative path; parses files outside the walked
        roots (cross-module rules read ``tests/...``) on demand."""
        if relpath in self._by_path:
            return self._by_path[relpath]
        if (self.root / relpath).is_file():
            return self._load(relpath)
        return None

    def module_named(self, name: str) -> SourceModule | None:
        return self._by_name.get(name)

    def memo(self, key: str, build):
        """Cross-rule cache (the jit rules share one call graph)."""
        if key not in self._caches:
            self._caches[key] = build()
        return self._caches[key]


def all_rules() -> list:
    """The registered rule corpus, in catalog order."""
    from repro.analysis import rules_jit, rules_obs, rules_project

    return [
        rules_jit.JitPurity(),
        rules_jit.RetraceHazard(),
        rules_jit.TracedBranch(),
        rules_obs.TracerGuard(),
        rules_project.RegistryCompleteness(),
        rules_project.SchemaDrift(),
    ]


def analyze(root: Path | str, paths: Iterable[str] = DEFAULT_PATHS,
            rules: Iterable | None = None) -> list[Finding]:
    """Run ``rules`` (default: all) over the tree; returns sorted findings
    with suppressions applied.  Unparseable files surface as
    ``parse-error`` findings rather than aborting the run."""
    project = Project(root, paths)
    out: list[Finding] = list(project.parse_errors)
    for rule in (all_rules() if rules is None else rules):
        for f in rule.check(project):
            mod = project.module_at(f.path)
            if mod is not None and mod.suppressed(f.rule, f.line):
                continue
            out.append(f)
    return sorted(out, key=lambda f: (f.path, f.line, f.rule, f.message))


def iter_findings(rule, project: Project) -> Iterator[Finding]:
    """Convenience for tests: one rule, suppressions applied."""
    for f in rule.check(project):
        mod = project.module_at(f.path)
        if mod is None or not mod.suppressed(f.rule, f.line):
            yield f
