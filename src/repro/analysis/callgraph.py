"""Best-effort project call graph rooted at the jit boundary.

The jit rules need to know which function bodies execute **under a
tracer** — i.e. are reachable from a ``jax.jit`` call site or from a
model entry point (``chunk_step``).  Python's dynamism makes a sound
call graph impossible; this one is deliberately conservative-by-name:

* **roots** — functions decorated ``@jax.jit`` / ``@functools.partial(
  jax.jit, ...)``; the function or lambda passed to a ``jax.jit(...)``
  call (including through a local name, e.g. ``step = make(...);
  jax.jit(step)`` marks ``make``'s nested defs); and any top-level
  function named ``chunk_step`` or ``flat_step`` (the serving step entry
  points, jitted by the engine through lambdas);
* **edges** — direct calls to names resolvable statically: same-module
  functions, ``from m import f`` symbols, ``mod.f`` through an imported
  module alias, ``self.m()`` methods of the enclosing class, and nested
  defs of the enclosing function.  Anything else (calls on call results,
  dict dispatch, higher-order arguments) is silently not followed.

When a function is reachable its nested ``def``s are reachable too —
they are constructed (and usually called) at trace time, e.g. Pallas
``@pl.when`` bodies.

Unresolvable edges mean the purity rules can miss violations behind
dynamic dispatch; they never cause false positives.  The fixture corpus
under ``tests/fixtures/analysis/`` pins what is and is not followed.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterator

from repro.analysis.engine import Project, SourceModule

# Entry points that are jitted indirectly (the serving engine wraps them
# in jax.jit lambdas; dryrun/train factories close over them).
ROOT_FUNCTION_NAMES = ("chunk_step", "flat_step")

_JIT_NAMES = {"jit"}          # from jax import jit
_PARTIAL_NAMES = {"partial"}  # functools.partial / from functools import partial


@dataclasses.dataclass
class FuncInfo:
    """One analyzable function body (def, method, nested def, or a lambda
    passed straight to ``jax.jit``)."""

    module: SourceModule
    qualname: str
    node: ast.AST                  # FunctionDef | AsyncFunctionDef | Lambda
    class_name: str | None = None  # enclosing class, for self.m() edges
    is_root: bool = False
    # For roots that ARE the jitted callable: parameter names bound to
    # tracers (params minus declared statics).  Name-seeded roots
    # (chunk_step — jitted through engine lambdas whose closures make
    # cfg/train static) keep this empty.
    traced_params: frozenset = frozenset()

    @property
    def body(self) -> list[ast.stmt]:
        if isinstance(self.node, ast.Lambda):
            return [ast.Expr(self.node.body)]
        return self.node.body

    @property
    def params(self) -> list[str]:
        a = self.node.args
        return [p.arg for p in
                (a.posonlyargs + a.args + a.kwonlyargs)
                ] + [p.arg for p in (a.vararg, a.kwarg) if p is not None]


def _spec_statics(call: ast.Call, params: list) -> set:
    """Parameter names a jit call/decorator declares static
    (``static_argnames`` strings + ``static_argnums`` indices)."""
    static: set = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) \
                        and isinstance(sub.value, str):
                    static.add(sub.value)
        elif kw.arg == "static_argnums":
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) \
                        and isinstance(sub.value, int) \
                        and 0 <= sub.value < len(params):
                    static.add(params[sub.value])
    return static


class ModuleIndex:
    """Per-module name tables: imports and function definitions."""

    def __init__(self, mod: SourceModule):
        self.mod = mod
        # local name -> dotted module ("jax", "repro.models.model_zoo")
        self.import_modules: dict[str, str] = {}
        # local name -> (dotted module, symbol)
        self.import_symbols: dict[str, tuple[str, str]] = {}
        # qualname -> FuncInfo for every def at any nesting level
        self.functions: dict[str, FuncInfo] = {}
        # parent qualname -> direct nested-def qualnames
        self.nested: dict[str, list[str]] = {}
        self._walk(mod.tree, prefix="", class_name=None)

    def _walk(self, node: ast.AST, prefix: str, class_name: str | None):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.Import):
                for alias in child.names:
                    local = alias.asname or alias.name.split(".")[0]
                    self.import_modules[local] = (alias.name if alias.asname
                                                  else alias.name.split(".")[0])
                    if alias.asname:
                        self.import_modules[alias.asname] = alias.name
            elif isinstance(child, ast.ImportFrom):
                if child.level:
                    # "from . import x" in pkg/mod.py: level 1 strips the
                    # module leaf; further levels strip packages.
                    base = self.mod.name.split(".")[:-child.level]
                    root = ".".join(base + ([child.module] if child.module
                                            else []))
                else:
                    root = child.module or ""
                for alias in child.names:
                    local = alias.asname or alias.name
                    self.import_symbols[local] = (root, alias.name)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                self.functions[qual] = FuncInfo(
                    module=self.mod, qualname=qual, node=child,
                    class_name=class_name)
                if prefix:
                    self.nested.setdefault(prefix.rstrip("."), []).append(qual)
                self._walk(child, prefix=f"{qual}.", class_name=class_name)
            elif isinstance(child, ast.ClassDef):
                self._walk(child, prefix=f"{prefix}{child.name}.",
                           class_name=child.name)
            else:
                self._walk(child, prefix=prefix, class_name=class_name)

    # -- name resolution ----------------------------------------------------

    def top_level(self, name: str) -> FuncInfo | None:
        return self.functions.get(name)

    def is_module_alias(self, name: str) -> str | None:
        return self.import_modules.get(name)

    def symbol_target(self, name: str) -> tuple[str, str] | None:
        return self.import_symbols.get(name)


class CallGraph:
    def __init__(self, project: Project):
        self.project = project
        self.indexes: dict[str, ModuleIndex] = {
            m.relpath: ModuleIndex(m) for m in project.modules}
        self.roots: list[FuncInfo] = []
        self._find_roots()
        self.reachable: dict[tuple[str, str], FuncInfo] = {}
        for fi in self.roots:
            self._reach(fi)

    # -- jit detection -------------------------------------------------------

    def _is_jit(self, node: ast.AST, idx: ModuleIndex) -> bool:
        """Is this expression ``jax.jit`` (or an alias of it)?"""
        if isinstance(node, ast.Attribute) and node.attr == "jit":
            v = node.value
            if isinstance(v, ast.Name) and idx.is_module_alias(v.id) == "jax":
                return True
        if isinstance(node, ast.Name):
            tgt = idx.symbol_target(node.id)
            return tgt is not None and tgt == ("jax", "jit")
        return False

    def jit_call_sites(self, idx: ModuleIndex) -> Iterator[ast.Call]:
        """Every ``jax.jit(...)`` / ``partial(jax.jit, ...)`` Call in the
        module (shared with the retrace-hazard rule)."""
        for node in ast.walk(idx.mod.tree):
            if isinstance(node, ast.Call) and self._jit_of_call(node, idx):
                yield node

    def _jit_of_call(self, call: ast.Call, idx: ModuleIndex) -> bool:
        if self._is_jit(call.func, idx):
            return True
        # functools.partial(jax.jit, ...) — the decorator spelling.
        f = call.func
        is_partial = (
            (isinstance(f, ast.Attribute) and f.attr == "partial")
            or (isinstance(f, ast.Name)
                and (f.id in _PARTIAL_NAMES
                     or idx.symbol_target(f.id) == ("functools", "partial"))))
        return (is_partial and call.args
                and self._is_jit(call.args[0], idx))

    def _find_roots(self):
        for idx in self.indexes.values():
            # decorated defs
            for fi in idx.functions.values():
                for dec in fi.node.decorator_list:
                    if self._is_jit(dec, idx):
                        self._add_root(fi, traced=set(fi.params))
                    elif isinstance(dec, ast.Call) \
                            and self._jit_of_call(dec, idx):
                        self._add_root(
                            fi, traced=set(fi.params)
                            - _spec_statics(dec, fi.params))
            # jax.jit(<fn>, ...) call sites
            assigned_from = self._factory_bindings(idx)
            for call in self.jit_call_sites(idx):
                if not self._is_jit(call.func, idx) or not call.args:
                    continue
                arg = call.args[0]
                if isinstance(arg, ast.Lambda):
                    fi = FuncInfo(module=idx.mod,
                                  qualname=f"<lambda:{arg.lineno}>",
                                  node=arg, class_name=None)
                    self._add_root(fi, traced=set(fi.params)
                                   - _spec_statics(call, fi.params))
                elif isinstance(arg, ast.Name):
                    fi = idx.top_level(arg.id)
                    if fi is not None:
                        self._add_root(fi, traced=set(fi.params)
                                       - _spec_statics(call, fi.params))
                    elif arg.id in assigned_from:
                        # step = make_step(...); jax.jit(step) — the
                        # factory's nested defs are what actually trace.
                        self._add_factory_root(assigned_from[arg.id])
                elif isinstance(arg, ast.Call):
                    target = self._resolve_call(arg, idx, None)
                    if target is not None:
                        self._add_factory_root(target)
            # named entry points (chunk_step/flat_step): jitted via engine
            # lambdas whose closures keep cfg/train static — no param taint.
            for name in ROOT_FUNCTION_NAMES:
                fi = idx.top_level(name)
                if fi is not None:
                    self._add_root(fi, traced=set())

    def _factory_bindings(self, idx: ModuleIndex) -> dict[str, FuncInfo]:
        """name -> factory FuncInfo, for ``name = some_fn(...)`` where
        ``some_fn`` resolves locally or through an import."""
        out: dict[str, FuncInfo] = {}
        for node in ast.walk(idx.mod.tree):
            if isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Call):
                target = self._resolve_call(node.value, idx, None)
                if target is None:
                    continue
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        out[t.id] = target
        return out

    def _add_root(self, fi: FuncInfo, traced: set):
        if not fi.is_root:
            fi.is_root = True
            fi.traced_params = frozenset(traced)
            self.roots.append(fi)

    def _add_factory_root(self, factory: FuncInfo):
        idx = self.indexes[factory.module.relpath]
        for nested in idx.nested.get(factory.qualname, ()):
            nfi = idx.functions[nested]
            self._add_root(nfi, traced=set(nfi.params))

    # -- reachability --------------------------------------------------------

    def _key(self, fi: FuncInfo) -> tuple[str, str]:
        return (fi.module.relpath, fi.qualname)

    def _reach(self, fi: FuncInfo):
        key = self._key(fi)
        if key in self.reachable:
            return
        self.reachable[key] = fi
        idx = self.indexes.get(fi.module.relpath)
        if idx is None:
            return
        # nested defs execute at trace time
        for nested in idx.nested.get(fi.qualname, ()):
            self._reach(idx.functions[nested])
        for node in ast.walk(fi.node):
            if isinstance(node, ast.Call):
                target = self._resolve_call(node, idx, fi)
                if target is not None:
                    self._reach(target)

    def _resolve_call(self, call: ast.Call, idx: ModuleIndex,
                      caller: FuncInfo | None) -> FuncInfo | None:
        f = call.func
        if isinstance(f, ast.Name):
            # sibling nested defs, then module scope, then imported symbol
            if caller is not None:
                parent = caller.qualname.rsplit(".", 1)[0] \
                    if "." in caller.qualname else None
                for scope in (caller.qualname, parent):
                    if scope is None:
                        continue
                    fi = idx.functions.get(f"{scope}.{f.id}")
                    if fi is not None:
                        return fi
            fi = idx.top_level(f.id)
            if fi is not None:
                return fi
            tgt = idx.symbol_target(f.id)
            if tgt is not None:
                other = self.project.module_named(tgt[0])
                if other is not None:
                    return self.indexes[other.relpath].top_level(tgt[1])
            return None
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
            base = f.value.id
            if base == "self" and caller is not None and caller.class_name:
                return idx.top_level(f"{caller.class_name}.{f.attr}")
            mod_name = idx.is_module_alias(base)
            if mod_name is None:
                tgt = idx.symbol_target(base)
                # "from repro.models import model_zoo" binds a module
                if tgt is not None:
                    mod_name = f"{tgt[0]}.{tgt[1]}"
            if mod_name is not None:
                other = self.project.module_named(mod_name)
                if other is not None:
                    return self.indexes[other.relpath].top_level(f.attr)
        return None


def jit_callgraph(project: Project) -> CallGraph:
    """The project's (memoized) jit-rooted call graph."""
    return project.memo("jit_callgraph", lambda: CallGraph(project))
