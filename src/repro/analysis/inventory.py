"""Shared AST inventories: the facts the cross-module rules check.

Everything here is derived **statically** from source text — no imports of
the target modules — so the linter (and the CI lint lane) needs neither
jax nor a configured ``PYTHONPATH`` beyond this package, and so
``tests/test_conformance.py`` can assert that the static view of the
kernel list agrees with the imported registry: one inventory, consumed by
both the static ``registry-completeness`` rule and the runtime
completeness gate, can never let the two drift apart.

Paths are repo-relative and fixed here (single source of truth for the
rules AND the tests):

* :data:`REGISTRY_PATH` — ``KernelImpl`` classes (``name`` class attr +
  a ``lower`` method) and their registrations;
* :data:`CONFORMANCE_PATH` — ``KERNEL_CASES`` rows and the ``ref.*``
  oracles each row binds to;
* :data:`ORACLES_PATH` — the oracle functions actually defined;
* :data:`VERSION_CONSTANTS` — every schema-version constant and the file
  that owns it.
"""
from __future__ import annotations

import ast
from pathlib import Path

REGISTRY_PATH = "src/repro/plan/registry.py"
ORACLES_PATH = "src/repro/kernels/ref.py"
CONFORMANCE_PATH = "tests/test_conformance.py"

# (repo-relative path, constant name) for every schema-versioned artifact;
# `doc_token` is how docs refer to the artifact (schema-drift scans
# docs/*.md for "<doc_token> ... schema v<N>" and "<doc_token> ...
# schema_version <N>" style mentions).
VERSION_CONSTANTS = (
    ("benchmarks/workloads/schema.py", "SCHEMA_VERSION", "BENCH_e2e"),
    ("benchmarks/workloads/trace.py", "TRACE_VERSION", "WORKLOAD_TRACE"),
    ("src/repro/obs/trace.py", "TRACE_SCHEMA_VERSION", "OBS_TRACE"),
    ("src/repro/obs/trace.py", "STREAM_SCHEMA_VERSION", "OBS_TRACE_STREAM"),
    ("src/repro/obs/incident.py", "INCIDENT_SCHEMA_VERSION", "OBS_INCIDENT"),
    ("src/repro/plan/plan.py", "PLAN_VERSION", "ModelPlan"),
)


def _parse(root: Path | str, relpath: str) -> ast.Module | None:
    p = Path(root) / relpath
    if not p.is_file():
        return None
    try:
        return ast.parse(p.read_text(), filename=relpath)
    except SyntaxError:
        return None


def registry_kernel_classes(root: Path | str) -> dict[str, str]:
    """kernel name -> class name, for every class in the registry module
    that declares a ``name`` string class attribute and a ``lower``
    method (the ``KernelImpl`` shape)."""
    tree = _parse(root, REGISTRY_PATH)
    out: dict[str, str] = {}
    if tree is None:
        return out
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        kname = None
        has_lower = False
        for stmt in node.body:
            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    if isinstance(t, ast.Name) and t.id == "name" \
                            and isinstance(stmt.value, ast.Constant) \
                            and isinstance(stmt.value.value, str):
                        kname = stmt.value.value
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and stmt.name == "lower":
                has_lower = True
        if kname is not None and has_lower:
            out[kname] = node.name
    return out


def registry_registered_classes(root: Path | str) -> set[str]:
    """Class names actually passed to ``register(...)`` — directly or via
    the module-bottom ``for _impl in (A(), B(), ...)`` idiom."""
    tree = _parse(root, REGISTRY_PATH)
    out: set[str] = set()
    if tree is None:
        return out
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "register":
            for arg in node.args:
                if isinstance(arg, ast.Call) \
                        and isinstance(arg.func, ast.Name):
                    out.add(arg.func.id)
        if isinstance(node, ast.For) and isinstance(node.iter,
                                                    (ast.Tuple, ast.List)):
            calls_register = any(
                isinstance(c, ast.Call) and isinstance(c.func, ast.Name)
                and c.func.id == "register" for c in ast.walk(node))
            if not calls_register:
                continue
            for el in node.iter.elts:
                if isinstance(el, ast.Call) \
                        and isinstance(el.func, ast.Name):
                    out.add(el.func.id)
    return out


def registry_kernel_names(root: Path | str) -> tuple[str, ...]:
    """The static kernel inventory: names of registered KernelImpl classes
    (what ``repro.plan.registry.names()`` returns at runtime)."""
    classes = registry_kernel_classes(root)
    registered = registry_registered_classes(root)
    return tuple(sorted(n for n, cls in classes.items()
                        if cls in registered))


def conformance_kernel_rows(root: Path | str) -> dict[str, int]:
    """``KERNEL_CASES`` keys -> line number, from the conformance suite."""
    tree = _parse(root, CONFORMANCE_PATH)
    out: dict[str, int] = {}
    if tree is None:
        return out
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) \
                and any(isinstance(t, ast.Name) and t.id == "KERNEL_CASES"
                        for t in node.targets) \
                and isinstance(node.value, ast.Dict):
            for k in node.value.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    out[k.value] = k.lineno
    return out


def conformance_oracle_refs(root: Path | str) -> dict[str, int]:
    """``ref.<fn>`` attributes the conformance suite reads -> line."""
    tree = _parse(root, CONFORMANCE_PATH)
    out: dict[str, int] = {}
    if tree is None:
        return out
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) \
                and isinstance(node.value, ast.Name) \
                and node.value.id == "ref":
            out.setdefault(node.attr, node.lineno)
    return out


def oracle_functions(root: Path | str) -> set[str]:
    """Top-level function names defined by the oracle module."""
    tree = _parse(root, ORACLES_PATH)
    if tree is None:
        return set()
    return {n.name for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def version_constant(root: Path | str, relpath: str,
                     const: str) -> tuple[int | None, int | None]:
    """(value, line) of a module-level integer constant; (None, None) when
    missing or not a plain int literal."""
    tree = _parse(root, relpath)
    if tree is None:
        return None, None
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == const:
                    if isinstance(node.value, ast.Constant) \
                            and isinstance(node.value.value, int):
                        return node.value.value, node.lineno
                    return None, node.lineno
    return None, None
