"""Committed-findings baseline: adopt the linter without fixing the world.

A baseline file is canonical JSON listing finding *keys* (rule + path +
message — line numbers excluded, so unrelated line churn never
invalidates an entry).  The CLI splits current findings into **new**
(fail), **baselined** (tolerated), and reports baseline entries that no
longer match anything as **expired** (also fail, so the file can only
shrink honestly); ``--update-baseline`` rewrites the file to exactly the
current findings — the add/expire round-trip.

Policy (docs/static-analysis.md): the baseline must stay empty for
``src/repro/`` — core findings get fixed or explicitly suppressed in
source, never parked.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.analysis.engine import Finding

BASELINE_VERSION = 1


def load(path: Path | str) -> list[str]:
    """Baseline keys, in file order.  Missing file = empty baseline."""
    p = Path(path)
    if not p.is_file():
        return []
    doc = json.loads(p.read_text())
    if not isinstance(doc, dict) or doc.get("version") != BASELINE_VERSION:
        raise ValueError(f"{path}: not a v{BASELINE_VERSION} analysis "
                         "baseline")
    entries = doc.get("findings")
    if not isinstance(entries, list) \
            or not all(isinstance(e, str) for e in entries):
        raise ValueError(f"{path}: 'findings' must be a list of keys")
    return entries


def save(path: Path | str, findings: list[Finding]) -> dict:
    doc = {"version": BASELINE_VERSION,
           "findings": sorted({f.key for f in findings})}
    Path(path).write_text(
        json.dumps(doc, indent=2, sort_keys=True) + "\n")
    return doc


def split(findings: list[Finding], baseline_keys: list[str]
          ) -> tuple[list[Finding], list[Finding], list[str]]:
    """(new, baselined, expired_keys)."""
    keys = set(baseline_keys)
    new = [f for f in findings if f.key not in keys]
    old = [f for f in findings if f.key in keys]
    live = {f.key for f in findings}
    expired = sorted(k for k in keys if k not in live)
    return new, old, expired
