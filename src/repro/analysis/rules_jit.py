"""Rules for the jit boundary: purity, retrace stability, traced branches.

All three rules scope their checks to functions the
:mod:`repro.analysis.callgraph` proves reachable from a ``jax.jit`` call
site (or a ``chunk_step`` entry point) — host-side engine code is free to
print, mutate, and draw numpy RNG; code under a tracer is not.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.callgraph import FuncInfo, jit_callgraph
from repro.analysis.engine import Finding, Project

# jnp-producing namespaces for the traced-branch taint (the repo idiom:
# ``import jax``, ``import jax.numpy as jnp``).
_TRACED_NAMESPACES = {"jnp", "jax"}

# Reads of these attributes are static at trace time even on tracers.
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize", "name"}

# Builtins whose result is static (or that never concretize a tracer).
_STATIC_CALLS = {"len", "isinstance", "issubclass", "hasattr", "getattr",
                 "type", "range", "enumerate", "zip"}

# jnp/jax functions that return static Python values even on tracers —
# branching on them is legitimate (`if jnp.ndim(cache_len) == 0:`).
_STATIC_QUERIES = {"ndim", "shape", "size", "result_type", "issubdtype",
                   "iscomplexobj", "isdtype"}


def _dotted(node: ast.AST) -> tuple[str, ...] | None:
    """``a.b.c`` → ("a", "b", "c"); None for non-name chains."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def walk_shallow(root: ast.AST) -> Iterator[ast.AST]:
    """Walk a function body WITHOUT descending into nested ``def``s —
    those are separate reachable entries in the call graph (lambdas and
    comprehensions, which execute inline under the trace, are descended)."""
    stack = list(ast.iter_child_nodes(root))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            stack.extend(ast.iter_child_nodes(node))


def _numpy_aliases(idx) -> set[str]:
    """Local names bound to the numpy module (``np``, ``numpy``, ...)."""
    out = set()
    for local, mod in idx.import_modules.items():
        if mod == "numpy" or mod.startswith("numpy."):
            out.add(local)
    return out


class JitPurity:
    """Host side effects inside jit-reachable bodies.

    Flags, inside any function reachable from the jit boundary:

    * ``print(...)`` — runs once at trace time, then never again;
    * ``np.*`` / ``numpy.*`` calls **fed a traced value** — they
      constant-fold it or raise ``TracerArrayConversionError`` at
      retrace.  numpy over static shapes/constants (LUT pattern tables,
      ``np.arange(1 << c)``) is the intended constant-folding idiom and
      is not flagged;
    * host RNG (``random.*``, ``np.random.*``) — a fresh draw per trace,
      frozen thereafter: silent nondeterminism across retraces;
    * ``global`` / ``nonlocal`` declarations and attribute-store mutation
      (``obj.attr = ...``, ``obj.attr += ...``) — trace-time mutation the
      compiled computation will not repeat.

    Functional ``.at[...].set`` updates and Pallas ref subscript stores
    (``o_ref[...] = ...``) are pure and not flagged.
    """

    name = "jit-purity"
    summary = "host side effects inside jit-reachable functions"

    def check(self, project: Project) -> Iterator[Finding]:
        cg = jit_callgraph(project)
        for fi in cg.reachable.values():
            idx = cg.indexes[fi.module.relpath]
            yield from self._check_body(fi, _numpy_aliases(idx))

    def _check_body(self, fi: FuncInfo, np_names: set[str]
                    ) -> Iterator[Finding]:
        mod = fi.module
        where = f"jit-reachable `{fi.qualname}`"
        tainted = _tainted_names(fi)
        for node in walk_shallow(fi.node):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                kw = "global" if isinstance(node, ast.Global) else "nonlocal"
                yield mod.finding(self.name, node,
                                  f"{where} declares `{kw} "
                                  f"{', '.join(node.names)}`: trace-time "
                                  "mutation of outer state")
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if isinstance(t, ast.Attribute):
                        d = _dotted(t)
                        tgt = ".".join(d) if d else f"<expr>.{t.attr}"
                        yield mod.finding(
                            self.name, node,
                            f"{where} mutates attribute `{tgt}`: runs once "
                            "at trace time, invisible to later calls")
            elif isinstance(node, ast.Call):
                yield from self._check_call(mod, node, np_names, where,
                                            tainted)

    def _check_call(self, mod, call: ast.Call, np_names: set[str],
                    where: str, tainted: set[str]) -> Iterator[Finding]:
        f = call.func
        if isinstance(f, ast.Name) and f.id == "print":
            yield mod.finding(self.name, call,
                              f"{where} calls `print`: executes at trace "
                              "time only")
            return
        d = _dotted(f)
        if d is None or len(d) < 2:
            return
        head = d[0]
        if head == "random" or (head in np_names and d[1] == "random"):
            yield mod.finding(self.name, call,
                              f"{where} draws host RNG `{'.'.join(d)}`: "
                              "sampled once at trace time, frozen into the "
                              "compiled program")
        elif head in np_names and any(
                _expr_tainted(a, tainted)
                for a in list(call.args)
                + [kw.value for kw in call.keywords]):
            yield mod.finding(self.name, call,
                              f"{where} calls `{'.'.join(d)}` on a traced "
                              "value: numpy constant-folds it at trace time "
                              "(or fails on tracers); use jnp")


class RetraceHazard:
    """jit configurations that retrace more than they should.

    * ``static_argnums=[...]`` / ``static_argnames=[...]`` given as a
      mutable ``list``/``set``/``dict`` display — use a tuple, so the spec
      itself can never be mutated between calls;
    * ``@jax.jit`` directly on a method (first parameter ``self``/``cls``)
      — every instance retraces, and the compilation cache pins the
      instance alive;
    * ``jax.jit(lambda ...)`` whose body reads ``self.<attr>`` — the jitted
      closure captures mutable instance state at trace time; later
      mutations silently do not retrigger a trace.
    """

    name = "retrace-hazard"
    summary = "unhashable/mutable jit statics and self-closures"

    def check(self, project: Project) -> Iterator[Finding]:
        cg = jit_callgraph(project)
        for idx in cg.indexes.values():
            mod = idx.mod
            for fi in idx.functions.values():
                if fi.class_name is None:
                    continue
                params = fi.params
                if not params or params[0] not in ("self", "cls"):
                    continue
                for dec in fi.node.decorator_list:
                    if cg._is_jit(dec, idx) or (
                            isinstance(dec, ast.Call)
                            and cg._jit_of_call(dec, idx)):
                        yield mod.finding(
                            self.name, fi.node,
                            f"`@jax.jit` on method `{fi.qualname}`: "
                            f"`{params[0]}` becomes a jit argument — every "
                            "instance retraces and the compilation cache "
                            "pins it; jit a free function instead")
            for call in cg.jit_call_sites(idx):
                for kw in call.keywords:
                    if kw.arg in ("static_argnums", "static_argnames") \
                            and isinstance(kw.value,
                                           (ast.List, ast.Set, ast.Dict)):
                        kind = type(kw.value).__name__.lower()
                        yield mod.finding(
                            self.name, kw.value,
                            f"`{kw.arg}` passed as a mutable {kind}: "
                            "use a tuple so the static spec is hashable "
                            "and immutable")
                if cg._is_jit(call.func, idx) and call.args \
                        and isinstance(call.args[0], ast.Lambda):
                    for sub in ast.walk(call.args[0].body):
                        if isinstance(sub, ast.Attribute) \
                                and isinstance(sub.value, ast.Name) \
                                and sub.value.id == "self":
                            yield mod.finding(
                                self.name, call,
                                "jitted lambda closes over mutable `self."
                                f"{sub.attr}`: captured at trace time, "
                                "mutations never retrigger a trace — pass "
                                "it as an argument")
                            break


class TracedBranch:
    """Python control flow on traced array values inside jitted bodies.

    Inside jit-reachable functions, an ``if``/``while`` (or ``assert``)
    whose test derives from a traced array forces ``bool()`` on a tracer —
    ``TracerBoolConversionError`` at best, silent trace-time
    specialization at worst.  Taint sources are ``jnp.*``/``jax.*`` calls
    and (for jit ROOT functions) the non-static parameters; taint flows
    through local assignments, arithmetic, comparisons, and subscripts.
    Static reads stay branchable: ``x is None``, ``isinstance``, ``len``,
    and ``.shape``/``.ndim``/``.dtype`` never concretize a tracer, and
    branching on config (``if cfg.family == ...``) is untouched.  The fix
    is ``jax.lax.cond`` / ``jnp.where`` / ``jax.lax.while_loop``.
    """

    name = "traced-branch"
    summary = "Python if/while on traced array values in jitted bodies"

    def check(self, project: Project) -> Iterator[Finding]:
        cg = jit_callgraph(project)
        for fi in cg.reachable.values():
            yield from self._check_fn(fi)

    def _check_fn(self, fi: FuncInfo) -> Iterator[Finding]:
        tainted = _tainted_names(fi)
        mod = fi.module
        for node in walk_shallow(fi.node):
            test = None
            kind = None
            if isinstance(node, (ast.If, ast.While)):
                test, kind = node.test, type(node).__name__.lower()
            elif isinstance(node, ast.Assert):
                test, kind = node.test, "assert"
            if test is None or not _expr_tainted(test, tainted):
                continue
            yield mod.finding(
                self.name, node,
                f"jit-reachable `{fi.qualname}` branches (`{kind}`) on a "
                "traced array value: concretizes a tracer — use "
                "jax.lax.cond/jnp.where (or jax.lax.while_loop)")


def _tainted_names(fi: FuncInfo) -> set[str]:
    """Local names bound to traced values inside ``fi``'s body.

    A jitted root's parameters ARE tracers (minus declared statics); the
    callgraph computed that set at root-marking time.  Taint then flows
    through local assignments — two passes so taint introduced later in
    the body reaches earlier reads in loops (the bodies are small).
    """
    tainted: set[str] = set(fi.traced_params)
    tainted.discard("self")
    for _ in range(2):
        for node in walk_shallow(fi.node):
            if isinstance(node, ast.Assign) \
                    and _expr_tainted(node.value, tainted):
                for t in node.targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            tainted.add(n.id)
            elif isinstance(node, ast.AugAssign) \
                    and isinstance(node.target, ast.Name) \
                    and _expr_tainted(node.value, tainted):
                tainted.add(node.target.id)
    return tainted


def _expr_tainted(expr: ast.AST, tainted: set[str]) -> bool:
    """Does evaluating ``expr`` produce a traced value (conservatively,
    with static reads — shape/ndim/is-None/isinstance/len — exempted)?"""
    if isinstance(expr, ast.Name):
        return expr.id in tainted
    if isinstance(expr, ast.Constant):
        return False
    if isinstance(expr, ast.Attribute):
        if expr.attr in _STATIC_ATTRS:
            return False
        return _expr_tainted(expr.value, tainted)
    if isinstance(expr, ast.Subscript):
        return _expr_tainted(expr.value, tainted)
    if isinstance(expr, ast.Call):
        f = expr.func
        if isinstance(f, ast.Name) and f.id in _STATIC_CALLS:
            return False
        d = _dotted(f)
        if d is not None and d[0] in _TRACED_NAMESPACES:
            return d[-1] not in _STATIC_QUERIES
        # method calls / other callables: tainted receiver or arguments
        # propagate (x.any(), bool(x), float(jnp.sum(x)))
        parts = ([f.value] if isinstance(f, ast.Attribute) else []) \
            + list(expr.args) + [kw.value for kw in expr.keywords]
        return any(_expr_tainted(a, tainted) for a in parts)
    if isinstance(expr, ast.Compare):
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in expr.ops):
            return False
        return any(_expr_tainted(e, tainted)
                   for e in [expr.left] + list(expr.comparators))
    if isinstance(expr, (ast.BoolOp, ast.BinOp, ast.UnaryOp, ast.IfExp,
                         ast.Tuple, ast.List, ast.Set, ast.Starred)):
        return any(_expr_tainted(c, tainted)
                   for c in ast.iter_child_nodes(expr)
                   if isinstance(c, ast.expr))
    return False
