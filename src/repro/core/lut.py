"""LUT-based ternary GEMM/GEMV algorithms (paper Sec. II + III-A/B).

Three algorithm families, all pure JAX:

1. ``tsar_*`` — the paper's method, with our single-shared-LUT compression:
   binary LUTs are built **on the fly** from the activation tile and consumed
   immediately (in registers/VMEM when lowered; nothing LUT-shaped is ever a
   kernel *input*).  The identity used (see DESIGN.md Sec. 2.1)::

       S[p]   = sum_i bit_i(p) * a_i                (2^c entries per block)
       <w,a>  = 2*S[idx_pos] + S[idx_zero] - sum(a)

   where ``idx_pos``/``idx_zero`` are the compile-time weight encodings from
   :func:`repro.core.ternary.pack_indices`.

2. ``memory_lut_*`` — the SOTA baseline the paper compares against (T-MAC /
   BitNet.cpp TL-2): the full ternary LUT (3^c entries/block) is materialized
   as an array in memory and the GEMV becomes pure gathers against it.  This
   reproduces the memory-bound dataflow of the paper's Fig. 3(a).

3. ``dense_*`` — reference dense paths: fp32/bf16 MAC (the FP16-kernel
   baseline of the paper's Sec. I) and the decode-to-int8 MXU path that our
   Pallas production kernel implements.

Shapes follow the paper's convention: GEMV is ``(1,K) x (K,M)``, GEMM is
``(N,K) x (K,M)``.  All functions accept activations ``a`` with arbitrary
leading batch dims ``(..., K)``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import ternary


# ---------------------------------------------------------------------------
# Shared binary LUT construction ("TLUT" in the paper)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _bit_matrix(c: int):
    """(2^c, c) float32 matrix with B[p, i] = bit_i(p)."""
    import numpy as np

    p = np.arange(1 << c, dtype=np.int32)
    bits = ((p[:, None] >> np.arange(c)) & 1).astype(np.float32)
    return bits


def build_lut(a: jax.Array, c: int) -> jax.Array:
    """Build the shared binary LUT S for every activation block.

    ``a`` (..., K) -> S (..., K//c, 2^c) with
    ``S[..., b, p] = sum_i bit_i(p) * a[..., b*c + i]``.

    The per-block construction is a (c -> 2^c) expansion, i.e. exactly what the
    paper's TLUT_cxs instruction computes inside SIMD registers.  Expressed as
    a tiny matmul so XLA maps it onto the MXU / vector unit.
    """
    k = a.shape[-1]
    if k % c != 0:
        raise ValueError(f"K={k} not a multiple of block size c={c}")
    blocks = a.reshape(a.shape[:-1] + (k // c, c))
    bm = jnp.asarray(_bit_matrix(c), dtype=a.dtype)  # (2^c, c)
    return blocks @ bm.T  # (..., B, 2^c)


def block_sums(a: jax.Array, c: int) -> jax.Array:
    """Per-block activation sums ``sum(a_block)`` -> (..., K//c)."""
    k = a.shape[-1]
    return a.reshape(a.shape[:-1] + (k // c, c)).sum(axis=-1)


# ---------------------------------------------------------------------------
# T-SAR on-the-fly LUT GEMV / GEMM
# ---------------------------------------------------------------------------

def tsar_lut_matmul(
    a: jax.Array,
    idx_pos: jax.Array,
    idx_zero: jax.Array,
    c: int,
    w_scale: jax.Array | None = None,
) -> jax.Array:
    """T-SAR LUT mat(vec)mul: ``a`` (..., K) x encoded weights (K//c, M) -> (..., M).

    LUTs are built on the fly from ``a`` and consumed immediately — they never
    appear as function inputs, mirroring the register-resident dataflow.

    Ragged K (``pack_indices`` zero-padded the tail block): the activations
    are zero-padded to match — pad positions carry the idx_zero bit, so each
    contributes ``2*0 + a_i - a_i = 0`` exactly.
    """
    kp = idx_pos.shape[-2] * c
    k = a.shape[-1]
    if kp != k:
        a = jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, kp - k)])
    s = build_lut(a, c)                          # (..., B, 2^c)
    tot = block_sums(a, c)                       # (..., B)
    # Gather per output channel: S[..., b, idx[b, m]].
    # take_along_axis over the last axis with idx broadcast to (..., B, M).
    bdims = s.shape[:-2]
    bcount = s.shape[-2]
    m = idx_pos.shape[-1]
    ip = jnp.broadcast_to(idx_pos.astype(jnp.int32), bdims + (bcount, m))
    iz = jnp.broadcast_to(idx_zero.astype(jnp.int32), bdims + (bcount, m))
    g_pos = jnp.take_along_axis(s, ip, axis=-1)  # (..., B, M)
    g_zero = jnp.take_along_axis(s, iz, axis=-1)
    y = (2.0 * g_pos + g_zero).sum(axis=-2) - tot.sum(axis=-1, keepdims=True)
    if w_scale is not None:
        y = y * w_scale
    return y


def tsar_lut_matmul_twolut(
    a: jax.Array,
    idx_pos: jax.Array,
    idx_zero: jax.Array,
    c: int,
    w_scale: jax.Array | None = None,
) -> jax.Array:
    """Paper-literal two-LUT form: ``<w,a> = <w_D,a> - <w_S,a>``.

    Builds *both* binary LUTs (dense in {-1,+1}, sparse in {0,1}) per block as
    the paper's TLUT instruction does, then subtracts the two gathers.  Kept
    for faithfulness + as the oracle for the compressed single-LUT form.

    The dense plane ``w_D`` is +1 wherever ``w in {0,+1}``, so its LUT index
    is the bitwise OR of the (disjoint) positive and zero encodings.
    """
    s = build_lut(a, c)                       # sparse-style LUT: sum of selected
    tot = block_sums(a, c)[..., None]         # (..., B, 1)
    dense_lut = 2.0 * s - tot                 # entries of the {-1,+1} LUT
    sparse_lut = s
    idx_dense = jnp.bitwise_or(idx_pos, idx_zero)
    bdims = s.shape[:-2]
    bcount = s.shape[-2]
    m = idx_dense.shape[-1]
    idn = jnp.broadcast_to(idx_dense.astype(jnp.int32), bdims + (bcount, m))
    izr = jnp.broadcast_to(idx_zero.astype(jnp.int32), bdims + (bcount, m))
    y = (jnp.take_along_axis(dense_lut, idn, axis=-1)
         - jnp.take_along_axis(sparse_lut, izr, axis=-1)).sum(axis=-2)
    if w_scale is not None:
        y = y * w_scale
    return y


# ---------------------------------------------------------------------------
# Memory-LUT baseline (T-MAC / BitNet.cpp TL-2 dataflow, paper Fig. 3(a))
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _ternary_patterns(c: int):
    """(3^c, c) int8 matrix enumerating every ternary block pattern."""
    import numpy as np

    n = 3 ** c
    digits = np.zeros((n, c), dtype=np.int8)
    idx = np.arange(n)
    for i in range(c):
        digits[:, i] = (idx % 3) - 1  # {-1, 0, +1}
        idx = idx // 3
    return digits


def ternary_lut_indices(t: jax.Array, c: int) -> jax.Array:
    """Base-3 encode ternary weights (K, M) -> (K//c, M) int32 LUT indices."""
    k, m = t.shape
    blocks = (t.reshape(k // c, c, m).astype(jnp.int32) + 1)  # {0,1,2}
    pows = (3 ** jnp.arange(c, dtype=jnp.int32)).reshape(1, c, 1)
    return jnp.sum(blocks * pows, axis=1)


def memory_lut_precompute(a: jax.Array, c: int) -> jax.Array:
    """Materialize the full ternary LUT in memory: (..., K//c, 3^c).

    This is the baseline's *stored* TLUT — 3^c fp entries per block, the
    object whose fetches dominate memory traffic in the paper's Fig. 2(c).
    """
    k = a.shape[-1]
    blocks = a.reshape(a.shape[:-1] + (k // c, c))
    pat = jnp.asarray(_ternary_patterns(c), dtype=a.dtype)  # (3^c, c)
    return blocks @ pat.T


def memory_lut_matmul(
    a: jax.Array,
    lut_idx: jax.Array,
    c: int,
    w_scale: jax.Array | None = None,
    precomputed_lut: jax.Array | None = None,
) -> jax.Array:
    """Baseline LUT mat(vec)mul: gathers against a memory-resident ternary LUT.

    If ``precomputed_lut`` is given it is used directly (steady-state decode,
    where the baseline reuses stored TLUTs and pays the fetch traffic).
    """
    lut = precomputed_lut if precomputed_lut is not None else memory_lut_precompute(a, c)
    bdims = lut.shape[:-2]
    bcount = lut.shape[-2]
    m = lut_idx.shape[-1]
    ix = jnp.broadcast_to(lut_idx.astype(jnp.int32), bdims + (bcount, m))
    y = jnp.take_along_axis(lut, ix, axis=-1).sum(axis=-2)
    if w_scale is not None:
        y = y * w_scale
    return y


# ---------------------------------------------------------------------------
# Dense reference paths
# ---------------------------------------------------------------------------

def dense_matmul(a: jax.Array, w: jax.Array, w_scale: jax.Array | None = None) -> jax.Array:
    """Dense fp MAC baseline: (..., K) x (K, M)."""
    y = a @ w.astype(a.dtype)
    if w_scale is not None:
        y = y * w_scale
    return y


def dense_int8_matmul(
    a_q: jax.Array, a_scale: jax.Array, t: jax.Array, w_scale: jax.Array
) -> jax.Array:
    """Decode-to-MXU path: int8 activations x int8 ternary weights, int32 acc.

    This is the pure-jnp spelling of the production Pallas kernel's math:
    ``y = (a_q @ t) * a_scale * w_scale`` with exact int32 accumulation.
    """
    acc = jax.lax.dot_general(
        a_q, t,
        dimension_numbers=(((a_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * a_scale * w_scale


def bitlinear_matmul_exact_int(
    a: jax.Array, t: jax.Array, w_scale: jax.Array
) -> jax.Array:
    """Full quant->int matmul->dequant BitLinear pipeline (paper Fig. 2(b))."""
    a_q, a_scale = ternary.quantize_activations(a)
    return dense_int8_matmul(a_q, a_scale, t.astype(jnp.int8), w_scale)


def bitlinear_matmul_fast(
    a: jax.Array, t: jax.Array, w_scale: jax.Array
) -> jax.Array:
    """Same pipeline, integer math carried in f32 FMAs.

    Numerically identical to the int path for K < 2^24/127 (~132k): the
    operands are exact small integers, so f32 accumulation is exact.  Used
    for wall-clock benchmarking on backends whose int8 dot lowering is slow
    (XLA:CPU); real deployments use the Pallas int8 kernel.
    """
    a_q, a_scale = ternary.quantize_activations(a)
    acc = a_q.astype(jnp.float32) @ t.astype(jnp.float32)
    return acc * a_scale * w_scale
