"""Adaptive kernel / dataflow selection (paper Sec. III-D).

The paper ships two microkernel dataflows and picks per layer at compile time:

* **AP (activation-persistent)** — activations (and the LUTs derived from
  them) stay resident; weight tiles stream past.  Wins when the LUT build cost
  is amortized over many output channels and the activation tile is reused
  (high N, K) — the GEMM/prefill regime.
* **OP (output-persistent)** — output accumulators stay resident; activation
  (LUT) tiles stream past.  Minimizes write-back traffic; wins for
  high-M GEMV/decode.

On TPU the same knob is the Pallas grid iteration order + which operand's
BlockSpec is pinned across the inner grid dimension.  The cost model below is
an analytic bytes/FLOPs estimate against the v5e roofline constants; it also
chooses *which* kernel family to run (in-VMEM LUT vs decode-to-MXU vs the
zero-block-skipping sparse pool), since on TPU the MXU path dominates once N
is large enough to fill a matmul tile, and the sparse path wins once enough
whole blocks are dead.

Density is an explicit input: the seed model implicitly assumed the uniform
~1/3-zeros BitNet prior for every layer; ``select_kernel`` now takes the
*measured* nonzero fraction (``density``) and live-block fraction
(``block_density``, e.g. ``BlockSparseTernary.block_density``) so the
per-layer choice tracks the checkpoint actually being served.
"""
from __future__ import annotations

from dataclasses import dataclass

# TPU v5e single-chip constants — shared with launch/roofline.py via core/hw.
from repro.core.hw import (  # noqa: F401  (re-exported for back-compat)
    HBM_BW,
    PEAK_FLOPS_BF16,
    PEAK_FLOPS_INT8,
    VMEM_BYTES,
)

# The BitNet-b1.58 prior: absmean ternarization zeroes ~1/3 of the weights.
# Used when no measured density is supplied.
DEFAULT_DENSITY = 2.0 / 3.0

# Canonical block-sparse tiling default; sparse/format re-exports it as
# DEFAULT_BLOCK_SHAPE (defined here, the import-graph root, to avoid a
# core <-> sparse cycle).
SPARSE_BLOCK = (256, 256)

# Issue-efficiency tax on the sparse kernel's live-block work: the
# scalar-prefetched gather walks the pool non-sequentially (no streaming
# prefetch), and strips with fewer live blocks than the grid's s_max still
# burn masked steps.  Charged on compute and the weight stream, it puts the
# analytic break-even near 1/1.1 ~ 0.9 live blocks instead of degenerately
# at 1.0.
SPARSE_ISSUE_TAX = 1.1


@dataclass(frozen=True)
class KernelChoice:
    kernel: str          # 'tsar_lut' | 'tsar_mxu' | 'tsar_sparse'
    dataflow: str        # 'AP' | 'OP'
    est_time_s: float
    bound: str           # 'compute' | 'memory'
    detail: dict


def _tsar_mxu_cost(n: int, k: int, m: int) -> tuple[float, float]:
    """(compute_s, memory_s) for the decode-to-MXU kernel."""
    flops = 2.0 * n * k * m                      # int8 MACs on the MXU
    decode_ops = k * m * 4.0                     # bitplane unpack ALU ops
    compute = flops / PEAK_FLOPS_INT8 + decode_ops / (PEAK_FLOPS_INT8 / 2)
    bytes_moved = (
        k * m * 0.25                             # 2-bit packed weights
        + n * k * 1.0                            # int8 activations
        + n * m * 2.0                            # bf16 outputs
        + m * 4.0                                # scales
    )
    return compute, bytes_moved / HBM_BW


def _tsar_lut_cost(n: int, k: int, m: int, c: int) -> tuple[float, float]:
    """(compute_s, memory_s) for the in-VMEM shared-LUT kernel."""
    blocks = k / c
    lut_build = n * blocks * (2 ** c) * 1.0      # TLUT expansion ops
    # Each gather lowered as one-hot x LUT: 2^c MACs per (block, m) pair, two
    # gathers per block (pos/zero) fused into one 2^c-wide matmul.
    gather = 2.0 * n * blocks * m * (2 ** c) / 8.0
    compute = (lut_build + gather) / PEAK_FLOPS_INT8
    bytes_moved = (
        2.0 * (k / c) * m * 1.0                  # idx_pos + idx_zero, 1B each
        + n * k * 1.0
        + n * m * 2.0
        + m * 4.0
    )
    return compute, bytes_moved / HBM_BW


def _tsar_sparse_cost(n: int, k: int, m: int, block_density: float,
                      block_shape: tuple = SPARSE_BLOCK) -> tuple[float, float]:
    """(compute_s, memory_s) for the zero-block-skipping kernel.

    MXU work and weight bytes scale with the LIVE-block fraction; the index
    map (int32 per block) and per-strip gather lists are the sparsity tax,
    which is why the dense kernel wins at block_density ~ 1.
    """
    bk, bm = block_shape
    kb, mb = max(k / bk, 1.0), max(m / bm, 1.0)
    live = block_density * kb * mb
    flops = 2.0 * n * bk * bm * live             # int8 MACs, live blocks only
    decode_ops = bk * bm * live * 4.0            # bitplane unpack, live only
    compute = SPARSE_ISSUE_TAX * (
        flops / PEAK_FLOPS_INT8 + decode_ops / (PEAK_FLOPS_INT8 / 2))
    bytes_moved = (
        SPARSE_ISSUE_TAX * live * bk * bm * 0.25  # 2-bit planes, live blocks
        + kb * mb * 4.0                          # block-index map (int32)
        + 2.0 * live * 4.0                       # kids+slots gather lists
        + n * k * 1.0                            # int8 activations
        + n * m * 2.0                            # bf16 outputs
        + m * 4.0                                # scales
    )
    return compute, bytes_moved / HBM_BW


def select_kernel(n: int, k: int, m: int, c: int = 4,
                  density: float = DEFAULT_DENSITY,
                  block_density: float | None = None,
                  block_shape: tuple = SPARSE_BLOCK) -> KernelChoice:
    """Compile-time per-layer selection (paper: 'empirically selects the
    fastest kernel for each layer'); here an analytic roofline pick.

    ``density`` is the measured nonzero-weight fraction (defaults to the
    BitNet ~2/3 prior); ``block_density`` the measured live-block fraction at
    ``block_shape`` tiling.  When ``block_density`` is omitted it is estimated
    from ``density`` assuming unstructured zeros — which makes essentially
    every block live (``1 - (1-d)^(bk*bm) ~ 1``), so the sparse path is only
    chosen on *measured* structured sparsity, never speculatively.
    """
    mxu_c, mxu_m = _tsar_mxu_cost(n, k, m)
    lut_c, lut_m = _tsar_lut_cost(n, k, m, c)
    if block_density is None:
        bk, bm = block_shape
        block_density = 1.0 - (1.0 - min(density, 1.0 - 1e-12)) ** (bk * bm)
    sp_c, sp_m = _tsar_sparse_cost(n, k, m, block_density, block_shape)
    cands = {
        "tsar_mxu": max(mxu_c, mxu_m),
        "tsar_lut": max(lut_c, lut_m),
        "tsar_sparse": max(sp_c, sp_m),
    }
    # Strict improvement required: at/above break-even the dense paths win
    # (no format conversion for a wash).
    dense_cands = {kn: v for kn, v in cands.items() if kn != "tsar_sparse"}
    kernel = min(dense_cands, key=dense_cands.get)
    if cands["tsar_sparse"] < dense_cands[kernel]:
        kernel = "tsar_sparse"
    comp, mem = {"tsar_mxu": (mxu_c, mxu_m), "tsar_lut": (lut_c, lut_m),
                 "tsar_sparse": (sp_c, sp_m)}[kernel]
    dataflow = select_dataflow(n, k, m, c)
    return KernelChoice(
        kernel=kernel,
        dataflow=dataflow,
        est_time_s=cands[kernel],
        bound="compute" if comp >= mem else "memory",
        detail={"compute_s": comp, "memory_s": mem, "candidates": cands,
                "density": density, "block_density": block_density},
    )


def sparse_break_even(n: int, k: int, m: int, c: int = 4,
                      block_shape: tuple = SPARSE_BLOCK) -> float:
    """Block density below which ``tsar_sparse`` beats the best dense kernel.

    The sparse cost is monotonically increasing in block density and the
    dense costs are constant, so the crossover is unique; found by bisection
    to stay consistent with :func:`select_kernel` exactly.
    """
    mxu_c, mxu_m = _tsar_mxu_cost(n, k, m)
    lut_c, lut_m = _tsar_lut_cost(n, k, m, c)
    best_dense = min(max(mxu_c, mxu_m), max(lut_c, lut_m))

    def sparse(bd: float) -> float:
        sc, sm = _tsar_sparse_cost(n, k, m, bd, block_shape)
        return max(sc, sm)

    if sparse(1.0) < best_dense:
        return 1.0
    if sparse(0.0) >= best_dense:
        return 0.0
    lo, hi = 0.0, 1.0
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if sparse(mid) < best_dense:
            lo = mid
        else:
            hi = mid
    return lo


def select_dataflow(n: int, k: int, m: int, c: int = 4,
                    vmem_budget: int = VMEM_BYTES) -> str:
    """AP vs OP (paper Fig. 7).

    AP pins the activation/LUT tile in VMEM and streams weights: write-back of
    partial outputs happens once per weight pass, LUTs are built exactly once.
    OP pins the (n, m_tile) accumulator and streams LUT tiles: zero
    intermediate write-back, LUTs may be rebuilt per m-tile.

    Heuristic mirror of the paper's empirical rule: high activation reuse
    (large n*k working set relative to outputs) -> AP; output-channel-heavy
    GEMV (m >> n) -> OP.
    """
    act_bytes = n * k                      # int8 activations
    lut_bytes = n * (k / c) * (2 ** c) * 2  # bf16 shared LUTs
    out_bytes = n * m * 4                  # f32 accumulators
    if act_bytes + lut_bytes <= vmem_budget * 0.5 and n >= 8:
        return "AP"
    if out_bytes <= vmem_budget * 0.5 and m >= n:
        return "OP"
    return "AP" if n * k >= m else "OP"


def layer_plan(shapes: dict[str, tuple[int, int, int]], c: int = 4) -> dict[str, KernelChoice]:
    """Whole-model compile-time plan: layer name -> choice.  Logged by the
    serving engine and train driver so the per-layer adaptivity is visible."""
    return {name: select_kernel(n, k, m, c) for name, (n, k, m) in shapes.items()}
