"""Adaptive kernel / dataflow selection (paper Sec. III-D).

The paper ships two microkernel dataflows and picks per layer at compile time:

* **AP (activation-persistent)** — activations (and the LUTs derived from
  them) stay resident; weight tiles stream past.  Wins when the LUT build cost
  is amortized over many output channels and the activation tile is reused
  (high N, K) — the GEMM/prefill regime.
* **OP (output-persistent)** — output accumulators stay resident; activation
  (LUT) tiles stream past.  Minimizes write-back traffic; wins for
  high-M GEMV/decode.

On TPU the same knob is the Pallas grid iteration order + which operand's
BlockSpec is pinned across the inner grid dimension.

Since the execution-plan redesign, the per-kernel cost models live on the
kernel implementations themselves (``repro.plan.registry`` — each
:class:`KernelImpl` carries ``cost(n, k, m, c, density, block_density)``);
:func:`select_kernel` is the argmin over the registry's selectable costs, and
:func:`layer_plan` is a thin wrapper over
``repro.plan.plan.compile_plan_from_shapes`` kept for compatibility.  The
durable, whole-model version of this choice is
``repro.plan.compile_plan`` -> ``ModelPlan``.
"""
from __future__ import annotations

from dataclasses import dataclass

# TPU v5e single-chip constants — shared with launch/roofline.py via core/hw.
from repro.core.hw import (  # noqa: F401  (re-exported for back-compat)
    HBM_BW,
    PEAK_FLOPS_BF16,
    PEAK_FLOPS_INT8,
    SPARSE_ISSUE_TAX,
    VMEM_BYTES,
)
from repro.plan import registry as _registry
from repro.plan.registry import (  # noqa: F401  (canonical home is the registry)
    DEFAULT_DENSITY,
    SPARSE_BLOCK,
    SPARSE_KERNELS,
)


@dataclass(frozen=True)
class KernelChoice:
    kernel: str          # 'tsar_lut' | 'tsar_mxu' | 'tsar_sparse'
    dataflow: str        # 'AP' | 'OP'
    est_time_s: float
    bound: str           # 'compute' | 'memory'
    detail: dict


# Back-compat aliases: the cost models moved behind the registry impls'
# ``cost()`` methods; these keep the old private names callable.

def _tsar_mxu_cost(n: int, k: int, m: int) -> tuple[float, float]:
    return _registry.get("tsar_mxu").cost(n, k, m)


def _tsar_lut_cost(n: int, k: int, m: int, c: int) -> tuple[float, float]:
    return _registry.get("tsar_lut").cost(n, k, m, c)


def _tsar_sparse_cost(n: int, k: int, m: int, block_density: float,
                      block_shape: tuple = SPARSE_BLOCK) -> tuple[float, float]:
    return _registry.get("tsar_sparse").cost(
        n, k, m, block_density=block_density, block_shape=block_shape)


def select_kernel(n: int, k: int, m: int, c: int = 4,
                  density: float = DEFAULT_DENSITY,
                  block_density: float | None = None,
                  block_shape: tuple = SPARSE_BLOCK,
                  sparse_ok: tuple | None = None) -> KernelChoice:
    """Compile-time per-layer selection (paper: 'empirically selects the
    fastest kernel for each layer'); an analytic roofline argmin over the
    registry's selectable kernels.

    ``density`` is the measured nonzero-weight fraction (defaults to the
    BitNet ~2/3 prior); ``block_density`` the measured live-block fraction at
    ``block_shape`` tiling.  When ``block_density`` is omitted it is estimated
    from ``density`` assuming unstructured zeros — which makes essentially
    every block live (``1 - (1-d)^(bk*bm) ~ 1``), so the sparse path is only
    chosen on *measured* structured sparsity, never speculatively.

    ``sparse_ok`` restricts the sparse-family candidates
    (``registry.SPARSE_KERNELS``) to the formats the layer actually carries:
    ``compile_plan`` passes the subset whose ``supports()`` gate passes, so a
    plan never commits to e.g. ``tsar_sparse`` on a layer that only holds a
    padded pool.  ``None`` keeps every selectable kernel in play (legacy
    shape-only calls; resolve-time degradation still guards execution).

    Serve-path note: this runs at PLAN time only.  The serving engine calls
    it (via ``repro.plan.compile_plan``) once at init; the jitted step then
    dispatches through the frozen ``ModelPlan``.
    """
    if block_density is None:
        block_density = _registry.estimate_block_density(density, block_shape)
    costs = _registry.candidate_costs(n, k, m, c, density=density,
                                     block_density=block_density,
                                     block_shape=block_shape)
    if sparse_ok is not None:
        costs = {kn: v for kn, v in costs.items()
                 if kn not in SPARSE_KERNELS or kn in sparse_ok}
    cands = {name: max(comp, mem) for name, (comp, mem) in costs.items()}
    # Strict improvement required: at/above break-even the dense paths win
    # (no format conversion for a wash).
    dense_cands = {kn: v for kn, v in cands.items()
                   if kn not in SPARSE_KERNELS}
    kernel = min(dense_cands, key=dense_cands.get)
    sparse_cands = {kn: v for kn, v in cands.items() if kn in SPARSE_KERNELS}
    if sparse_cands:
        best_sparse = min(sparse_cands, key=sparse_cands.get)
        if sparse_cands[best_sparse] < dense_cands[kernel]:
            kernel = best_sparse
    comp, mem = costs[kernel]
    dataflow = select_dataflow(n, k, m, c)
    return KernelChoice(
        kernel=kernel,
        dataflow=dataflow,
        est_time_s=cands[kernel],
        bound="compute" if comp >= mem else "memory",
        detail={"compute_s": comp, "memory_s": mem, "candidates": cands,
                "density": density, "block_density": block_density},
    )


def sparse_break_even(n: int, k: int, m: int, c: int = 4,
                      block_shape: tuple = SPARSE_BLOCK,
                      kernel: str = "tsar_sparse") -> float:
    """Block density below which ``kernel`` (a sparse-family member — the
    compacted ``tsar_sparse`` by default, or ``tsar_sparse_padded``) beats
    the best dense kernel.

    The sparse cost is monotonically increasing in block density and the
    dense costs are constant, so the crossover is unique; found by bisection
    to stay consistent with :func:`select_kernel` exactly.
    """
    if kernel not in SPARSE_KERNELS:
        raise ValueError(f"{kernel!r} is not a sparse kernel: {SPARSE_KERNELS}")
    best_dense = min(
        max(*_registry.get(name).cost(n, k, m, c))
        for name in _registry.selectable_names()
        if name not in SPARSE_KERNELS)
    sp = _registry.get(kernel)

    def sparse(bd: float) -> float:
        sc, sm = sp.cost(n, k, m, c, block_density=bd, block_shape=block_shape)
        return max(sc, sm)

    if sparse(1.0) < best_dense:
        return 1.0
    if sparse(0.0) >= best_dense:
        return 0.0
    lo, hi = 0.0, 1.0
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if sparse(mid) < best_dense:
            lo = mid
        else:
            hi = mid
    return lo


def select_dataflow(n: int, k: int, m: int, c: int = 4,
                    vmem_budget: int = VMEM_BYTES) -> str:
    """AP vs OP (paper Fig. 7).

    AP pins the activation/LUT tile in VMEM and streams weights: write-back of
    partial outputs happens once per weight pass, LUTs are built exactly once.
    OP pins the (n, m_tile) accumulator and streams LUT tiles: zero
    intermediate write-back, LUTs may be rebuilt per m-tile.

    Heuristic mirror of the paper's empirical rule: high activation reuse
    (large n*k working set relative to outputs) -> AP; output-channel-heavy
    GEMV (m >> n) -> OP.
    """
    act_bytes = n * k                      # int8 activations
    lut_bytes = n * (k / c) * (2 ** c) * 2  # bf16 shared LUTs
    out_bytes = n * m * 4                  # f32 accumulators
    if act_bytes + lut_bytes <= vmem_budget * 0.5 and n >= 8:
        return "AP"
    if out_bytes <= vmem_budget * 0.5 and m >= n:
        return "OP"
    return "AP" if n * k >= m else "OP"


def layer_plan(shapes: dict, c: int = 4) -> dict[str, KernelChoice]:
    """Whole-model compile-time plan: layer name -> choice.

    Thin compatibility wrapper over ``repro.plan.compile_plan_from_shapes``.
    Specs may be ``(n, k, m)``, ``(n, k, m, c)``, or dicts with optional
    per-layer ``c`` / ``density`` / ``block_density`` — so e.g. MoE expert
    layers with a different LUT block size or measured density cost
    correctly.  Prefer ``repro.plan.compile_plan`` for a durable, savable
    ModelPlan.
    """
    from repro.plan.plan import compile_plan_from_shapes

    mp = compile_plan_from_shapes(shapes, c=c)
    out: dict[str, KernelChoice] = {}
    for name, by_bucket in mp.layers.items():
        ((n, lp),) = by_bucket.items()
        out[name] = KernelChoice(
            kernel=lp.kernel, dataflow=lp.dataflow, est_time_s=lp.est_time_s,
            bound=lp.bound,
            detail={"density": lp.density, "tile_sizes": lp.tile_sizes,
                    "bucket": n})
    return out
