"""Adaptive kernel / dataflow selection (paper Sec. III-D).

The paper ships two microkernel dataflows and picks per layer at compile time:

* **AP (activation-persistent)** — activations (and the LUTs derived from
  them) stay resident; weight tiles stream past.  Wins when the LUT build cost
  is amortized over many output channels and the activation tile is reused
  (high N, K) — the GEMM/prefill regime.
* **OP (output-persistent)** — output accumulators stay resident; activation
  (LUT) tiles stream past.  Minimizes write-back traffic; wins for
  high-M GEMV/decode.

On TPU the same knob is the Pallas grid iteration order + which operand's
BlockSpec is pinned across the inner grid dimension.  The cost model below is
an analytic bytes/FLOPs estimate against the v5e roofline constants; it also
chooses *which* kernel family to run (in-VMEM LUT vs decode-to-MXU), since on
TPU the MXU path dominates once N is large enough to fill a matmul tile.
"""
from __future__ import annotations

from dataclasses import dataclass

# TPU v5e single-chip constants (also used by launch/roofline.py).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
PEAK_FLOPS_INT8 = 394e12      # int8 ops/s (2x bf16 on v5e MXU)
HBM_BW = 819e9                # bytes/s
VMEM_BYTES = 128 * 1024 * 1024


@dataclass(frozen=True)
class KernelChoice:
    kernel: str          # 'tsar_lut' | 'tsar_mxu'
    dataflow: str        # 'AP' | 'OP'
    est_time_s: float
    bound: str           # 'compute' | 'memory'
    detail: dict


def _tsar_mxu_cost(n: int, k: int, m: int) -> tuple[float, float]:
    """(compute_s, memory_s) for the decode-to-MXU kernel."""
    flops = 2.0 * n * k * m                      # int8 MACs on the MXU
    decode_ops = k * m * 4.0                     # bitplane unpack ALU ops
    compute = flops / PEAK_FLOPS_INT8 + decode_ops / (PEAK_FLOPS_INT8 / 2)
    bytes_moved = (
        k * m * 0.25                             # 2-bit packed weights
        + n * k * 1.0                            # int8 activations
        + n * m * 2.0                            # bf16 outputs
        + m * 4.0                                # scales
    )
    return compute, bytes_moved / HBM_BW


def _tsar_lut_cost(n: int, k: int, m: int, c: int) -> tuple[float, float]:
    """(compute_s, memory_s) for the in-VMEM shared-LUT kernel."""
    blocks = k / c
    lut_build = n * blocks * (2 ** c) * 1.0      # TLUT expansion ops
    # Each gather lowered as one-hot x LUT: 2^c MACs per (block, m) pair, two
    # gathers per block (pos/zero) fused into one 2^c-wide matmul.
    gather = 2.0 * n * blocks * m * (2 ** c) / 8.0
    compute = (lut_build + gather) / PEAK_FLOPS_INT8
    bytes_moved = (
        2.0 * (k / c) * m * 1.0                  # idx_pos + idx_zero, 1B each
        + n * k * 1.0
        + n * m * 2.0
        + m * 4.0
    )
    return compute, bytes_moved / HBM_BW


def select_kernel(n: int, k: int, m: int, c: int = 4) -> KernelChoice:
    """Compile-time per-layer selection (paper: 'empirically selects the
    fastest kernel for each layer'); here an analytic roofline pick."""
    mxu_c, mxu_m = _tsar_mxu_cost(n, k, m)
    lut_c, lut_m = _tsar_lut_cost(n, k, m, c)
    cands = {
        "tsar_mxu": max(mxu_c, mxu_m),
        "tsar_lut": max(lut_c, lut_m),
    }
    kernel = min(cands, key=cands.get)
    comp, mem = (mxu_c, mxu_m) if kernel == "tsar_mxu" else (lut_c, lut_m)
    dataflow = select_dataflow(n, k, m, c)
    return KernelChoice(
        kernel=kernel,
        dataflow=dataflow,
        est_time_s=cands[kernel],
        bound="compute" if comp >= mem else "memory",
        detail={"compute_s": comp, "memory_s": mem, "candidates": cands},
    )


def select_dataflow(n: int, k: int, m: int, c: int = 4,
                    vmem_budget: int = VMEM_BYTES) -> str:
    """AP vs OP (paper Fig. 7).

    AP pins the activation/LUT tile in VMEM and streams weights: write-back of
    partial outputs happens once per weight pass, LUTs are built exactly once.
    OP pins the (n, m_tile) accumulator and streams LUT tiles: zero
    intermediate write-back, LUTs may be rebuilt per m-tile.

    Heuristic mirror of the paper's empirical rule: high activation reuse
    (large n*k working set relative to outputs) -> AP; output-channel-heavy
    GEMV (m >> n) -> OP.
    """
    act_bytes = n * k                      # int8 activations
    lut_bytes = n * (k / c) * (2 ** c) * 2  # bf16 shared LUTs
    out_bytes = n * m * 4                  # f32 accumulators
    if act_bytes + lut_bytes <= vmem_budget * 0.5 and n >= 8:
        return "AP"
    if out_bytes <= vmem_budget * 0.5 and m >= n:
        return "OP"
    return "AP" if n * k >= m else "OP"


def layer_plan(shapes: dict[str, tuple[int, int, int]], c: int = 4) -> dict[str, KernelChoice]:
    """Whole-model compile-time plan: layer name -> choice.  Logged by the
    serving engine and train driver so the per-layer adaptivity is visible."""
    return {name: select_kernel(n, k, m, c) for name, (n, k, m) in shapes.items()}
