"""Core T-SAR algorithmic layer: ternary quantization, LUT algorithms,
BitLinear, and the adaptive AP/OP dataflow selector."""
from repro.core import bitlinear, dataflow, lut, ternary  # noqa: F401
