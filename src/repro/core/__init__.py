"""Core T-SAR algorithmic layer: ternary quantization, LUT algorithms,
BitLinear, shared hardware constants, and the adaptive AP/OP dataflow
selector (density-aware — see ``repro.sparse``; kernel costs and lowerings
live on the ``repro.plan.registry`` implementations)."""
from repro.core import bitlinear, dataflow, hw, lut, ternary  # noqa: F401
