"""Core T-SAR algorithmic layer: ternary quantization, LUT algorithms,
BitLinear, shared hardware constants, and the adaptive AP/OP dataflow
selector (now density-aware — see ``repro.sparse``)."""
from repro.core import bitlinear, dataflow, hw, lut, ternary  # noqa: F401
