"""TPU v5e single-chip hardware constants — the one shared definition.

Previously duplicated between ``core/dataflow.py`` (kernel selection cost
model) and ``launch/roofline.py`` (dry-run roofline extraction); both now
import from here so a calibration tweak cannot desynchronize the two models.

Besides the fixed datasheet numbers, this module owns the **calibratable**
cost-model constants.  ``SPARSE_ISSUE_TAX`` started life as an analytic guess
(the sparse kernels' scalar-prefetched pool gather walks HBM non-sequentially
and masked tail steps still burn grid issue slots); the calibration mode in
``benchmarks/bench_kernels.py`` fits it from measured interpret-mode timings
and installs the fitted value here (``set_calibration``), which every
registry cost model then reads through :func:`sparse_issue_tax` — so a
measured machine overrides the guess without touching the cost formulas.
"""
from __future__ import annotations

import json

PEAK_FLOPS_BF16 = 197e12       # FLOP/s
PEAK_FLOPS_INT8 = 394e12       # int8 ops/s (2x bf16 on the v5e MXU)
HBM_BW = 819e9                 # bytes/s
VMEM_BYTES = 128 * 1024 * 1024
ICI_LINK_BW = 50e9             # bytes/s per ICI link (~ spec value)

# Issue-efficiency tax on the sparse kernels' live-block work (analytic
# default; see module docstring).  Puts the break-even near 1/1.1 ~ 0.9 live
# blocks instead of degenerately at 1.0.
SPARSE_ISSUE_TAX = 1.1

# Cost of one MASKED grid step in the padded-pool sparse kernel, as a
# fraction of a live block's compute: the static s_steps walk issues the
# step (grid bookkeeping + predicated-off DMA slot) even when the
# ``s < counts[j]`` guard drops the MXU work.
SPARSE_PAD_STEP_FRAC = 0.05

# Calibratable keys and their analytic defaults.  Values installed via
# set_calibration() shadow the module constants for every reader that goes
# through the accessor functions (the kernel registry cost models do).
_CALIBRATION_DEFAULTS = {
    "sparse_issue_tax": SPARSE_ISSUE_TAX,
    "sparse_pad_step_frac": SPARSE_PAD_STEP_FRAC,
}
_CALIBRATED: dict[str, float] = {}


def sparse_issue_tax() -> float:
    """The live value: calibrated if installed, else the analytic default."""
    return _CALIBRATED.get("sparse_issue_tax", SPARSE_ISSUE_TAX)


def sparse_pad_step_frac() -> float:
    return _CALIBRATED.get("sparse_pad_step_frac", SPARSE_PAD_STEP_FRAC)


def set_calibration(**values: float) -> None:
    """Install measured cost-model constants (``benchmarks/bench_kernels.py
    --calibrate`` is the producer).  Unknown keys / non-positive values are
    rejected loudly — a typo'd calibration silently reverting to defaults
    would defeat the point."""
    for key, val in values.items():
        if key not in _CALIBRATION_DEFAULTS:
            raise ValueError(
                f"unknown calibration key {key!r}; known: "
                f"{sorted(_CALIBRATION_DEFAULTS)}")
        val = float(val)
        if not val > 0.0:
            raise ValueError(f"calibration {key}={val!r} must be > 0")
        _CALIBRATED[key] = val


def clear_calibration(*keys: str) -> None:
    """Drop calibrated values (all of them when called with no args)."""
    if not keys:
        _CALIBRATED.clear()
        return
    for key in keys:
        _CALIBRATED.pop(key, None)


def calibration() -> dict[str, float]:
    """The effective constants (defaults overlaid with calibrated values)."""
    out = dict(_CALIBRATION_DEFAULTS)
    out.update(_CALIBRATED)
    return out


def save_calibration(path, values: dict | None = None) -> None:
    """Write the calibration JSON ``load_calibration`` consumes.

    ``values`` defaults to the currently installed calibration; an explicit
    dict (validated against the known keys) lets a fit be persisted without
    installing it process-globally — either way this function is the one
    writer of the file format.
    """
    if values is None:
        values = dict(_CALIBRATED)
    else:
        for key, val in values.items():
            if key not in _CALIBRATION_DEFAULTS:
                raise ValueError(
                    f"unknown calibration key {key!r}; known: "
                    f"{sorted(_CALIBRATION_DEFAULTS)}")
            if not float(val) > 0.0:
                raise ValueError(f"calibration {key}={val!r} must be > 0")
    with open(path, "w") as f:
        json.dump({"version": 1, "calibration": dict(values)}, f, indent=2)


def load_calibration(path) -> dict[str, float]:
    with open(path) as f:
        payload = json.load(f)
    if payload.get("version") != 1:
        raise ValueError(f"calibration version {payload.get('version')!r} != 1")
    set_calibration(**payload["calibration"])
    return dict(payload["calibration"])
