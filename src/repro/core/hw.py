"""TPU v5e single-chip hardware constants — the one shared definition.

Previously duplicated between ``core/dataflow.py`` (kernel selection cost
model) and ``launch/roofline.py`` (dry-run roofline extraction); both now
import from here so a calibration tweak cannot desynchronize the two models.
"""
from __future__ import annotations

PEAK_FLOPS_BF16 = 197e12       # FLOP/s
PEAK_FLOPS_INT8 = 394e12       # int8 ops/s (2x bf16 on the v5e MXU)
HBM_BW = 819e9                 # bytes/s
VMEM_BYTES = 128 * 1024 * 1024
ICI_LINK_BW = 50e9             # bytes/s per ICI link (~ spec value)
