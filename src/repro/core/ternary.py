"""Ternary quantization, bitplane packing, and the T-SAR ternary->binary decomposition.

This module is the algorithmic layer of the paper (Sec. III-A):

* ``absmean`` ternarization of latent fp weights (BitNet-b1.58 recipe).
* Decomposition of a ternary tensor ``w in {-1,0,1}`` into two binary planes::

      dense  w_D in {-1,+1}:  w_D = w  where w != 0, else +1
      sparse w_S in {0, 1}:   w_S = 1  where w == 0, else 0

  so that ``<w, a> = <w_D, a> - <w_S, a>`` for any activation vector ``a``.
* Bitplane packing: the *sign* plane (bit of w_D) and the *zero* plane (bit of
  w_S) are each packed 8 weights/byte -> 2 bits/weight total in HBM, the 8x
  compression the paper's Fig. 1(a) shows.
* Per-token int8 activation quantization (absmax), the input half of the
  BitLinear pipeline in the paper's Fig. 2(b).

Everything here is pure JAX and shape-polymorphic; the Pallas kernels in
``repro.kernels`` consume the packed representation produced here.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# Number of weights packed per byte in a bitplane.
PACK = 8


class TernaryWeights(NamedTuple):
    """Frozen, packed ternary weight tensor for inference.

    Logical layout is ``(K, M)`` (in-features, out-features).  Both planes are
    packed along K so a Pallas kernel tile of ``bk`` input channels reads
    ``bk // 8`` bytes per output channel per plane.
    """

    sign_plane: jax.Array   # uint8 (ceil(K/8), M)  bit=1 where w == -1 (sign of dense plane)
    zero_plane: jax.Array   # uint8 (ceil(K/8), M)  bit=1 where w == 0
    scale: jax.Array        # f32   (M,) per-output-channel dequant scale
    shape: tuple            # static logical (K, M)

    @property
    def k(self) -> int:
        return self.shape[0]

    @property
    def m(self) -> int:
        return self.shape[1]

    def nbytes(self) -> int:
        """HBM bytes for the packed planes (the paper's 2-bit/weight claim)."""
        return int(self.sign_plane.size + self.zero_plane.size + self.scale.size * 4)


def absmean_ternarize(w: jax.Array, eps: float = 1e-6) -> tuple[jax.Array, jax.Array]:
    """BitNet-b1.58 absmean ternarization.

    ``w`` fp latent weights; the last two dims are the (K, M) matrix, any
    leading dims are batch (stacked layers, stacked experts).  Returns
    ``(t, scale)`` with ``t in {-1,0,+1}`` (same dtype as w) and
    per-(batch, output-channel) scale such that ``w ~= t * scale``.
    """
    # Per-matrix absmean threshold (the BitNet recipe uses per-tensor gamma).
    gamma = jnp.mean(jnp.abs(w), axis=(-2, -1), keepdims=True) + eps
    t = jnp.clip(jnp.round(w / gamma), -1, 1)
    # Per-output-channel scale refits the dequant step: least-squares of w on t.
    num = jnp.sum(w * t, axis=-2)
    den = jnp.sum(t * t, axis=-2) + eps
    scale = num / den
    return t, scale


def decompose(t: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Ternary -> (dense, sparse) binary decomposition (paper Sec. III-A).

    Returns ``(w_d, w_s)`` with ``w_d in {-1,+1}`` and ``w_s in {0,1}`` so that
    ``t == w_d - w_s`` elementwise.
    """
    w_s = (t == 0).astype(t.dtype)
    w_d = jnp.where(t == 0, jnp.ones_like(t), t)
    return w_d, w_s


def recompose(w_d: jax.Array, w_s: jax.Array) -> jax.Array:
    """Inverse of :func:`decompose`."""
    return w_d - w_s


def _pack_bits(bits: jax.Array, pad_value: int = 0) -> jax.Array:
    """Pack a ``{0,1}`` array along axis 0: (K, ...) uint -> (ceil(K/8), ...)
    uint8.

    Bit i of byte j holds element ``j*8 + i`` (LSB-first), matching the
    unpacking order in the Pallas kernels.  A ragged tail (K not a multiple
    of 8) is padded with ``pad_value`` bits; :func:`_unpack_bits` slices them
    off.  :func:`pack` pads the zero plane with 1s so pad positions decode to
    weight 0 — consumers that can't know the true K (density telemetry, the
    MoE stacked decode) then see harmless zeros instead of phantom +1s.
    """
    k = bits.shape[0]
    pad = (-k) % PACK
    if pad:
        widths = [(0, pad)] + [(0, 0)] * (bits.ndim - 1)
        bits = jnp.pad(bits, widths, constant_values=pad_value)
    kp = k + pad
    b = bits.astype(jnp.uint8).reshape((kp // PACK, PACK) + bits.shape[1:])
    shifts = jnp.arange(PACK, dtype=jnp.uint8).reshape((1, PACK) + (1,) * (bits.ndim - 1))
    return jnp.sum(b << shifts, axis=1).astype(jnp.uint8)


def _unpack_bits(packed: jax.Array, k: int) -> jax.Array:
    """Inverse of :func:`_pack_bits` -> int8 {0,1} of shape (k, ...)."""
    shifts = jnp.arange(PACK, dtype=jnp.uint8).reshape((1, PACK) + (1,) * (packed.ndim - 1))
    bits = (packed[:, None] >> shifts) & jnp.uint8(1)
    kp = packed.shape[0] * PACK
    return bits.reshape((kp,) + packed.shape[1:])[:k].astype(jnp.int8)


def pack(t: jax.Array, scale: jax.Array | None = None) -> TernaryWeights:
    """Pack a ternary (K, M) matrix into 2-bit bitplanes.

    sign_plane bit = 1 where t == -1 (so dense value = 1 - 2*bit),
    zero_plane bit = 1 where t == 0.
    """
    if t.ndim != 2:
        raise ValueError(f"pack expects a 2-D (K, M) matrix, got {t.shape}")
    k, m = t.shape
    if scale is None:
        scale = jnp.ones((m,), jnp.float32)
    sign = (t < 0)
    zero = (t == 0)
    return TernaryWeights(
        sign_plane=_pack_bits(sign),
        zero_plane=_pack_bits(zero, pad_value=1),   # ragged tail decodes to 0
        scale=scale.astype(jnp.float32),
        shape=(k, m),
    )


def unpack(tw: TernaryWeights, dtype=jnp.int8) -> jax.Array:
    """Unpack bitplanes back to a dense ternary (K, M) matrix (no scale)."""
    k, _ = tw.shape
    sign = _unpack_bits(tw.sign_plane, k)   # {0,1}, 1 => -1
    zero = _unpack_bits(tw.zero_plane, k)   # {0,1}, 1 => 0
    vals = (1 - 2 * sign.astype(jnp.int8)) * (1 - zero.astype(jnp.int8))
    return vals.astype(dtype)


def unpack_dequant(tw: TernaryWeights, dtype=jnp.float32) -> jax.Array:
    """Unpack + apply per-channel scale -> approximate original fp weights."""
    return unpack(tw, jnp.float32) * tw.scale[None, :].astype(jnp.float32)


def pack_indices(t: jax.Array, c: int) -> tuple[jax.Array, jax.Array]:
    """Encode ternary (K, M) weights as per-block LUT indices (compile-time
    weight encoding in the paper's Fig. 5).

    Splits K into blocks of ``c`` and returns ``(idx_d, idx_s)`` of shape
    (K//c, M), uint8 (requires c <= 8), where bit i of ``idx_d`` is
    ``1`` iff ``w[block*c+i] == +1`` (dense-plane positive bit) and bit i of
    ``idx_s`` is ``1`` iff ``w[block*c+i] == 0``.

    With the shared binary LUT ``S[p] = sum_i bit_i(p) * a_i`` these satisfy
    ``<w, a>_block = 2*S[idx_d] + S[idx_s] - sum(a_block)``  ... see lut.py.
    """
    if c > 8:
        raise ValueError("block size c must be <= 8 to fit uint8 indices")
    k, m = t.shape
    pad = (-k) % c
    if pad:
        # Pad with zeros: pad positions get their idx_s bit set (value 0), so
        # the LUT identity contributes 2*0 + a_i - a_i = 0 per pad position.
        t = jnp.pad(t, ((0, pad), (0, 0)))
    blocks = t.reshape((k + pad) // c, c, m)
    shifts = (1 << jnp.arange(c, dtype=jnp.int32)).reshape(1, c, 1)
    idx_d = jnp.sum(jnp.where(blocks > 0, shifts, 0), axis=1).astype(jnp.uint8)
    idx_s = jnp.sum(jnp.where(blocks == 0, shifts, 0), axis=1).astype(jnp.uint8)
    return idx_d, idx_s


def unpack_indices(idx_d: jax.Array, idx_s: jax.Array, c: int, k: int | None = None) -> jax.Array:
    """Inverse of :func:`pack_indices` -> dense ternary (k, M) int8.

    ``k`` recovers a ragged tail that :func:`pack_indices` zero-padded; it
    defaults to the full ``blocks * c`` rows.
    """
    blocks, m = idx_d.shape
    kp = blocks * c
    if k is None:
        k = kp
    shifts = jnp.arange(c, dtype=jnp.int32).reshape(1, c, 1)
    bit_d = (idx_d[:, None, :].astype(jnp.int32) >> shifts) & 1   # 1 => w == +1
    bit_s = (idx_s[:, None, :].astype(jnp.int32) >> shifts) & 1   # 1 => w == 0
    vals = jnp.where(bit_d == 1, 1, jnp.where(bit_s == 1, 0, -1))
    return vals.reshape(kp, m)[:k].astype(jnp.int8)


def zero_plane_density(zero_plane: jax.Array, k: int) -> jax.Array:
    """Nonzero-weight fraction measured from a packed zero plane.

    ``zero_plane`` (ceil(K/8), M) uint8 (leading batch dims allowed on the
    *trailing* side, matching the plane layout); bit=1 marks a zero weight.
    Pad bits beyond ``k`` are excluded.
    """
    bits = _unpack_bits(zero_plane, k).astype(jnp.float32)   # (k, ...) {0,1}
    return 1.0 - jnp.mean(bits)


def quantize_activations(a: jax.Array, eps: float = 1e-6) -> tuple[jax.Array, jax.Array]:
    """Per-token absmax int8 activation quantization (paper Fig. 2(b)).

    ``a`` (..., K) float -> (q int8 (..., K), scale f32 (..., 1)) with
    ``a ~= q * scale``.
    """
    absmax = jnp.max(jnp.abs(a), axis=-1, keepdims=True)
    scale = (absmax / 127.0 + eps).astype(jnp.float32)
    q = jnp.clip(jnp.round(a / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ternary_density(t: jax.Array) -> jax.Array:
    """Fraction of non-zero weights — used by the AP/OP cost model."""
    return jnp.mean((t != 0).astype(jnp.float32))


def random_ternary(key: jax.Array, shape: tuple, p_zero: float = 1.0 / 3.0) -> jax.Array:
    """Random ternary matrix for tests/benchmarks (int8)."""
    kz, ks = jax.random.split(key)
    zero = jax.random.bernoulli(kz, p_zero, shape)
    sign = jax.random.bernoulli(ks, 0.5, shape)
    return jnp.where(zero, 0, jnp.where(sign, 1, -1)).astype(jnp.int8)


def packed_bytes_per_weight() -> float:
    """Storage cost of the T-SAR packing: 2 bits/weight."""
    return 2.0 / 8.0


def tl2_bytes_per_weight() -> float:
    """TL-2 baseline packing density from the paper footnote: 1.67 bits/weight."""
    return 1.67 / 8.0


def np_pack_reference(t: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """NumPy oracle for the bitplane packing (used by property tests)."""
    k, m = t.shape
    sign = (t < 0).astype(np.uint8)
    zero = (t == 0).astype(np.uint8)

    def p(bits):
        return np.packbits(bits.reshape(k // PACK, PACK, m), axis=1, bitorder="little").reshape(k // PACK, m)

    return p(sign), p(zero)
