"""BitLinear: the ternary linear layer (paper Fig. 2(a,b)).

Two operating modes:

* **Training (QAT)** — latent fp32 master weights; forward ternarizes with the
  absmean recipe and fake-quantizes activations to int8 levels, with
  straight-through-estimator gradients to the latent weights.  This is the
  BitNet-b1.58 training recipe; the paper consumes such checkpoints.
* **Inference (frozen)** — weights ternarized once, packed to 2-bit bitplanes
  + LUT index encodings; forward dispatches to one of the T-SAR kernels
  (in-VMEM LUT, decode-to-MXU Pallas, or pure-jnp fallbacks) chosen by the
  AP/OP dataflow selector (paper Sec. III-D).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import lut, ternary
from repro.core.dataflow import select_kernel

# Default LUT block size: c=4 -> 16-entry shared binary LUT, the sweet spot
# for the TGEMV_16x16 configuration in the paper's Fig. 6 examples.
DEFAULT_C = 4

# Only compact a block-sparse sidecar at freeze time when the measured
# live-block fraction sits below this — just above the ~0.9 analytic
# break-even (dataflow.sparse_break_even), so borderline layers keep the
# option while dense checkpoints (unstructured zeros -> every block live)
# don't duplicate their planes into a pool no dispatch will ever pick.
SPARSE_SIDE_CAR_THRESHOLD = 0.95


# ---------------------------------------------------------------------------
# Straight-through estimators
# ---------------------------------------------------------------------------

@jax.custom_vjp
def ste_ternarize(w: jax.Array) -> jax.Array:
    """Absmean-ternarize + rescale, identity gradient (STE)."""
    t, scale = ternary.absmean_ternarize(w)
    return t * scale[..., None, :]


def _ste_t_fwd(w):
    return ste_ternarize(w), None


def _ste_t_bwd(_, g):
    return (g,)


ste_ternarize.defvjp(_ste_t_fwd, _ste_t_bwd)


@jax.custom_vjp
def ste_act_quant(x: jax.Array) -> jax.Array:
    """Fake int8 absmax quantization of activations, identity gradient."""
    q, scale = ternary.quantize_activations(x)
    return q.astype(x.dtype) * scale.astype(x.dtype)


def _ste_a_fwd(x):
    return ste_act_quant(x), None


def _ste_a_bwd(_, g):
    return (g,)


ste_act_quant.defvjp(_ste_a_fwd, _ste_a_bwd)


# ---------------------------------------------------------------------------
# Layer init / apply
# ---------------------------------------------------------------------------

def init(key: jax.Array, k: int, m: int, dtype=jnp.float32) -> dict:
    """Latent master weights, fan-in scaled init."""
    w = jax.random.normal(key, (k, m), dtype) * (1.0 / jnp.sqrt(k))
    return {"w": w}


class FrozenBitLinear(NamedTuple):
    """Packed inference-time parameters for one BitLinear layer."""

    packed: ternary.TernaryWeights   # 2-bit bitplanes + per-channel scale
    idx_pos: jax.Array               # (K//c, M) uint8 LUT encodings
    idx_zero: jax.Array
    c: int
    # Sparsity sidecar (None when frozen under tracing — compaction is
    # data-dependent): the block pool + the measured densities that drive
    # the 'auto' kernel dispatch.
    sparse: Any = None               # sparse_format.BlockSparseTernary | None
    density: float | None = None     # measured nonzero-weight fraction
    block_density: float | None = None  # measured live-block fraction

    @property
    def shape(self):
        return self.packed.shape


def freeze(params: dict, c: int = DEFAULT_C,
           block_shape: tuple | None = None) -> FrozenBitLinear:
    """Compile-time weight encoding (paper Fig. 5 'offline' phase).

    On concrete weights this measures density / block occupancy and — only
    when the live-block fraction is below ``SPARSE_SIDE_CAR_THRESHOLD`` —
    compacts the block-sparse sidecar
    (``repro.sparse.format.BlockSparseTernary``); under tracing
    (``jax.eval_shape`` etc.) all of it is skipped — pool compaction is
    data-dependent.
    """
    t, scale = ternary.absmean_ternarize(params["w"])
    t8 = t.astype(jnp.int8)
    idx_pos, idx_zero = ternary.pack_indices(t8, c)
    sparse = None
    density = block_density = None
    if not isinstance(t8, jax.core.Tracer):
        from repro.sparse import format as sparse_format
        from repro.sparse import stats as sparse_stats

        bk, bm = block_shape or sparse_format.DEFAULT_BLOCK_SHAPE
        occ = sparse_stats.block_occupancy(t8, bk, bm)
        density = float(ternary.ternary_density(t8))
        block_density = float((occ > 0).mean())
        if block_density < SPARSE_SIDE_CAR_THRESHOLD:
            sparse = sparse_format.from_ternary(t8, scale, bk=bk, bm=bm,
                                                occupancy=occ)
    return FrozenBitLinear(
        packed=ternary.pack(t, scale), idx_pos=idx_pos, idx_zero=idx_zero, c=c,
        sparse=sparse, density=density, block_density=block_density,
    )


def apply_train(params: dict, x: jax.Array) -> jax.Array:
    """QAT forward: fake-quant activations x ternarized weights."""
    w_t = ste_ternarize(params["w"])
    x_q = ste_act_quant(x)
    return x_q @ w_t.astype(x_q.dtype)


def apply_eval(params: dict, x: jax.Array) -> jax.Array:
    """Eval-mode forward from latent weights (exact int8 pipeline)."""
    t, scale = ternary.absmean_ternarize(params["w"])
    return lut.bitlinear_matmul_exact_int(x, t, scale).astype(x.dtype)


def apply_frozen(
    frozen: FrozenBitLinear,
    x: jax.Array,
    kernel: str = "auto",
    use_pallas: bool = False,
) -> jax.Array:
    """Inference forward with kernel dispatch.

    kernel: 'auto' | 'tsar_lut' | 'tsar_mxu' | 'tsar_sparse' | 'memory_lut'
    | 'dense'.  'auto' feeds the layer's *measured* density / block occupancy
    (stamped by :func:`freeze`) into the cost model, so a checkpoint with
    structurally dead blocks is served by the zero-skipping kernel without
    any caller change.
    """
    k, m = frozen.shape
    n = 1
    for d in x.shape[:-1]:   # static shape math — keeps apply_frozen jittable
        n *= d
    if kernel == "auto":
        kw = {}
        if frozen.density is not None:
            kw["density"] = frozen.density
        if frozen.block_density is not None and frozen.sparse is not None:
            kw["block_density"] = frozen.block_density
            kw["block_shape"] = frozen.sparse.block_shape
        kernel = select_kernel(n=n, k=k, m=m, c=frozen.c, **kw).kernel
        if kernel == "tsar_sparse" and frozen.sparse is None:
            kernel = "tsar_mxu"

    x32 = x.astype(jnp.float32)
    w_scale = frozen.packed.scale

    if kernel == "tsar_sparse":
        if frozen.sparse is None:
            raise ValueError("layer was frozen without a block-sparse sidecar")
        if use_pallas:
            from repro.kernels import ops

            y = ops.tsar_sparse_matmul(x32, frozen.sparse)
        else:
            # Traceable jnp fallback: identical math to the sparse kernel
            # (the planes decode to the same ternary matrix, and skipped
            # blocks contribute exact int32 zeros either way).  The zero-skip
            # advantage itself only materializes in the Pallas kernel.
            a_q, a_scale = ternary.quantize_activations(x32)
            t = ternary.unpack(frozen.packed)
            y = lut.dense_int8_matmul(a_q, a_scale, t, w_scale)
    elif kernel == "tsar_lut":
        y = lut.tsar_lut_matmul(x32, frozen.idx_pos, frozen.idx_zero, frozen.c, w_scale)
    elif kernel == "tsar_mxu":
        if use_pallas:
            from repro.kernels import ops

            y = ops.tsar_matmul(x32, frozen.packed)
        else:
            a_q, a_scale = ternary.quantize_activations(x32)
            t = ternary.unpack(frozen.packed)
            y = lut.dense_int8_matmul(a_q, a_scale, t, w_scale)
    elif kernel == "memory_lut":
        t = ternary.unpack(frozen.packed)
        li = lut.ternary_lut_indices(t, frozen.c)
        y = lut.memory_lut_matmul(x32, li, frozen.c, w_scale)
    elif kernel == "dense":
        w = ternary.unpack_dequant(frozen.packed)
        y = lut.dense_matmul(x32, w)
    else:
        raise ValueError(f"unknown kernel {kernel!r}")
    return y.astype(x.dtype)


def apply(params: Any, x: jax.Array, *, train: bool = True, **kw) -> jax.Array:
    """Unified entry point used by the model zoo."""
    if isinstance(params, FrozenBitLinear):
        return apply_frozen(params, x, **kw)
    if train:
        return apply_train(params, x)
    return apply_eval(params, x)
