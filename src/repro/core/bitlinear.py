"""BitLinear: the ternary linear layer (paper Fig. 2(a,b)).

Two operating modes:

* **Training (QAT)** — latent fp32 master weights; forward ternarizes with the
  absmean recipe and fake-quantizes activations to int8 levels, with
  straight-through-estimator gradients to the latent weights.  This is the
  BitNet-b1.58 training recipe; the paper consumes such checkpoints.
* **Inference (frozen)** — weights ternarized once, packed to 2-bit bitplanes
  + LUT index encodings; forward dispatches to one of the T-SAR kernels
  (in-VMEM LUT, decode-to-MXU Pallas, or pure-jnp fallbacks) chosen by the
  AP/OP dataflow selector (paper Sec. III-D).
"""
from __future__ import annotations

import warnings
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import lut, ternary
from repro.plan import registry

# Default LUT block size: c=4 -> 16-entry shared binary LUT, the sweet spot
# for the TGEMV_16x16 configuration in the paper's Fig. 6 examples.
DEFAULT_C = 4

# Only compact a block-sparse sidecar at freeze time when the measured
# live-block fraction sits below this — just above the ~0.9 analytic
# break-even (dataflow.sparse_break_even), so borderline layers keep the
# option while dense checkpoints (unstructured zeros -> every block live)
# don't duplicate their planes into a pool no dispatch will ever pick.
SPARSE_SIDE_CAR_THRESHOLD = 0.95


# ---------------------------------------------------------------------------
# Straight-through estimators
# ---------------------------------------------------------------------------

@jax.custom_vjp
def ste_ternarize(w: jax.Array) -> jax.Array:
    """Absmean-ternarize + rescale, identity gradient (STE)."""
    t, scale = ternary.absmean_ternarize(w)
    return t * scale[..., None, :]


def _ste_t_fwd(w):
    return ste_ternarize(w), None


def _ste_t_bwd(_, g):
    return (g,)


ste_ternarize.defvjp(_ste_t_fwd, _ste_t_bwd)


@jax.custom_vjp
def ste_act_quant(x: jax.Array) -> jax.Array:
    """Fake int8 absmax quantization of activations, identity gradient."""
    q, scale = ternary.quantize_activations(x)
    return q.astype(x.dtype) * scale.astype(x.dtype)


def _ste_a_fwd(x):
    return ste_act_quant(x), None


def _ste_a_bwd(_, g):
    return (g,)


ste_act_quant.defvjp(_ste_a_fwd, _ste_a_bwd)


# ---------------------------------------------------------------------------
# Layer init / apply
# ---------------------------------------------------------------------------

def init(key: jax.Array, k: int, m: int, dtype=jnp.float32) -> dict:
    """Latent master weights, fan-in scaled init."""
    w = jax.random.normal(key, (k, m), dtype) * (1.0 / jnp.sqrt(k))
    return {"w": w}


class FrozenBitLinear(NamedTuple):
    """Packed inference-time parameters for one BitLinear layer."""

    packed: ternary.TernaryWeights   # 2-bit bitplanes + per-channel scale
    idx_pos: jax.Array               # (K//c, M) uint8 LUT encodings
    idx_zero: jax.Array
    c: int
    # Sparsity sidecars: the compacted pool is None when frozen under tracing
    # (compaction is data-dependent); the PADDED pool (static shapes) can be
    # emitted even under tracing.  Plus the measured densities that drive
    # the 'auto' kernel dispatch.
    sparse: Any = None               # sparse_format.BlockSparseTernary | None
    density: float | None = None     # measured nonzero-weight fraction
    block_density: float | None = None  # measured live-block fraction
    padded: Any = None               # sparse_format.PaddedBlockSparseTernary

    @property
    def shape(self):
        return self.packed.shape


def freeze(params: dict, c: int = DEFAULT_C,
           block_shape: tuple | None = None,
           padded: bool | None = None,
           max_live: int | None = None,
           s_steps: int | None = None) -> FrozenBitLinear:
    """Compile-time weight encoding (paper Fig. 5 'offline' phase).

    On concrete weights this measures density / block occupancy and — only
    when the live-block fraction is below ``SPARSE_SIDE_CAR_THRESHOLD`` —
    emits the sparse sidecars: the compacted
    ``repro.sparse.format.BlockSparseTernary`` pool AND its padded
    (vmappable) twin, the latter sized to this layer's own live count unless
    the caller passes a model-wide ``max_live``/``s_steps`` bound.

    Under tracing (``jax.eval_shape``, ``vmap``) compaction is impossible
    (data-dependent pool size), so the compacted sidecar and the measured
    densities are skipped — but ``padded=True`` still emits the padded pool:
    its construction is pure ``jnp`` (static shapes, default full-grid
    ``max_live``), which is what lets stacked scan-layer freezes carry
    per-layer pools through ``vmap``.  ``padded=True`` uses those same
    defaults on CONCRETE weights too, so traced and eager freezes of the
    same call agree on every sidecar shape (tight data-dependent sizing is
    the ``padded=None`` auto behavior, which tracing skips entirely).
    """
    t, scale = ternary.absmean_ternarize(params["w"])
    t8 = t.astype(jnp.int8)
    idx_pos, idx_zero = ternary.pack_indices(t8, c)
    sparse = padded_sidecar = None
    density = block_density = None
    from repro.sparse import format as sparse_format

    bk, bm = block_shape or sparse_format.DEFAULT_BLOCK_SHAPE
    if isinstance(t8, jax.core.Tracer):
        if padded:
            padded_sidecar = sparse_format.pad_from_ternary(
                t8, scale, bk=bk, bm=bm, max_live=max_live, s_steps=s_steps)
    else:
        from repro.sparse import stats as sparse_stats

        occ = sparse_stats.block_occupancy(t8, bk, bm)
        density = float(ternary.ternary_density(t8))
        block_density = float((occ > 0).mean())
        if block_density < SPARSE_SIDE_CAR_THRESHOLD:
            sparse = sparse_format.from_ternary(t8, scale, bk=bk, bm=bm,
                                                occupancy=occ)
        if padded:
            # Same defaults as the traced branch (full-grid max_live when
            # unspecified), so eval_shape/jit freezes and eager freezes of
            # the same call agree on every sidecar shape.
            padded_sidecar = sparse_format.pad_from_ternary(
                t8, scale, bk=bk, bm=bm, max_live=max_live, s_steps=s_steps)
        elif padded is None and sparse is not None:
            # Auto: tight per-layer pool (tracing emits nothing under auto,
            # so there is no traced counterpart to stay shape-compatible
            # with).
            padded_sidecar = sparse_format.pad_pool(
                sparse, max_live=max_live, s_steps=s_steps)
    return FrozenBitLinear(
        packed=ternary.pack(t, scale), idx_pos=idx_pos, idx_zero=idx_zero, c=c,
        sparse=sparse, density=density, block_density=block_density,
        padded=padded_sidecar,
    )


def apply_train(params: dict, x: jax.Array) -> jax.Array:
    """QAT forward: fake-quant activations x ternarized weights."""
    w_t = ste_ternarize(params["w"])
    x_q = ste_act_quant(x)
    return x_q @ w_t.astype(x_q.dtype)


def apply_eval(params: dict, x: jax.Array) -> jax.Array:
    """Eval-mode forward from latent weights (exact int8 pipeline)."""
    t, scale = ternary.absmean_ternarize(params["w"])
    return lut.bitlinear_matmul_exact_int(x, t, scale).astype(x.dtype)


# Sentinel distinguishing "caller passed nothing" from explicit values in the
# deprecated apply_frozen(kernel=..., use_pallas=...) signature.
_UNSET = object()


def resolve_kernel(frozen: FrozenBitLinear, n: int, plan=None) -> str:
    """Resolve a plan spec to a registered kernel name for one layer.

    ``plan`` is a kernel name, a ``repro.plan.LayerPlan``, ``'auto'``, or
    None (auto).  Auto feeds the layer's *measured* density / block occupancy
    (stamped by :func:`freeze`) into the registry cost models, so a
    checkpoint with structurally dead blocks is served by the zero-skipping
    kernel without any caller change.  A planned/auto sparse-family kernel
    on a layer missing that format (e.g. a saved plan applied to a model
    re-frozen under tracing, where compaction is skipped) degrades to its
    sibling format when present, else ``tsar_mxu`` — same math; only an
    *explicit* sparse kernel name string still raises.
    """
    if plan is None or plan == "auto":
        from repro.core.dataflow import select_kernel

        k, m = frozen.shape
        kw = {}
        if frozen.density is not None:
            kw["density"] = frozen.density
        sidecar = frozen.sparse if frozen.sparse is not None else frozen.padded
        if frozen.block_density is not None and sidecar is not None:
            kw["block_density"] = frozen.block_density
            kw["block_shape"] = sidecar.block_shape
            kw["sparse_ok"] = tuple(
                kn for kn in registry.SPARSE_KERNELS
                if registry.get(kn).supports(frozen))
        name = select_kernel(n=n, k=k, m=m, c=frozen.c, **kw).kernel
    elif isinstance(plan, str):
        name = plan
    else:                        # LayerPlan (or anything with .kernel)
        name = plan.kernel
    explicit = isinstance(plan, str) and plan != "auto"
    if name in registry.SPARSE_KERNELS and not explicit \
            and not registry.get(name).supports(frozen):
        name = next((kn for kn in registry.SPARSE_KERNELS
                     if kn != name and registry.get(kn).supports(frozen)),
                    "tsar_mxu")
    return name


def apply_frozen(
    frozen: FrozenBitLinear,
    x: jax.Array,
    kernel=_UNSET,
    use_pallas=_UNSET,
    *,
    plan=None,
    interpret: bool | None = None,
) -> jax.Array:
    """Inference forward through the kernel registry.

    ``plan`` — a kernel name (``registry.names()``), a ``repro.plan.LayerPlan``
    (e.g. ``model_plan.lookup(layer, n)``), or None/'auto' to cost-select
    from the layer's measured density.  The chosen implementation's
    ``lower()`` runs the math; whether it binds the Pallas kernel auto-resolves
    from the backend (TPU -> Pallas, else the traceable jnp spelling), and
    ``interpret`` forces Pallas interpret mode for validation.

    ``kernel=``/``use_pallas=`` are the deprecated string-dispatch spelling:
    still honored (``use_pallas=None`` now auto-resolves instead of silently
    skipping Pallas on TPU), but emitting ``DeprecationWarning``.
    """
    up = None
    if kernel is not _UNSET or use_pallas is not _UNSET:
        warnings.warn(
            "repro.core.bitlinear.apply_frozen: the kernel=/use_pallas= "
            "signature is deprecated; pass plan= (a kernel name or a "
            "repro.plan.LayerPlan) and interpret= instead — see docs/plan.md",
            DeprecationWarning, stacklevel=2)
        if kernel is not _UNSET and plan is None:
            plan = kernel
        if use_pallas is not _UNSET:
            up = use_pallas
    n = 1
    for d in x.shape[:-1]:   # static shape math — keeps apply_frozen jittable
        n *= d
    name = resolve_kernel(frozen, n, plan)
    # A LayerPlan carries more than the kernel name: its dataflow + tile
    # sizes are executed by the Pallas-bound lowerings (grid order, tiling).
    lp = plan if (plan is not None and not isinstance(plan, str)) else None
    y = registry.get(name).lower(frozen, x, use_pallas=up,
                                 interpret=interpret, lp=lp)
    return y.astype(x.dtype)


def apply(params: Any, x: jax.Array, *, train: bool = True, **kw) -> jax.Array:
    """Unified entry point used by the model zoo."""
    if isinstance(params, FrozenBitLinear):
        return apply_frozen(params, x, **kw)
    if train:
        return apply_train(params, x)
    return apply_eval(params, x)
