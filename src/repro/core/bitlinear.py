"""BitLinear: the ternary linear layer (paper Fig. 2(a,b)).

Two operating modes:

* **Training (QAT)** — latent fp32 master weights; forward ternarizes with the
  absmean recipe and fake-quantizes activations to int8 levels, with
  straight-through-estimator gradients to the latent weights.  This is the
  BitNet-b1.58 training recipe; the paper consumes such checkpoints.
* **Inference (frozen)** — weights ternarized once, packed to 2-bit bitplanes
  + LUT index encodings; forward dispatches to one of the T-SAR kernels
  (in-VMEM LUT, decode-to-MXU Pallas, or pure-jnp fallbacks) chosen by the
  AP/OP dataflow selector (paper Sec. III-D).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import lut, ternary
from repro.core.dataflow import select_kernel

# Default LUT block size: c=4 -> 16-entry shared binary LUT, the sweet spot
# for the TGEMV_16x16 configuration in the paper's Fig. 6 examples.
DEFAULT_C = 4


# ---------------------------------------------------------------------------
# Straight-through estimators
# ---------------------------------------------------------------------------

@jax.custom_vjp
def ste_ternarize(w: jax.Array) -> jax.Array:
    """Absmean-ternarize + rescale, identity gradient (STE)."""
    t, scale = ternary.absmean_ternarize(w)
    return t * scale[..., None, :]


def _ste_t_fwd(w):
    return ste_ternarize(w), None


def _ste_t_bwd(_, g):
    return (g,)


ste_ternarize.defvjp(_ste_t_fwd, _ste_t_bwd)


@jax.custom_vjp
def ste_act_quant(x: jax.Array) -> jax.Array:
    """Fake int8 absmax quantization of activations, identity gradient."""
    q, scale = ternary.quantize_activations(x)
    return q.astype(x.dtype) * scale.astype(x.dtype)


def _ste_a_fwd(x):
    return ste_act_quant(x), None


def _ste_a_bwd(_, g):
    return (g,)


ste_act_quant.defvjp(_ste_a_fwd, _ste_a_bwd)


# ---------------------------------------------------------------------------
# Layer init / apply
# ---------------------------------------------------------------------------

def init(key: jax.Array, k: int, m: int, dtype=jnp.float32) -> dict:
    """Latent master weights, fan-in scaled init."""
    w = jax.random.normal(key, (k, m), dtype) * (1.0 / jnp.sqrt(k))
    return {"w": w}


class FrozenBitLinear(NamedTuple):
    """Packed inference-time parameters for one BitLinear layer."""

    packed: ternary.TernaryWeights   # 2-bit bitplanes + per-channel scale
    idx_pos: jax.Array               # (K//c, M) uint8 LUT encodings
    idx_zero: jax.Array
    c: int

    @property
    def shape(self):
        return self.packed.shape


def freeze(params: dict, c: int = DEFAULT_C) -> FrozenBitLinear:
    """Compile-time weight encoding (paper Fig. 5 'offline' phase)."""
    t, scale = ternary.absmean_ternarize(params["w"])
    t8 = t.astype(jnp.int8)
    idx_pos, idx_zero = ternary.pack_indices(t8, c)
    return FrozenBitLinear(
        packed=ternary.pack(t, scale), idx_pos=idx_pos, idx_zero=idx_zero, c=c
    )


def apply_train(params: dict, x: jax.Array) -> jax.Array:
    """QAT forward: fake-quant activations x ternarized weights."""
    w_t = ste_ternarize(params["w"])
    x_q = ste_act_quant(x)
    return x_q @ w_t.astype(x_q.dtype)


def apply_eval(params: dict, x: jax.Array) -> jax.Array:
    """Eval-mode forward from latent weights (exact int8 pipeline)."""
    t, scale = ternary.absmean_ternarize(params["w"])
    return lut.bitlinear_matmul_exact_int(x, t, scale).astype(x.dtype)


def apply_frozen(
    frozen: FrozenBitLinear,
    x: jax.Array,
    kernel: str = "auto",
    use_pallas: bool = False,
) -> jax.Array:
    """Inference forward with kernel dispatch.

    kernel: 'auto' | 'tsar_lut' | 'tsar_mxu' | 'memory_lut' | 'dense'
    """
    k, m = frozen.shape
    n = int(jnp.prod(jnp.asarray(x.shape[:-1]))) if x.ndim > 1 else 1
    if kernel == "auto":
        kernel = select_kernel(n=n, k=k, m=m, c=frozen.c).kernel

    x32 = x.astype(jnp.float32)
    w_scale = frozen.packed.scale

    if kernel == "tsar_lut":
        y = lut.tsar_lut_matmul(x32, frozen.idx_pos, frozen.idx_zero, frozen.c, w_scale)
    elif kernel == "tsar_mxu":
        if use_pallas:
            from repro.kernels import ops

            y = ops.tsar_matmul(x32, frozen.packed)
        else:
            a_q, a_scale = ternary.quantize_activations(x32)
            t = ternary.unpack(frozen.packed)
            y = lut.dense_int8_matmul(a_q, a_scale, t, w_scale)
    elif kernel == "memory_lut":
        t = ternary.unpack(frozen.packed)
        li = lut.ternary_lut_indices(t, frozen.c)
        y = lut.memory_lut_matmul(x32, li, frozen.c, w_scale)
    elif kernel == "dense":
        w = ternary.unpack_dequant(frozen.packed)
        y = lut.dense_matmul(x32, w)
    else:
        raise ValueError(f"unknown kernel {kernel!r}")
    return y.astype(x.dtype)


def apply(params: Any, x: jax.Array, *, train: bool = True, **kw) -> jax.Array:
    """Unified entry point used by the model zoo."""
    if isinstance(params, FrozenBitLinear):
        return apply_frozen(params, x, **kw)
    if train:
        return apply_train(params, x)
    return apply_eval(params, x)
