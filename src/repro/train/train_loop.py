"""Training step construction: pjit'd 2-D/3-D-sharded steps, gradient
accumulation, remat, and the compressed-DP shard_map variant.

``TrainState`` is a plain pytree (params, opt state, step) so checkpointing
and resharding treat it uniformly.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models import model_zoo
from repro.optim import OptConfig, adamw_init, adamw_update, compression
from repro.train import sharding


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array
    err_buf: Any = None      # int8-compression error feedback (optional)


def init_state(cfg, key, opt_cfg: OptConfig, compressed: bool = False) -> TrainState:
    params = model_zoo.init_params(cfg, key)
    return TrainState(
        params=params,
        opt=adamw_init(params, jnp.dtype(opt_cfg.moment_dtype)),
        step=jnp.zeros((), jnp.int32),
        err_buf=compression.init_error_buffer(params) if compressed else None,
    )


def make_train_step(cfg, opt_cfg: OptConfig, *, remat: bool = False,
                    accum_steps: int = 1):
    """Plain SPMD train step (pjit handles all collectives).

    With ``accum_steps > 1`` the batch's leading dim is split into
    microbatches scanned sequentially with gradient accumulation — the
    standard trick to hit large global batches within HBM limits.
    """

    def loss(params, batch):
        l, metrics = model_zoo.loss_fn(cfg, params, batch, train=True, remat=remat)
        return l, metrics

    def train_step(state: TrainState, batch):
        if accum_steps == 1:
            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(state.params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps) + x.shape[1:]),
                batch,
            )

            def micro_step(acc, mb):
                (l, metrics), g = jax.value_and_grad(loss, has_aux=True)(state.params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, (l, metrics)

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            grads, (ls, ms) = jax.lax.scan(micro_step, zeros, micro)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            l, metrics = jnp.mean(ls), jax.tree.map(jnp.mean, ms)

        new_params, new_opt, opt_metrics = adamw_update(opt_cfg, grads, state.opt, state.params)
        metrics = dict(metrics, **opt_metrics, loss=l)
        return TrainState(new_params, new_opt, state.step + 1, state.err_buf), metrics

    return train_step


def jit_train_step(cfg, opt_cfg, mesh, state, batch_example, *, fsdp: bool = False, **kw):
    """Build + jit the step with explicit in/out shardings on ``mesh``."""
    step_fn = make_train_step(cfg, opt_cfg, **kw)
    pspecs = sharding.param_specs(state.params, mesh, fsdp=fsdp)
    state_specs = TrainState(
        params=pspecs,
        opt=type(state.opt)(mu=pspecs, nu=pspecs, count=P()),
        step=P(),
        err_buf=pspecs if state.err_buf is not None else None,
    )
    bspecs = sharding.batch_specs(mesh, batch_example)
    return jax.jit(
        step_fn,
        in_shardings=(sharding.to_named(mesh, state_specs),
                      sharding.to_named(mesh, bspecs)),
        out_shardings=(sharding.to_named(mesh, state_specs), None),
        donate_argnums=(0,),
    )


# ---------------------------------------------------------------------------
# Compressed-DP variant (shard_map over the data axis)
# ---------------------------------------------------------------------------

def make_compressed_dp_train_step(cfg, opt_cfg: OptConfig, mesh, *, remat: bool = False):
    """Pure-DP train step with the int8 error-feedback gradient all-reduce.

    Params are replicated across 'data'; the gradient exchange — the
    cross-pod-dominant collective at 1000+ nodes — moves int8/bf16 on the
    wire (see repro.optim.compression).  Used by tests + the train driver's
    ``--compress-grads`` flag; composable with TP by nesting meshes.
    """
    axis = "data"

    def local_loss(params, batch):
        l, metrics = model_zoo.loss_fn(cfg, params, batch, train=True, remat=remat)
        return l, metrics

    def step(state: TrainState, batch):
        (l, metrics), grads = jax.value_and_grad(local_loss, has_aux=True)(
            state.params, batch)
        grads, new_err = compression.psum_compressed(grads, state.err_buf, axis)
        l = jax.lax.pmean(l, axis)
        metrics = jax.lax.pmean(metrics, axis)
        new_params, new_opt, opt_metrics = adamw_update(opt_cfg, grads, state.opt, state.params)
        metrics = dict(metrics, **opt_metrics, loss=l)
        return TrainState(new_params, new_opt, state.step + 1, new_err), metrics

    replicated = P()

    def wrapped(state, batch):
        state_spec = jax.tree.map(lambda _: replicated, state)
        # batch leaves are (B, ...): shard B over the DP axis.
        batch_spec = jax.tree.map(lambda x: P(axis, *([None] * (x.ndim - 1))), batch)
        fn = shard_map(step, mesh=mesh,
                       in_specs=(state_spec, batch_spec),
                       out_specs=(state_spec, replicated),
                       check_rep=False)
        return fn(state, batch)

    return jax.jit(wrapped)
