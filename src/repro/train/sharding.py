"""Partitioning rules: param/batch/cache PartitionSpecs for the 2-D/3-D mesh.

Axes: ``data`` (+ ``pod`` stacked on top of it in multi-pod meshes) carry
batch; ``model`` carries tensor parallelism (attention heads / FFN hidden /
vocab) and expert parallelism (MoE expert axis).  Rules are path-based over
the param pytree so they survive arbitrary stacking (the leading scan-layer
axis is always replicated).

Key choices (see EXPERIMENTS.md §Perf for measured effect):
* column-parallel in-projections (wq/wk/wv/w_gate/w_up/in_proj) shard M,
  row-parallel out-projections (wo/w_down/out_proj) shard K — the Megatron
  pattern: one all-reduce per block instead of four.
* embeddings shard the vocab axis; MoE expert stacks shard the expert axis
  (EP); routers/norms/scalars replicate.
* decode KV caches shard batch on 'data' when batch >= |data|, otherwise the
  *sequence* axis (sequence parallelism for the long_500k single-request
  cell).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# --- param rules -----------------------------------------------------------

_COL = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj"}    # shard out-features
_ROW = {"wo", "w_down", "out_proj"}                        # shard in-features
_REPL = {"router", "frontend_proj", "conv_w", "conv_b", "A_log", "D",
         "dt_bias", "qn", "kn", "g"}


def param_spec(path: tuple, leaf, fsdp: bool = False) -> P:
    """PartitionSpec for one param leaf given its tree path (tuple of str).

    ``fsdp=True`` additionally shards one free axis over the data axes
    (weights are all-gathered per scanned layer at use — the standard
    FSDP-in-SPMD pattern; required to fit 33B/400B-class training state).
    """
    names = [p for p in path if isinstance(p, str)]
    leafname = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""
    ndim = leaf.ndim
    spec = [None] * ndim

    if leafname == "embed":
        spec = ["model", None]
        if fsdp:
            spec[1] = "__data__"
        return P(*spec)
    if parent == "lm_head":
        spec = [None, "model"]
        if fsdp:
            spec[0] = "__data__"
        return P(*spec)
    if any(n in _REPL for n in names):
        return P(*spec)

    # Expert stacks: (..., E, K, M) — the *expert* axis is the EP axis.
    is_expert = (parent in ("w_gate", "w_up", "w_down") and ndim >= 3
                 and "moe" in names and "shared" not in names)
    if is_expert:
        spec[-3] = "model"
        if fsdp:
            spec[-2] = "__data__"
    elif parent in _COL and ndim >= 2:
        spec[-1] = "model"
        if fsdp:
            spec[-2] = "__data__"
    elif parent in _ROW and ndim >= 2:
        spec[-2] = "model"
        if fsdp:
            spec[-1] = "__data__"
    return P(*spec)


def param_specs(params, mesh: Mesh | None = None, fsdp: bool = False) -> dict:
    """Pytree of PartitionSpecs matching ``params``.

    When ``mesh`` is given, specs are sanitized: any sharded axis whose size
    does not divide the mesh axes is dropped to replicated (handles e.g.
    whisper's vocab=51865 or head counts < |model|), and the '__data__'
    placeholder resolves to the mesh's (pod,)data axes.
    """
    specs = jax.tree_util.tree_map_with_path(
        lambda kp, leaf: param_spec(_keypath_names(kp), leaf, fsdp=fsdp), params
    )
    if mesh is not None:
        specs = jax.tree.map(
            lambda leaf, s: sanitize_spec(mesh, leaf.shape, s), params, specs,
            is_leaf=lambda x: isinstance(x, P),
        )
    return specs


def sanitize_spec(mesh: Mesh, shape: tuple, spec: P) -> P:
    """Drop shardings that don't divide; resolve the '__data__' placeholder."""
    dax = _data_axes(mesh)
    dsz = 1
    for a in dax:
        dsz *= mesh.shape[a]
    out = []
    for i, s in enumerate(tuple(spec) + (None,) * (len(shape) - len(spec))):
        if s is None:
            out.append(None)
            continue
        if s == "__data__":
            out.append(dax if shape[i] % dsz == 0 else None)
            continue
        axes = s if isinstance(s, tuple) else (s,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(s if shape[i] % size == 0 else None)
    return P(*out)


def _keypath_names(kp) -> tuple:
    names = []
    for k in kp:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "name"):
            names.append(str(k.name))
        elif hasattr(k, "idx"):
            names.append(f"[{k.idx}]")
    return tuple(names)


# --- batch / cache rules ----------------------------------------------------

def batch_spec(mesh: Mesh, batch_size: int) -> P:
    """Token batches: shard batch over the (pod, data) axes when divisible."""
    dax = _data_axes(mesh)
    total = 1
    for a in dax:
        total *= mesh.shape[a]
    if batch_size % total == 0:
        return P(dax, None)
    return P(None, None)


def batch_specs(mesh: Mesh, batch) -> dict:
    """Specs for a batch dict: leading dim is batch for every leaf."""
    def spec(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        bs = leaf.shape[0]
        lead = batch_spec(mesh, bs)
        return P(*(tuple(lead)[:1] + (None,) * (nd - 1)))
    return jax.tree.map(spec, batch)


def cache_spec(mesh: Mesh, leaf_shape: tuple, n_kv_heads: int) -> P:
    """KV/SSM cache leaves, stacked (L, B, ...).

    (L, B, S, Hk, Dh) attention cache; (L, B, W, C) conv; (L, B, H, P, N) ssm.
    Batch -> data axes when divisible, else sequence-parallel on axis 2.
    Head axis -> 'model' when divisible.
    """
    dax = _data_axes(mesh)
    dsz = 1
    for a in dax:
        dsz *= mesh.shape[a]
    msz = mesh.shape["model"]
    nd = len(leaf_shape)
    spec = [None] * nd
    b = leaf_shape[1]
    if b % dsz == 0:
        spec[1] = dax
    elif nd >= 3 and leaf_shape[2] % dsz == 0:
        spec[2] = dax            # sequence parallelism (long-context decode)
    # Shard one inner axis on 'model': prefer heads, then the SEQUENCE axis,
    # then head_dim.  Sequence beats head_dim when KV-heads don't divide:
    # a Dh-sharded cache against head-sharded queries makes XLA all-gather
    # the full cache every layer (measured 2.1 GB x 64 layers/step on qwen3
    # decode — §Perf iter 3); a seq-sharded cache is the split-KV
    # (flash-decoding) scheme: local partial softmax + tiny psum.
    for ax in ((3, 2, 4) if nd == 5 else (3, 2) if nd == 4 else ()):
        if ax < nd and spec[ax] is None and leaf_shape[ax] % msz == 0:
            spec[ax] = "model"
            break
    return P(*spec)


def cache_specs(mesh: Mesh, cache, n_kv_heads: int):
    return jax.tree.map(lambda l: cache_spec(mesh, l.shape, n_kv_heads), cache)


def to_named(mesh: Mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
