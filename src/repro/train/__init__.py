from repro.train.train_loop import (  # noqa: F401
    TrainState, init_state, jit_train_step, make_compressed_dp_train_step,
    make_train_step,
)
from repro.train import sharding  # noqa: F401
