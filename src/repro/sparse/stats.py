"""Density profiling: per-layer and per-block zero statistics of a packed model.

This is the measurement half of the density-driven dispatch: ``profile_params``
walks a params pytree (either frozen packed dicts ``{'sign','zero','scale',...}``
as produced by ``models.layers.pack_linear`` / ``serving.engine.freeze_params``,
or latent ``{'w'}`` dicts which are ternarized on the fly) and reports, per
BitLinear layer:

* overall nonzero-weight density (the zero plane's popcount);
* the block-occupancy histogram at a given (bk, bm) tiling;
* the live-block fraction — the number the ``tsar_sparse`` cost model needs.

Everything runs host-side on concrete arrays (numpy); the serving engine calls
it once at init for telemetry, never inside a jitted step.
"""
from __future__ import annotations

import numpy as np

from repro.core import ternary
from repro.sparse import format as sparse_format


def weight_density(t) -> float:
    """Nonzero fraction of a dense ternary matrix (any leading batch dims)."""
    tn = np.asarray(t)
    return float(np.count_nonzero(tn)) / max(tn.size, 1)


def block_occupancy(t, bk: int = sparse_format.DEFAULT_BK,
                    bm: int = sparse_format.DEFAULT_BM) -> np.ndarray:
    """Per-block nonzero fraction of a ternary (K, M) matrix -> (kb, mb) f32.

    Ragged edges are zero-padded (padding counts as zeros), matching
    ``BlockSparseTernary`` occupancy exactly.
    """
    tn = np.asarray(t, np.int8)
    k, m = tn.shape
    kb, mb = -(-k // bk), -(-m // bm)
    tn = np.pad(tn, ((0, kb * bk - k), (0, mb * bm - m)))
    blocks = tn.reshape(kb, bk, mb, bm).transpose(0, 2, 1, 3)
    return np.count_nonzero(blocks, axis=(2, 3)).astype(np.float32) / (bk * bm)


def occupancy_histogram(occ: np.ndarray, bins: int = 10) -> tuple[np.ndarray, np.ndarray]:
    """Histogram of per-block occupancies over [0, 1]."""
    return np.histogram(np.asarray(occ).ravel(), bins=bins, range=(0.0, 1.0))


def _decode_planes(sign: np.ndarray, zero: np.ndarray) -> np.ndarray:
    """One layer's (K//8, M) planes -> dense ternary (K', M) int8.

    K' is the padded ``K//8 * 8``; ragged-K pad bits carry zero_plane=1
    (``ternary._pack_bits`` convention) so they decode to harmless 0s.
    """
    k = sign.shape[0] * ternary.PACK
    s = np.unpackbits(sign, axis=0, bitorder="little", count=k).astype(np.int8)
    z = np.unpackbits(zero, axis=0, bitorder="little", count=k).astype(np.int8)
    return (1 - 2 * s) * (1 - z)


def _layer_slices(leaf: dict):
    """Yield dense ternary (K, M) matrices, one per stacked layer/expert.

    Decodes one slice at a time so profiling a (30, K//8, M) scan stack never
    materializes the whole stack densely.
    """
    if "sign" in leaf and "zero" in leaf:
        sign, zero = np.asarray(leaf["sign"]), np.asarray(leaf["zero"])
        s3 = sign.reshape((-1,) + sign.shape[-2:])
        z3 = zero.reshape((-1,) + zero.shape[-2:])
        for i in range(s3.shape[0]):
            yield _decode_planes(s3[i], z3[i])
    elif "w" in leaf:
        import jax.numpy as jnp
        t, _ = ternary.absmean_ternarize(jnp.asarray(leaf["w"]))
        t3 = np.asarray(t, np.int8).reshape((-1,) + t.shape[-2:])
        for i in range(t3.shape[0]):
            yield t3[i]


def profile_params(params, bk: int = sparse_format.DEFAULT_BK,
                   bm: int = sparse_format.DEFAULT_BM, bins: int = 10) -> list[dict]:
    """Per-BitLinear-layer density profile of a params pytree.

    Returns a list of dicts ``{path, shape, density, block_density, hist,
    edges}``; stacked (scan-layer / expert) weights are profiled over the full
    stack with the last two dims as (K, M).
    """
    out = []

    def walk(node, path):
        if isinstance(node, dict):
            keys = set(node)
            if {"sign", "zero"} <= keys or keys == {"w"}:
                # One slice at a time: blocks never straddle two stacked
                # layers and the dense transient stays one (K, M) matrix.
                occs, nnz, size = [], 0, 0
                for t in _layer_slices(node):
                    occs.append(block_occupancy(t, bk, bm))
                    nnz += int(np.count_nonzero(t))
                    size += t.size
                if not occs:
                    return
                occ = np.concatenate(occs, axis=0)
                hist, edges = occupancy_histogram(occ, bins)
                # pack_linear stamps the measured density at freeze time;
                # prefer it over re-deriving from the planes (the planes'
                # ragged pad rows count as zeros, the stamp does not).
                if "density" in node:
                    density = float(np.mean(np.asarray(node["density"])))
                else:
                    density = nnz / max(size, 1)
                if "sign" in node:
                    ps = node["sign"].shape
                    shape = tuple(ps[:-2]) + (ps[-2] * ternary.PACK, ps[-1])
                else:
                    shape = tuple(node["w"].shape)
                out.append({
                    "path": path,
                    "shape": shape,
                    "density": density,
                    "block_density": float((occ > 0).mean()),
                    "hist": hist,
                    "edges": edges,
                })
                return
            for k in sorted(node):
                walk(node[k], f"{path}/{k}" if path else str(k))

    walk(params, "")
    return out


def summarize(profile: list[dict]) -> dict:
    """Aggregate a :func:`profile_params` report into scalar telemetry."""
    if not profile:
        return {"layers": 0, "density_mean": float("nan"),
                "density_min": float("nan"), "block_density_mean": float("nan")}
    d = [p["density"] for p in profile]
    b = [p["block_density"] for p in profile]
    return {
        "layers": len(profile),
        "density_mean": sum(d) / len(d),
        "density_min": min(d),
        "block_density_mean": sum(b) / len(b),
    }


def format_report(profile: list[dict]) -> str:
    """Human-readable per-layer density table."""
    lines = [f"| {'layer':40s} | {'shape':>16s} | density | blk_dens |",
             "|" + "-" * 76 + "|"]
    for p in profile:
        lines.append(
            f"| {p['path'][:40]:40s} | {str(p['shape']):>16s} "
            f"| {p['density']:7.3f} | {p['block_density']:8.3f} |")
    return "\n".join(lines)
