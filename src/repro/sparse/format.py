"""Block-sparse ternary weight format: compacted bitplane pool + block map.

T-SAR's dense/sparse bitplane decomposition (``core/ternary``) stores the
zero plane but never *exploits* it: every kernel streams all K x M packed
positions even when whole (bk, bm) blocks of a BitNet-style checkpoint are
exactly zero.  ``BlockSparseTernary`` tiles the ternary matrix into (bk, bm)
blocks and keeps only the live (any-nonzero) blocks:

* ``sign_pool`` / ``zero_pool`` — uint8 (n_slots, bk//8, bm): the 2-bit
  bitplanes of each live block, compacted in block-raster order.  Dead blocks
  cost zero pool bytes.
* ``block_map`` — int32 (K/bk, M/bm): grid position -> pool slot, ``-1`` for
  an all-zero block.  This is the index map the ``tsar_sparse`` Pallas kernel
  walks (via :func:`strip_schedule`) to skip dead blocks entirely.
* ``occupancy`` — f32 (K/bk, M/bm): per-tile nonzero fraction, the metadata
  that feeds the density-driven kernel dispatch (``core/dataflow``) and the
  profiling report (``sparse/stats``).

Construction compacts data-dependently (the pool size depends on the weight
values), so the builders run host-side on concrete arrays — exactly like the
paper's compile-time weight encoding, and like ``bitlinear.freeze``.  Ragged
K/M are zero-padded up to block multiples; padding creates *dead* blocks (or
zero tails inside edge blocks), so the round-trip back to a dense ternary
matrix / ``TernaryWeights`` is exact.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ternary
# One canonical default tiling, shared with the dispatch cost model so the
# formats being built and the break-even being computed can't drift.  The
# dense Pallas kernel's (bk=512, bm=256) would waste skip granularity;
# 256x256 keeps MXU-sized tiles while giving the zero-skip logic 4x finer
# blocks along K.
from repro.core.dataflow import SPARSE_BLOCK as DEFAULT_BLOCK_SHAPE

DEFAULT_BK, DEFAULT_BM = DEFAULT_BLOCK_SHAPE


class BlockSparseTernary(NamedTuple):
    """Compacted block-sparse 2-bit ternary weights (frozen, inference-only).

    The per-m-strip kernel schedule (``kids``/``slots``/``counts``/``s_max``)
    is derived from ``block_map`` once at construction so the hot path never
    re-runs the host-side compaction walk per matmul call.
    """

    sign_pool: jax.Array    # uint8 (n_slots, bk//8, bm)
    zero_pool: jax.Array    # uint8 (n_slots, bk//8, bm)
    block_map: jax.Array    # int32 (kb, mb)  pool slot, -1 = all-zero block
    occupancy: jax.Array    # f32   (kb, mb)  nonzero fraction per block
    scale: jax.Array        # f32   (M,) per-output-channel dequant scale
    shape: tuple            # static logical (K, M)
    block_shape: tuple      # static (bk, bm)
    n_live: int             # static number of live blocks (pool slots used)
    kids: jax.Array         # int32 (mb, max(s_max,1)) live k-block ids per strip
    slots: jax.Array        # int32 (mb, max(s_max,1)) matching pool slots
    counts: jax.Array       # int32 (mb,) live blocks per strip
    s_max: int              # static max live blocks over strips

    @property
    def k(self) -> int:
        return self.shape[0]

    @property
    def m(self) -> int:
        return self.shape[1]

    @property
    def grid(self) -> tuple:
        """(kb, mb) block-grid dims (over the zero-padded logical shape)."""
        bk, bm = self.block_shape
        return (-(-self.shape[0] // bk), -(-self.shape[1] // bm))

    @property
    def block_density(self) -> float:
        """Fraction of blocks that are live — the dispatch signal."""
        kb, mb = self.grid
        return self.n_live / max(kb * mb, 1)

    def nbytes(self) -> int:
        """HBM bytes: compacted pools + block map + occupancy + scales.

        Only ``n_live`` slots count (the pool pads to >= 1 slot so XLA never
        sees a zero-sized array; the pad slot stores no weights).
        """
        bk, bm = self.block_shape
        pool = 2 * self.n_live * (bk // ternary.PACK) * bm
        return int(pool + self.block_map.size * 4 + self.occupancy.size * 4
                   + self.scale.size * 4)


def from_ternary(
    t: jax.Array,
    scale: jax.Array | None = None,
    bk: int = DEFAULT_BK,
    bm: int = DEFAULT_BM,
    occupancy: np.ndarray | None = None,
) -> BlockSparseTernary:
    """Tile a dense ternary (K, M) matrix into a compacted block pool.

    Host-side (concrete arrays only): the pool size is data-dependent.
    ``occupancy`` accepts a precomputed ``stats.block_occupancy(t, bk, bm)``
    grid so callers that already measured it (``bitlinear.freeze``) don't pay
    the popcount twice.
    """
    if t.ndim != 2:
        raise ValueError(f"from_ternary expects a 2-D (K, M) matrix, got {t.shape}")
    if bk % ternary.PACK != 0:
        raise ValueError(f"bk={bk} must be a multiple of {ternary.PACK}")
    tn = np.asarray(t, np.int8)
    k, m = tn.shape
    if scale is None:
        scale = jnp.ones((m,), jnp.float32)
    kb, mb = -(-k // bk), -(-m // bm)
    pad_k, pad_m = kb * bk - k, mb * bm - m
    if pad_k or pad_m:
        tn = np.pad(tn, ((0, pad_k), (0, pad_m)))

    # (kb, mb, bk, bm) block view.
    blocks = tn.reshape(kb, bk, mb, bm).transpose(0, 2, 1, 3)
    if occupancy is None:
        occ = np.count_nonzero(blocks, axis=(2, 3)).astype(np.float32) / (bk * bm)
    else:
        occ = np.asarray(occupancy, np.float32)
        if occ.shape != (kb, mb):
            raise ValueError(f"occupancy grid {occ.shape} != block grid {(kb, mb)}")
    live = occ > 0.0
    n_live = int(live.sum())

    block_map = np.full((kb, mb), -1, np.int32)
    block_map[live] = np.arange(n_live, dtype=np.int32)

    n_slots = max(n_live, 1)            # never materialize a 0-sized pool
    sign_pool = np.zeros((n_slots, bk // ternary.PACK, bm), np.uint8)
    zero_pool = np.zeros((n_slots, bk // ternary.PACK, bm), np.uint8)
    if n_live:
        lv = blocks[live]                                    # (n_live, bk, bm)
        sign = (lv < 0).astype(np.uint8)
        zero = (lv == 0).astype(np.uint8)
        pack = lambda b: np.packbits(
            b.reshape(n_live, bk // ternary.PACK, ternary.PACK, bm),
            axis=2, bitorder="little").reshape(n_live, bk // ternary.PACK, bm)
        sign_pool = pack(sign)
        zero_pool = pack(zero)
    else:
        # Dead-block pad slot must still decode to value 0, not +1 (the
        # sparse kernel masks its contribution, but the round-trip reads it
        # for no block, so this only guards against misuse).
        zero_pool[:] = 0xFF

    kids, slots, counts, s_max = _strip_schedule_np(block_map)
    return BlockSparseTernary(
        sign_pool=jnp.asarray(sign_pool),
        zero_pool=jnp.asarray(zero_pool),
        block_map=jnp.asarray(block_map),
        occupancy=jnp.asarray(occ),
        scale=jnp.asarray(scale, jnp.float32),
        shape=(k, m),
        block_shape=(bk, bm),
        n_live=n_live,
        kids=jnp.asarray(kids),
        slots=jnp.asarray(slots),
        counts=jnp.asarray(counts),
        s_max=s_max,
    )


def from_packed(tw: ternary.TernaryWeights, bk: int = DEFAULT_BK,
                bm: int = DEFAULT_BM) -> BlockSparseTernary:
    """``TernaryWeights`` (dense 2-bit planes) -> block-sparse pool."""
    return from_ternary(ternary.unpack(tw), tw.scale, bk=bk, bm=bm)


def to_ternary(bst: BlockSparseTernary) -> jax.Array:
    """Exact inverse of :func:`from_ternary` -> dense ternary (K, M) int8."""
    bk, bm = bst.block_shape
    kb, mb = bst.grid
    k, m = bst.shape
    bmap = np.asarray(bst.block_map)
    sp = np.asarray(bst.sign_pool)
    zp = np.asarray(bst.zero_pool)

    out = np.zeros((kb, mb, bk, bm), np.int8)
    for i in range(kb):
        for j in range(mb):
            slot = int(bmap[i, j])
            if slot < 0:
                continue
            sign = np.unpackbits(sp[slot], axis=0, bitorder="little",
                                 count=bk).astype(np.int8)
            zero = np.unpackbits(zp[slot], axis=0, bitorder="little",
                                 count=bk).astype(np.int8)
            out[i, j] = (1 - 2 * sign) * (1 - zero)
    dense = out.transpose(0, 2, 1, 3).reshape(kb * bk, mb * bm)
    return jnp.asarray(dense[:k, :m])


def to_packed(bst: BlockSparseTernary) -> ternary.TernaryWeights:
    """Exact round-trip back to dense ``TernaryWeights``."""
    return ternary.pack(to_ternary(bst).astype(jnp.float32), bst.scale)


def _strip_schedule_np(bmap: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Per-m-strip gather lists derived from a block map (construction time).

    Returns ``(kids, slots, counts, s_max)``:

    * ``kids``   int32 (mb, s_max) — the s-th live block's k-block index in
      strip j (padded with 0 past ``counts[j]``);
    * ``slots``  int32 (mb, s_max) — matching pool slot (padded with 0, a
      valid slot, so the padded DMA reads real memory; the kernel masks it);
    * ``counts`` int32 (mb,) — live blocks per strip;
    * ``s_max``  — max live blocks over strips == the kernel's inner grid
      extent; the whole point: grid work scales with live blocks, not K.
    """
    kb, mb = bmap.shape
    counts = (bmap >= 0).sum(axis=0).astype(np.int32)
    s_max = int(counts.max()) if mb else 0
    kids = np.zeros((mb, max(s_max, 1)), np.int32)
    slots = np.zeros((mb, max(s_max, 1)), np.int32)
    for j in range(mb):
        lv = np.nonzero(bmap[:, j] >= 0)[0]
        kids[j, : len(lv)] = lv
        slots[j, : len(lv)] = bmap[lv, j]
    return kids, slots, counts, s_max


def strip_schedule(bst: BlockSparseTernary) -> tuple[jax.Array, jax.Array, jax.Array, int]:
    """The kernel schedule — precomputed at construction, returned as-is."""
    return bst.kids, bst.slots, bst.counts, bst.s_max


# ---------------------------------------------------------------------------
# Padded pool: the vmappable variant
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PaddedBlockSparseTernary:
    """Block-sparse ternary weights with a STATIC-shaped (padded) pool.

    :class:`BlockSparseTernary` compacts its pool to exactly ``n_live`` slots
    — a data-dependent size, so stacked scan-layer / expert weights cannot
    carry per-layer pools through ``vmap`` (every slice would need its own
    array shape).  This variant pads the pool to a static per-model
    ``max_live`` and the per-strip schedule to a static ``s_steps``:

    * every array field's shape depends only on ``(K, M, bk, bm, max_live,
      s_steps)`` — all static — so the format is a **vmappable pytree**
      (arrays are children, the static metadata is aux data);
    * pad slots decode to all-zero blocks (``zero_pool`` bits set), pad
      schedule entries point at slot 0 and are masked by ``counts`` — both
      contribute exactly nothing, so round-trips and matmuls stay exact;
    * construction (:func:`pad_from_ternary`) is pure ``jnp`` — it runs
      under ``vmap``/``jit`` tracing, which is how ``freeze_params`` emits
      stacked padded pools for scan-layer weights.

    The trade: pool bytes scale with ``max_live`` (an upper bound over the
    stacked layers), not per-layer ``n_live`` — memory for vmappability.
    ``max_live`` defaults to the full grid (always safe); freeze-time
    callers that measured the checkpoint pass the stack-wide maximum.
    """

    sign_pool: jax.Array    # uint8 (max_live, bk//8, bm)
    zero_pool: jax.Array    # uint8 (max_live, bk//8, bm)  pad slots = 0xFF
    block_map: jax.Array    # int32 (kb, mb)  pool slot, -1 = dead block
    occupancy: jax.Array    # f32   (kb, mb)  nonzero fraction per block
    scale: jax.Array        # f32   (M,) per-output-channel dequant scale
    kids: jax.Array         # int32 (mb, s_steps) live k-block ids per strip
    slots: jax.Array        # int32 (mb, s_steps) matching pool slots
    counts: jax.Array       # int32 (mb,) live blocks per strip
    shape: tuple            # static logical (K, M)
    block_shape: tuple      # static (bk, bm)
    max_live: int           # static pool slots (>= any slice's n_live)
    s_steps: int            # static per-strip walk extent (>= any s_max)

    def tree_flatten(self):
        children = (self.sign_pool, self.zero_pool, self.block_map,
                    self.occupancy, self.scale, self.kids, self.slots,
                    self.counts)
        aux = (self.shape, self.block_shape, self.max_live, self.s_steps)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def k(self) -> int:
        return self.shape[0]

    @property
    def m(self) -> int:
        return self.shape[1]

    @property
    def grid(self) -> tuple:
        bk, bm = self.block_shape
        return (-(-self.shape[0] // bk), -(-self.shape[1] // bm))

    @property
    def n_live(self) -> jax.Array:
        """Live blocks — DATA here (the static shape is ``max_live``)."""
        return jnp.sum(self.counts)

    @property
    def block_density(self) -> jax.Array:
        kb, mb = self.grid
        return self.n_live / max(kb * mb, 1)

    def nbytes(self) -> int:
        """HBM bytes — static math; monotonic in ``max_live`` (pad slots are
        the price of the static shape, whether or not they hold weights)."""
        bk, bm = self.block_shape
        kb, mb = self.grid
        pool = 2 * self.max_live * (bk // ternary.PACK) * bm
        sched = (2 * mb * self.s_steps + mb) * 4        # kids + slots + counts
        return int(pool + sched + self.block_map.size * 4
                   + self.occupancy.size * 4 + self.scale.size * 4)


def pad_from_ternary(
    t: jax.Array,
    scale: jax.Array | None = None,
    bk: int = DEFAULT_BK,
    bm: int = DEFAULT_BM,
    max_live: int | None = None,
    s_steps: int | None = None,
) -> PaddedBlockSparseTernary:
    """Dense ternary (K, M) -> padded-pool block-sparse format.

    Pure ``jnp`` (traceable: runs under ``vmap``/``jit``, unlike
    :func:`from_ternary`).  ``max_live`` defaults to the full block grid and
    ``s_steps`` to ``K/bk`` — always lossless.  A caller passing tighter
    bounds promises they hold: on concrete arrays a violation raises; under
    tracing the overflowing blocks (beyond ``max_live`` in raster order, or
    beyond ``s_steps`` within a strip) are deterministically treated as
    DEAD — dropped from the pool, the block map, AND the schedule, so every
    consumer (the Pallas kernel, :func:`padded_to_ternary`, round-trips)
    sees the same truncated matrix.  Consistent, but lossy.
    """
    if t.ndim != 2:
        raise ValueError(f"pad_from_ternary expects (K, M), got {t.shape}")
    if bk % ternary.PACK != 0:
        raise ValueError(f"bk={bk} must be a multiple of {ternary.PACK}")
    t8 = jnp.asarray(t, jnp.int8)
    k, m = t8.shape
    if scale is None:
        scale = jnp.ones((m,), jnp.float32)
    kb, mb = -(-k // bk), -(-m // bm)
    grid_n = kb * mb
    if max_live is None:
        max_live = grid_n
    max_live = max(int(max_live), 1)
    if s_steps is None:
        s_steps = kb
    s_steps = max(min(int(s_steps), kb), 1)

    pad_k, pad_m = kb * bk - k, mb * bm - m
    if pad_k or pad_m:
        t8 = jnp.pad(t8, ((0, pad_k), (0, pad_m)))
    flat = t8.reshape(kb, bk, mb, bm).transpose(0, 2, 1, 3).reshape(
        grid_n, bk, bm)
    occ = jnp.count_nonzero(flat, axis=(1, 2)).astype(jnp.float32) / (bk * bm)
    live_raw = occ > 0.0
    slot = jnp.cumsum(live_raw.astype(jnp.int32)) - 1   # raster-order slot id
    live = live_raw & (slot < max_live)
    if not isinstance(flat, jax.core.Tracer):
        n_live = int(jnp.sum(live_raw))
        if n_live > max_live:
            raise ValueError(
                f"max_live={max_live} < {n_live} live blocks; pass a larger "
                "pool (or None for the full grid)")

    # Pack each block's 2-bit planes (same LSB-first layout as core/ternary).
    shifts = jnp.arange(ternary.PACK, dtype=jnp.uint8).reshape(1, 1, -1, 1)
    def _pack(bits):
        b = bits.astype(jnp.uint8).reshape(
            grid_n, bk // ternary.PACK, ternary.PACK, bm)
        return jnp.sum(b << shifts, axis=2).astype(jnp.uint8)
    sign_b = _pack(flat < 0)
    zero_b = _pack(flat == 0)

    # Scatter live blocks into the pool; dead blocks target the out-of-range
    # index max_live and are dropped.  Pad slots keep the all-zero decode
    # (zero_pool bits set).
    idx = jnp.where(live, slot, max_live)
    sign_pool = jnp.zeros((max_live, bk // ternary.PACK, bm), jnp.uint8
                          ).at[idx].set(sign_b, mode="drop")
    zero_pool = jnp.full((max_live, bk // ternary.PACK, bm), 0xFF, jnp.uint8
                         ).at[idx].set(zero_b, mode="drop")

    block_map = jnp.where(live, slot, -1).reshape(kb, mb).astype(jnp.int32)

    # Static-width strip schedule: live k-blocks first (k order preserved by
    # the stable sort), padded with (kid=0, slot=0) past counts[j] — a valid
    # address the kernel masks, exactly like the compacted schedule's pad.
    lv = block_map >= 0                                  # (kb, mb)
    counts_full = jnp.sum(lv, axis=0).astype(jnp.int32)  # (mb,)
    if not isinstance(flat, jax.core.Tracer):
        s_max = int(jnp.max(counts_full)) if mb else 0
        if s_max > s_steps:
            raise ValueError(
                f"s_steps={s_steps} < {s_max} live blocks in the fullest "
                "strip; pass a larger s_steps (or None for K/bk)")
    # Strip-overflow blocks (rank >= s_steps within their column) fall out
    # of the truncated schedule; kill them in the MAP too so the jnp decode
    # (padded_to_ternary) and the kernel's walk agree on the same matrix.
    rank = jnp.cumsum(lv.astype(jnp.int32), axis=0) - 1  # live-first rank
    block_map = jnp.where(lv & (rank >= s_steps), -1, block_map)
    lv = block_map >= 0
    order = jnp.argsort(jnp.logical_not(lv), axis=0, stable=True)
    kids_full = order.T                                  # (mb, kb)
    slots_full = jnp.take_along_axis(block_map, order, axis=0).T
    counts = jnp.minimum(counts_full, s_steps)
    valid = jnp.arange(s_steps)[None, :] < counts[:, None]
    kids = jnp.where(valid, kids_full[:, :s_steps], 0).astype(jnp.int32)
    slots = jnp.where(valid, slots_full[:, :s_steps], 0).astype(jnp.int32)

    return PaddedBlockSparseTernary(
        sign_pool=sign_pool, zero_pool=zero_pool, block_map=block_map,
        occupancy=occ.reshape(kb, mb), scale=jnp.asarray(scale, jnp.float32),
        kids=kids, slots=slots, counts=counts,
        shape=(k, m), block_shape=(bk, bm),
        max_live=max_live, s_steps=s_steps,
    )


def pad_from_packed(tw: ternary.TernaryWeights, bk: int = DEFAULT_BK,
                    bm: int = DEFAULT_BM, max_live: int | None = None,
                    s_steps: int | None = None) -> PaddedBlockSparseTernary:
    """``TernaryWeights`` (dense 2-bit planes) -> padded block pool."""
    return pad_from_ternary(ternary.unpack(tw), tw.scale, bk=bk, bm=bm,
                            max_live=max_live, s_steps=s_steps)


def pad_pool(bst: BlockSparseTernary, max_live: int | None = None,
             s_steps: int | None = None) -> PaddedBlockSparseTernary:
    """Compacted -> padded (host-side; sizes default to this matrix's own
    ``n_live``/``s_max``, i.e. the tightest lossless pool)."""
    if max_live is None:
        max_live = max(bst.n_live, 1)
    if s_steps is None:
        s_steps = max(bst.s_max, 1)
    bk, bm = bst.block_shape
    return pad_from_ternary(to_ternary(bst), bst.scale, bk=bk, bm=bm,
                            max_live=max_live, s_steps=s_steps)


def compact(pbst: PaddedBlockSparseTernary) -> BlockSparseTernary:
    """Padded -> compacted (host-side; exact)."""
    bk, bm = pbst.block_shape
    return from_ternary(padded_to_ternary(pbst), pbst.scale, bk=bk, bm=bm)


def padded_to_ternary(pbst: PaddedBlockSparseTernary) -> jax.Array:
    """Exact inverse of :func:`pad_from_ternary` -> dense (K, M) int8.

    Pure ``jnp`` — this is also the serve-path realization of the padded
    kernel off-TPU: decoding FROM THE POOL (not from dense planes) keeps the
    padded format load-bearing inside the jitted step while staying
    bit-identical to the dense decode (the pool round-trips exactly).
    """
    bk, bm = pbst.block_shape
    kb, mb = pbst.grid
    k, m = pbst.shape
    slot = jnp.clip(pbst.block_map, 0, pbst.max_live - 1)
    sp = jnp.take(pbst.sign_pool, slot, axis=0)      # (kb, mb, bk//8, bm)
    zp = jnp.take(pbst.zero_pool, slot, axis=0)
    shifts = jnp.arange(ternary.PACK, dtype=jnp.uint8).reshape(1, 1, 1, -1, 1)
    sbits = ((sp[:, :, :, None, :] >> shifts) & jnp.uint8(1)
             ).reshape(kb, mb, bk, bm).astype(jnp.int8)
    zbits = ((zp[:, :, :, None, :] >> shifts) & jnp.uint8(1)
             ).reshape(kb, mb, bk, bm).astype(jnp.int8)
    vals = (1 - 2 * sbits) * (1 - zbits)
    vals = vals * (pbst.block_map >= 0)[:, :, None, None].astype(jnp.int8)
    dense = vals.transpose(0, 2, 1, 3).reshape(kb * bk, mb * bm)
    return dense[:k, :m]


def padded_to_packed(pbst: PaddedBlockSparseTernary) -> ternary.TernaryWeights:
    """Exact round-trip back to dense ``TernaryWeights``."""
    return ternary.pack(padded_to_ternary(pbst).astype(jnp.float32),
                        pbst.scale)


def random_block_sparse_ternary(
    key: jax.Array,
    shape: tuple,
    bk: int = DEFAULT_BK,
    bm: int = DEFAULT_BM,
    p_zero_block: float = 0.5,
    p_zero: float = 1.0 / 3.0,
) -> jax.Array:
    """Random ternary matrix with *block-structured* sparsity (int8).

    Whole (bk, bm) blocks are zeroed with probability ``p_zero_block``; the
    surviving blocks carry the usual unstructured ``p_zero`` zeros.  This is
    the workload where zero-block skipping pays: unstructured sparsity almost
    never kills a whole 256x256 block ((1/3)^65536 ~ 0), so benchmarks sweep
    the block-kill rate instead.
    """
    k, m = shape
    kb, mb = -(-k // bk), -(-m // bm)
    kz, kt = jax.random.split(key)
    dead = jax.random.bernoulli(kz, p_zero_block, (kb, mb))
    mask = 1 - jnp.repeat(jnp.repeat(dead.astype(jnp.int8), bk, 0), bm, 1)
    t = ternary.random_ternary(kt, (kb * bk, mb * bm), p_zero)
    return (t * mask)[:k, :m]
