"""Block-sparse ternary weight format: compacted bitplane pool + block map.

T-SAR's dense/sparse bitplane decomposition (``core/ternary``) stores the
zero plane but never *exploits* it: every kernel streams all K x M packed
positions even when whole (bk, bm) blocks of a BitNet-style checkpoint are
exactly zero.  ``BlockSparseTernary`` tiles the ternary matrix into (bk, bm)
blocks and keeps only the live (any-nonzero) blocks:

* ``sign_pool`` / ``zero_pool`` — uint8 (n_slots, bk//8, bm): the 2-bit
  bitplanes of each live block, compacted in block-raster order.  Dead blocks
  cost zero pool bytes.
* ``block_map`` — int32 (K/bk, M/bm): grid position -> pool slot, ``-1`` for
  an all-zero block.  This is the index map the ``tsar_sparse`` Pallas kernel
  walks (via :func:`strip_schedule`) to skip dead blocks entirely.
* ``occupancy`` — f32 (K/bk, M/bm): per-tile nonzero fraction, the metadata
  that feeds the density-driven kernel dispatch (``core/dataflow``) and the
  profiling report (``sparse/stats``).

Construction compacts data-dependently (the pool size depends on the weight
values), so the builders run host-side on concrete arrays — exactly like the
paper's compile-time weight encoding, and like ``bitlinear.freeze``.  Ragged
K/M are zero-padded up to block multiples; padding creates *dead* blocks (or
zero tails inside edge blocks), so the round-trip back to a dense ternary
matrix / ``TernaryWeights`` is exact.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ternary
# One canonical default tiling, shared with the dispatch cost model so the
# formats being built and the break-even being computed can't drift.  The
# dense Pallas kernel's (bk=512, bm=256) would waste skip granularity;
# 256x256 keeps MXU-sized tiles while giving the zero-skip logic 4x finer
# blocks along K.
from repro.core.dataflow import SPARSE_BLOCK as DEFAULT_BLOCK_SHAPE

DEFAULT_BK, DEFAULT_BM = DEFAULT_BLOCK_SHAPE


class BlockSparseTernary(NamedTuple):
    """Compacted block-sparse 2-bit ternary weights (frozen, inference-only).

    The per-m-strip kernel schedule (``kids``/``slots``/``counts``/``s_max``)
    is derived from ``block_map`` once at construction so the hot path never
    re-runs the host-side compaction walk per matmul call.
    """

    sign_pool: jax.Array    # uint8 (n_slots, bk//8, bm)
    zero_pool: jax.Array    # uint8 (n_slots, bk//8, bm)
    block_map: jax.Array    # int32 (kb, mb)  pool slot, -1 = all-zero block
    occupancy: jax.Array    # f32   (kb, mb)  nonzero fraction per block
    scale: jax.Array        # f32   (M,) per-output-channel dequant scale
    shape: tuple            # static logical (K, M)
    block_shape: tuple      # static (bk, bm)
    n_live: int             # static number of live blocks (pool slots used)
    kids: jax.Array         # int32 (mb, max(s_max,1)) live k-block ids per strip
    slots: jax.Array        # int32 (mb, max(s_max,1)) matching pool slots
    counts: jax.Array       # int32 (mb,) live blocks per strip
    s_max: int              # static max live blocks over strips

    @property
    def k(self) -> int:
        return self.shape[0]

    @property
    def m(self) -> int:
        return self.shape[1]

    @property
    def grid(self) -> tuple:
        """(kb, mb) block-grid dims (over the zero-padded logical shape)."""
        bk, bm = self.block_shape
        return (-(-self.shape[0] // bk), -(-self.shape[1] // bm))

    @property
    def block_density(self) -> float:
        """Fraction of blocks that are live — the dispatch signal."""
        kb, mb = self.grid
        return self.n_live / max(kb * mb, 1)

    def nbytes(self) -> int:
        """HBM bytes: compacted pools + block map + occupancy + scales.

        Only ``n_live`` slots count (the pool pads to >= 1 slot so XLA never
        sees a zero-sized array; the pad slot stores no weights).
        """
        bk, bm = self.block_shape
        pool = 2 * self.n_live * (bk // ternary.PACK) * bm
        return int(pool + self.block_map.size * 4 + self.occupancy.size * 4
                   + self.scale.size * 4)


def from_ternary(
    t: jax.Array,
    scale: jax.Array | None = None,
    bk: int = DEFAULT_BK,
    bm: int = DEFAULT_BM,
    occupancy: np.ndarray | None = None,
) -> BlockSparseTernary:
    """Tile a dense ternary (K, M) matrix into a compacted block pool.

    Host-side (concrete arrays only): the pool size is data-dependent.
    ``occupancy`` accepts a precomputed ``stats.block_occupancy(t, bk, bm)``
    grid so callers that already measured it (``bitlinear.freeze``) don't pay
    the popcount twice.
    """
    if t.ndim != 2:
        raise ValueError(f"from_ternary expects a 2-D (K, M) matrix, got {t.shape}")
    if bk % ternary.PACK != 0:
        raise ValueError(f"bk={bk} must be a multiple of {ternary.PACK}")
    tn = np.asarray(t, np.int8)
    k, m = tn.shape
    if scale is None:
        scale = jnp.ones((m,), jnp.float32)
    kb, mb = -(-k // bk), -(-m // bm)
    pad_k, pad_m = kb * bk - k, mb * bm - m
    if pad_k or pad_m:
        tn = np.pad(tn, ((0, pad_k), (0, pad_m)))

    # (kb, mb, bk, bm) block view.
    blocks = tn.reshape(kb, bk, mb, bm).transpose(0, 2, 1, 3)
    if occupancy is None:
        occ = np.count_nonzero(blocks, axis=(2, 3)).astype(np.float32) / (bk * bm)
    else:
        occ = np.asarray(occupancy, np.float32)
        if occ.shape != (kb, mb):
            raise ValueError(f"occupancy grid {occ.shape} != block grid {(kb, mb)}")
    live = occ > 0.0
    n_live = int(live.sum())

    block_map = np.full((kb, mb), -1, np.int32)
    block_map[live] = np.arange(n_live, dtype=np.int32)

    n_slots = max(n_live, 1)            # never materialize a 0-sized pool
    sign_pool = np.zeros((n_slots, bk // ternary.PACK, bm), np.uint8)
    zero_pool = np.zeros((n_slots, bk // ternary.PACK, bm), np.uint8)
    if n_live:
        lv = blocks[live]                                    # (n_live, bk, bm)
        sign = (lv < 0).astype(np.uint8)
        zero = (lv == 0).astype(np.uint8)
        pack = lambda b: np.packbits(
            b.reshape(n_live, bk // ternary.PACK, ternary.PACK, bm),
            axis=2, bitorder="little").reshape(n_live, bk // ternary.PACK, bm)
        sign_pool = pack(sign)
        zero_pool = pack(zero)
    else:
        # Dead-block pad slot must still decode to value 0, not +1 (the
        # sparse kernel masks its contribution, but the round-trip reads it
        # for no block, so this only guards against misuse).
        zero_pool[:] = 0xFF

    kids, slots, counts, s_max = _strip_schedule_np(block_map)
    return BlockSparseTernary(
        sign_pool=jnp.asarray(sign_pool),
        zero_pool=jnp.asarray(zero_pool),
        block_map=jnp.asarray(block_map),
        occupancy=jnp.asarray(occ),
        scale=jnp.asarray(scale, jnp.float32),
        shape=(k, m),
        block_shape=(bk, bm),
        n_live=n_live,
        kids=jnp.asarray(kids),
        slots=jnp.asarray(slots),
        counts=jnp.asarray(counts),
        s_max=s_max,
    )


def from_packed(tw: ternary.TernaryWeights, bk: int = DEFAULT_BK,
                bm: int = DEFAULT_BM) -> BlockSparseTernary:
    """``TernaryWeights`` (dense 2-bit planes) -> block-sparse pool."""
    return from_ternary(ternary.unpack(tw), tw.scale, bk=bk, bm=bm)


def to_ternary(bst: BlockSparseTernary) -> jax.Array:
    """Exact inverse of :func:`from_ternary` -> dense ternary (K, M) int8."""
    bk, bm = bst.block_shape
    kb, mb = bst.grid
    k, m = bst.shape
    bmap = np.asarray(bst.block_map)
    sp = np.asarray(bst.sign_pool)
    zp = np.asarray(bst.zero_pool)

    out = np.zeros((kb, mb, bk, bm), np.int8)
    for i in range(kb):
        for j in range(mb):
            slot = int(bmap[i, j])
            if slot < 0:
                continue
            sign = np.unpackbits(sp[slot], axis=0, bitorder="little",
                                 count=bk).astype(np.int8)
            zero = np.unpackbits(zp[slot], axis=0, bitorder="little",
                                 count=bk).astype(np.int8)
            out[i, j] = (1 - 2 * sign) * (1 - zero)
    dense = out.transpose(0, 2, 1, 3).reshape(kb * bk, mb * bm)
    return jnp.asarray(dense[:k, :m])


def to_packed(bst: BlockSparseTernary) -> ternary.TernaryWeights:
    """Exact round-trip back to dense ``TernaryWeights``."""
    return ternary.pack(to_ternary(bst).astype(jnp.float32), bst.scale)


def _strip_schedule_np(bmap: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Per-m-strip gather lists derived from a block map (construction time).

    Returns ``(kids, slots, counts, s_max)``:

    * ``kids``   int32 (mb, s_max) — the s-th live block's k-block index in
      strip j (padded with 0 past ``counts[j]``);
    * ``slots``  int32 (mb, s_max) — matching pool slot (padded with 0, a
      valid slot, so the padded DMA reads real memory; the kernel masks it);
    * ``counts`` int32 (mb,) — live blocks per strip;
    * ``s_max``  — max live blocks over strips == the kernel's inner grid
      extent; the whole point: grid work scales with live blocks, not K.
    """
    kb, mb = bmap.shape
    counts = (bmap >= 0).sum(axis=0).astype(np.int32)
    s_max = int(counts.max()) if mb else 0
    kids = np.zeros((mb, max(s_max, 1)), np.int32)
    slots = np.zeros((mb, max(s_max, 1)), np.int32)
    for j in range(mb):
        lv = np.nonzero(bmap[:, j] >= 0)[0]
        kids[j, : len(lv)] = lv
        slots[j, : len(lv)] = bmap[lv, j]
    return kids, slots, counts, s_max


def strip_schedule(bst: BlockSparseTernary) -> tuple[jax.Array, jax.Array, jax.Array, int]:
    """The kernel schedule — precomputed at construction, returned as-is."""
    return bst.kids, bst.slots, bst.counts, bst.s_max


def random_block_sparse_ternary(
    key: jax.Array,
    shape: tuple,
    bk: int = DEFAULT_BK,
    bm: int = DEFAULT_BM,
    p_zero_block: float = 0.5,
    p_zero: float = 1.0 / 3.0,
) -> jax.Array:
    """Random ternary matrix with *block-structured* sparsity (int8).

    Whole (bk, bm) blocks are zeroed with probability ``p_zero_block``; the
    surviving blocks carry the usual unstructured ``p_zero`` zeros.  This is
    the workload where zero-block skipping pays: unstructured sparsity almost
    never kills a whole 256x256 block ((1/3)^65536 ~ 0), so benchmarks sweep
    the block-kill rate instead.
    """
    k, m = shape
    kb, mb = -(-k // bk), -(-m // bm)
    kz, kt = jax.random.split(key)
    dead = jax.random.bernoulli(kz, p_zero_block, (kb, mb))
    mask = 1 - jnp.repeat(jnp.repeat(dead.astype(jnp.int8), bk, 0), bm, 1)
    t = ternary.random_ternary(kt, (kb * bk, mb * bm), p_zero)
    return (t * mask)[:k, :m]
