"""Sparsity-aware ternary subsystem: block-sparse packing + density profiling.

* ``format`` — :class:`BlockSparseTernary`: (bk, bm)-tiled ternary weights
  with only live blocks' 2-bit bitplanes kept in a compacted pool, plus the
  block-index map the zero-skipping kernel walks.
  :class:`PaddedBlockSparseTernary`: the static-shape (pool padded to
  ``max_live``) variant whose construction is traceable and whose pytree is
  vmappable — the format stacked scan-layer weights carry through the
  serving path.
* ``stats`` — per-layer / per-block density profiling over packed params.

The matching Pallas kernels live in ``repro.kernels.tsar_sparse`` (wrappers:
``repro.kernels.ops.tsar_sparse_matmul`` / ``tsar_sparse_padded_matmul``);
the density-driven dispatch in ``repro.core.dataflow.select_kernel``.
"""
from repro.sparse import format, stats  # noqa: F401
from repro.sparse.format import (  # noqa: F401
    BlockSparseTernary,
    PaddedBlockSparseTernary,
)
