"""Sparsity-aware ternary subsystem: block-sparse packing + density profiling.

* ``format`` — :class:`BlockSparseTernary`: (bk, bm)-tiled ternary weights
  with only live blocks' 2-bit bitplanes kept in a compacted pool, plus the
  block-index map the zero-skipping kernel walks.
* ``stats`` — per-layer / per-block density profiling over packed params.

The matching Pallas kernel lives in ``repro.kernels.tsar_sparse`` (wrapper:
``repro.kernels.ops.tsar_sparse_matmul``); the density-driven dispatch in
``repro.core.dataflow.select_kernel``.
"""
from repro.sparse import format, stats  # noqa: F401
from repro.sparse.format import BlockSparseTernary  # noqa: F401
