"""Table III reproduction: decode throughput + energy/token.

The paper compares gem5-modeled CPUs (+3.2% T-SAR power) against a Jetson
AGX Orin.  Our platform stand-in is TPU v5e: tokens/s from the dry-run
roofline (decode-step time = max of the three terms), J/token from chip TDP.
We also reproduce the paper's *methodology* numbers: P_TSAR = 1.032 * P_base
scaling and energy/token arithmetic, validated against Table III's own rows.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import csv_row

V5E_TDP_W = 170.0          # per-chip nominal
PAPER_TABLE3 = {
    # platform: (tokens/s, J/token) for Llama-b1.58-8B from the paper
    "workstation": (128.96, 0.616),
    "laptop": (61.00, 0.405),
    "mobile": (5.18, 0.733),
    "jetson": (16.78, 1.839),
}


def paper_methodology_check():
    """Re-derive the paper's J/token from its own published P and tokens/s:
    E = P_TSAR / throughput, P_TSAR = 1.032 * P_TL2."""
    rows = []
    for plat, (tps, jtok) in PAPER_TABLE3.items():
        p_implied = jtok * tps           # W implied by the table
        rows.append({"platform": plat, "tokens_s": tps, "J_tok": jtok,
                     "implied_W": p_implied})
        csv_row(f"table3_{plat}", 1e6 / tps, f"J_per_tok={jtok};implied_W={p_implied:.1f}")
    return rows


def tpu_energy_from_dryrun(path="results/dryrun_packed.json"):
    rows = []
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        recs = json.load(f)
    for r in recs:
        if r.get("status") != "ok" or r["shape"] not in ("decode_32k", "long_500k"):
            continue
        if r["mesh"] != "single":
            continue
        roof = r["roofline"]
        step_s = max(roof["compute_s"], roof["memory_s"], roof["collective_s"])
        tokens = {"decode_32k": 128, "long_500k": 1}[r["shape"]]
        tps = tokens / step_s
        j_tok = (r["chips"] * V5E_TDP_W) * step_s / tokens
        rows.append({"arch": r["arch"], "shape": r["shape"],
                     "tokens_s": tps, "J_tok": j_tok})
        csv_row(f"energy_{r['arch']}_{r['shape']}", step_s * 1e6,
                f"tokens_s={tps:.0f};J_per_tok={j_tok:.4f}")
    return rows


def run(quick: bool = False):
    return {"paper_check": paper_methodology_check(),
            "tpu": tpu_energy_from_dryrun()}


if __name__ == "__main__":
    run()
