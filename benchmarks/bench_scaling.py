"""Fig. 10 reproduction: kernel microbenchmarks on the paper's exact shapes +
scaling study.

The paper's Fig. 10 runs BitNet-b1.58-2B-4T kernel shapes (128x2560x6912
GEMM, 1x2560x6912 / 1x8192x45568-class GEMV) over 1-16 CPU threads.  The TPU
analogue of thread-scaling is chip-scaling: we evaluate the roofline terms of
the T-SAR BitLinear at mesh sizes {1, 4, 16, 64, 256} chips, and measure
wall-clock for the jitted kernels on this container's CPU at the paper shapes
(relative T-SAR vs baseline = the reproduced quantity).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, timeit
from repro.core import lut, ternary
from repro.core.dataflow import HBM_BW, PEAK_FLOPS_INT8

C = 4
# The paper's Fig. 10 kernel shapes (N, K, M).
SHAPES = [
    (128, 2560, 6912),   # GEMM prefill (2B-4T mlp up)
    (1, 2560, 6912),     # GEMV decode
    (1, 8192, 45568),    # GEMV (the paper's Mobile LLC case study shape)
]  # (128x6912x2560 mlp-down omitted: same regime as mlp-up, single-core budget
CHIPS = [1, 4, 16, 64, 256]


def measured(quick: bool = False):
    rows = []
    for (n, k, m) in SHAPES:
        key = jax.random.PRNGKey(n * 7 + m)
        t = ternary.random_ternary(key, (k, m))
        a = jax.random.normal(key, (n, k))
        ip, iz = ternary.pack_indices(t, C)
        li = lut.ternary_lut_indices(t, C)
        scale = jnp.ones((m,))

        # All variants recompute from fresh activations (steady-state decode
        # semantics), and all weight encodings are jit ARGUMENTS so XLA can
        # neither constant-fold the baseline away nor stall folding gathers.
        fns = {
            "tsar": (jax.jit(lambda a, ip, iz: lut.tsar_lut_matmul(a, ip, iz, C)),
                     (ip, iz)),
            "tsar_mxu": (jax.jit(lambda a, t, s: lut.bitlinear_matmul_fast(a, t, s)),
                         (t, scale)),
            "memlut": (jax.jit(lambda a, li: lut.memory_lut_matmul(a, li, C)), (li,)),
            "dense": (jax.jit(lambda a, w: a @ w), (t.astype(jnp.float32),)),
        }
        times = {name: timeit(fn, a, *extra, reps=2, warmup=1)
                 for name, (fn, extra) in fns.items()}
        best_tsar = min(times["tsar"], times["tsar_mxu"])
        csv_row(f"kernel_{n}x{k}x{m}_tsar", best_tsar * 1e6,
                f"vs_memlut={times['memlut']/best_tsar:.2f}x;"
                f"vs_dense={times['dense']/best_tsar:.2f}x")
        rows.append({"shape": (n, k, m), **{f"t_{k_}": v for k_, v in times.items()}})
    return rows


def chip_scaling():
    """Roofline chip-scaling of one 2B-4T BitLinear layer set (analytic)."""
    rows = []
    for chips in CHIPS:
        # Per-chip share of the 2B-4T decode GEMV workload (M sharded).
        n, k, m = 1, 2560, 6912 * 3  # qkv+mlp aggregate width
        m_local = max(m // chips, 128)
        flops = 2 * n * k * m_local
        mem = k * m_local * 0.25 + n * k + n * m_local * 4
        t_c = flops / PEAK_FLOPS_INT8
        t_m = mem / HBM_BW
        t = max(t_c, t_m)
        rows.append({"chips": chips, "t_us": t * 1e6,
                     "bound": "memory" if t_m > t_c else "compute"})
        csv_row(f"chip_scaling_gemv_{chips}", t * 1e6,
                f"bound={'memory' if t_m > t_c else 'compute'}")
    return rows


def run(quick: bool = False):
    return {"measured": measured(quick), "chip_scaling": chip_scaling()}


if __name__ == "__main__":
    run()
