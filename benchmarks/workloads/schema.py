"""Versioned schema for the persisted serving benchmark report
(``BENCH_e2e.json``) — the on-disk perf trajectory.

One report = one run of the trace-driven serving suite: git revision, seed,
config, and a per-workload block of percentile metrics + deterministic
counters + the trace fingerprint that produced them.  Reports are written in
**canonical JSON** (sorted keys, fixed separators) so load -> validate ->
dump is byte-exact (pinned by ``tests/test_bench_report.py``) and diffs
between commits are minimal.

The validator is hand-rolled (no jsonschema dependency on this container):
:func:`validate` walks the document against the structural spec below and
raises ``ValueError`` naming the offending path.  ``schema_version`` bumps
on any shape change; the comparator refuses cross-version diffs.
"""
from __future__ import annotations

import json
import subprocess

# v2: metrics-registry step accounting joined the counter block
# (planned/realized tokens, prefill/decode step split, admissions) and the
# per-machine SLO calibration factor joined the provenance
# (slo_scale / ref_decode_step_s).
SCHEMA_VERSION = 2
KIND = "BENCH_e2e"

_PCT_KEYS = ("p50", "p90", "p99", "mean", "max", "n")
_GOODPUT_KEYS = ("slo_attained", "good", "total", "good_per_s")
_REQUIRED_COUNTERS = (
    "steps", "preemptions", "preempt_readmissions", "prefill_tokens",
    "prefill_tokens_planned", "cached_tokens_skipped", "decode_tokens",
    "total_tokens", "max_step_tokens", "peak_kv_blocks", "whole_prefills",
    "planned_tokens", "realized_tokens", "prefill_steps", "decode_steps",
    "admissions", "plan_kernel",
)
_TOP_KEYS = ("schema_version", "kind", "git_rev", "created_unix", "quick",
             "seed", "arch", "slo_scale", "ref_decode_step_s", "workloads")


def git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, check=True).stdout.strip()
    except Exception:
        return "unknown"


def make_report(*, arch: str, seed: int, quick: bool, workloads: dict,
                created_unix: float | None = None,
                rev: str | None = None, slo_scale: float = 1.0,
                ref_decode_step_s: float = 0.0) -> dict:
    """Assemble a schema-valid report document from per-workload blocks.

    ``slo_scale`` / ``ref_decode_step_s`` record the per-machine SLO
    calibration (``workloads.runner.measure_slo_scale``); the defaults mean
    "uncalibrated" (thresholds used as written, no reference measured).
    """
    doc = {
        "schema_version": SCHEMA_VERSION,
        "kind": KIND,
        "git_rev": git_rev() if rev is None else rev,
        "created_unix": 0.0 if created_unix is None else float(created_unix),
        "quick": bool(quick),
        "seed": int(seed),
        "arch": arch,
        "slo_scale": float(slo_scale),
        "ref_decode_step_s": float(ref_decode_step_s),
        "workloads": workloads,
    }
    validate(doc)
    return doc


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def _fail(path: str, msg: str):
    raise ValueError(f"BENCH_e2e schema: {path}: {msg}")


def _need(d: dict, keys, path: str):
    for k in keys:
        if k not in d:
            _fail(path, f"missing key {k!r}")


def _num(v, path: str):
    if not isinstance(v, (int, float)) or isinstance(v, bool):
        _fail(path, f"expected number, got {type(v).__name__}")


def _pct_block(d, path: str):
    if not isinstance(d, dict):
        _fail(path, "expected percentile block (dict)")
    _need(d, _PCT_KEYS, path)
    for k in _PCT_KEYS:
        _num(d[k], f"{path}.{k}")


def validate(doc: dict) -> dict:
    """Structural validation; returns ``doc`` unchanged on success."""
    if not isinstance(doc, dict):
        _fail("$", "expected object")
    _need(doc, _TOP_KEYS, "$")
    if doc["schema_version"] != SCHEMA_VERSION:
        _fail("$.schema_version",
              f"{doc['schema_version']!r} != {SCHEMA_VERSION}")
    if doc["kind"] != KIND:
        _fail("$.kind", f"{doc['kind']!r} != {KIND!r}")
    if not isinstance(doc["git_rev"], str):
        _fail("$.git_rev", "expected string")
    _num(doc["created_unix"], "$.created_unix")
    if not isinstance(doc["quick"], bool):
        _fail("$.quick", "expected bool")
    if not isinstance(doc["seed"], int) or isinstance(doc["seed"], bool):
        _fail("$.seed", "expected int")
    if not isinstance(doc["arch"], str):
        _fail("$.arch", "expected string")
    _num(doc["slo_scale"], "$.slo_scale")
    _num(doc["ref_decode_step_s"], "$.ref_decode_step_s")
    wl = doc["workloads"]
    if not isinstance(wl, dict) or not wl:
        _fail("$.workloads", "expected non-empty object")
    for name, blk in wl.items():
        p = f"$.workloads.{name}"
        if not isinstance(blk, dict):
            _fail(p, "expected object")
        _need(blk, ("spec", "trace_fingerprint", "metrics", "counters"), p)
        if not isinstance(blk["spec"], dict):
            _fail(f"{p}.spec", "expected object")
        fp = blk["trace_fingerprint"]
        if not (isinstance(fp, str) and fp.startswith("sha256:")):
            _fail(f"{p}.trace_fingerprint", f"malformed fingerprint {fp!r}")
        m = blk["metrics"]
        if not isinstance(m, dict):
            _fail(f"{p}.metrics", "expected object")
        _need(m, ("ttft_s", "tpot_s", "queue_s", "goodput", "output_tok_s",
                  "wall_s"), f"{p}.metrics")
        for lk in ("ttft_s", "tpot_s", "queue_s"):
            _pct_block(m[lk], f"{p}.metrics.{lk}")
        g = m["goodput"]
        if not isinstance(g, dict):
            _fail(f"{p}.metrics.goodput", "expected object")
        _need(g, _GOODPUT_KEYS, f"{p}.metrics.goodput")
        for k in _GOODPUT_KEYS:
            _num(g[k], f"{p}.metrics.goodput.{k}")
        _num(m["output_tok_s"], f"{p}.metrics.output_tok_s")
        _num(m["wall_s"], f"{p}.metrics.wall_s")
        c = blk["counters"]
        if not isinstance(c, dict):
            _fail(f"{p}.counters", "expected object")
        _need(c, _REQUIRED_COUNTERS, f"{p}.counters")
        for k in _REQUIRED_COUNTERS:
            if k == "plan_kernel":
                if not isinstance(c[k], str):
                    _fail(f"{p}.counters.plan_kernel", "expected string")
            else:
                _num(c[k], f"{p}.counters.{k}")
        if "obs_trace" in blk:
            # Optional observability-trace attachment (run_suite --trace-out):
            # provenance of the saved Perfetto document, not the events.
            ot = blk["obs_trace"]
            if not isinstance(ot, dict):
                _fail(f"{p}.obs_trace", "expected object")
            _need(ot, ("fingerprint", "schema_version", "n_events", "path"),
                  f"{p}.obs_trace")
            if not (isinstance(ot["fingerprint"], str)
                    and ot["fingerprint"].startswith("sha256:")):
                _fail(f"{p}.obs_trace.fingerprint",
                      f"malformed fingerprint {ot['fingerprint']!r}")
            _num(ot["schema_version"], f"{p}.obs_trace.schema_version")
            _num(ot["n_events"], f"{p}.obs_trace.n_events")
    return doc


# ---------------------------------------------------------------------------
# canonical IO
# ---------------------------------------------------------------------------

def dumps(doc: dict) -> str:
    """Canonical serialization (sorted keys, fixed separators, trailing
    newline) — the byte-exact round-trip form."""
    return json.dumps(doc, sort_keys=True, separators=(",", ":"),
                      allow_nan=True) + "\n"


def save(doc: dict, path: str) -> None:
    validate(doc)
    with open(path, "w") as f:
        f.write(dumps(doc))


def load(path: str) -> dict:
    with open(path) as f:
        return validate(json.load(f))
