"""Trace replay against the serving engine + the benchmark suite driver.

``replay`` drives a :class:`~repro.serving.ServingEngine` step-by-step in
**virtual time**: one engine step advances the clock by ``step_dt`` units,
and every trace request whose arrival time has passed is submitted before
the next step.  The scheduling structure (who queues behind whom, when
admission happens relative to running decodes) is therefore a pure function
of the trace — wall-clock enters only through the measured latencies, so
two runs of the same trace are structurally identical and their
deterministic counters (preemptions, scheduled prefill tokens, hit rates)
must match exactly.

``run_suite`` runs the named workload set (``generator.WORKLOADS``) and
assembles the persisted ``BENCH_e2e.json`` report.  It also enforces the
serving-regression contracts inline, so a rotted benchmark fails loudly
instead of producing a plausible report:

* shared-prefix replayed cache-on AND cache-off must be token-identical,
  with a nonzero hit rate and strictly fewer scheduled prefill tokens warm;
* the preemption storm must actually preempt (and, with the prefix cache
  on, reuse preempted partial prefills at re-admission);
* eviction pressure must actually evict;
* every workload's counters carry the execution plan's kernel choice.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from benchmarks.common import csv_row
from benchmarks.workloads import generator, metrics, schema
from benchmarks.workloads.generator import WorkloadSpec, generate, preset
from benchmarks.workloads.trace import Trace

DEFAULT_ARCH = "bitnet-2b-4t"


def build_engine(spec: WorkloadSpec, cfg, params, *, packed: bool = True,
                 policy: str | None = None, prefix_cache=None, tracer=None,
                 incidents=None):
    """Construct a ServingEngine from a workload spec's engine hints.
    ``prefix_cache`` overrides the spec hint (the cache-off control
    replay); ``tracer`` attaches an ``repro.obs.trace.EventTracer`` so the
    replay records its lifecycle/step events; ``incidents`` attaches an
    ``repro.obs.incident.IncidentMonitor`` (bound to the engine's registry
    and tracer by the engine itself)."""
    from repro.serving import ServingEngine

    e = spec.engine
    if prefix_cache is None:
        prefix_cache = e.get("prefix_cache", False)
    return ServingEngine(
        cfg, params,
        max_len=e.get("max_len", 128),
        batch_slots=e.get("slots", 4),
        packed=packed,
        prefill_chunk=e.get("prefill_chunk", 16),
        block_size=e.get("block_size", 16),
        kv_blocks=e.get("kv_blocks"),
        policy=policy,
        prefix_cache=prefix_cache,
        tracer=tracer,
        incidents=incidents)


def replay(engine, trace: Trace, *, step_dt: float = 1.0,
           warmup: bool = True) -> tuple[list, float]:
    """Replay ``trace`` through ``engine``; returns (requests, wall_s).

    Requests are returned in trace (uid) order with latency stamps filled.
    ``warmup`` pre-compiles the jitted step paths on a throwaway request
    (and resets counters), so percentiles measure steady-state serving, not
    XLA compile time — pass False to measure cold-start behavior.
    """
    from repro.serving import Request

    order = sorted(trace.requests, key=lambda t: (t.arrival, t.uid))
    by_uid = {}
    reqs = []
    for t in order:
        r = Request(uid=t.uid, prompt=np.asarray(t.prompt, np.int32),
                    max_new_tokens=t.max_new_tokens,
                    temperature=t.temperature)
        reqs.append(r)
        by_uid[t.uid] = r
    if warmup:
        longest = max((len(t.prompt) + t.max_new_tokens for t in order),
                      default=0)
        engine.warmup(seq_len=longest)

    vt, i, n = 0.0, 0, len(reqs)
    t0 = time.perf_counter()
    while i < n or engine.busy:
        while i < n and order[i].arrival <= vt + 1e-9:
            engine.submit(reqs[i])
            i += 1
        if not engine.step():
            if engine.queue_len:
                # Mirrors ServingEngine.run(): the pool can never cover the
                # head-of-queue request — a workload/engine config error.
                raise RuntimeError(
                    f"trace {trace.name!r}: request cannot be admitted on an "
                    "idle engine; check the spec's kv_blocks/max_len hints")
            if i < n:
                vt = max(vt, order[i].arrival)   # idle gap: jump to arrival
                continue
        vt += step_dt
    wall = time.perf_counter() - t0
    return [by_uid[t.uid] for t in trace.requests], wall


def run_workload(spec: WorkloadSpec, cfg, params, *, packed: bool = True,
                 policy: str | None = None, prefix_cache=None,
                 warmup: bool = True, trace: Trace | None = None,
                 tracer=None, slo_scale: float = 1.0, incidents=None):
    """Generate (or take) the trace, replay it, and return
    ``(report_block, engine, requests)``."""
    trace = generate(spec) if trace is None else trace
    engine = build_engine(spec, cfg, params, packed=packed, policy=policy,
                          prefix_cache=prefix_cache, tracer=tracer,
                          incidents=incidents)
    reqs, wall = replay(engine, trace, warmup=warmup)
    block = {
        "spec": spec.to_dict(),
        "trace_fingerprint": trace.fingerprint(),
        "metrics": metrics.latency_metrics(reqs, trace, wall, slo_scale),
        "counters": metrics.engine_counters(engine),
    }
    return block, engine, reqs


def measure_slo_scale(cfg, params, *, packed: bool = True) -> tuple[float, float]:
    """Per-machine SLO calibration: measure this host's reference decode-step
    latency and return ``(slo_scale, ref_decode_step_s)``.

    A tiny engine decodes a short burst after warm-up; the mean pure-decode
    step wall time divided by :data:`metrics.NOMINAL_DECODE_STEP_S` is the
    factor every preset SLO threshold gets scaled by — a machine 3x slower
    than the reference gets 3x looser latency SLOs, so goodput measures
    scheduling behavior, not raw CPU speed.  The scale is clamped to
    [0.2, 50] (beyond that the measurement itself is suspect — report it,
    but don't let one scheduling hiccup turn every SLO vacuous)."""
    from repro.serving import Request, ServingEngine

    eng = ServingEngine(cfg, params, max_len=64, batch_slots=2, packed=packed,
                        prefill_chunk=8, block_size=8)
    eng.warmup(seq_len=40)
    rng = np.random.default_rng(0xca11b)
    reqs = [Request(uid=i, prompt=rng.integers(
                0, cfg.vocab_size, size=4, dtype=np.int32),
                    max_new_tokens=24) for i in range(2)]
    eng.run(reqs)
    reg = eng.metrics
    n_decode = reg.get("decode_steps").value
    decode_s = reg.get("step_time_s").labels(phase="decode").value
    per_step = decode_s / max(n_decode, 1)
    scale = min(max(per_step / metrics.NOMINAL_DECODE_STEP_S, 0.2), 50.0)
    return scale, per_step


def _emit_csv(name: str, block: dict) -> None:
    m = block["metrics"]
    c = block["counters"]
    csv_row(
        f"serve_wl_{name}", m["ttft_s"]["p50"] * 1e6,
        f"ttft_p99_ms={m['ttft_s']['p99'] * 1e3:.1f};"
        f"tpot_p50_ms={m['tpot_s']['p50'] * 1e3:.2f};"
        f"tpot_p99_ms={m['tpot_s']['p99'] * 1e3:.2f};"
        f"goodput={m['goodput']['slo_attained']:.2f};"
        f"out_tok_s={m['output_tok_s']:.1f};"
        f"preemptions={c['preemptions']};"
        f"prefix_hit_rate={c.get('prefix_hit_rate', 0.0):.3f};"
        f"prefill_tokens={c['prefill_tokens']};"
        f"plan_kernel={c['plan_kernel']}")


SUITE = ("steady", "bursty", "shared-prefix", "decode-heavy",
         "preemption-storm", "eviction-pressure")


def _stream_path(trace_out: str) -> str:
    """The JSONL stream path derived from a --trace-out document path."""
    return (trace_out[:-5] if trace_out.endswith(".json") else trace_out) \
        + ".jsonl"


def run_suite(*, quick: bool = False, seed: int = 0,
              arch: str = DEFAULT_ARCH, names=SUITE,
              trace_out: str | None = None,
              calibrate_slo: bool = True,
              incident_dir: str | None = None) -> dict:
    """Run the workload suite and return the schema-valid report document.

    ``trace_out`` records the shared-prefix warm replay's observability
    trace BOTH ways at once (a ``TeeSink`` over a ``MemorySink`` and a
    ``StreamingSink``): the Perfetto document goes to ``trace_out``, the
    JSONL stream to the same path with ``.jsonl``, and the suite asserts
    the two produce identical structure fingerprints and identical
    ``timeline`` analyses — the disk-streamed path can never silently
    diverge from the in-memory one.  Provenance attaches to the report
    block OUTSIDE the counters section, so tracing can never perturb the
    exact-gated numbers.  ``incident_dir`` arms a per-workload
    ``IncidentMonitor`` (ring-buffer flight recorder attached when no
    tracer is, SLO thresholds from the spec scaled by the calibration) and
    records what fired per block.  ``calibrate_slo`` measures this host's
    reference decode-step latency first and scales every preset SLO
    threshold by it (recorded in the report provenance)."""
    import jax

    import repro.configs as configs
    from repro.models import model_zoo as zoo
    from repro.obs import timeline
    from repro.obs.incident import IncidentMonitor
    from repro.obs.trace import EventTracer, MemorySink, RingSink, \
        StreamingSink, TeeSink

    cfg = configs.get(arch).reduced()
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))

    slo_scale, ref_step = 1.0, 0.0
    if calibrate_slo:
        slo_scale, ref_step = measure_slo_scale(cfg, params)
        print(f"#   slo calibration: decode step {ref_step * 1e3:.2f} ms "
              f"-> slo_scale {slo_scale:.2f}", file=sys.stderr)

    blocks: dict = {}
    for name in names:
        spec = preset(name, quick=quick, seed=seed)
        trace = generate(spec)
        print(f"#   workload {name}: {trace.n_requests} requests, "
              f"{trace.total_prompt_tokens()} prompt tokens", file=sys.stderr)
        stream = None
        tracer = None
        if trace_out and name == "shared-prefix":
            stream = StreamingSink(_stream_path(trace_out))
            tracer = EventTracer(sink=TeeSink(MemorySink(), stream))
        monitor = None
        if incident_dir:
            slo = spec.slo or {}
            monitor = IncidentMonitor(
                incident_dir, prefix=name,
                slo_ttft_s=(slo["ttft_s"] * slo_scale
                            if slo.get("ttft_s") else None),
                slo_tpot_s=(slo["tpot_s"] * slo_scale
                            if slo.get("tpot_s") else None))
            if tracer is None:
                # Flight recorder so incident dumps carry recent events.
                # Attaching a tracer cannot perturb the exact-gated
                # counters (traced-vs-untraced bit-identity, tested).
                tracer = EventTracer(sink=RingSink())
        block, engine, reqs = run_workload(spec, cfg, params, trace=trace,
                                           tracer=tracer, slo_scale=slo_scale,
                                           incidents=monitor)
        blocks[name] = block
        _emit_csv(name, block)
        if stream is not None:
            doc = tracer.save(trace_out)
            info = stream.finalize()
            # The tentpole contract: the disk-streamed trace fingerprints
            # byte-for-byte identically to the in-memory export, and the
            # timeline analysis of the JSONL round-trip matches exactly.
            assert info["fingerprint"] == doc["otherData"]["fingerprint"], (
                f"StreamingSink fingerprint {info['fingerprint']} != "
                f"MemorySink fingerprint {doc['otherData']['fingerprint']}")
            mem_a = timeline.analyze(doc)
            st_a = timeline.analyze_stream(info["path"])
            st_a.pop("stream")
            assert mem_a == st_a, (
                "timeline analysis of the JSONL stream diverged from the "
                "in-memory document")
            block["obs_trace"] = {
                "path": trace_out,
                "fingerprint": doc["otherData"]["fingerprint"],
                "schema_version": doc["otherData"]["schema_version"],
                "n_events": len(doc["traceEvents"]),
                "stream": {
                    "path": info["path"],
                    "segments": info["segments"],
                    "peak_resident_events": stream.peak_resident_events,
                },
            }
            print(f"#   obs trace: {trace_out} "
                  f"({len(doc['traceEvents'])} events, "
                  f"{doc['otherData']['fingerprint'][:23]}...) + stream "
                  f"{info['path']} (fingerprint identical)",
                  file=sys.stderr)
        if monitor is not None:
            block["incidents"] = monitor.summary()
            if monitor.paths:
                by = ", ".join(f"{k}: {v}"
                               for k, v in sorted(monitor.fired.items()))
                print(f"#   incidents[{name}]: {len(monitor.paths)} "
                      f"snapshot(s) ({by})", file=sys.stderr)

        if name == "shared-prefix":
            # Serving-regression contract: the same trace with the cache off
            # must be token-identical, schedule strictly more prefill work,
            # and the warm run must actually hit.
            cold, cold_eng, cold_reqs = run_workload(
                spec, cfg, params, trace=trace, prefix_cache=False,
                slo_scale=slo_scale)
            blocks["shared-prefix-cold"] = cold
            _emit_csv("shared-prefix-cold", cold)
            for a, b in zip(reqs, cold_reqs):
                assert a.out_tokens == b.out_tokens, (
                    f"prefix-cache hit path diverged from cold path "
                    f"(uid {a.uid})")
            warm_c, cold_c = block["counters"], cold["counters"]
            assert warm_c.get("prefix_hit_rate", 0.0) > 0, \
                f"prefix cache never hit: {warm_c}"
            assert warm_c["prefill_tokens"] < cold_c["prefill_tokens"], \
                "prefix cache did not reduce scheduled prefill tokens"
        elif name == "preemption-storm":
            c = block["counters"]
            assert c["preemptions"] > 0, \
                f"preemption storm did not preempt: {c}"
            # Preempted partial prefills are registered into the prefix
            # cache, so recompute-readmission must reuse full blocks.
            assert c["cached_tokens_skipped"] > 0, \
                f"preempted prefills were not reused at re-admission: {c}"
        elif name == "eviction-pressure":
            c = block["counters"]
            assert c.get("prefix_evictions", 0) > 0, \
                f"eviction pressure never evicted: {c}"

    return schema.make_report(arch=cfg.name, seed=seed, quick=quick,
                              workloads=blocks,
                              created_unix=time.time(),
                              slo_scale=slo_scale,
                              ref_decode_step_s=ref_step)
